.PHONY: artifacts accuracy goldens test test-rust test-python bench bench-smoke bench-diff lint

# AOT-lower the L2 model + L1 kernels to HLO text + goldens (needs jax)
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# python training pass -> artifacts/accuracy.json (needs jax; slow)
accuracy:
	cd python && python3 -m compile.fcc.train --out ../artifacts --quick

# regenerate the checked-in reference kernel goldens (numpy only)
goldens:
	python3 python/tools/gen_ref_goldens.py

test-rust:
	cargo build --release && cargo test -q

# repo-invariant static analysis + seeded interleaving check of the
# steal/admission protocols.  Exit codes (docs/linting.md): 0 clean,
# 1 findings or shuttle violations, 2 usage/manifest error.
lint:
	cargo run --release --bin ddc-lint
	cargo run --release --bin ddc-lint -- --self-check

test-python:
	python3 -m pytest python/tests -q

test: test-rust test-python

# populate the bench trajectory: BENCH_*.json at the repo root
# (mean/min/max ns per named hot path; schema + gate contract:
# docs/benching.md, architecture: DESIGN.md §7).
# cargo runs bench binaries with cwd = the package root (rust/), so the
# --json paths are ../-prefixed to land at the repo root.
bench:
	cargo build --release --benches
	cargo bench --bench pim_fabric -- --json ../BENCH_pim_fabric.json
	cargo bench --bench fig13_speedup -- --json ../BENCH_fig13.json

# tiny-iteration executor-regression run (what CI's bench-smoke job does)
bench-smoke:
	cargo build --release --benches
	cargo bench --bench pim_fabric -- --quick --json ../BENCH_pim_fabric.json

# bench trajectory gate: run a fresh full pim_fabric pass and diff it
# against the checked-in baseline; fails on >10% mean regressions.
# Exit codes (full contract: docs/benching.md): 0 ok, 1 regression,
# 2 usage/structural error, 3 baseline unfit (carries
# "estimated"/"quick": true — regenerate via `make bench` on a
# toolchain host and commit the result; CI's bench gate step fails
# loudly on exit 3 instead of silently skipping).
bench-diff:
	cargo build --release --benches --bin bench-diff
	cargo bench --bench pim_fabric -- --json ../BENCH_pim_fabric.new.json
	cargo run --release --bin bench-diff -- BENCH_pim_fabric.json BENCH_pim_fabric.new.json --max-regress 10
