.PHONY: artifacts accuracy goldens test test-rust test-python

# AOT-lower the L2 model + L1 kernels to HLO text + goldens (needs jax)
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# python training pass -> artifacts/accuracy.json (needs jax; slow)
accuracy:
	cd python && python3 -m compile.fcc.train --out ../artifacts --quick

# regenerate the checked-in reference kernel goldens (numpy only)
goldens:
	python3 python/tools/gen_ref_goldens.py

test-rust:
	cargo build --release && cargo test -q

test-python:
	python3 -m pytest python/tests -q

test: test-rust test-python
