//! Design-space exploration with the cost model: how the DDC doubling
//! trades area against density/efficiency across macro geometries and
//! technology nodes — the analysis behind Table II / Fig. 2.
//!
//!     cargo run --release --example capacity_explorer

use ddc_pim::arch::cost::CostModel;
use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::model::zoo;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::table::{f2, Table};

fn main() {
    // ---- sweep 1: DDC on/off across nodes ----------------------------
    let mut t = Table::new("DDC vs baseline across technology nodes").header(&[
        "node",
        "variant",
        "macro mm2",
        "WtDens Kb/mm2",
        "WtDens @28nm",
        "peak GOPS",
        "AreaEff @28nm",
    ]);
    for node in [28.0, 22.0, 14.0, 7.0] {
        for (label, mut cfg) in [
            ("baseline", ArchConfig::baseline()),
            ("DDC-PIM", ArchConfig::ddc_pim()),
        ] {
            cfg.node_nm = node;
            let cost = CostModel::new(cfg.clone());
            t.row(vec![
                format!("{node}nm"),
                label.into(),
                format!("{:.4}", cost.macro_area_mm2()),
                f2(cost.weight_density(false)),
                f2(cost.weight_density(true)),
                f2(cfg.peak_gops()),
                f2(cost.area_efficiency(true)),
            ]);
        }
    }
    println!("{}", t.render());

    // ---- sweep 2: compartment count vs MobileNetV2 latency -----------
    let net = zoo::mobilenet_v2();
    let mut t2 = Table::new("\ncompartments per core vs MobileNetV2 latency (DDC)").header(&[
        "compartments",
        "array Kb",
        "cycles",
        "latency ms",
        "speedup vs baseline-32",
    ]);
    let base32 = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
    for cmp in [16usize, 32, 64, 128] {
        let mut cfg = ArchConfig::ddc_pim();
        cfg.compartments = cmp;
        let run = simulate_network(&net, &cfg, &SimConfig::ddc_full());
        t2.row(vec![
            cmp.to_string(),
            f2(cfg.macro_array_kb()),
            run.total_cycles.to_string(),
            format!("{:.3}", run.latency_ms()),
            format!("{:.3}x", base32.total_cycles as f64 / run.total_cycles as f64),
        ]);
    }
    println!("{}", t2.render());

    // ---- sweep 3: DRAM bandwidth sensitivity (prefetch masking) ------
    let mut t3 = Table::new("\nDRAM bytes/cycle vs exposed stalls (DDC, MobileNetV2)").header(&[
        "bytes/cycle",
        "total cycles",
        "exposed DRAM cycles",
        "stall share",
    ]);
    for bw in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        let mut cfg = ArchConfig::ddc_pim();
        cfg.dram_bytes_per_cycle = bw;
        let run = simulate_network(&net, &cfg, &SimConfig::ddc_full());
        let stalls: u64 = run.layers.iter().map(|l| l.exposed_dram_cycles).sum();
        t3.row(vec![
            format!("{bw}"),
            run.total_cycles.to_string(),
            stalls.to_string(),
            format!("{:.1}%", 100.0 * stalls as f64 / run.total_cycles as f64),
        ]);
    }
    println!("{}", t3.render());
}
