//! Depthwise-convolution mapping walkthrough (paper Fig. 11).
//!
//! dw-conv is the paper's motivating bottleneck: only 9 of 32
//! compartments light up for a 3x3 filter and only one channel computes
//! per row-step on the baseline.  This example walks the three mapping
//! rungs on a real MobileNetV2 dw layer, shows the utilization /
//! parallelism ladder (9x1x8 -> 9x1x16 -> 18x1x16), and functionally
//! verifies the padded two-stage reconfig mapping bit-for-bit.
//!
//!     cargo run --release --example dwconv_mapping

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::mapping::exec::exec_dw_fcc;
use ddc_pim::mapping::im2col::direct_dwconv;
use ddc_pim::mapping::{plan_layer, PlanKind};
use ddc_pim::model::{ConvKind, Layer};
use ddc_pim::util::rng::Rng;

fn main() {
    // a real MobileNetV2 dw layer shape (CIFAR stage 3): 3x3 dw over 192
    // channels at 8x8
    let layer = Layer::Conv {
        name: "dw_stage3".into(),
        kind: ConvKind::Depthwise,
        k: 3,
        cin: 192,
        cout: 192,
        stride: 1,
        in_h: 8,
        in_w: 8,
    };
    let arch = ArchConfig::ddc_pim();
    let mut arch_no_reconf = ArchConfig::ddc_pim();
    arch_no_reconf.reconfig = false;

    println!("layer: 3x3 dw, 192 channels @ 8x8 ({} MACs)\n", layer.macs());
    println!("{:<28} {:>12} {:>12} {:>14}", "mapping", "cycles", "util", "parallelism");
    for (label, arch, sim, par) in [
        ("baseline (regular)", &ArchConfig::baseline(), SimConfig::baseline(), "9x1x8"),
        ("FCC + DBIS", &arch_no_reconf, SimConfig::ddc_full(), "9x1x16"),
        ("FCC + DBIS + reconfig", &arch, SimConfig::ddc_full(), "18x1x16"),
    ] {
        let p = plan_layer(&layer, arch, &sim);
        println!(
            "{:<28} {:>12} {:>11.1}% {:>14}   ({:?})",
            label,
            p.pim_cycles(),
            100.0 * p.utilization,
            par,
            p.kind
        );
    }

    // functional verification of the padded two-stage mapping on a
    // smaller instance (bit-level sim is slow at full size)
    println!("\nfunctional check (16 channels, 4x4):");
    let mut rng = Rng::new(11);
    let (h, w, c, k) = (4, 4, 16, 3);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let bank = FilterBank::new(
        (0..c * k * k).map(|_| rng.int8() as i32).collect(),
        c,
        k * k,
    );
    let fcc = fcc_transform(&bank);

    // oracle with the recomposed biased-comp filters
    let mut bc = vec![0i32; c * k * k];
    for p in 0..c / 2 {
        for i in 0..k * k {
            bc[(2 * p) * 9 + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
            bc[(2 * p + 1) * 9 + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
        }
    }
    let want = direct_dwconv(&input, h, w, c, &bc, k, 1);

    for (label, reconfig) in [("DBIS only", false), ("DBIS + reconfig", true)] {
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, reconfig);
        assert_eq!(got, want, "{label} mismatch");
        println!("  {label:<16} OK ({} outputs, exact match)", got.len());
    }

    // plan kinds for the 5x5 case (EfficientNet-B0): reconfig cannot
    // double a 25-tap filter within 32 compartments
    let l5 = Layer::Conv {
        name: "dw_5x5".into(),
        kind: ConvKind::Depthwise,
        k: 5,
        cin: 64,
        cout: 64,
        stride: 1,
        in_h: 8,
        in_w: 8,
    };
    let p5 = plan_layer(&l5, &arch, &SimConfig::ddc_full());
    assert_eq!(p5.kind, PlanKind::DwDbis);
    println!("\n5x5 dw falls back to DBIS-only (2*25 > 32 compartments): {:?}", p5.kind);
}
