//! End-to-end driver (DESIGN.md §6): the full stack on a real small
//! workload, hermetically.
//!
//! 1. constructs the execution backend (PJRT + AOT artifacts when the
//!    `pjrt` feature and `make artifacts` outputs are present, else the
//!    pure-Rust reference backend) and verifies its kernels against the
//!    L1 oracles (dense INT8 MVM, Eq. 7 ARU recovery);
//! 2. starts the inference coordinator on that backend and serves a
//!    batch of synthetic CIFAR-like requests, reporting wall-clock
//!    latency/throughput;
//! 3. runs the cycle-accurate simulator on MobileNetV2 for the modelled
//!    DDC-PIM latency and the speedup over the PIM baseline.
//!
//!     cargo run --release --example e2e_inference [artifact_dir]

use std::time::Instant;

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::coordinator::{BatchPolicy, InferenceService, IMG_ELEMS, NUM_CLASSES};
use ddc_pim::model::zoo;
use ddc_pim::runtime::{create_backend, verify_kernel_oracles, Backend, BackendKind, Session};
use ddc_pim::sim::simulate_network;
use ddc_pim::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());

    // ---- 1: backend up, kernels verified against the oracles --------
    println!("== constructing backend (artifact dir: {artifact_dir}) ==");
    let mut backend = create_backend(BackendKind::Auto, &artifact_dir)?;
    println!("backend: {}", backend.name());

    if backend.supports_arbitrary_kernel_shapes() {
        // dense INT8 MVM + Eq. 7 ARU recovery vs the L1 oracles
        verify_kernel_oracles(backend.as_mut())?;
        println!("kernel oracles: OK (dense INT8 MVM + half-stored FCC, Eq. 7 recovery)");
    } else {
        // AOT executables are lowered at fixed shapes; their kernel
        // goldens are replayed by `ddc-pim selfcheck` instead.
        println!("kernel oracles: skipped ({} executes fixed AOT shapes)", backend.name());
    }

    // the plan/execute split: prepare once (weights resident), then
    // run batches into a caller-owned buffer — zero steady-state
    // allocation (this is exactly what the service worker does)
    let mut session = backend.prepare()?;
    let mut rng0 = Rng::new(7);
    let warm: Vec<f32> = (0..2 * IMG_ELEMS).map(|_| rng0.normal() as f32).collect();
    let mut warm_out = vec![0f32; 2 * NUM_CLASSES];
    session.infer_batch_into(&warm, 2, &mut warm_out)?;
    session.infer_batch_into(&warm, 2, &mut warm_out)?;
    println!("session: prepared once, 2 batches executed into a reused buffer");
    drop(session);
    drop(backend); // the service owns its own backend thread

    // ---- 2: serve a batch of requests -------------------------------
    println!("\n== serving 64 synthetic CIFAR requests ==");
    let svc = InferenceService::start(artifact_dir.clone(), BatchPolicy::default());
    let mut rng = Rng::new(42);
    let start = Instant::now();
    let rxs: Vec<_> = (0..64)
        .map(|_| {
            let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
            svc.submit(img)
        })
        .collect();
    let mut class_hist = [0usize; 10];
    for rx in rxs {
        let r = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        class_hist[r.argmax] += 1;
    }
    let elapsed = start.elapsed();
    let stats = svc.stats().unwrap_or_default();
    println!(
        "throughput: {:.1} req/s | batches: {} | mean latency {:.2} ms | p99 {:.2} ms",
        64.0 / elapsed.as_secs_f64(),
        stats.batches,
        stats.mean_latency().as_secs_f64() * 1e3,
        stats.p99().as_secs_f64() * 1e3,
    );
    println!("predicted-class histogram: {class_hist:?}");

    // ---- 3: modelled hardware latency + speedup ----------------------
    println!("\n== cycle-accurate DDC-PIM model (full-size MobileNetV2 shapes) ==");
    let net = zoo::mobilenet_v2();
    let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
    let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
    println!(
        "baseline: {:>10} cycles = {:.3} ms (dw fraction {:.1}%)",
        base.total_cycles,
        base.latency_ms(),
        100.0 * base.dw_fraction()
    );
    println!(
        "DDC-PIM:  {:>10} cycles = {:.3} ms (dw fraction {:.1}%)",
        ddc.total_cycles,
        ddc.latency_ms(),
        100.0 * ddc.dw_fraction()
    );
    println!(
        "speedup: {:.3}x (paper Fig. 13: 2.841x) | DRAM traffic {:.2} -> {:.2} KB",
        base.total_cycles as f64 / ddc.total_cycles as f64,
        base.total_dram_bytes as f64 / 1024.0,
        ddc.total_dram_bytes as f64 / 1024.0,
    );
    println!("\ne2e OK");
    Ok(())
}
