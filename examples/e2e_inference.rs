//! End-to-end driver (DESIGN.md §6): the full three-layer stack on a
//! real small workload.
//!
//! 1. loads the python-AOT HLO artifacts (quantized-FCC MobileNetV2-tiny
//!    + the Pallas kernel artifacts) through the PJRT runtime;
//! 2. replays the build-time goldens to prove the AOT bridge is
//!    numerically faithful;
//! 3. starts the inference coordinator and serves a batch of synthetic
//!    CIFAR-like requests, reporting wall-clock latency/throughput;
//! 4. runs the cycle-accurate simulator on the same model for the
//!    modelled DDC-PIM latency and the speedup over the PIM baseline.
//!
//!     make artifacts && cargo run --release --example e2e_inference

use std::time::Instant;

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::coordinator::{BatchPolicy, InferenceService};
use ddc_pim::model::zoo;
use ddc_pim::runtime::{artifacts, Runtime};
use ddc_pim::sim::simulate_network;
use ddc_pim::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());

    // ---- 1+2: runtime up, goldens replayed --------------------------
    println!("== loading AOT artifacts from {artifact_dir} ==");
    let mut rt = Runtime::cpu(&artifact_dir)?;
    println!("PJRT platform: {}", rt.platform());
    let goldens = artifacts::load_goldens(&artifact_dir)?;
    for (name, g) in &goldens {
        match name.as_str() {
            "fcc_mvm" => {
                let exe = rt.load("fcc_mvm")?;
                let out = exe.run_i32(&[
                    (&g.x_i32(), &g.x_shape),
                    (&g.w_i32(), &g.w_shape),
                    (&g.m_i32(), &g.m_shape),
                ])?;
                anyhow::ensure!(out == g.out_i32(), "fcc_mvm golden mismatch");
                println!("golden fcc_mvm: OK (pallas FCC kernel, {} outputs)", out.len());
            }
            "model_b1" => {
                let weights = artifacts::load_model_weights(&artifact_dir)?;
                let out = rt.run_model("model_b1", &g.x_f32(), &g.x_shape, &weights)?;
                let max_err = out
                    .iter()
                    .zip(g.out_f32())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                anyhow::ensure!(max_err < 1e-3, "model_b1 max err {max_err}");
                println!("golden model_b1: OK (max |err| = {max_err:.2e})");
            }
            _ => {}
        }
    }
    drop(rt); // the service owns its own runtime thread

    // ---- 3: serve a batch of requests -------------------------------
    println!("\n== serving 64 synthetic CIFAR requests ==");
    let svc = InferenceService::start(artifact_dir.clone(), BatchPolicy::default());
    let mut rng = Rng::new(42);
    let start = Instant::now();
    let rxs: Vec<_> = (0..64)
        .map(|_| {
            let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.normal() as f32).collect();
            svc.submit(img)
        })
        .collect();
    let mut class_hist = [0usize; 10];
    for rx in rxs {
        let r = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        class_hist[r.argmax] += 1;
    }
    let elapsed = start.elapsed();
    let stats = svc.stats().unwrap_or_default();
    println!(
        "throughput: {:.1} req/s | batches: {} | mean latency {:.2} ms | max {:.2} ms",
        64.0 / elapsed.as_secs_f64(),
        stats.batches,
        stats.mean_latency().as_secs_f64() * 1e3,
        stats.max_latency.as_secs_f64() * 1e3,
    );
    println!("predicted-class histogram: {class_hist:?}");

    // ---- 4: modelled hardware latency + speedup ----------------------
    println!("\n== cycle-accurate DDC-PIM model (full-size MobileNetV2 shapes) ==");
    let net = zoo::mobilenet_v2();
    let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
    let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
    println!(
        "baseline: {:>10} cycles = {:.3} ms (dw fraction {:.1}%)",
        base.total_cycles,
        base.latency_ms(),
        100.0 * base.dw_fraction()
    );
    println!(
        "DDC-PIM:  {:>10} cycles = {:.3} ms (dw fraction {:.1}%)",
        ddc.total_cycles,
        ddc.latency_ms(),
        100.0 * ddc.dw_fraction()
    );
    println!(
        "speedup: {:.3}x (paper Fig. 13: 2.841x) | DRAM traffic {:.2} -> {:.2} KB",
        base.total_cycles as f64 / ddc.total_cycles as f64,
        base.total_dram_bytes as f64 / 1024.0,
        ddc.total_dram_bytes as f64 / 1024.0,
    );
    println!("\ne2e OK");
    Ok(())
}
