//! Quickstart: the FCC transform + functional PIM execution in ~60
//! lines.
//!
//! Takes a random INT8 filter bank, runs the deployment FCC pipeline
//! (symmetrize -> complementize -> decompose), stores only HALF the
//! filters in the bit-true PIM macro model, executes a convolution in
//! double-computing mode, and checks the recovered outputs equal the
//! direct convolution — the core DDC-PIM claim, end to end.
//!
//!     cargo run --release --example quickstart

use ddc_pim::fcc::{fcc_transform, is_bitwise_complementary, FilterBank};
use ddc_pim::mapping::exec::{exec_std_fcc, ExecCtx, PlannedConv};
use ddc_pim::mapping::im2col::direct_conv;
use ddc_pim::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(2023);
    let (h, w, c, k, n) = (8, 8, 8, 3, 16);
    let l = k * k * c;

    // 1. random INT8 filters, paired (f0,f1), (f2,f3), ...
    let bank = FilterBank::new(
        (0..n * l).map(|_| rng.int8() as i32).collect(),
        n,
        l,
    );

    // 2. FCC deployment transform: after this, twin filters are exact
    //    bitwise complements — only the even ones need storing.
    let fcc = fcc_transform(&bank);
    assert!(is_bitwise_complementary(&fcc.comp));
    println!(
        "FCC transform: {} filters -> {} stored ({} weights instead of {})",
        n,
        n / 2,
        fcc.comp.pairs() * l,
        n * l
    );
    println!(
        "transfer bits: {} vs dense {} ({:.1}% of dense)",
        fcc.transfer_bits(),
        fcc.dense_transfer_bits(),
        100.0 * fcc.transfer_bits() as f64 / fcc.dense_transfer_bits() as f64
    );

    // 3. run the conv through the bit-true PIM macro (Q/Q-bar dual paths)
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);

    // 4. oracle: direct conv with the FULL biased-comp bank
    let mut bc = vec![0i32; n * l];
    for p in 0..n / 2 {
        for i in 0..l {
            bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
            bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
        }
    }
    let want = direct_conv(&input, h, w, c, &bc, n, k, 1);
    assert_eq!(got, want, "PIM outputs != direct conv");
    println!(
        "functional check OK: {} outputs from half the stored weights match direct conv",
        got.len()
    );

    // 5. serving shape of the same computation: plan once (weights
    //    written into SRAM exactly once), execute many — repeat runs
    //    reuse one ExecCtx and allocate nothing
    let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    let writes = plan.weight_writes();
    for _ in 0..3 {
        plan.execute(&input, &mut ctx, &mut out);
        assert_eq!(out, want);
    }
    assert_eq!(plan.weight_writes(), writes, "execute never rewrites weights");
    println!(
        "plan/execute OK: {} load pass(es), {} weight writes at plan time, 0 during execute",
        plan.load_passes(),
        writes
    );
}
