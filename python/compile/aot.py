"""AOT lowering: jax -> HLO **text** -> ``artifacts/`` (build-time only).

HLO text (NOT ``lowered.compile()`` output or ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 rust crate links) rejects (``proto.id() <= INT_MAX``).  The
text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.

Also emits ``goldens.json`` — deterministic inputs/outputs for every
artifact — which the rust integration tests replay through PJRT.

Usage (from ``python/``):  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .fcc.core import fcc_quantize, decompose
from .model import build_param_model, fcc_mvm_entry, load_or_init, pim_mac_entry

# Representative layer shape for the kernel artifacts: a MobileNetV2-tiny
# pw-conv (L = 1x1x144-ish reduction, 32 output channels = 16 stored pairs)
KB, KL, KN = 32, 144, 32  # fcc_mvm: x [KB, KL], w_even [KL, KN/2], m [KN/2]
PB, PL, PN = 8, 64, 32  # pim_mac: x [PB, PL], w [PL, PN]

MODEL_BATCHES = (1, 8)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path, fn, *example_args):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--weights", default=None,
                    help="trained npz from fcc.train (default: <out>/mobilenet_v2_tiny.npz)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    weights = args.weights or os.path.join(args.out, "mobilenet_v2_tiny.npz")

    goldens = {}
    rng = np.random.default_rng(42)

    # ---- full model artifacts -------------------------------------------
    # weights are lowered as PARAMETERS (xla_extension 0.5.1 executes
    # dot-with-dense-constant text as zeros) and shipped in a sidecar:
    # model_weights.bin (raw f32 LE) + shape manifest in goldens.json.
    spec, params = load_or_init(weights)
    fwd, arrays = build_param_model(spec, params)
    wspecs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrays]
    for b in MODEL_BATCHES:
        shape = jax.ShapeDtypeStruct((b, 32, 32, 3), jnp.float32)
        lower_to(os.path.join(args.out, f"model_b{b}.hlo.txt"), fwd, shape, *wspecs)
    with open(os.path.join(args.out, "model_weights.bin"), "wb") as f:
        for a in arrays:
            f.write(np.ascontiguousarray(a, np.float32).tobytes())
    with open(os.path.join(args.out, "model_weights.json"), "w") as f:
        json.dump(dict(shapes=[list(a.shape) for a in arrays]), f)
    print(f"wrote {os.path.join(args.out, 'model_weights.bin')} "
          f"({sum(a.size for a in arrays)} f32, {len(arrays)} tensors)")
    x_img = rng.normal(0, 1, (1, 32, 32, 3)).astype(np.float32)
    logits = np.asarray(fwd(jnp.asarray(x_img), *[jnp.asarray(a) for a in arrays]))
    goldens["model_b1"] = dict(
        x=x_img.ravel().tolist(),
        x_shape=list(x_img.shape),
        out=logits.ravel().tolist(),
        out_shape=list(logits.shape),
    )

    # ---- fcc_mvm kernel artifact ----------------------------------------
    x = rng.integers(-128, 128, (KB, KL)).astype(np.int32)
    w_raw = rng.integers(-127, 127, (KN, KL)).astype(np.int32)
    wbc, m = fcc_quantize(jnp.asarray(w_raw, jnp.float32), 1.0)
    wc = decompose(wbc, m)  # [KN, KL] comp filters; even rows are stored
    w_even = np.asarray(wc)[0::2, :].T.copy()  # [KL, KN/2]
    m_np = np.asarray(m, np.int32)
    lower_to(
        os.path.join(args.out, "fcc_mvm.hlo.txt"),
        fcc_mvm_entry,
        jax.ShapeDtypeStruct((KB, KL), jnp.int32),
        jax.ShapeDtypeStruct((KL, KN // 2), jnp.int32),
        jax.ShapeDtypeStruct((KN // 2,), jnp.int32),
    )
    out = np.asarray(fcc_mvm_entry(jnp.asarray(x), jnp.asarray(w_even), jnp.asarray(m_np)))
    goldens["fcc_mvm"] = dict(
        x=x.ravel().tolist(), x_shape=[KB, KL],
        w=w_even.ravel().tolist(), w_shape=[KL, KN // 2],
        m=m_np.ravel().tolist(), m_shape=[KN // 2],
        out=out.ravel().tolist(), out_shape=[KB, KN],
    )

    # ---- pim_mac kernel artifact ----------------------------------------
    xp = rng.integers(-128, 128, (PB, PL)).astype(np.int32)
    wp = rng.integers(-128, 128, (PL, PN)).astype(np.int32)
    lower_to(
        os.path.join(args.out, "pim_mac.hlo.txt"),
        pim_mac_entry,
        jax.ShapeDtypeStruct((PB, PL), jnp.int32),
        jax.ShapeDtypeStruct((PL, PN), jnp.int32),
    )
    outp = np.asarray(pim_mac_entry(jnp.asarray(xp), jnp.asarray(wp)))
    goldens["pim_mac"] = dict(
        x=xp.ravel().tolist(), x_shape=[PB, PL],
        w=wp.ravel().tolist(), w_shape=[PL, PN],
        out=outp.ravel().tolist(), out_shape=[PB, PN],
    )

    with open(os.path.join(args.out, "goldens.json"), "w") as f:
        json.dump(goldens, f)
    print(f"wrote {os.path.join(args.out, 'goldens.json')}")


if __name__ == "__main__":
    main()
