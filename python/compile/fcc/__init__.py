"""FCC (Filter-wise Complementary Correlation) algorithm — build-time only.

Implements the paper's two-stage algorithm:
  * Alg. 1 Symmetrization  — pair adjacent filters, mirror the weight
    closer to the pair mean M so that  w0 - M = -(w1 - M).
  * Alg. 2 Complementization — on INT8 symmetric filters, subtract 1 from
    the smaller twin so that  w0 - M = ~(w1 - M)  (bitwise complement).
  * Decomposition — biased-comp filters -> comp filters + M, where the
    comp twins are exact bitwise complements (w0^c == ~w1^c), so only one
    of each pair is stored/transferred (the Q-bar side of the 6T cell
    recovers the other for free).

Python runs once at build time; the rust coordinator consumes the
decomposed weights via AOT artifacts and its own `fcc` module.
"""

from .core import (
    pair_means,
    symmetrize,
    symmetrize_int,
    complementize,
    decompose,
    recompose,
    is_symmetric,
    is_biased_complementary,
    is_bitwise_complementary,
    fcc_quantize,
)
from .quant import quantize_int8, dequantize_int8, prune_2_4
from .qat import fcc_quant_ste

__all__ = [
    "pair_means",
    "symmetrize",
    "symmetrize_int",
    "complementize",
    "decompose",
    "recompose",
    "is_symmetric",
    "is_biased_complementary",
    "is_bitwise_complementary",
    "fcc_quantize",
    "quantize_int8",
    "dequantize_int8",
    "prune_2_4",
    "fcc_quant_ste",
]
