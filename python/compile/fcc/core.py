"""Core FCC transforms (Alg. 1 / Alg. 2 / decomposition) over jnp arrays.

Filters are handled in flattened form ``w: [N, L]`` where ``N`` is the
number of output channels (must be even — filters pair up as
``(f_0,f_1), (f_2,f_3), ...``) and ``L = K*K*C`` is the per-filter length.
All transforms are elementwise over twin-weights (same position ``i`` in
the two filters of a pair), exactly as Alg. 1 / Alg. 2 in the paper.
"""

import jax.numpy as jnp

# INT8 twin range: after complementization the smaller twin loses 1, and
# the pairwise-symmetric clipping below keeps both M+dev and M-dev-1 in
# the representable signed-8-bit range.
INT8_MIN = -128
INT8_MAX = 127


def _as_pairs(w):
    """[N, L] -> (f0, f1) each [N/2, L]."""
    n = w.shape[0]
    if n % 2 != 0:
        raise ValueError(f"FCC needs an even number of filters, got {n}")
    wp = w.reshape(n // 2, 2, -1)
    return wp[:, 0, :], wp[:, 1, :]


def _from_pairs(f0, f1, shape):
    return jnp.stack([f0, f1], axis=1).reshape(shape)


def pair_means(w):
    """Per-pair mean M_j = (sum f_j + sum f_{j+1}) / (2L).  Returns [N/2]."""
    f0, f1 = _as_pairs(w)
    length = f0.shape[-1]
    return (f0.sum(-1) + f1.sum(-1)) / (2.0 * length)


def symmetrize(w):
    """Alg. 1 — float-domain symmetrization.

    For each twin pair, the weight *closer* to the pair mean M is replaced
    with the mirror image of the other, so that afterwards
    ``f0^s - M = -(f1^s - M)`` holds elementwise (Eq. 1/5).
    Returns ``(w_sym [N, L], M [N/2])``.
    """
    shape = w.shape
    w2 = w.reshape(shape[0], -1)
    f0, f1 = _as_pairs(w2)
    m = pair_means(w2)[:, None]
    keep0 = jnp.abs(f0 - m) >= jnp.abs(f1 - m)
    f0s = jnp.where(keep0, f0, 2.0 * m - f1)
    f1s = jnp.where(keep0, 2.0 * m - f0, f1)
    return _from_pairs(f0s, f1s, shape), m[:, 0]


def symmetrize_int(w_int):
    """Alg. 1 over INT8 filters, with M rounded to an integer.

    The deviation ``dev = f^s - M`` is clamped pairwise so that both
    ``M + dev`` and ``M - dev - 1`` (the post-complementization smaller
    twin) stay inside [INT8_MIN, INT8_MAX].  Clamping the *deviation*
    (not the endpoints) preserves Eq. 1 exactly.
    Returns ``(w_sym int32 [N, L], M int32 [N/2])``.
    """
    shape = w_int.shape
    w2 = w_int.astype(jnp.int32).reshape(shape[0], -1)
    f0, f1 = _as_pairs(w2)
    length = f0.shape[-1]
    m = jnp.round((f0.sum(-1) + f1.sum(-1)) / (2.0 * length)).astype(jnp.int32)[:, None]
    keep0 = jnp.abs(f0 - m) >= jnp.abs(f1 - m)
    f0s = jnp.where(keep0, f0, 2 * m - f1)
    f1s = jnp.where(keep0, 2 * m - f0, f1)
    dev = f0s - m  # = -(f1s - m)
    # both M+dev and M-dev must fit, and the later "-1" of Alg. 2 too:
    dmax = jnp.minimum(INT8_MAX - m, m - (INT8_MIN + 1))
    dmax = jnp.maximum(dmax, 0)
    dev = jnp.clip(dev, -dmax, dmax)
    f0s = m + dev
    f1s = m - dev
    return _from_pairs(f0s, f1s, shape).astype(jnp.int32), m[:, 0]


def complementize(w_sym_int):
    """Alg. 2 — subtract 1 from the smaller twin of each symmetric pair.

    Input must be integer symmetric filters; afterwards
    ``w0^bc - M = ~(w1^bc - M)`` holds elementwise (Eq. 3), because for
    two's-complement integers ``~x = -x - 1`` (Eq. 4).
    """
    shape = w_sym_int.shape
    w2 = w_sym_int.astype(jnp.int32).reshape(shape[0], -1)
    f0, f1 = _as_pairs(w2)
    ge = f0 >= f1
    f0bc = jnp.where(ge, f0, f0 - 1)
    f1bc = jnp.where(ge, f1 - 1, f1)
    return _from_pairs(f0bc, f1bc, shape).astype(jnp.int32)


def decompose(w_bc_int, m):
    """Biased-comp filters -> (comp filters, M):  f^c = f^bc - M.

    After decomposition the twins are exact bitwise complements
    (``w0^c == ~w1^c``), so storing one of each pair in the Q side of a 6T
    cell makes the Q-bar side hold the other — this is the doubling.
    """
    shape = w_bc_int.shape
    w2 = w_bc_int.astype(jnp.int32).reshape(shape[0], -1)
    npairs = w2.shape[0] // 2
    mm = jnp.repeat(m.astype(jnp.int32), 2).reshape(2 * npairs, 1)
    return (w2 - mm).reshape(shape)


def recompose(w_c_int, m):
    """Inverse of :func:`decompose` — f^bc = f^c + M."""
    shape = w_c_int.shape
    w2 = w_c_int.astype(jnp.int32).reshape(shape[0], -1)
    npairs = w2.shape[0] // 2
    mm = jnp.repeat(m.astype(jnp.int32), 2).reshape(2 * npairs, 1)
    return (w2 + mm).reshape(shape)


def is_symmetric(w, m, atol=1e-5):
    """Check Eq. 1:  (w0 - M) == -(w1 - M)."""
    f0, f1 = _as_pairs(jnp.asarray(w, jnp.float32).reshape(w.shape[0], -1))
    return bool(jnp.allclose(f0 - m[:, None], -(f1 - m[:, None]), atol=atol))


def is_biased_complementary(w_bc, m):
    """Check Eq. 3:  (w0 - M) == ~(w1 - M)  i.e. (w0-M)+(w1-M) == -1."""
    f0, f1 = _as_pairs(jnp.asarray(w_bc, jnp.int32).reshape(w_bc.shape[0], -1))
    s = (f0 - m[:, None]) + (f1 - m[:, None])
    return bool(jnp.all(s == -1))


def is_bitwise_complementary(w_c):
    """Check Eq. 2:  w0^c == ~w1^c  elementwise (two's complement)."""
    f0, f1 = _as_pairs(jnp.asarray(w_c, jnp.int32).reshape(w_c.shape[0], -1))
    return bool(jnp.all(f0 == ~f1))


def fcc_quantize(w_float, scale):
    """FCC quantization (paper §III-B-2, steps 1-3): float weights ->
    (biased-comp INT filters, integer M).  ``scale`` is the INT8
    quantization scale (w_q = round(w / scale)).
    """
    wq = jnp.clip(jnp.round(w_float / scale), INT8_MIN, INT8_MAX).astype(jnp.int32)
    ws, m = symmetrize_int(wq)
    wbc = complementize(ws)
    return wbc, m
