"""Synthetic CIFAR-like corpus (substitution for CIFAR-10/100 — see
DESIGN.md §2).

Deterministic, label-consistent generator: each class owns a smooth
spatial template (mixture of oriented sinusoids + colored blobs) and
samples are template + per-sample affine jitter + Gaussian noise.  A small
CNN reaches high accuracy on it, and — crucially for the reproduction —
constraining its conv filters (FCC) costs accuracy in the same *ordering*
the paper reports, because the constraint acts on weight distributions,
not on the data.
"""

import numpy as np


def _class_template(rng, num_channels=3, size=32):
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    img = np.zeros((size, size, num_channels), np.float32)
    for c in range(num_channels):
        # two oriented sinusoids
        for _ in range(2):
            fx, fy = rng.uniform(0.05, 0.45, 2)
            phase = rng.uniform(0, 2 * np.pi)
            img[:, :, c] += rng.uniform(0.4, 1.0) * np.sin(
                2 * np.pi * (fx * xx + fy * yy) + phase
            )
        # one Gaussian blob
        cx, cy = rng.uniform(6, size - 6, 2)
        sig = rng.uniform(3, 8)
        img[:, :, c] += rng.uniform(0.5, 1.5) * np.exp(
            -((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sig**2)
        )
    return img


def make_dataset(num_classes=10, train_per_class=64, test_per_class=16,
                 size=32, noise=0.35, seed=0):
    """Returns ``(x_train, y_train, x_test, y_test)`` with images in
    NHWC float32 (roughly zero-mean, unit-ish scale)."""
    rng = np.random.default_rng(seed)
    templates = [_class_template(rng, size=size) for _ in range(num_classes)]

    def sample(per_class, rng):
        xs, ys = [], []
        for k, tpl in enumerate(templates):
            for _ in range(per_class):
                shift = rng.integers(-3, 4, size=2)
                img = np.roll(tpl, shift, axis=(0, 1))
                img = img * rng.uniform(0.8, 1.2) + rng.normal(
                    0, noise, tpl.shape
                ).astype(np.float32)
                xs.append(img.astype(np.float32))
                ys.append(k)
        xs = np.stack(xs)
        ys = np.array(ys, np.int32)
        perm = rng.permutation(len(ys))
        return xs[perm], ys[perm]

    x_tr, y_tr = sample(train_per_class, np.random.default_rng(seed + 1))
    x_te, y_te = sample(test_per_class, np.random.default_rng(seed + 2))
    return x_tr, y_tr, x_te, y_te
