"""Tiny JAX variants of the paper's benchmark models (scaled substitution,
DESIGN.md §2): MobileNetV2, EfficientNet-B0, AlexNet, VGG19, ResNet18,
MobileViT-XS — all for 32x32x3 inputs.

Each model is a flat list of layer *specs*; parameters live in a parallel
pytree.  Conv layers carry ``fcc``-eligibility metadata (kind, out
channels) so the training loop can apply the FCC constraint to exactly the
scope S(i) under study.  Layer kinds:

  conv    — std-conv  KxKxCxN
  dwconv  — depthwise KxKx1 per channel (pairing pairs adjacent channels)
  pwconv  — pointwise 1x1xCxN
  fc      — dense [out, in]
  pool    — 2x2 avg pool
  gap     — global average pool
  flatten
  res     — residual enter/exit markers (identity skip)

Activations (relu / swish / none) are part of the conv/fc spec.
"""

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------- specs


def conv(cin, cout, k=3, stride=1, act="relu"):
    return dict(kind="conv", cin=cin, cout=cout, k=k, stride=stride, act=act)


def dwconv(c, k=3, stride=1, act="relu"):
    return dict(kind="dwconv", cin=c, cout=c, k=k, stride=stride, act=act)


def pwconv(cin, cout, act="relu"):
    return dict(kind="pwconv", cin=cin, cout=cout, k=1, stride=1, act=act)


def fc(fin, fout, act="none"):
    return dict(kind="fc", cin=fin, cout=fout, act=act)


def pool():
    return dict(kind="pool")


def gap():
    return dict(kind="gap")


def flatten():
    return dict(kind="flatten")


def res_enter():
    return dict(kind="res_enter")


def res_exit():
    return dict(kind="res_exit")


def inv_residual(cin, cout, t=2, stride=1, act="relu"):
    """MobileNetV2 inverted residual: pw expand -> dw -> pw project."""
    mid = cin * t
    block = [
        pwconv(cin, mid, act=act),
        dwconv(mid, stride=stride, act=act),
        pwconv(mid, cout, act="none"),
    ]
    if stride == 1 and cin == cout:
        return [res_enter()] + block + [res_exit()]
    return block


def basic_block(cin, cout, stride=1):
    """ResNet basic block (projection shortcut omitted: when shapes change
    we drop the skip — adequate at this scale)."""
    block = [conv(cin, cout, 3, stride), conv(cout, cout, 3, 1, act="none")]
    if stride == 1 and cin == cout:
        return [res_enter()] + block + [res_exit()]
    return block


def attention(dim, heads=2):
    return dict(kind="attn", dim=dim, heads=heads)


# ------------------------------------------------------------- catalogs


def mobilenet_v2_tiny(num_classes=10):
    spec = [conv(3, 16, 3, 1)]
    spec += inv_residual(16, 16, t=2)
    spec += inv_residual(16, 24, t=2, stride=2)
    spec += inv_residual(24, 24, t=2)
    spec += inv_residual(24, 32, t=2, stride=2)
    spec += inv_residual(32, 32, t=2)
    spec += [pwconv(32, 64), gap(), fc(64, num_classes)]
    return spec


def efficientnet_b0_tiny(num_classes=10):
    a = "swish"
    spec = [conv(3, 16, 3, 1, act=a)]
    spec += inv_residual(16, 16, t=2, act=a)
    spec += inv_residual(16, 24, t=4, stride=2, act=a)
    spec += inv_residual(24, 24, t=4, act=a)
    spec += inv_residual(24, 40, t=4, stride=2, act=a)
    spec += [pwconv(40, 80, act=a), gap(), fc(80, num_classes)]
    return spec


def alexnet_tiny(num_classes=10):
    return [
        conv(3, 32, 5, 2),
        pool(),
        conv(32, 48, 3, 1),
        conv(48, 48, 3, 1),
        pool(),
        flatten(),
        fc(48 * 4 * 4, 256, act="relu"),
        fc(256, num_classes),
    ]


def vgg19_tiny(num_classes=10):
    return [
        conv(3, 32, 3, 1),
        conv(32, 32, 3, 1),
        pool(),
        conv(32, 64, 3, 1),
        conv(64, 64, 3, 1),
        pool(),
        conv(64, 64, 3, 1),
        pool(),
        flatten(),
        fc(64 * 4 * 4, 256, act="relu"),
        fc(256, num_classes),
    ]


def resnet18_tiny(num_classes=10):
    spec = [conv(3, 16, 3, 1)]
    spec += basic_block(16, 16)
    spec += basic_block(16, 32, stride=2)
    spec += basic_block(32, 32)
    spec += basic_block(32, 64, stride=2)
    spec += [gap(), fc(64, num_classes)]
    return spec


def mobilevit_xs_tiny(num_classes=10):
    spec = [conv(3, 16, 3, 2)]
    spec += inv_residual(16, 24, t=2, stride=2, act="swish")
    spec += [pwconv(24, 32, act="none"), attention(32), pwconv(32, 32, act="swish")]
    spec += [gap(), fc(32, num_classes)]
    return spec


MODELS = {
    "mobilenet_v2": mobilenet_v2_tiny,
    "efficientnet_b0": efficientnet_b0_tiny,
    "alexnet": alexnet_tiny,
    "vgg19": vgg19_tiny,
    "resnet18": resnet18_tiny,
    "mobilevit_xs": mobilevit_xs_tiny,
}


# ----------------------------------------------------------- parameters


def init_params(spec, seed=0):
    """He-normal init.  Conv weights are stored flattened as [N, K*K*C]
    (the filter-major layout FCC and the mapper operate on); dw weights as
    [C, K*K]; fc as [out, in]."""
    rng = np.random.default_rng(seed)
    params = []
    for layer in spec:
        kind = layer["kind"]
        if kind in ("conv", "pwconv"):
            k, cin, cout = layer["k"], layer["cin"], layer["cout"]
            fan_in = k * k * cin
            w = rng.normal(0, np.sqrt(2.0 / fan_in), (cout, k * k * cin))
            params.append(
                dict(w=jnp.asarray(w, jnp.float32), b=jnp.zeros((cout,), jnp.float32))
            )
        elif kind == "dwconv":
            k, c = layer["k"], layer["cin"]
            w = rng.normal(0, np.sqrt(2.0 / (k * k)), (c, k * k))
            params.append(
                dict(w=jnp.asarray(w, jnp.float32), b=jnp.zeros((c,), jnp.float32))
            )
        elif kind == "fc":
            fin, fout = layer["cin"], layer["cout"]
            w = rng.normal(0, np.sqrt(2.0 / fin), (fout, fin))
            params.append(
                dict(w=jnp.asarray(w, jnp.float32), b=jnp.zeros((fout,), jnp.float32))
            )
        elif kind == "attn":
            d = layer["dim"]
            params.append(
                dict(
                    wq=jnp.asarray(rng.normal(0, d**-0.5, (d, d)), jnp.float32),
                    wk=jnp.asarray(rng.normal(0, d**-0.5, (d, d)), jnp.float32),
                    wv=jnp.asarray(rng.normal(0, d**-0.5, (d, d)), jnp.float32),
                    wo=jnp.asarray(rng.normal(0, d**-0.5, (d, d)), jnp.float32),
                )
            )
        else:
            params.append(dict())
    return params


def _act(x, name):
    if name == "relu":
        return jax.nn.relu(x)
    if name == "swish":
        return jax.nn.swish(x)
    return x


def _conv2d(x, w4, stride):
    return jax.lax.conv_general_dilated(
        x,
        w4,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


# --- patches-based convolution (export path) -------------------------
#
# xla_extension 0.5.1 (the version the rust `xla` crate links) executes
# `convolution` HLO ops parsed from jax>=0.8 text as zeros, so the AOT
# export path lowers convs as explicit patch extraction + dot — which is
# precisely the im2col + MVM decomposition the PIM hardware performs
# (paper §III-D), so the exported HLO mirrors the silicon dataflow.
# Padding is symmetric (k-1)//2, windows anchored on the stride grid —
# identical to the rust mapper's im2col.


def extract_patches(x, k, stride):
    """[B,H,W,C] -> [B,oh,ow,K*K*C] via pad + strided slices (no conv op)."""
    b, h, w, c = x.shape
    p = (k - 1) // 2
    oh = -(-h // stride)
    ow = -(-w // stride)
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    taps = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[:, ky : ky + stride * (oh - 1) + 1 : stride,
                    kx : kx + stride * (ow - 1) + 1 : stride, :]
            taps.append(sl)
    return jnp.concatenate(taps, axis=-1)  # [B,oh,ow,K*K*C] (tap-major)


def conv2d_patches(x, w, k, cout, stride, wt=None):
    """Conv as im2col+dot. ``w: [N, K*K*C]`` filter-major (tap-major per
    filter, matching extract_patches ordering).  The dot is kept strictly
    2-D and, when ``wt`` ([K*K*C, N], pre-transposed *outside* the traced
    graph) is given, transpose-free: xla_extension 0.5.1 executes rank>2
    dot_general and `transpose`-of-constant text as zeros (parser bug
    family shared with `convolution`)."""
    pat = extract_patches(x, k, stride)  # [B,oh,ow,K*K*C]
    b, oh, ow, l = pat.shape
    w2 = wt if wt is not None else w.T
    y = pat.reshape(b * oh * ow, l) @ w2  # [B*oh*ow, N]
    return y.reshape(b, oh, ow, cout)


def dwconv2d_patches(x, w, k, stride):
    """Depthwise conv via patches. ``w: [C, K*K]``."""
    b, h, wd, c = x.shape
    p = (k - 1) // 2
    oh = -(-h // stride)
    ow = -(-wd // stride)
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    acc = jnp.zeros((b, oh, ow, c), x.dtype)
    for ky in range(k):
        for kx in range(k):
            sl = xp[:, ky : ky + stride * (oh - 1) + 1 : stride,
                    kx : kx + stride * (ow - 1) + 1 : stride, :]
            acc = acc + sl * w[:, ky * k + kx][None, None, None, :]
    return acc


def forward(spec, params, x, weight_tf=None, conv_impl="lax"):
    """Run the model.  ``weight_tf(layer_index, layer_spec, w) -> w`` lets
    the training loop interpose FCC / plain fake-quant on a per-layer
    basis; identity when None.  ``conv_impl="patches"`` selects the
    im2col+dot lowering used for AOT export (see above)."""

    def tf(i, layer, w):
        return w if weight_tf is None else weight_tf(i, layer, w)

    stack = []
    for i, (layer, p) in enumerate(zip(spec, params)):
        kind = layer["kind"]
        if kind in ("conv", "pwconv"):
            k, cin, cout = layer["k"], layer["cin"], layer["cout"]
            w = tf(i, layer, p["w"])  # [N, K*K*C]
            if conv_impl == "patches":
                y = conv2d_patches(x, w, k, cout, layer["stride"],
                                   wt=p.get("wt"))
            else:
                w4 = w.reshape(cout, k, k, cin).transpose(1, 2, 3, 0)  # HWIO
                y = _conv2d(x, w4, layer["stride"])
            x = _act(y + p["b"], layer["act"])
        elif kind == "dwconv":
            k, c = layer["k"], layer["cin"]
            w = tf(i, layer, p["w"])  # [C, K*K]
            if conv_impl == "patches":
                y = dwconv2d_patches(x, w, k, layer["stride"])
            else:
                w4 = w.reshape(c, k, k, 1).transpose(1, 2, 3, 0)  # HWIO
                y = jax.lax.conv_general_dilated(
                    x,
                    w4,
                    window_strides=(layer["stride"], layer["stride"]),
                    padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=c,
                )
            x = _act(y + p["b"], layer["act"])
        elif kind == "fc":
            w = tf(i, layer, p["w"])
            w2 = p["wt"] if "wt" in p else w.T
            x = _act(x @ w2 + p["b"], layer["act"])
        elif kind == "attn":
            b, h, wdt, c = x.shape
            seq = x.reshape(b, h * wdt, c)
            q, k_, v = seq @ p["wq"], seq @ p["wk"], seq @ p["wv"]
            att = jax.nn.softmax(q @ k_.transpose(0, 2, 1) / np.sqrt(c), axis=-1)
            seq = seq + (att @ v) @ p["wo"]
            x = seq.reshape(b, h, wdt, c)
        elif kind == "pool":
            x = jax.lax.reduce_window(
                x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            ) / 4.0
        elif kind == "gap":
            x = x.mean(axis=(1, 2))
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "res_enter":
            stack.append(x)
        elif kind == "res_exit":
            x = x + stack.pop()
        else:
            raise ValueError(kind)
    return x


def conv_layer_indices(spec):
    """Indices of FCC-eligible conv-ish layers (even out-channel count)."""
    return [
        i
        for i, l in enumerate(spec)
        if l["kind"] in ("conv", "pwconv", "dwconv") and l["cout"] % 2 == 0
    ]


def fc_layer_indices(spec):
    return [i for i, l in enumerate(spec) if l["kind"] == "fc" and l["cout"] % 2 == 0]


def param_counts(spec):
    """(conv_params, fc_params, total) — for the paper's FC-ratio column."""
    conv_n = fc_n = other = 0
    for l in spec:
        if l["kind"] in ("conv", "pwconv"):
            conv_n += l["k"] * l["k"] * l["cin"] * l["cout"] + l["cout"]
        elif l["kind"] == "dwconv":
            conv_n += l["k"] * l["k"] * l["cin"] + l["cout"]
        elif l["kind"] == "fc":
            fc_n += l["cin"] * l["cout"] + l["cout"]
        elif l["kind"] == "attn":
            other += 4 * l["dim"] * l["dim"]
    return conv_n, fc_n, conv_n + fc_n + other
