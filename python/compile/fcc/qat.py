"""FCC-aware QAT (paper §III-B-2): quantize -> symmetrize ->
complementize -> de-quantize, with a straight-through estimator so the
constraint is *felt* by the optimizer while gradients still flow.
"""

import jax
import jax.numpy as jnp

from .core import fcc_quantize, decompose, recompose
from .quant import quant_scale


def fcc_quant_dequant(w):
    """The forward FCC-quantization round trip (float -> float)."""
    scale = quant_scale(w)
    n = w.shape[0]
    flat = w.reshape(n, -1)
    wbc, m = fcc_quantize(flat, scale)
    return (wbc.astype(jnp.float32) * scale).reshape(w.shape)


def fcc_quant_ste(w):
    """Straight-through FCC quantization: forward value is the
    FCC-quantized/de-quantized weight, gradient is identity."""
    return w + jax.lax.stop_gradient(fcc_quant_dequant(w) - w)


def quant_dequant(w):
    """Plain INT8 fake-quant round trip (baseline QAT, no FCC)."""
    scale = quant_scale(w)
    q = jnp.clip(jnp.round(w / scale), -128, 127)
    return q * scale


def quant_ste(w):
    return w + jax.lax.stop_gradient(quant_dequant(w) - w)


def fcc_export(w):
    """Export a trained conv weight for deployment.

    Returns ``(w_comp int32 [N, L], m int32 [N/2], scale float)`` — the
    comp filters (only even-indexed ones need transfer: odd are their
    bitwise complements) and per-pair means, as consumed by the mapper.
    """
    scale = quant_scale(w)
    n = w.shape[0]
    wbc, m = fcc_quantize(w.reshape(n, -1), scale)
    wc = decompose(wbc, m)
    return wc, m, scale


def fcc_import(wc, m, scale, shape):
    """Inverse of :func:`fcc_export` (for round-trip tests)."""
    wbc = recompose(wc, m)
    return (wbc.astype(jnp.float32) * scale).reshape(shape)
