"""Symmetric per-tensor INT8 quantization and 2:4 structured pruning."""

import jax.numpy as jnp

from .core import INT8_MAX, INT8_MIN


def quant_scale(w, qmax=INT8_MAX):
    """Symmetric per-tensor scale: max|w| / qmax (never zero)."""
    amax = jnp.max(jnp.abs(w))
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_int8(w, scale=None):
    """Quantize float tensor to INT8 codes (int32 storage).

    Returns ``(codes, scale)``.
    """
    if scale is None:
        scale = quant_scale(w)
    codes = jnp.clip(jnp.round(w / scale), INT8_MIN, INT8_MAX).astype(jnp.int32)
    return codes, scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def prune_2_4(w):
    """NVIDIA-style 2:4 fine-grained structured pruning mask.

    In every group of 4 consecutive weights (along the last axis of the
    flattened filter), the 2 smallest-magnitude weights are zeroed.
    Returns the pruned tensor (same shape).  Tail elements (len % 4) are
    kept.
    """
    shape = w.shape
    flat = w.reshape(-1)
    n4 = (flat.shape[0] // 4) * 4
    head, tail = flat[:n4].reshape(-1, 4), flat[n4:]
    # rank within each group of 4 by |w|; keep top-2
    order = jnp.argsort(jnp.abs(head), axis=1)  # ascending
    mask = jnp.ones_like(head)
    rows = jnp.arange(head.shape[0])[:, None]
    mask = mask.at[rows, order[:, :2]].set(0.0)
    pruned = jnp.concatenate([(head * mask).reshape(-1), tail])
    return pruned.reshape(shape)


def sparsity(w, atol=0.0):
    """Fraction of exactly-zero (or |w|<=atol) weights."""
    return float(jnp.mean(jnp.abs(w) <= atol))
