"""FCC training experiment driver (build-time).

Trains the scaled model zoo on the synthetic CIFAR-like corpus and
measures the accuracy impact of the FCC constraint, reproducing the
accuracy side of Table III, Table IV, Table V and Fig. 14.  Results are
written to ``artifacts/accuracy.json`` for the rust report generators, and
the trained MobileNetV2 weights are exported for the AOT inference model.

Usage (from ``python/``):
    python -m compile.fcc.train --out ../artifacts [--quick] [--only table3]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import core
from .data import make_dataset
from .models import (
    MODELS,
    conv_layer_indices,
    fc_layer_indices,
    forward,
    init_params,
    param_counts,
)
from .qat import fcc_quant_ste, quant_ste
from .quant import prune_2_4


# ------------------------------------------------------------ optimizer


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return dict(m=zeros, v=jax.tree_util.tree_map(jnp.zeros_like, params), t=0)


def adam_update(params, grads, state, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads
    )
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, dict(m=m, v=v, t=t)


# ------------------------------------------------------------- training


def scope_layers(spec, threshold):
    """S(i): FCC-eligible conv layers with more than `threshold` filters."""
    if threshold is None:
        return set()
    return {i for i in conv_layer_indices(spec) if spec[i]["cout"] > threshold}


def make_weight_tf(fcc_set, quantize):
    """Per-layer weight transform for QAT: FCC-STE on scoped layers, plain
    INT8 fake-quant elsewhere (paper applies INT8 to all layers)."""

    def tf(i, layer, w):
        if i in fcc_set:
            return fcc_quant_ste(w)
        if quantize:
            return quant_ste(w)
        return w

    return tf


def symmetrize_project(params, spec, fcc_set):
    """Projection used during FCC-aware pre-training (Alg. 1 float)."""
    out = []
    for i, (layer, p) in enumerate(zip(spec, params)):
        if i in fcc_set and "w" in p:
            ws, _ = core.symmetrize(p["w"])
            out.append(dict(p, w=ws))
        else:
            out.append(p)
    return out


def train_model(
    model_name,
    fcc_conv=False,
    fcc_fc=False,
    scope_threshold=0,
    prune24=False,
    num_classes=10,
    steps_pre=150,
    steps_qat=80,
    batch=64,
    seed=0,
    data=None,
):
    """Two-stage FCC training (pre-train with symmetrization projection,
    then FCC-aware QAT).  Returns dict of accuracies + metadata."""
    spec = MODELS[model_name](num_classes)
    params = init_params(spec, seed=seed)
    if data is None:
        data = make_dataset(num_classes=num_classes, seed=seed)
    x_tr, y_tr, x_te, y_te = data

    fcc_set = scope_layers(spec, scope_threshold) if fcc_conv else set()
    if fcc_fc:
        fcc_set |= set(fc_layer_indices(spec))

    def loss_fn(params, x, y, weight_tf):
        logits = forward(spec, params, x, weight_tf)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    @jax.jit
    def pre_step(params, opt, x, y):
        l, g = jax.value_and_grad(loss_fn)(params, x, y, None)
        params, opt = adam_update(params, g, opt)
        return params, opt, l

    qat_tf = make_weight_tf(fcc_set, quantize=True)

    @jax.jit
    def qat_step(params, opt, x, y):
        l, g = jax.value_and_grad(lambda p, a, b: loss_fn(p, a, b, qat_tf))(
            params, x, y
        )
        params, opt = adam_update(params, g, opt, lr=5e-4)
        return params, opt, l

    @jax.jit
    def eval_logits(params, x):
        return forward(spec, params, x, qat_tf)

    # Schedule (stability on the scaled models): dense warmup for the
    # first half of pre-training, then the symmetrization projection
    # every 4 steps (projecting every step thrashes Adam's moments and
    # collapses narrow models); 2:4 pruning engages only after 2/3 of
    # pre-training (ASP-style: prune a trained dense model, then
    # fine-tune under the mask).
    rng = np.random.default_rng(seed + 7)
    opt = adam_init(params)
    n = len(y_tr)
    prune_start = (2 * steps_pre) // 3
    for s in range(steps_pre):
        idx = rng.integers(0, n, batch)
        params, opt, _ = pre_step(params, opt, x_tr[idx], y_tr[idx])
        if fcc_set and s >= steps_pre // 2 and (s % 4 == 3 or s == steps_pre - 1):
            params = symmetrize_project(params, spec, fcc_set)
        if prune24 and s >= prune_start:
            params = [
                dict(p, w=prune_2_4(p["w"])) if "w" in p else p for p in params
            ]

    opt = adam_init(params)
    for s in range(steps_qat):
        idx = rng.integers(0, n, batch)
        params, opt, _ = qat_step(params, opt, x_tr[idx], y_tr[idx])
        if fcc_set and (s % 4 == 3 or s == steps_qat - 1):
            params = symmetrize_project(params, spec, fcc_set)
        if prune24:
            params = [
                dict(p, w=prune_2_4(p["w"])) if "w" in p else p for p in params
            ]

    # accuracy under deployment (quantized/FCC) weights
    preds = []
    for i in range(0, len(y_te), 256):
        preds.append(np.argmax(np.asarray(eval_logits(params, x_te[i : i + 256])), -1))
    acc = float((np.concatenate(preds) == y_te).mean()) * 100.0

    conv_n, fc_n, total = param_counts(spec)
    fcc_params = sum(
        spec[i]["k"] * spec[i]["k"] * spec[i]["cin"] * spec[i]["cout"]
        if spec[i]["kind"] in ("conv", "pwconv")
        else (
            spec[i]["k"] * spec[i]["k"] * spec[i]["cin"]
            if spec[i]["kind"] == "dwconv"
            else spec[i]["cin"] * spec[i]["cout"]
        )
        for i in fcc_set
    )
    return dict(
        model=model_name,
        acc=acc,
        fcc_conv=fcc_conv,
        fcc_fc=fcc_fc,
        scope_threshold=scope_threshold,
        prune24=prune24,
        fc_param_ratio=100.0 * fc_n / total,
        fcc_param_ratio=100.0 * fcc_params / total,
        params=params,
        spec=spec,
    )


def strip(result):
    r = dict(result)
    r.pop("params")
    r.pop("spec")
    return r


# ------------------------------------------------------------ experiments


def run_table3(out, steps_pre, steps_qat, log):
    rows = []
    models = ["mobilenet_v2", "efficientnet_b0", "alexnet", "vgg19", "resnet18"]
    data = make_dataset(seed=0)
    export = None
    for name in models:
        base = train_model(name, fcc_conv=False, data=data,
                           steps_pre=steps_pre, steps_qat=steps_qat)
        conv = train_model(name, fcc_conv=True, data=data,
                           steps_pre=steps_pre, steps_qat=steps_qat)
        both = train_model(name, fcc_conv=True, fcc_fc=True, data=data,
                           steps_pre=steps_pre, steps_qat=steps_qat)
        rows.append(
            dict(
                model=name,
                baseline_acc=base["acc"],
                conv_acc=conv["acc"],
                conv_drop=base["acc"] - conv["acc"],
                conv_fc_acc=both["acc"],
                conv_fc_drop=base["acc"] - both["acc"],
                fc_param_ratio=base["fc_param_ratio"],
            )
        )
        log(f"table3 {name}: base={base['acc']:.2f} conv={conv['acc']:.2f} "
            f"conv+fc={both['acc']:.2f}")
        if name == "mobilenet_v2":
            export = conv
    return rows, export


def run_fig14(out, steps_pre, steps_qat, log):
    # scaled thresholds: the tiny models top out at 64-80 filters, so the
    # paper's S(112)...S(0) sweep maps to these i values.
    thresholds = [None, 48, 32, 24, 16, 0]
    series = {}
    data = make_dataset(seed=0)
    for name in ["mobilenet_v2", "efficientnet_b0"]:
        pts = []
        for th in thresholds:
            r = train_model(
                name,
                fcc_conv=th is not None,
                scope_threshold=th if th is not None else 0,
                data=data,
                steps_pre=steps_pre,
                steps_qat=steps_qat,
            )
            pts.append(
                dict(
                    threshold=-1 if th is None else th,
                    acc=r["acc"],
                    fcc_param_ratio=r["fcc_param_ratio"],
                )
            )
            log(f"fig14 {name} S({th}): acc={r['acc']:.2f} "
                f"params={r['fcc_param_ratio']:.1f}%")
        series[name] = pts
    return series


def run_table4(out, steps_pre, steps_qat, log):
    data = make_dataset(num_classes=100, train_per_class=24,
                        test_per_class=8, seed=3)
    orig = train_model("mobilenet_v2", num_classes=100, data=data,
                       steps_pre=steps_pre, steps_qat=steps_qat)
    pruned = train_model("mobilenet_v2", num_classes=100, prune24=True,
                         data=data, steps_pre=steps_pre, steps_qat=steps_qat)
    both = train_model("mobilenet_v2", num_classes=100, prune24=True,
                       fcc_conv=True, data=data,
                       steps_pre=steps_pre, steps_qat=steps_qat)
    log(f"table4: orig={orig['acc']:.2f} 2:4={pruned['acc']:.2f} "
        f"fcc+2:4={both['acc']:.2f}")
    return dict(
        original_acc=orig["acc"],
        pruned_acc=pruned["acc"],
        fcc_pruned_acc=both["acc"],
    )


def run_table5(out, steps_pre, steps_qat, log):
    data = make_dataset(seed=5)
    orig = train_model("mobilevit_xs", data=data,
                       steps_pre=steps_pre, steps_qat=steps_qat)
    fcc = train_model("mobilevit_xs", fcc_conv=True, data=data,
                      steps_pre=steps_pre, steps_qat=steps_qat)
    log(f"table5: orig={orig['acc']:.2f} fcc={fcc['acc']:.2f}")
    return dict(original_acc=orig["acc"], fcc_acc=fcc["acc"])


def export_weights(result, path):
    """Export the trained FCC MobileNetV2 for aot.py / rust goldens."""
    arrs, meta = {}, []
    for i, (layer, p) in enumerate(zip(result["spec"], result["params"])):
        entry = dict(layer)
        if "w" in p:
            arrs[f"w{i}"] = np.asarray(p["w"], np.float32)
            arrs[f"b{i}"] = np.asarray(p["b"], np.float32)
        meta.append(entry)
    arrs["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    np.savez(path, **arrs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke configuration")
    ap.add_argument("--only", default=None,
                    choices=[None, "table3", "table4", "table5", "fig14"])
    ap.add_argument("--steps-pre", type=int, default=None)
    ap.add_argument("--steps-qat", type=int, default=None)
    args = ap.parse_args()

    steps_pre = args.steps_pre or (20 if args.quick else 150)
    steps_qat = args.steps_qat or (10 if args.quick else 80)
    os.makedirs(args.out, exist_ok=True)

    def log(msg):
        print(msg, flush=True)

    results = {}
    acc_path = os.path.join(args.out, "accuracy.json")
    if os.path.exists(acc_path):
        with open(acc_path) as f:
            results = json.load(f)

    def save():
        results["config"] = dict(steps_pre=steps_pre, steps_qat=steps_qat)
        with open(acc_path, "w") as f:
            json.dump(results, f, indent=2)
        log(f"saved {acc_path}")

    if args.only in (None, "table3"):
        rows, export = run_table3(args.out, steps_pre, steps_qat, log)
        results["table3"] = rows
        if export is not None:
            export_weights(export, os.path.join(args.out, "mobilenet_v2_tiny.npz"))
        save()
    if args.only in (None, "table4"):
        results["table4"] = run_table4(args.out, steps_pre, steps_qat, log)
        save()
    if args.only in (None, "table5"):
        results["table5"] = run_table5(args.out, steps_pre, steps_qat, log)
        save()
    if args.only in (None, "fig14"):
        results["fig14"] = run_fig14(args.out, steps_pre, steps_qat, log)
        save()


if __name__ == "__main__":
    main()
