"""L1 Pallas kernels (build-time; lowered into the L2 HLO)."""

from .fcc_conv import fcc_mvm
from .pim_mac import pim_mac

__all__ = ["fcc_mvm", "pim_mac"]
