"""L1 Pallas kernel: FCC MVM with fused ARU recovery (paper Eq. 7).

The DDC headline at kernel level: only the *even* comp filters are stored
(``w_even``); the odd twins are their exact bitwise complements, which the
6T array holds for free in Q-bar.  Algebraically ``~w = -w - 1``, so the
odd-channel partial sum is recovered from the stored plane and the input
row-sum without a second reduction:

    psum_odd = -psum_even - sum(x)

followed by the ARU epilogue ``out = psum + sum(x) * M`` for both twins.
One stored bit-plane therefore serves two output channels — double
capacity AND double parallelism, which is exactly the double-computing
mode of Fig. 7(b).

Grid/BlockSpec express the compartment schedule: each grid step processes
one tile of stored filter pairs (a compartment group's worth).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fcc_mvm_kernel(x_ref, w_ref, m_ref, even_ref, odd_ref):
    """x: [B, L] int32, w: [L, TH] int32 (stored even comp filters),
    m: [1, TH] int32 pair means -> even/odd: [B, TH] int32."""
    x = x_ref[...]
    w = w_ref[...]
    m = m_ref[...]
    psum = jnp.dot(x, w, preferred_element_type=jnp.int32)  # adder tree
    si = x.sum(axis=1, keepdims=True)  # (sum I), computed once per tile
    even_ref[...] = psum + si * m  # ARU: psum + (sum I) * M
    odd_ref[...] = si * (m - 1) - psum  # Q-bar recovery + ARU, fused


@functools.partial(jax.jit, static_argnames=("tile_h",))
def fcc_mvm(x, w_even, m, tile_h=16):
    """FCC MVM: ``[B, L] x [L, N/2] (+ M [N/2]) -> [B, N]`` int32,
    channels interleaved (even, odd, even, odd, ...)."""
    x = x.astype(jnp.int32)
    w_even = w_even.astype(jnp.int32)
    b, l = x.shape
    l2, half = w_even.shape
    assert l == l2, (l, l2)
    assert half % tile_h == 0, (half, tile_h)
    m2 = m.astype(jnp.int32).reshape(1, half)
    grid = (half // tile_h,)
    even, odd = pl.pallas_call(
        _fcc_mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, l), lambda i: (0, 0)),
            pl.BlockSpec((l, tile_h), lambda i: (0, i)),
            pl.BlockSpec((1, tile_h), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((b, tile_h), lambda i: (0, i)),
            pl.BlockSpec((b, tile_h), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, half), jnp.int32),
            jax.ShapeDtypeStruct((b, half), jnp.int32),
        ],
        interpret=True,
    )(x, w_even, m2)
    return jnp.stack([even, odd], axis=2).reshape(b, 2 * half)
