"""L1 Pallas kernel: bit-serial digital-PIM MAC (the macro's hot-spot).

Emulates the DDC-PIM compute fabric: the pre-process unit feeds inputs
bit-serially (8 cycles), each stored weight bit-plane is ANDed with the
broadcast input bit across all compartments, the adder tree reduces
spatially, and the shift-&-add unit recombines bit positions (MSBs carry
negative two's-complement weight).

HARDWARE ADAPTATION (DESIGN.md §8): the silicon expresses the
(wordline, bit-position) schedule with row decoders; here it is a
``(n_tile,)`` grid of BlockSpec-tiled VMEM blocks, with the AND+adder-tree
realized as an integer matmul per (input-bit × weight-bit) plane — the
MXU-friendly form of the same reduction.  Runs under ``interpret=True``
(CPU); real-TPU lowering would emit a Mosaic custom-call the CPU PJRT
plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pim_mac_kernel(x_ref, w_ref, o_ref):
    """One output tile: bit-serial MAC over full reduction length.

    x_ref: [B, L] int32 (int8-range), w_ref: [L, TN] int32,
    o_ref: [B, TN] int32.
    """
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    # 8 bit-serial input cycles x 8 stored weight bit-planes = the 64
    # AND/accumulate passes the macro performs per row activation group.
    for kx in range(8):
        sx = -(1 << kx) if kx == 7 else (1 << kx)
        xb = ((x & 0xFF) >> kx) & 1  # broadcast input bit (DBIS INP)
        for kw in range(8):
            sw = -(1 << kw) if kw == 7 else (1 << kw)
            wb = ((w & 0xFF) >> kw) & 1  # stored weight bit (Q state)
            # bitwise AND of a 1b input and 1b weight == 1x1 multiply;
            # the adder tree is the reduction of the matmul.
            acc = acc + jnp.dot(xb, wb, preferred_element_type=jnp.int32) * (
                sx * sw
            )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_n",))
def pim_mac(x, w, tile_n=32):
    """Bit-serial PIM MVM: ``[B, L] x [L, N] -> [B, N]`` (int32).

    ``tile_n`` is the output-channel tile per grid step (a PIM-core's
    worth of adder-tree outputs).
    """
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    b, l = x.shape
    l2, n = w.shape
    assert l == l2, (l, l2)
    assert n % tile_n == 0, (n, tile_n)
    grid = (n // tile_n,)
    return pl.pallas_call(
        _pim_mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, l), lambda i: (0, 0)),
            pl.BlockSpec((l, tile_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=True,
    )(x, w)
