"""Pure-jnp oracles for the L1 Pallas kernels.

These define *correct* numerics; the Pallas kernels must match them
bit-exactly (integer outputs) under pytest/hypothesis.
"""

import jax.numpy as jnp


def mvm_int8_ref(x, w):
    """Dense signed-INT8 matrix-vector-multiply oracle.

    x: [B, L] int8-range ints, w: [L, N] int8-range ints -> [B, N] int32.
    The PIM array's bit-serial AND + adder-tree + shift-&-add must reduce
    to exactly this.
    """
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def fcc_mvm_ref(x, w_even, m):
    """FCC MVM oracle with ARU recovery (paper Eq. 7).

    Only the even-indexed comp filters are stored (``w_even: [L, N/2]``);
    the odd twins are their bitwise complements (``w_odd = ~w_even =
    -w_even - 1``), held for free in the Q-bar side of the 6T array.  With
    ``si = sum(x)`` per row:

        psum_even = x @ w_even
        psum_odd  = x @ (-w_even - 1) = -psum_even - si
        out_even  = psum_even + si * M          (ARU recovery, Eq. 7)
        out_odd   = psum_odd  + si * M = si * (M - 1) - psum_even

    Returns [B, N] int32 with channels interleaved (even, odd, even, ...).
    """
    x = x.astype(jnp.int32)
    w_even = w_even.astype(jnp.int32)
    m = m.astype(jnp.int32)
    psum = x @ w_even  # [B, N/2]
    si = x.sum(axis=1, keepdims=True)  # [B, 1]
    out_even = psum + si * m[None, :]
    out_odd = si * (m[None, :] - 1) - psum
    b, half = psum.shape
    return jnp.stack([out_even, out_odd], axis=2).reshape(b, 2 * half)


def bit_serial_ref(x, w):
    """Bit-level reference: explicitly decompose both operands into bit
    planes (two's complement, MSB negative) and accumulate AND products —
    the exact dataflow of the digital PIM macro (Fig. 6/7).  Must equal
    :func:`mvm_int8_ref`."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for kx in range(8):
        sx = -(1 << kx) if kx == 7 else (1 << kx)
        xb = ((x & 0xFF) >> kx) & 1
        for kw in range(8):
            sw = -(1 << kw) if kw == 7 else (1 << kw)
            wb = ((w & 0xFF) >> kw) & 1
            acc = acc + (xb @ wb) * (sx * sw)
    return acc
