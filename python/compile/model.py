"""L2 — JAX inference model (build-time; lowered to HLO for the rust
runtime).

Two artifact families are produced from here (see aot.py):

* ``model_b{B}.hlo.txt`` — the full quantized-FCC MobileNetV2-tiny forward
  pass with weights baked as constants.  Deployment numerics: every conv
  weight goes through the FCC quantize/de-quantize round trip (the
  biased-comp INT8 grid), every FC weight through plain INT8 fake-quant,
  so the HLO computes exactly what the PIM array computes up to the
  float/int epilogue.  This is the request-path artifact the coordinator
  serves.
* ``fcc_mvm.hlo.txt`` / ``pim_mac.hlo.txt`` — the L1 Pallas kernels
  lowered standalone at a representative layer shape, used by the rust
  runtime micro-bench and the golden integration tests.

Python never runs at inference time; the rust binary loads the HLO text.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .fcc.models import MODELS, forward, init_params
from .fcc.qat import fcc_quant_dequant, quant_dequant
from .kernels import fcc_mvm, pim_mac


def load_or_init(npz_path, model_name="mobilenet_v2", num_classes=10, seed=0):
    """Load trained weights exported by fcc.train, or fall back to a
    deterministic random init (functional path does not require trained
    weights; the e2e example prefers them)."""
    spec = MODELS[model_name](num_classes)
    params = init_params(spec, seed=seed)
    if npz_path and os.path.exists(npz_path):
        data = np.load(npz_path, allow_pickle=False)
        meta = json.loads(bytes(data["meta"]).decode())
        assert len(meta) == len(spec), "weight file does not match model spec"
        for i in range(len(spec)):
            if f"w{i}" in data:
                params[i] = dict(
                    w=jnp.asarray(data[f"w{i}"]), b=jnp.asarray(data[f"b{i}"])
                )
    return spec, params


def deploy_weight_tf(i, layer, w):
    """Deployment numerics: FCC grid for conv-ish layers (even N), plain
    INT8 grid otherwise."""
    if layer["kind"] in ("conv", "pwconv", "dwconv") and layer["cout"] % 2 == 0:
        return fcc_quant_dequant(w)
    return quant_dequant(w)


def build_forward(spec, params):
    """Returns ``fn(x: [B,32,32,3] f32) -> logits [B,10] f32`` with
    deployment (FCC-quantized) weights baked in as constants."""

    # Deployment weights are baked EAGERLY (numpy): the FCC/INT8 grid is
    # applied here, and conv/fc weights are pre-transposed to [L, N] so
    # the exported graph contains no `transpose`-of-constant nodes —
    # xla_extension 0.5.1 executes those (like `convolution` and rank>2
    # dot_general) as zeros.  The traced fn is pad/slice/concat/dot/
    # add/max/reduce only.
    frozen = freeze_deployed(spec, params)

    def fn(x):
        # patches lowering: convs become im2col + dot, matching both the
        # PIM dataflow and what xla_extension 0.5.1 can execute.
        return forward(spec, frozen, x, conv_impl="patches")

    return fn


def freeze_deployed(spec, params):
    """Apply the deployment (FCC/INT8) grid eagerly and pre-transpose
    conv/fc weights; returns numpy param dicts."""
    frozen = []
    for i, (layer, p) in enumerate(zip(spec, params)):
        q = {k: jax.device_get(v) for k, v in p.items()}
        if "w" in q:
            w_dep = np.asarray(deploy_weight_tf(i, layer, jnp.asarray(q["w"])))
            q["w"] = w_dep
            if layer["kind"] in ("conv", "pwconv", "fc"):
                q["wt"] = np.ascontiguousarray(w_dep.T)
        frozen.append(q)
    return frozen


def build_param_model(spec, params):
    """AOT export form: weights as *parameters*, not constants.

    xla_extension 0.5.1 executes ``dot(param, dense_constant)`` HLO text
    as zeros (param-param dots are fine), so the deployed model is
    lowered as ``fn(x, *weights)`` and the rust runtime streams the
    weights in from the ``model_weights.bin`` sidecar at execute time.

    Returns ``(fn, arrays)``: the traced function and the deployment
    weight arrays (f32, call order).
    """
    frozen = freeze_deployed(spec, params)
    arrays, layout = [], []
    for layer, q in zip(spec, frozen):
        entry = []
        if "w" in q:
            if layer["kind"] in ("conv", "pwconv", "fc"):
                arrays.append(np.asarray(q["wt"], np.float32))
                entry.append("wt")
            else:
                arrays.append(np.asarray(q["w"], np.float32))
                entry.append("w")
            arrays.append(np.asarray(q["b"], np.float32))
            entry.append("b")
        layout.append(entry)

    def fn(x, *ws):
        ps, k = [], 0
        for entry in layout:
            d = {}
            for key in entry:
                d[key] = ws[k]
                k += 1
            if "wt" in d:
                d["w"] = d["wt"]  # placeholder; the patches path uses wt
            ps.append(d)
        return forward(spec, ps, x, conv_impl="patches")

    return fn, arrays


# ---------------------------------------------------------------- kernels


def fcc_mvm_entry(x, w_even, m):
    """Standalone FCC-MVM entry (kernel artifact)."""
    return fcc_mvm(x, w_even, m)


def pim_mac_entry(x, w):
    """Standalone bit-serial PIM-MAC entry (kernel artifact)."""
    return pim_mac(x, w)
