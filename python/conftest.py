"""Pytest root conftest for the L1/L2 build-time layer.

Ensures ``python/`` is importable as the package root (tests import
``compile.*``) regardless of how pytest is invoked (``pytest
python/tests`` from the repo root, or ``python -m pytest tests`` from
``python/``).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
