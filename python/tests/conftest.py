"""Optional-dependency gating for the test suite.

The CI runner installs only ``numpy`` + ``pytest``; jax, hypothesis and
torch are optional extras of the training/AOT path.  Any test module
whose hard imports are absent is skipped at collection time instead of
erroring, so ``pytest python/tests -q`` is green on a minimal
environment and exercises progressively more of the suite as extras are
installed.
"""

import importlib.util
import os
import sys

import pytest

# Make ``python/`` importable as the package root (tests import
# ``compile.*``) no matter where pytest is invoked from — this conftest
# always loads because it sits next to the tests, unlike the one at
# ``python/`` which pytest skips when rootdir lands below it.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _missing(*mods):
    return [m for m in mods if importlib.util.find_spec(m) is None]


# module -> hard (import-time) optional dependencies
_REQUIRES = {
    "test_aot_model.py": ("jax",),
    "test_fcc_core.py": ("jax", "hypothesis"),
    "test_kernels.py": ("jax", "hypothesis"),
    "test_models_train.py": ("jax",),
    "test_patches_conv.py": ("jax", "hypothesis"),
    "test_quant_qat.py": ("jax", "hypothesis"),
}

collect_ignore = [
    name for name, deps in _REQUIRES.items() if _missing(*deps)
]


def pytest_collection_modifyitems(config, items):
    """Honor explicit markers too: @pytest.mark.jax / .torch /
    .hypothesis skip when the package is absent."""
    for pkg in ("jax", "torch", "hypothesis"):
        if not _missing(pkg):
            continue
        skip = pytest.mark.skip(reason=f"optional dependency {pkg!r} not installed")
        for item in items:
            if pkg in item.keywords:
                item.add_marker(skip)


def pytest_configure(config):
    for pkg in ("jax", "torch", "hypothesis"):
        config.addinivalue_line(
            "markers", f"{pkg}: test requires the optional {pkg} package"
        )
