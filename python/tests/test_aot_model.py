"""AOT export-path consistency: the parameterized model (weights as
HLO parameters, the form the rust runtime executes) must agree with the
constant-baked deployment forward, and the weights sidecar layout must
be reconstructible."""

import jax.numpy as jnp
import numpy as np

from compile.model import (
    build_forward,
    build_param_model,
    freeze_deployed,
    load_or_init,
)


class TestParamModel:
    def test_param_model_matches_constant_model(self):
        spec, params = load_or_init(None)  # deterministic random init
        fwd_const = build_forward(spec, params)
        fwd_param, arrays = build_param_model(spec, params)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (2, 32, 32, 3)), jnp.float32)
        a = np.asarray(fwd_const(x))
        b = np.asarray(fwd_param(x, *[jnp.asarray(w) for w in arrays]))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_weight_arrays_cover_all_layers(self):
        spec, params = load_or_init(None)
        _, arrays = build_param_model(spec, params)
        weighted_layers = sum(1 for p in params if "w" in p)
        assert len(arrays) == 2 * weighted_layers  # w + b per layer

    def test_conv_weights_pre_transposed(self):
        spec, params = load_or_init(None)
        frozen = freeze_deployed(spec, params)
        for layer, q in zip(spec, frozen):
            if layer["kind"] in ("conv", "pwconv", "fc"):
                assert q["wt"].shape == (q["w"].shape[1], q["w"].shape[0])
                np.testing.assert_array_equal(q["wt"], q["w"].T)

    def test_deployed_weights_on_fcc_grid(self):
        from compile.fcc.core import is_bitwise_complementary
        from compile.fcc.qat import fcc_export

        spec, params = load_or_init(None)
        frozen = freeze_deployed(spec, params)
        for layer, q in zip(spec, frozen):
            if layer["kind"] in ("conv", "pwconv") and layer["cout"] % 2 == 0:
                wc, m, scale = fcc_export(jnp.asarray(q["w"]))
                assert is_bitwise_complementary(wc)
                break
