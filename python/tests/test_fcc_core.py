"""FCC core invariants: Alg. 1 / Alg. 2 postconditions and the
decomposition identities (Eqs. 1-5, 7) — including hypothesis sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.fcc import core


def rand_filters(n, l, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, (n, l)), jnp.float32)


class TestSymmetrize:
    def test_eq1_holds(self):
        w = rand_filters(8, 18, seed=1)
        ws, m = core.symmetrize(w)
        assert core.is_symmetric(ws, m)

    def test_mean_preserved(self):
        # M is computed from the ORIGINAL pair; mirrored pairs share it.
        w = rand_filters(4, 9, seed=2)
        _, m = core.symmetrize(w)
        assert m.shape == (2,)

    def test_keeps_farther_twin(self):
        # the twin farther from M must be kept verbatim
        w = jnp.asarray([[-1.5, 0.0], [6.5, 2.0]], jnp.float32)
        ws, m = core.symmetrize(w)
        f0, f1 = np.asarray(ws[0]), np.asarray(ws[1])
        orig = np.asarray(w)
        for i in range(2):
            kept = f0[i] == orig[0, i] or f1[i] == orig[1, i]
            assert kept

    def test_paper_example(self):
        # Fig. 4: M0 = 1.0, w00 = -1.5, w01 = 6.5 -> w00^s = -4.5, w01^s = 6.5
        w = jnp.asarray([[-1.5], [6.5]], jnp.float32)
        ws, m = core.symmetrize(w)
        assert float(m[0]) == pytest.approx(2.5)  # mean of just these two
        # with L=1 the pair mean is (w00+w01)/2; the farther twin (6.5) is
        # kept and -1.5 is replaced by 2M - 6.5
        assert float(ws[1, 0]) == pytest.approx(6.5)
        assert float(ws[0, 0]) == pytest.approx(2 * 2.5 - 6.5)

    def test_odd_filters_rejected(self):
        with pytest.raises(ValueError):
            core.symmetrize(rand_filters(3, 4))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([2, 4, 6]),
        l=st.integers(1, 30),
        seed=st.integers(0, 1000),
    )
    def test_eq1_property(self, n, l, seed):
        w = rand_filters(n, l, seed=seed, scale=3.0)
        ws, m = core.symmetrize(w)
        assert core.is_symmetric(ws, m, atol=1e-4)


class TestSymmetrizeInt:
    def test_eq1_int(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.integers(-127, 128, (8, 16)), jnp.int32)
        ws, m = core.symmetrize_int(w)
        f0 = np.asarray(ws)[0::2]
        f1 = np.asarray(ws)[1::2]
        mm = np.asarray(m)[:, None]
        assert np.all(f0 - mm == -(f1 - mm))

    def test_range_safe(self):
        # extreme values must stay in int8 range even after the later -1
        w = jnp.asarray([[127, -128, 127], [-128, 127, -128]], jnp.int32)
        ws, m = core.symmetrize_int(w)
        wbc = core.complementize(ws)
        assert int(jnp.min(wbc)) >= core.INT8_MIN
        assert int(jnp.max(wbc)) <= core.INT8_MAX

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 500), l=st.integers(1, 25))
    def test_int_property(self, seed, l):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.integers(-128, 128, (4, l)), jnp.int32)
        ws, m = core.symmetrize_int(w)
        wbc = core.complementize(ws)
        assert core.is_biased_complementary(wbc, m)
        assert int(jnp.min(wbc)) >= core.INT8_MIN
        assert int(jnp.max(wbc)) <= core.INT8_MAX


class TestComplementize:
    def test_eq3(self):
        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.integers(-100, 100, (6, 9)), jnp.int32)
        ws, m = core.symmetrize_int(w)
        wbc = core.complementize(ws)
        assert core.is_biased_complementary(wbc, m)

    def test_paper_example(self):
        # Fig. 4: after quant+sym: w00^s=-4, w01^s=6, M=1
        # complementize: smaller twin -1 -> w00^bc=-5, w01^bc=6
        ws = jnp.asarray([[-4], [6]], jnp.int32)
        wbc = core.complementize(ws)
        assert int(wbc[0, 0]) == -5
        assert int(wbc[1, 0]) == 6


class TestDecompose:
    def test_paper_example(self):
        # Fig. 9: w00^bc=-5, w01^bc=6, M=1 -> w00^c=-6 (0b11111010),
        # w01^c=5 (0b00000101) — exact bitwise complements
        wbc = jnp.asarray([[-5], [6]], jnp.int32)
        m = jnp.asarray([1], jnp.int32)
        wc = core.decompose(wbc, m)
        assert int(wc[0, 0]) == -6
        assert int(wc[1, 0]) == 5
        assert (int(wc[0, 0]) & 0xFF) == 0b11111010
        assert (int(wc[1, 0]) & 0xFF) == 0b00000101
        assert core.is_bitwise_complementary(wc)

    def test_roundtrip(self):
        rng = np.random.default_rng(5)
        w = jnp.asarray(rng.integers(-128, 128, (8, 27)), jnp.int32)
        ws, m = core.symmetrize_int(w)
        wbc = core.complementize(ws)
        wc = core.decompose(wbc, m)
        assert core.is_bitwise_complementary(wc)
        back = core.recompose(wc, m)
        assert bool(jnp.all(back == wbc))

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.sampled_from([2, 4, 8]),
           l=st.integers(1, 40))
    def test_bitwise_complement_property(self, seed, n, l):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, 1, (n, l)), jnp.float32)
        wbc, m = core.fcc_quantize(w, float(jnp.abs(w).max()) / 127 + 1e-9)
        wc = core.decompose(wbc, m)
        # Eq. 2: exact two's-complement bitwise complement per twin
        f0, f1 = np.asarray(wc)[0::2], np.asarray(wc)[1::2]
        assert np.all(f0 == ~f1)


class TestFccQuantize:
    def test_int8_range(self):
        rng = np.random.default_rng(6)
        w = jnp.asarray(rng.normal(0, 2, (16, 9)), jnp.float32)
        wbc, m = core.fcc_quantize(w, 2.0 / 127)
        assert int(jnp.min(wbc)) >= core.INT8_MIN
        assert int(jnp.max(wbc)) <= core.INT8_MAX

    def test_only_half_needed(self):
        # storing even comp filters + M reconstructs the odd ones exactly
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.normal(0, 1, (8, 12)), jnp.float32)
        wbc, m = core.fcc_quantize(w, 1.0 / 64)
        wc = core.decompose(wbc, m)
        even = np.asarray(wc)[0::2]
        odd_reconstructed = ~even
        assert np.all(odd_reconstructed == np.asarray(wc)[1::2])
