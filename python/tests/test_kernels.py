"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Integer outputs must match bit-exactly; hypothesis sweeps shapes and
value ranges.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.fcc.core import decompose, fcc_quantize
from compile.kernels import fcc_mvm, pim_mac
from compile.kernels.ref import bit_serial_ref, fcc_mvm_ref, mvm_int8_ref


def rand_int8(rng, shape, lo=-128, hi=128):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int32)


class TestBitSerialRef:
    """The bit-level oracle itself must equal the dense matmul — this
    validates the shift-&-add weighting (MSB negative) before we trust it
    as a reference."""

    def test_matches_dense(self):
        rng = np.random.default_rng(0)
        x = rand_int8(rng, (4, 16))
        w = rand_int8(rng, (16, 8))
        assert np.array_equal(bit_serial_ref(x, w), mvm_int8_ref(x, w))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 300), b=st.integers(1, 5), l=st.integers(1, 20),
           n=st.integers(1, 10))
    def test_matches_dense_property(self, seed, b, l, n):
        rng = np.random.default_rng(seed)
        x = rand_int8(rng, (b, l))
        w = rand_int8(rng, (l, n))
        assert np.array_equal(bit_serial_ref(x, w), mvm_int8_ref(x, w))


class TestPimMac:
    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        x = rand_int8(rng, (4, 32))
        w = rand_int8(rng, (32, 64))
        out = pim_mac(x, w, tile_n=32)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(mvm_int8_ref(x, w)))

    def test_single_tile(self):
        rng = np.random.default_rng(2)
        x = rand_int8(rng, (2, 8))
        w = rand_int8(rng, (8, 16))
        out = pim_mac(x, w, tile_n=16)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(mvm_int8_ref(x, w)))

    def test_extremes(self):
        # full-scale int8 corners exercise the MSB-negative path
        x = jnp.asarray([[-128, 127], [127, -128]], jnp.int32)
        w = jnp.asarray([[-128, 127, 1, 0], [127, -128, 0, 1]], jnp.int32)
        out = pim_mac(x, w, tile_n=4)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(mvm_int8_ref(x, w)))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 200), b=st.integers(1, 4),
           l=st.sampled_from([4, 9, 16]), tiles=st.integers(1, 3))
    def test_property(self, seed, b, l, tiles):
        rng = np.random.default_rng(seed)
        n = 8 * tiles
        x = rand_int8(rng, (b, l))
        w = rand_int8(rng, (l, n))
        out = pim_mac(x, w, tile_n=8)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(mvm_int8_ref(x, w)))


class TestFccMvm:
    def _setup(self, seed, b, l, n):
        rng = np.random.default_rng(seed)
        x = rand_int8(rng, (b, l))
        w_raw = jnp.asarray(rng.normal(0, 1, (n, l)), jnp.float32)
        wbc, m = fcc_quantize(w_raw, 1.0 / 100)
        wc = decompose(wbc, m)
        w_even = jnp.asarray(np.asarray(wc)[0::2].T)  # [L, N/2]
        return x, w_even, m, wc

    def test_matches_ref(self):
        x, w_even, m, _ = self._setup(3, 8, 36, 32)
        out = fcc_mvm(x, w_even, m, tile_h=16)
        ref = fcc_mvm_ref(x, w_even, m)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_recovery_equals_full_conv(self):
        """End-to-end FCC identity: the recovered interleaved outputs must
        equal the dense MVM against the FULL biased-comp filter bank
        (Eq. 7) — both twins, even though only half was stored."""
        x, w_even, m, wc = self._setup(4, 4, 18, 8)
        out = fcc_mvm(x, w_even, m, tile_h=4)
        w_bc_full = np.asarray(wc).T + np.repeat(np.asarray(m), 2)[None, :]
        ref = mvm_int8_ref(x, jnp.asarray(w_bc_full))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 200), b=st.integers(1, 4),
           l=st.sampled_from([9, 16, 27]), pairs=st.sampled_from([4, 8]))
    def test_property(self, seed, b, l, pairs):
        x, w_even, m, _ = self._setup(seed, b, l, 2 * pairs)
        out = fcc_mvm(x, w_even, m, tile_h=pairs)
        ref = fcc_mvm_ref(x, w_even, m)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_zero_input(self):
        x = jnp.zeros((2, 9), jnp.int32)
        _, w_even, m, _ = self._setup(9, 2, 9, 8)
        out = fcc_mvm(x, w_even, m, tile_h=4)
        assert np.all(np.asarray(out) == 0)
