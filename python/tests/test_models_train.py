"""Model zoo shape checks and a smoke training run (quick config)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.fcc.data import make_dataset
from compile.fcc.models import (
    MODELS,
    conv_layer_indices,
    fc_layer_indices,
    forward,
    init_params,
    param_counts,
)
from compile.fcc.train import scope_layers, train_model


@pytest.fixture(scope="module")
def tiny_data():
    return make_dataset(num_classes=10, train_per_class=8, test_per_class=4,
                        seed=11)


class TestModels:
    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_forward_shape(self, name):
        spec = MODELS[name](10)
        params = init_params(spec, seed=0)
        x = jnp.zeros((2, 32, 32, 3), jnp.float32)
        out = forward(spec, params, x)
        assert out.shape == (2, 10)

    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_conv_layers_pairable(self, name):
        spec = MODELS[name](10)
        for i in conv_layer_indices(spec):
            assert spec[i]["cout"] % 2 == 0

    def test_fc_ratio_ordering(self):
        """Paper Table III: AlexNet/VGG19 are FC-heavy, the compact NNs and
        ResNet18 are not — the ordering must hold for our scaled zoo."""
        ratios = {}
        for name in MODELS:
            conv_n, fc_n, total = param_counts(MODELS[name](10))
            ratios[name] = fc_n / total
        assert ratios["alexnet"] > 0.5
        assert ratios["vgg19"] > 0.3
        assert ratios["mobilenet_v2"] < 0.1
        assert ratios["resnet18"] < 0.1

    def test_scope_selection(self):
        spec = MODELS["mobilenet_v2"](10)
        all_layers = scope_layers(spec, 0)
        some = scope_layers(spec, 32)
        none = scope_layers(spec, None)
        assert none == set()
        assert some.issubset(all_layers)
        assert len(some) < len(all_layers)

    def test_dataset_learnable_labels(self, tiny_data):
        x_tr, y_tr, x_te, y_te = tiny_data
        assert x_tr.shape[1:] == (32, 32, 3)
        assert set(np.unique(y_tr)) == set(range(10))


class TestTrainSmoke:
    def test_quick_train_runs(self, tiny_data):
        r = train_model(
            "mobilenet_v2",
            fcc_conv=True,
            data=tiny_data,
            steps_pre=4,
            steps_qat=2,
            batch=16,
        )
        assert 0.0 <= r["acc"] <= 100.0
        assert r["fcc_param_ratio"] > 50.0  # conv dominates MobileNetV2

    def test_fcc_weights_on_grid_after_training(self, tiny_data):
        from compile.fcc.qat import fcc_export
        from compile.fcc.core import is_bitwise_complementary

        r = train_model(
            "mobilenet_v2",
            fcc_conv=True,
            data=tiny_data,
            steps_pre=4,
            steps_qat=2,
            batch=16,
        )
        idx = conv_layer_indices(r["spec"])[0]
        wc, m, scale = fcc_export(r["params"][idx]["w"])
        assert is_bitwise_complementary(wc)
