"""Patches-based (im2col+dot) conv lowering vs lax conv.

The AOT export path cannot use `convolution` HLO ops (xla_extension
0.5.1 executes jax>=0.8 conv text as zeros), so convs are lowered as
patch extraction + dot.  At stride 1 the two implementations must agree
exactly (identical SAME padding); at stride 2 the padding anchor differs
by design (the patches form matches the rust mapper's im2col), so we
check shapes + the interior.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.fcc.models import (
    MODELS,
    conv2d_patches,
    dwconv2d_patches,
    forward,
    init_params,
)


def rand(rng, shape):
    return jnp.asarray(rng.normal(0, 1, shape), jnp.float32)


class TestPatchesConv:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), k=st.sampled_from([1, 3, 5]),
           c=st.integers(1, 6), n=st.integers(1, 8))
    def test_stride1_matches_lax(self, seed, k, c, n):
        import jax.lax as lax

        rng = np.random.default_rng(seed)
        x = rand(rng, (2, 8, 8, c))
        w = rand(rng, (n, k * k * c))
        got = conv2d_patches(x, w, k, n, 1)
        w4 = w.reshape(n, k, k, c).transpose(1, 2, 3, 0)
        want = lax.conv_general_dilated(
            x, w4, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), k=st.sampled_from([3, 5]),
           c=st.sampled_from([2, 4]))
    def test_dw_stride1_matches_lax(self, seed, k, c):
        import jax.lax as lax

        rng = np.random.default_rng(seed)
        x = rand(rng, (1, 6, 6, c))
        w = rand(rng, (c, k * k))
        got = dwconv2d_patches(x, w, k, 1)
        w4 = w.reshape(c, k, k, 1).transpose(1, 2, 3, 0)
        want = lax.conv_general_dilated(
            x, w4, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_stride2_shape(self):
        rng = np.random.default_rng(0)
        x = rand(rng, (1, 32, 32, 3))
        w = rand(rng, (8, 9 * 3))
        out = conv2d_patches(x, w, 3, 8, 2)
        assert out.shape == (1, 16, 16, 8)
        out = dwconv2d_patches(x, rand(rng, (3, 9)), 3, 2)
        assert out.shape == (1, 16, 16, 3)

    def test_full_model_forward_both_impls_close(self):
        # stride-2 edge anchoring differs slightly; logits must still be
        # highly correlated between the two lowerings
        spec = MODELS["mobilenet_v2"](10)
        params = init_params(spec, seed=0)
        rng = np.random.default_rng(1)
        x = rand(rng, (2, 32, 32, 3))
        a = np.asarray(forward(spec, params, x, conv_impl="lax"))
        b = np.asarray(forward(spec, params, x, conv_impl="patches"))
        assert a.shape == b.shape
        # stride-2 layers anchor their padding differently (patches form
        # matches the rust mapper); with random untrained weights the
        # boundary taps diverge, so require strong but not exact
        # agreement
        corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
        assert corr > 0.9, f"corr={corr}"
