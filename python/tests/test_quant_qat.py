"""Quantization, 2:4 pruning and FCC-QAT/export round trips."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.fcc.qat import fcc_export, fcc_import, fcc_quant_ste, quant_ste
from compile.fcc.quant import dequantize_int8, prune_2_4, quantize_int8, sparsity
from compile.fcc.core import is_bitwise_complementary


class TestQuant:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
        codes, scale = quantize_int8(w)
        back = dequantize_int8(codes, scale)
        assert float(jnp.max(jnp.abs(back - w))) <= float(scale) / 2 + 1e-6

    def test_codes_in_range(self):
        w = jnp.asarray([-10.0, 10.0, 0.0], jnp.float32)
        codes, _ = quantize_int8(w)
        assert int(codes.min()) >= -128 and int(codes.max()) <= 127

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 500), scale=st.floats(0.1, 10.0))
    def test_property(self, seed, scale):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(0, scale, (32,)), jnp.float32)
        codes, s = quantize_int8(w)
        assert int(jnp.max(jnp.abs(codes))) <= 127


class TestPrune24:
    def test_half_sparse(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32)
        p = prune_2_4(w)
        assert abs(sparsity(p) - 0.5) < 1e-6

    def test_keeps_largest(self):
        w = jnp.asarray([[1.0, -4.0, 0.5, 3.0]], jnp.float32)
        p = np.asarray(prune_2_4(w))
        assert p[0, 1] == -4.0 and p[0, 3] == 3.0
        assert p[0, 0] == 0.0 and p[0, 2] == 0.0

    def test_tail_kept(self):
        w = jnp.arange(6, dtype=jnp.float32) + 1.0
        p = np.asarray(prune_2_4(w))
        assert p[4] == 5.0 and p[5] == 6.0  # tail untouched

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 300), n=st.integers(4, 64))
    def test_sparsity_property(self, seed, n):
        rng = np.random.default_rng(seed)
        n4 = (n // 4) * 4
        w = jnp.asarray(rng.normal(0, 1, (n4,)), jnp.float32)
        assert abs(sparsity(prune_2_4(w)) - 0.5) < 1e-6


class TestSTE:
    def test_gradient_is_identity(self):
        w = jnp.asarray(np.random.default_rng(2).normal(0, 1, (4, 6)),
                        jnp.float32)
        g = jax.grad(lambda w: fcc_quant_ste(w).sum())(w)
        np.testing.assert_allclose(np.asarray(g), np.ones_like(g), atol=1e-6)
        g2 = jax.grad(lambda w: quant_ste(w).sum())(w)
        np.testing.assert_allclose(np.asarray(g2), np.ones_like(g2), atol=1e-6)

    def test_forward_is_quantized(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.normal(0, 1, (4, 9)), jnp.float32)
        out = fcc_quant_ste(w)
        # forward values live on the FCC INT8 grid: out/scale integral
        from compile.fcc.quant import quant_scale

        scale = quant_scale(w)
        codes = np.asarray(out / scale)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-3)


class TestExport:
    def test_export_bitwise_complementary(self):
        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.normal(0, 1, (8, 27)), jnp.float32)
        wc, m, scale = fcc_export(w)
        assert is_bitwise_complementary(wc)

    def test_import_matches_ste_forward(self):
        rng = np.random.default_rng(5)
        w = jnp.asarray(rng.normal(0, 1, (8, 18)), jnp.float32)
        wc, m, scale = fcc_export(w)
        back = fcc_import(wc, m, scale, w.shape)
        fwd = fcc_quant_ste(w)
        np.testing.assert_allclose(np.asarray(back), np.asarray(fwd), atol=1e-5)
