"""Reference-kernel golden semantics — numpy-only (no jax, no
hypothesis), so the minimal CI environment (`pip install numpy pytest`)
always has live tests and the checked-in golden file is validated on
every run.

The oracles under test are the pure-numpy restatements in
``tools/gen_ref_goldens.py`` of ``compile/kernels/ref.py``; the rust
``ReferenceBackend`` replays the same file from
``rust/tests/data/ref_kernel_goldens.json``.
"""

import json
import os

import numpy as np

from tools.gen_ref_goldens import OUT, fcc_mvm_ref, mvm_int8_ref

GOLDEN_PATH = os.path.normpath(OUT)


def load_goldens():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


class TestOracleSemantics:
    def test_mvm_matches_dense_int64(self):
        rng = np.random.default_rng(1)
        x = rng.integers(-128, 128, (3, 7)).astype(np.int32)
        w = rng.integers(-128, 128, (7, 5)).astype(np.int32)
        want = x.astype(np.int64) @ w.astype(np.int64)
        assert np.array_equal(mvm_int8_ref(x, w), want.astype(np.int32))

    def test_fcc_mvm_equals_dense_with_recomposed_bank(self):
        # Eq. 7: the half-stored recovery must equal a dense MVM with
        # the recomposed biased-comp bank [even+m, odd+m] interleaved,
        # where odd = bitwise complement = -even - 1.
        rng = np.random.default_rng(2)
        b, l, half = 4, 9, 3
        x = rng.integers(-128, 128, (b, l)).astype(np.int32)
        w_even = rng.integers(-128, 128, (l, half)).astype(np.int32)
        m = rng.integers(-20, 21, (half,)).astype(np.int32)
        got = fcc_mvm_ref(x, w_even, m)
        w_odd = -w_even - 1
        bank = np.empty((l, 2 * half), np.int64)
        bank[:, 0::2] = w_even.astype(np.int64) + m
        bank[:, 1::2] = w_odd.astype(np.int64) + m
        want = (x.astype(np.int64) @ bank).astype(np.int32)
        assert np.array_equal(got, want)

    def test_fcc_mvm_interleaves_even_odd(self):
        x = np.array([[1, 2]], np.int32)
        w_even = np.array([[3], [4]], np.int32)  # psum = 11, si = 3
        m = np.array([5], np.int32)
        out = fcc_mvm_ref(x, w_even, m)
        assert out.shape == (1, 2)
        assert out[0, 0] == 11 + 3 * 5  # even: psum + si*m
        assert out[0, 1] == 3 * 4 - 11  # odd: si*(m-1) - psum


class TestCheckedInGoldens:
    def test_file_exists_and_shapes_consistent(self):
        g = load_goldens()
        for key in ("pim_mac", "fcc_mvm"):
            assert key in g, f"golden {key} missing"
        p = g["pim_mac"]
        assert len(p["x"]) == p["b"] * p["l"]
        assert len(p["w"]) == p["l"] * p["n"]
        assert len(p["out"]) == p["b"] * p["n"]
        f = g["fcc_mvm"]
        assert len(f["x"]) == f["b"] * f["l"]
        assert len(f["w_even"]) == f["l"] * f["half"]
        assert len(f["m"]) == f["half"]
        assert len(f["out"]) == f["b"] * 2 * f["half"]

    def test_pim_mac_golden_semantics(self):
        p = load_goldens()["pim_mac"]
        x = np.array(p["x"], np.int32).reshape(p["b"], p["l"])
        w = np.array(p["w"], np.int32).reshape(p["l"], p["n"])
        assert mvm_int8_ref(x, w).ravel().tolist() == p["out"]

    def test_fcc_mvm_golden_semantics(self):
        f = load_goldens()["fcc_mvm"]
        x = np.array(f["x"], np.int32).reshape(f["b"], f["l"])
        w = np.array(f["w_even"], np.int32).reshape(f["l"], f["half"])
        m = np.array(f["m"], np.int32)
        assert fcc_mvm_ref(x, w, m).ravel().tolist() == f["out"]

    def test_values_fit_int8_operand_range(self):
        g = load_goldens()
        for key, fields in (("pim_mac", ("x", "w")), ("fcc_mvm", ("x", "w_even", "m"))):
            for field in fields:
                vals = g[key][field]
                assert all(-128 <= v <= 127 for v in vals), f"{key}.{field} out of int8 range"
