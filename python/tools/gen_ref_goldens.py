"""Generate rust/tests/data/ref_kernel_goldens.json — golden vectors for
the L1 kernel oracles of ``compile/kernels/ref.py``, replayed by the rust
``ReferenceBackend`` integration tests.

The math here is a pure-numpy restatement of the jnp oracles (int32
matmul for ``mvm_int8_ref``/``pim_mac``; the Eq. 7 ARU recovery for
``fcc_mvm_ref``) so the goldens pin the *python reference semantics*
without requiring jax at generation time.  Deterministic: fixed seed.

Usage (from the repo root):

    python3 python/tools/gen_ref_goldens.py            # (re)generate
    python3 python/tools/gen_ref_goldens.py --check    # verify checked-in file

``--check`` validates the checked-in goldens *semantically* (recompute
the outputs from the stored inputs) rather than byte-comparing a fresh
generation — NumPy's NEP 19 allows Generator bit streams to change
across releases, so a byte gate would rot; the semantic gate cannot.
"""

import json
import os
import sys

import numpy as np

OUT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    "..", "..", "rust", "tests", "data", "ref_kernel_goldens.json",
)


def mvm_int8_ref(x, w):
    """x [B, L] int8-range, w [L, N] int8-range -> [B, N] int32."""
    return (x.astype(np.int64) @ w.astype(np.int64)).astype(np.int32)


def fcc_mvm_ref(x, w_even, m):
    """FCC MVM with ARU recovery (paper Eq. 7); see kernels/ref.py.

    x [B, L], w_even [L, N/2], m [N/2] -> [B, N] int32 interleaved
    (even, odd, even, ...).
    """
    x = x.astype(np.int64)
    w_even = w_even.astype(np.int64)
    m = m.astype(np.int64)
    psum = x @ w_even                      # [B, N/2]
    si = x.sum(axis=1, keepdims=True)      # [B, 1]
    out_even = psum + si * m[None, :]
    out_odd = si * (m[None, :] - 1) - psum
    b, half = psum.shape
    return (
        np.stack([out_even, out_odd], axis=2).reshape(b, 2 * half).astype(np.int32)
    )


def check(path):
    """Recompute every golden output from its stored inputs; exit 1 on
    any semantic mismatch."""
    with open(path) as f:
        g = json.load(f)
    p = g["pim_mac"]
    px = np.array(p["x"], np.int32).reshape(p["b"], p["l"])
    pw = np.array(p["w"], np.int32).reshape(p["l"], p["n"])
    assert mvm_int8_ref(px, pw).ravel().tolist() == p["out"], "pim_mac golden mismatch"
    fc = g["fcc_mvm"]
    fx = np.array(fc["x"], np.int32).reshape(fc["b"], fc["l"])
    fw = np.array(fc["w_even"], np.int32).reshape(fc["l"], fc["half"])
    fm = np.array(fc["m"], np.int32)
    assert fcc_mvm_ref(fx, fw, fm).ravel().tolist() == fc["out"], "fcc_mvm golden mismatch"
    print(f"checked {path}: goldens match the reference semantics")


def main():
    if "--check" in sys.argv[1:]:
        check(os.path.normpath(OUT))
        return
    rng = np.random.default_rng(20231031)  # the paper's arXiv date

    # ---- pim_mac golden: dense INT8 MVM ---------------------------------
    pb, pl, pn = 4, 16, 6
    px = rng.integers(-128, 128, (pb, pl)).astype(np.int32)
    pw = rng.integers(-128, 128, (pl, pn)).astype(np.int32)
    pout = mvm_int8_ref(px, pw)

    # ---- fcc_mvm golden: Eq. 7 recovery ---------------------------------
    fb, fl, fhalf = 3, 10, 4
    fx = rng.integers(-128, 128, (fb, fl)).astype(np.int32)
    # comp filters are int8 codes; means are small ints (pair means of
    # int8 filters always fit int8)
    fw_even = rng.integers(-128, 128, (fl, fhalf)).astype(np.int32)
    fm = rng.integers(-20, 21, (fhalf,)).astype(np.int32)
    fout = fcc_mvm_ref(fx, fw_even, fm)

    goldens = {
        "pim_mac": {
            "b": pb, "l": pl, "n": pn,
            "x": px.ravel().tolist(),
            "w": pw.ravel().tolist(),
            "out": pout.ravel().tolist(),
        },
        "fcc_mvm": {
            "b": fb, "l": fl, "half": fhalf,
            "x": fx.ravel().tolist(),
            "w_even": fw_even.ravel().tolist(),
            "m": fm.ravel().tolist(),
            "out": fout.ravel().tolist(),
        },
    }
    out_path = os.path.normpath(OUT)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(goldens, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
