//! Ablation benches for the design choices DESIGN.md calls out (beyond
//! the paper's own Fig. 13 ladder):
//!
//! * bit-sliced fabric compartment-count scaling (incl. the >64-lane
//!   multi-word geometries the density argument is about, each
//!   cross-checked against the scalar oracle before timing),
//! * DRAM prefetch on/off (exposed stalls),
//! * macro count scaling,
//! * weight-memory capacity sensitivity,
//! * batching policy for the serving path (latency/throughput trade).
//!
//! `--smoke` runs only the geometry sweep (CI's envelope smoke: the
//! scaled-up configs must execute — and agree with the oracle — on
//! every build).

use ddc_pim::arch::lpu::Mode;
use ddc_pim::arch::pim_core::MacroGeometry;
use ddc_pim::arch::pim_macro::{MvmScratch, PimMacro};
use ddc_pim::arch::reconfig::Grouping;
use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::coordinator::scheduler::{schedule, total_stall};
use ddc_pim::mapping::plan_network;
use ddc_pim::model::zoo;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::benchkit::{bench, report};
use ddc_pim::util::rng::Rng;

/// Row-step cost across macro compartment counts on the functional
/// bit-sliced fabric.  32/64 lanes pack into one plane word; 96/128
/// take the multi-word path (rejected outright before the multi-word
/// `WeightPlanes`).  Each geometry is proven bit-true against the
/// scalar oracle before it is timed.
fn fabric_geometry_sweep(iters: u32) {
    println!("== ablation: fabric compartment count (bit-true row-step) ==");
    let mut rng = Rng::new(5);
    for lanes in [32usize, 64, 96, 128] {
        let mut mac = PimMacro::with_geometry(MacroGeometry::with_compartments(lanes));
        for cmp in 0..lanes {
            for slot in 0..2 {
                mac.load_weight(cmp, 0, slot, rng.int8() as i32);
            }
        }
        let xs: Vec<i32> = (0..lanes).map(|_| rng.int8() as i32).collect();
        let mut scratch = MvmScratch::new();
        mac.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
        assert_eq!(
            scratch.to_vecs(),
            mac.mvm_row_scalar(0, &xs, &xs, Mode::Double, Grouping::Combined),
            "bitsliced row-step diverged from the scalar oracle at {lanes} lanes"
        );
        let r = bench(&format!("fabric.c{lanes}.mvm_row"), 10, iters, || {
            mac.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
            std::hint::black_box(scratch.psum(0, 0));
        });
        report(&format!("fabric.c{lanes}.ns_per_lane"), r.mean_ns / lanes as f64, "ns/lane");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    fabric_geometry_sweep(if smoke { 50 } else { 2000 });
    if smoke {
        println!("geometry smoke OK: multi-word envelope executes bit-true");
        return;
    }

    let net = zoo::mobilenet_v2();
    let sim = SimConfig::ddc_full();

    println!("== ablation: DRAM prefetch (scheduler stalls) ==");
    for bw in [1.0, 8.0, 64.0] {
        let mut arch = ArchConfig::ddc_pim();
        arch.dram_bytes_per_cycle = bw;
        let plans = plan_network(&net, &arch, &sim);
        let (slots, makespan) = schedule(&plans, &arch, 3072);
        report(
            &format!("prefetch.bw{bw}.stall_share"),
            100.0 * total_stall(&slots) as f64 / makespan as f64,
            "% of makespan",
        );
    }

    println!("\n== ablation: macro count ==");
    let base = simulate_network(&net, &ArchConfig::ddc_pim(), &sim).total_cycles;
    for macros in [1usize, 2, 4, 8, 16] {
        let mut arch = ArchConfig::ddc_pim();
        arch.macros = macros;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("macros.{macros}.speedup_vs_4"),
            base as f64 / run.total_cycles as f64,
            "x (dw-conv does not scale across macros: Y=1)",
        );
    }

    println!("\n== ablation: input-bit precision (bit-serial depth) ==");
    for bits in [4usize, 8, 16] {
        let mut arch = ArchConfig::ddc_pim();
        arch.input_bits = bits;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("input_bits.{bits}.cycles"),
            run.total_cycles as f64,
            "cycles (linear in bit-serial depth)",
        );
    }

    println!("\n== ablation: compartment rows (weight-reload pressure) ==");
    for rows in [16usize, 32, 64, 128] {
        let mut arch = ArchConfig::ddc_pim();
        arch.rows = rows;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("rows.{rows}.cycles"),
            run.total_cycles as f64,
            "cycles",
        );
    }
}
