//! Ablation benches for the design choices DESIGN.md calls out (beyond
//! the paper's own Fig. 13 ladder):
//!
//! * DRAM prefetch on/off (exposed stalls),
//! * macro count scaling,
//! * weight-memory capacity sensitivity,
//! * batching policy for the serving path (latency/throughput trade).

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::coordinator::scheduler::{schedule, total_stall};
use ddc_pim::mapping::plan_network;
use ddc_pim::model::zoo;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::benchkit::report;

fn main() {
    let net = zoo::mobilenet_v2();
    let sim = SimConfig::ddc_full();

    println!("== ablation: DRAM prefetch (scheduler stalls) ==");
    for bw in [1.0, 8.0, 64.0] {
        let mut arch = ArchConfig::ddc_pim();
        arch.dram_bytes_per_cycle = bw;
        let plans = plan_network(&net, &arch, &sim);
        let (slots, makespan) = schedule(&plans, &arch, 3072);
        report(
            &format!("prefetch.bw{bw}.stall_share"),
            100.0 * total_stall(&slots) as f64 / makespan as f64,
            "% of makespan",
        );
    }

    println!("\n== ablation: macro count ==");
    let base = simulate_network(&net, &ArchConfig::ddc_pim(), &sim).total_cycles;
    for macros in [1usize, 2, 4, 8, 16] {
        let mut arch = ArchConfig::ddc_pim();
        arch.macros = macros;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("macros.{macros}.speedup_vs_4"),
            base as f64 / run.total_cycles as f64,
            "x (dw-conv does not scale across macros: Y=1)",
        );
    }

    println!("\n== ablation: input-bit precision (bit-serial depth) ==");
    for bits in [4usize, 8, 16] {
        let mut arch = ArchConfig::ddc_pim();
        arch.input_bits = bits;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("input_bits.{bits}.cycles"),
            run.total_cycles as f64,
            "cycles (linear in bit-serial depth)",
        );
    }

    println!("\n== ablation: compartment rows (weight-reload pressure) ==");
    for rows in [16usize, 32, 64, 128] {
        let mut arch = ArchConfig::ddc_pim();
        arch.rows = rows;
        let run = simulate_network(&net, &arch, &sim);
        report(
            &format!("rows.{rows}.cycles"),
            run.total_cycles as f64,
            "cycles",
        );
    }
}
