//! Bench: Fig. 12 — implementation summary (area, peak, efficiency,
//! end-to-end MobileNetV2 latency) and macro area breakdown.

use ddc_pim::arch::cost::CostModel;
use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::model::zoo;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::benchkit::report;

fn main() {
    println!("== fig12: implementation summary ==");
    let cfg = ArchConfig::ddc_pim();
    let cost = CostModel::new(cfg.clone());
    report("system.area_mm2", cost.system_area_mm2(), "mm2 (paper 0.918)");
    report("system.peak_gops", cfg.peak_gops(), "GOPS (paper 42.67)");
    report(
        "macro.energy_eff",
        cost.energy_efficiency_tops_w(),
        "TOPS/W (paper 72.41)",
    );
    for (name, frac) in cost.macro_breakdown() {
        report(
            &format!("breakdown.{}", name.replace(' ', "_")),
            100.0 * frac,
            "% of macro area",
        );
    }
    let run = simulate_network(&zoo::mobilenet_v2(), &cfg, &SimConfig::ddc_full());
    report(
        "mobilenet_v2.latency_ms",
        run.latency_ms(),
        "ms CIFAR-scale (paper 20.97 ms ImageNet-scale)",
    );
    report(
        "mobilenet_v2.mvm_share",
        100.0 * run.mvm_cycles() as f64 / run.total_cycles as f64,
        "% (paper 18.02/20.97 = 85.9%)",
    );
    report("mobilenet_v2.achieved_gops", run.achieved_gops(), "GOPS");
    report(
        "mobilenet_v2.energy_eff",
        run.achieved_tops_per_w(),
        "TOPS/W (system incl. DRAM)",
    );
}
