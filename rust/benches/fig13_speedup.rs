//! Bench: Fig. 13 — speedup ablation ladder for MobileNetV2 and
//! EfficientNet-B0, plus the wall-clock cost of the cycle simulation
//! itself (the L3 hot path measured in §Perf).
//!
//! `--json BENCH_fig13.json` persists the ladder factors and simulator
//! timings for the bench trajectory (see `make bench`).

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::model::zoo;
use ddc_pim::report::fig13::ladder;
use ddc_pim::sim::simulate_network;
use ddc_pim::util::benchkit::BenchSession;

fn main() {
    let mut s = BenchSession::from_env("fig13");
    println!("== fig13: speedup ladder (paper: MNv2 2.841x, ENB0 2.694x) ==");
    for (model, paper) in [("mobilenet_v2", 2.841), ("efficientnet_b0", 2.694)] {
        let l = ladder(model);
        let (a, b, c, total) = l.factors();
        s.report(&format!("{model}.fcc_std_pw"), a, "x");
        s.report(&format!("{model}.fcc_dw_dbis"), b, "x");
        s.report(&format!("{model}.arch_reconfig"), c, "x");
        s.report(&format!("{model}.overall"), total, "x");
        s.report(&format!("{model}.paper"), paper, "x");
    }

    println!("\n== simulator wall-clock (L3 hot path) ==");
    let net = zoo::mobilenet_v2();
    let arch = ArchConfig::ddc_pim();
    let sim = SimConfig::ddc_full();
    s.bench("simulate.mobilenet_v2.ddc", 3, 50, || {
        std::hint::black_box(simulate_network(&net, &arch, &sim));
    });
    let base_arch = ArchConfig::baseline();
    let base_sim = SimConfig::baseline();
    s.bench("simulate.mobilenet_v2.baseline", 3, 50, || {
        std::hint::black_box(simulate_network(&net, &base_arch, &base_sim));
    });
    let enb0 = zoo::efficientnet_b0();
    s.bench("simulate.efficientnet_b0.ddc", 3, 50, || {
        std::hint::black_box(simulate_network(&enb0, &arch, &sim));
    });

    s.finish();
}
