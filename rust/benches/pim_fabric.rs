//! Bench: the bit-true fabric hot paths (functional macro executor) and
//! the runtime artifact path.  Not a paper table — this is the §Perf
//! instrumentation for the L3 hot loops.

use ddc_pim::arch::lpu::Mode;
use ddc_pim::arch::pim_macro::PimMacro;
use ddc_pim::arch::reconfig::Grouping;
use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::mapping::exec::exec_std_fcc;
use ddc_pim::util::benchkit::{bench, report};
use ddc_pim::util::rng::Rng;

fn main() {
    println!("== pim fabric hot paths ==");
    let mut rng = Rng::new(3);

    // single row-step (the innermost simulator unit: 8 bit cycles x 32
    // compartments x 16 columns)
    let mut mac = PimMacro::paper();
    let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
    for (cmp, &w) in ws.iter().enumerate() {
        mac.load_weight(cmp, 0, 0, w);
        mac.load_weight(cmp, 0, 1, !w);
    }
    let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
    let r = bench("mvm_row.double.combined", 10, 2000, || {
        std::hint::black_box(mac.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined));
    });
    // each row-step models 8 hardware cycles; how much faster than
    // real-time 333 MHz are we?
    let hw_ns = 8.0 * 3.0; // 8 cycles @ 3 ns
    report("mvm_row.vs_realtime", r.mean_ns / hw_ns, "x slower than silicon (bit-true model)");

    bench("mvm_row.regular.split", 10, 2000, || {
        std::hint::black_box(mac.mvm_row(0, &xs, &xs, Mode::Regular, Grouping::Split));
    });

    // a full small conv layer through the functional path
    let (h, w, c, k, n) = (6, 6, 8, 3, 8);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let bank = FilterBank::new(
        (0..n * k * k * c).map(|_| rng.int8() as i32).collect(),
        n,
        k * k * c,
    );
    let fcc = fcc_transform(&bank);
    bench("exec_std_fcc.6x6x8.k3.n8", 1, 10, || {
        std::hint::black_box(exec_std_fcc(&input, h, w, c, &fcc, k, 1));
    });

    // FCC transform itself (deployment path, MobileNetV2-layer-sized)
    let big = FilterBank::new(
        (0..320 * 960).map(|_| rng.int8() as i32).collect(),
        320,
        960,
    );
    bench("fcc_transform.320x960", 2, 50, || {
        std::hint::black_box(fcc_transform(&big));
    });
}
