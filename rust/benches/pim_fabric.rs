//! Bench: the bit-true fabric hot paths (functional macro executor) and
//! the runtime kernels.  Not a paper table — this is the §Perf
//! instrumentation for the L3 hot loops.
//!
//! The bitsliced `mvm_row` and the retained scalar oracle are measured
//! in the same run so the reported speedup compares like with like on
//! the same host; `--json BENCH_pim_fabric.json` persists the numbers
//! for the bench trajectory (see `make bench`).

use ddc_pim::arch::fault::{FaultConfig, FaultPlan};
use ddc_pim::arch::grid::{GridShape, MacroGrid};
use ddc_pim::arch::lpu::Mode;
use ddc_pim::arch::pim_core::{MacroGeometry, PimCore};
use ddc_pim::arch::pim_macro::{MvmScratch, PimMacro};
use ddc_pim::arch::reconfig::Grouping;
use ddc_pim::coordinator::{BatchPolicy, InferenceService, ServiceConfig, ServiceStats};
use ddc_pim::fcc::{fcc_transform, FilterBank};
use ddc_pim::mapping::exec::{exec_std_fcc, ExecCtx, ExecPool, PlannedConv};
use ddc_pim::mapping::ShardedConv;
use ddc_pim::runtime::reference::{mvm_i32, ReferenceBackend, StreamConfig, DEFAULT_SEED};
use ddc_pim::runtime::{BackendKind, BackendSpec, FabricChoice, Session, IMG_ELEMS, NUM_CLASSES};
use ddc_pim::util::benchkit::BenchSession;
use ddc_pim::util::rng::Rng;

fn main() {
    let mut s = BenchSession::from_env("pim_fabric");
    println!("== pim fabric hot paths ==");
    let mut rng = Rng::new(3);

    // single row-step (the innermost simulator unit: 8 bit cycles x 32
    // compartments x 16 columns)
    let mut mac = PimMacro::paper();
    let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
    for (cmp, &w) in ws.iter().enumerate() {
        mac.load_weight(cmp, 0, 0, w);
        mac.load_weight(cmp, 0, 1, !w);
    }
    let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
    let mut scratch = MvmScratch::new();

    let fast = s.bench("mvm_row.double.combined", 10, 2000, || {
        mac.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
        std::hint::black_box(scratch.psum(0, 0));
    });
    let slow = s.bench("mvm_row.double.combined.scalar_oracle", 10, 2000, || {
        std::hint::black_box(mac.mvm_row_scalar(0, &xs, &xs, Mode::Double, Grouping::Combined));
    });
    s.report(
        "mvm_row.double.combined.speedup_vs_scalar",
        slow.mean_ns / fast.mean_ns,
        "x",
    );
    // each row-step models 8 hardware cycles; how much faster than
    // real-time 333 MHz are we?
    let hw_ns = 8.0 * 3.0; // 8 cycles @ 3 ns
    s.report(
        "mvm_row.vs_realtime",
        fast.mean_ns / hw_ns,
        "x slower than silicon (bit-true model)",
    );

    let fast_split = s.bench("mvm_row.regular.split", 10, 2000, || {
        mac.mvm_row_into(0, &xs, &xs, Mode::Regular, Grouping::Split, &mut scratch);
        std::hint::black_box(scratch.psum(1, 0));
    });
    let slow_split = s.bench("mvm_row.regular.split.scalar_oracle", 10, 2000, || {
        std::hint::black_box(mac.mvm_row_scalar(0, &xs, &xs, Mode::Regular, Grouping::Split));
    });
    s.report(
        "mvm_row.regular.split.speedup_vs_scalar",
        slow_split.mean_ns / fast_split.mean_ns,
        "x",
    );

    // dense-weight Regular-mode baseline for the sparse-weight case
    let dense_reg = s.bench("mvm_row.regular.combined", 10, 2000, || {
        mac.mvm_row_into(0, &xs, &[], Mode::Regular, Grouping::Combined, &mut scratch);
        std::hint::black_box(scratch.psum(0, 0));
    });

    // sparse stored weights, Q path: both slots hold {0, 1}, so 14 of
    // the 16 stored Q planes (kw 1..7 of each slot) are all-zero and
    // the nonzero summaries skip those adder-tree columns outright —
    // the ≥50%-zero-weight-plane workload of the acceptance criterion
    let mut sparse_q_mac = PimMacro::paper();
    for cmp in 0..32 {
        sparse_q_mac.load_weight(cmp, 0, 0, rng.below(2) as i32);
        sparse_q_mac.load_weight(cmp, 0, 1, rng.below(2) as i32);
    }
    let sparse_reg = s.bench("mvm_row.sparse_w.regular.combined", 10, 2000, || {
        sparse_q_mac.mvm_row_into(0, &xs, &[], Mode::Regular, Grouping::Combined, &mut scratch);
        std::hint::black_box(scratch.psum(0, 0));
    });
    s.report(
        "mvm_row.sparse_w.regular.speedup_vs_dense",
        dense_reg.mean_ns / sparse_reg.mean_ns,
        "x (14/16 Q planes dark)",
    );

    // polarity-split sparsity, Double mode: slot 0 holds {0, 1} (Q
    // planes kw 1..7 dark), slot 1 holds {-1, -2} (Q̄ planes kw 1..7
    // dark) — each polarity skips 7/8 of one slot's columns, proving
    // the skip is tracked per polarity, not just on Q
    let mut sparse_mixed_mac = PimMacro::paper();
    for cmp in 0..32 {
        sparse_mixed_mac.load_weight(cmp, 0, 0, rng.below(2) as i32);
        sparse_mixed_mac.load_weight(cmp, 0, 1, -1 - rng.below(2) as i32);
    }
    let sparse_dbl = s.bench("mvm_row.sparse_w.double.combined", 10, 2000, || {
        sparse_mixed_mac
            .mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
        std::hint::black_box(scratch.psum(0, 0));
    });
    s.report(
        "mvm_row.sparse_w.double.speedup_vs_dense",
        fast.mean_ns / sparse_dbl.mean_ns,
        "x (7/8 planes dark per polarity per slot)",
    );

    // scaled-up geometry: 128 compartments = 2 plane words per column
    // (hard-rejected before the multi-word WeightPlanes)
    let c128 = 128usize;
    let mut wide_mac = PimMacro::with_geometry(MacroGeometry::with_compartments(c128));
    for cmp in 0..c128 {
        for slot in 0..2 {
            wide_mac.load_weight(cmp, 0, slot, rng.int8() as i32);
        }
    }
    let wide_xs: Vec<i32> = (0..c128).map(|_| rng.int8() as i32).collect();
    let wide = s.bench("mvm_row.double.combined.c128", 10, 2000, || {
        wide_mac
            .mvm_row_into(0, &wide_xs, &wide_xs, Mode::Double, Grouping::Combined, &mut scratch);
        std::hint::black_box(scratch.psum(0, 0));
    });
    s.report(
        "mvm_row.c128_vs_c32.cost_ratio",
        wide.mean_ns / fast.mean_ns,
        "x time for 4x lanes",
    );

    // a full small conv layer through the functional path
    let (h, w, c, k, n) = (6, 6, 8, 3, 8);
    let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
    let bank = FilterBank::new(
        (0..n * k * k * c).map(|_| rng.int8() as i32).collect(),
        n,
        k * k * c,
    );
    let fcc = fcc_transform(&bank);
    let one_shot = s.bench("exec_std_fcc.6x6x8.k3.n8", 1, 10, || {
        std::hint::black_box(exec_std_fcc(&input, h, w, c, &fcc, k, 1));
    });

    // the same layer on the plan/execute split: weights written once at
    // plan time, execute reuses one ExecCtx (the session hot path)
    let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
    let mut ctx = ExecCtx::new();
    let mut planned_out = vec![0i64; plan.out_len()];
    let planned = s.bench("planned_conv.execute.6x6x8.k3.n8", 1, 10, || {
        plan.execute(&input, &mut ctx, &mut planned_out);
        std::hint::black_box(planned_out[0]);
    });
    s.report(
        "planned_conv.execute.amortization_vs_one_shot",
        one_shot.mean_ns / planned.mean_ns,
        "x",
    );

    // the parallel executor on a layer with enough pixel blocks to
    // shard (256 pixels = 4 blocks): same resident weights, work units
    // stolen across pool lanes.  t1 runs the serial block walk, so the
    // t4 ratio is the host-parallel speedup on this machine.
    let (bh, bw, bc, bk, bn) = (18, 18, 8, 3, 8);
    let binput: Vec<i32> = (0..bh * bw * bc).map(|_| rng.int8() as i32).collect();
    let bbank = FilterBank::new(
        (0..bn * bk * bk * bc).map(|_| rng.int8() as i32).collect(),
        bn,
        bk * bk * bc,
    );
    let bfcc = fcc_transform(&bbank);
    let bplan = PlannedConv::std_fcc(bh, bw, bc, &bfcc, bk, 1);
    let mut bout = vec![0i64; bplan.out_len()];
    let mut pool1 = ExecPool::new(1);
    let par1 = s.bench("planned_conv.execute_par.t1.18x18x8.k3.n8", 1, 10, || {
        bplan.execute_par(&binput, &mut pool1, &mut bout);
        std::hint::black_box(bout[0]);
    });
    let mut pool4 = ExecPool::new(4);
    let par4 = s.bench("planned_conv.execute_par.t4.18x18x8.k3.n8", 1, 10, || {
        bplan.execute_par(&binput, &mut pool4, &mut bout);
        std::hint::black_box(bout[0]);
    });
    s.report(
        "planned_conv.execute_par.t4_speedup_vs_t1",
        par1.mean_ns / par4.mean_ns,
        "x",
    );

    // multi-macro grid: the same layer sharded across a 2x2 macro grid
    // (4 FCC pair-range shards, one stored pair each), executed on the
    // same 4-lane pool.  Outputs are byte-identical to the single-macro
    // plan; the ratio is the host-side cost of the shard scatter
    // (per-shard scratch + channel-slice copy) the grid adds.
    let sharded = ShardedConv::std_fcc(
        &MacroGrid::new(GridShape::new(2, 2), MacroGeometry::paper()),
        bh, bw, bc, &bfcc, bk, 1, None,
    );
    let mut shard_scratch: Vec<i64> = Vec::new();
    let mut sharded_out = vec![0i64; sharded.out_len()];
    let grid4 = s.bench("sharded_conv.execute_par.2x2.t4.18x18x8.k3.n8", 1, 10, || {
        sharded.execute_par(&binput, &mut pool4, &mut shard_scratch, &mut sharded_out);
        std::hint::black_box(sharded_out[0]);
    });
    s.report(
        "sharded_conv.2x2.overhead_vs_single_macro",
        grid4.mean_ns / par4.mean_ns,
        "x (scatter cost at equal host parallelism)",
    );

    // session batching: 8 images streamed through one resident weight
    // pass (batch folded into the pixel dimension), 4 pool lanes
    let batch = 8usize;
    let batch_in: Vec<i32> = (0..batch * bh * bw * bc).map(|_| rng.int8() as i32).collect();
    let mut batch_out = vec![0i64; batch * bplan.out_len()];
    let b8 = s.bench("planned_conv.execute_batch_par.b8.t4.18x18x8.k3.n8", 1, 10, || {
        bplan.execute_batch_par(&batch_in, batch, &mut pool4, &mut batch_out);
        std::hint::black_box(batch_out[0]);
    });
    s.report(
        "planned_conv.execute_batch_par.b8.amortization_vs_t1_serial",
        par1.mean_ns * batch as f64 / b8.mean_ns,
        "x",
    );

    // the dense runtime kernel (register-blocked 4-column unroll)
    let (mb, ml, mn) = (16, 128, 128);
    let mx: Vec<i32> = (0..mb * ml).map(|_| rng.int8() as i32).collect();
    let mw: Vec<i32> = (0..ml * mn).map(|_| rng.int8() as i32).collect();
    s.bench("mvm_i32.16x128x128", 3, 200, || {
        std::hint::black_box(mvm_i32(&mx, &mw, mb, ml, mn));
    });

    // FCC transform itself (deployment path, MobileNetV2-layer-sized)
    let big = FilterBank::new((0..320 * 960).map(|_| rng.int8() as i32).collect(), 320, 960);
    s.bench("fcc_transform.320x960", 2, 50, || {
        std::hint::black_box(fcc_transform(&big));
    });

    // weight streaming: the deep seeded net (stored conv footprints
    // [216, 2304, 4608, 4608] B) fully resident vs. under a 9300 B
    // capacity budget (2 reload passes, prefetch on).  The overhead
    // ratio is what the double-buffered stager fails to hide; the
    // CapacityPressure reports pin the pressure counters alongside it.
    let sbatch = 4usize;
    let simgs: Vec<f32> = (0..sbatch * IMG_ELEMS)
        .map(|_| rng.int8() as f32 / 127.0)
        .collect();
    let mut slogits = vec![0f32; sbatch * NUM_CLASSES];
    let mut resident = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .plan()
        .expect("resident plan");
    let res = s.bench("session.resident.deep4.b4", 1, 10, || {
        resident
            .infer_batch_into(&simgs, sbatch, &mut slogits)
            .expect("resident infer");
        std::hint::black_box(slogits[0]);
    });
    let mut streamed = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_streaming(StreamConfig::budget(9300))
        .plan()
        .expect("streamed plan");
    let strm = s.bench("session.streamed.p2.deep4.b4", 1, 10, || {
        streamed
            .infer_batch_into(&simgs, sbatch, &mut slogits)
            .expect("streamed infer");
        std::hint::black_box(slogits[0]);
    });
    s.report(
        "session.streamed.p2.overhead_vs_resident",
        strm.mean_ns / res.mean_ns,
        "x",
    );
    let pressure = streamed
        .capacity_pressure_stats()
        .expect("streamed session reports pressure");
    s.report(
        "session.streamed.p2.reloads",
        pressure.reloads as f64,
        "pass reloads (run total)",
    );
    s.report(
        "session.streamed.p2.prefetch_overlap",
        pressure.overlap_ratio(),
        "fraction of staging hidden",
    );
    s.report(
        "session.streamed.p2.peak_occupancy",
        pressure.peak_occupancy(),
        "of the 9300 B budget",
    );

    // serving-time scrub (PR 10): the same resident deep session with
    // the incremental scrub scheduler at full coverage — every stored
    // stripe re-verified at every batch boundary, the worst case a
    // server would configure.  The overhead ratio is the pure cost of
    // continuous verification on a clean fabric (no upsets, so no
    // repair work is mixed into the number).
    let mut scrubbed = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::BitSliced, 2)
        .with_scrub_stripes(usize::MAX)
        .plan()
        .expect("scrubbed plan");
    let scr = s.bench("session.scrubbed.full.deep4.b4", 1, 10, || {
        scrubbed
            .infer_batch_into(&simgs, sbatch, &mut slogits)
            .expect("scrubbed infer");
        std::hint::black_box(slogits[0]);
    });
    s.report(
        "session.scrubbed.full.overhead_vs_resident",
        scr.mean_ns / res.mean_ns,
        "x (full-coverage boundary scrub, clean fabric)",
    );
    let (scrub_checked, scrub_space) = scrubbed.scrub_progress();
    s.report(
        "session.scrubbed.full.stripes_per_boundary",
        scrub_space as f64,
        "stripes (resident scrub space)",
    );
    s.report(
        "session.scrubbed.full.stripes_checked",
        scrub_checked as f64,
        "stripe verifications (run total)",
    );

    // integrity scrub (PR 7): a seeded-faulted core at macro-like
    // geometry (32 compartments x 64 rows, BER 1e-3), weights written
    // into 48 rows with 16 left as repair spares.  The *cold* scrub —
    // paid once, after staging — detects the corrupted rows against the
    // Q/Q̄-complement checksums and re-homes them onto spares; it
    // mutates the core, so it is timed as a single pass and reported as
    // a value.  The `faulty.scrub` bench case is the steady-state sweep
    // a server would run periodically: re-verifying an already-clean
    // fabric (pure checksum walk, no mutation), so it can be iterated
    // in place.
    let fgeom = MacroGeometry {
        compartments: 32,
        rows: 64,
        dbmus: 16,
    };
    let fcfg = FaultConfig::new(0xDDC7, 0.001);
    let mut fcore = PimCore::with_geometry(fgeom);
    fcore.install_fault_plan(&FaultPlan::seeded(fgeom, &fcfg, 0));
    for cmp in 0..fgeom.compartments {
        for row in 0..48 {
            for slot in 0..fgeom.dbmus / 8 {
                fcore.write_weight(cmp, row, slot, rng.int8() as i32);
            }
        }
    }
    let t0 = std::time::Instant::now();
    let cold = fcore.scrub();
    let cold_ns = t0.elapsed().as_nanos() as f64;
    s.report("faulty.scrub.cold", cold_ns, "ns (one detect+repair pass)");
    s.report(
        "faulty.scrub.quarantined_rows",
        cold.quarantined_rows as f64,
        "rows (seed 0xDDC7, BER 1e-3)",
    );
    s.report("faulty.scrub.repaired_rows", cold.repaired_rows as f64, "rows");
    s.bench("faulty.scrub", 2, 200, || {
        std::hint::black_box(fcore.scrub().checked_words);
    });

    // the serving tier: a 24-request burst through the batching
    // dispatcher, 1 worker session vs 2.  Wall-clock per burst plus the
    // SLO percentiles the service books — the numbers `serve` reports,
    // pinned here against the bench trajectory.  One timed pass per
    // worker count: service startup (session prepare + sim model) would
    // otherwise dominate an iterated measurement.
    let burst = 24usize;
    let mut burst_rng = Rng::new(99);
    let burst_imgs: Vec<Vec<f32>> = (0..burst)
        .map(|_| (0..IMG_ELEMS).map(|_| burst_rng.normal() as f32).collect())
        .collect();
    let serve_burst = |workers: usize, scrub_stripes: u32| -> (f64, ServiceStats) {
        let svc = InferenceService::start_cluster(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                threads: 2,
                scrub_stripes,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
            },
            ServiceConfig {
                workers,
                max_queue_depth: 0,
            },
        );
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = burst_imgs.iter().map(|img| svc.submit(img.clone())).collect();
        for rx in rxs {
            rx.recv().expect("channel").expect("burst inference");
        }
        let elapsed_ns = t0.elapsed().as_nanos() as f64;
        (elapsed_ns, svc.stats().expect("stats"))
    };
    let (w1_ns, _) = serve_burst(1, 0);
    let (w2_ns, w2_stats) = serve_burst(2, 0);
    s.report("service.burst24.w1", w1_ns, "ns (1 worker, batch<=4)");
    s.report("service.burst24.w2", w2_ns, "ns (2 workers, batch<=4)");
    s.report("service.burst24.w2_speedup_vs_w1", w1_ns / w2_ns, "x");
    s.report(
        "service.burst24.w2.p50",
        w2_stats.p50().as_nanos() as f64,
        "ns (request latency, log-bucket upper edge)",
    );
    s.report(
        "service.burst24.w2.p95",
        w2_stats.p95().as_nanos() as f64,
        "ns",
    );
    s.report(
        "service.burst24.w2.p99",
        w2_stats.p99().as_nanos() as f64,
        "ns",
    );

    // the same 2-worker burst with full-coverage serving-time scrub on
    // every worker — `serving.scrubbed` vs the scrub-off burst above is
    // what the reliability runtime costs a clean serving tier
    let (w2s_ns, w2s_stats) = serve_burst(2, u32::MAX);
    s.report(
        "serving.scrubbed.burst24.w2",
        w2s_ns,
        "ns (2 workers, full boundary scrub)",
    );
    s.report(
        "serving.scrubbed.overhead_vs_scrub_off",
        w2s_ns / w2_ns,
        "x",
    );
    s.report(
        "serving.scrubbed.stripes_checked",
        w2s_stats.reliability.scrub_stripes_checked as f64,
        "stripe verifications (burst total, both workers)",
    );

    s.finish();
}
