//! Bench: Table II — macro density/efficiency metrics, recomputed from
//! the cost model, against the paper's published values.

use ddc_pim::arch::cost::CostModel;
use ddc_pim::config::ArchConfig;
use ddc_pim::report::table2::prior_works;
use ddc_pim::util::benchkit::report;

fn main() {
    println!("== table2: PIM macro comparison (ours vs paper constants) ==");
    let cost = CostModel::new(ArchConfig::ddc_pim());
    report("this_work.macro_area_mm2", cost.macro_area_mm2(), "mm2 (paper 0.0115)");
    report(
        "this_work.integration_density_28nm",
        cost.integration_density(true),
        "Kb/mm2 (paper 697)",
    );
    report(
        "this_work.weight_density_28nm",
        cost.weight_density(true),
        "Kb/mm2 (paper 1391)",
    );
    report(
        "this_work.area_efficiency_28nm",
        cost.area_efficiency(true),
        "GOPS/mm2 (paper 231.9)",
    );
    report(
        "this_work.energy_efficiency",
        cost.energy_efficiency_tops_w(),
        "TOPS/W (paper 72.41)",
    );

    let base = CostModel::new(ArchConfig::baseline());
    report(
        "baseline.integration_density_28nm",
        base.integration_density(true),
        "Kb/mm2 (ISSCC'22 [14]: 800)",
    );

    for p in prior_works() {
        report(
            &format!("prior.{}.weight_density_28nm", p.name.replace(' ', "_")),
            p.weight_density_28(),
            "Kb/mm2",
        );
    }
    let weakest_sram = prior_works()
        .iter()
        .filter(|p| p.device == "SRAM")
        .map(|p| p.weight_density_28())
        .fold(f64::MAX, f64::min);
    report(
        "improvement.weight_density_vs_weakest_sram",
        cost.weight_density(true) / weakest_sram,
        "x (paper: up to 8.41x)",
    );
    report(
        "improvement.area_eff_vs_isscc22",
        cost.area_efficiency(true) / 133.3,
        "x (paper: 1.74x / up to 2.75x vs weakest)",
    );
}
