//! Bench: Table III — FCC accuracy table.  The accuracy cells come from
//! the python training pass (artifacts/accuracy.json); this bench
//! re-derives the structural column (FC parameter ratios) from the
//! full-size shape books and prints the combined table.

use ddc_pim::model::zoo;
use ddc_pim::report::{table3, ReportCtx};
use ddc_pim::util::benchkit::report;

fn main() {
    println!("== table3: FCC accuracy across models ==");
    for (model, _) in table3::MODELS {
        let net = zoo::by_name(model).unwrap();
        report(
            &format!("{model}.fc_param_ratio"),
            net.fc_param_ratio(),
            "% of parameters in FC layers",
        );
        report(
            &format!("{model}.total_params"),
            net.total_params() as f64 / 1e6,
            "M weights (full-size book)",
        );
    }
    let ctx = ReportCtx::new("artifacts");
    println!("\n{}", table3::render(&ctx));
}
