//! Bench: Table IV — FCC + 2:4 pruning compression accounting (and the
//! accuracy table when the python pass has produced it), plus the rust
//! 2:4 pruning hot path.

use ddc_pim::quant::{prune_2_4, sparsity};
use ddc_pim::report::{table4, ReportCtx};
use ddc_pim::util::benchkit::{bench, report};
use ddc_pim::util::rng::Rng;

fn main() {
    println!("== table4: FCC + 2:4 pruning ==");
    report(
        "mobilenet_v2.fcc_prune_compression",
        100.0 * table4::fcc_prune_compression("mobilenet_v2"),
        "% (paper ~75%)",
    );
    report(
        "alexnet.fcc_prune_compression",
        100.0 * table4::fcc_prune_compression("alexnet"),
        "% (FC-heavy: less benefit)",
    );

    // hot path: pruning a full MobileNetV2-sized weight vector
    let mut rng = Rng::new(5);
    let weights: Vec<f32> = (0..2_300_000).map(|_| rng.normal() as f32).collect();
    bench("prune_2_4.mobilenet_sized", 2, 20, || {
        let mut w = weights.clone();
        prune_2_4(&mut w);
        std::hint::black_box(w);
    });
    let mut w = weights.clone();
    prune_2_4(&mut w);
    report("prune_2_4.sparsity", 100.0 * sparsity(&w), "% (target 50%)");

    let ctx = ReportCtx::new("artifacts");
    println!("\n{}", table4::render(&ctx));
}
