//! Bench: Table V — MobileViT-XS structural metrics + accuracy table,
//! and the simulated latency of the transformer-variant on DDC-PIM.

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::model::zoo;
use ddc_pim::report::{table5, ReportCtx};
use ddc_pim::sim::simulate_network;
use ddc_pim::util::benchkit::report;

fn main() {
    println!("== table5: MobileViT-XS ==");
    let net = zoo::mobilevit_xs();
    report(
        "mobilevit_xs.conv_param_share",
        100.0 * net.conv_params() as f64 / net.total_params() as f64,
        "% of parameters in conv layers (FCC-eligible)",
    );
    let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
    let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &SimConfig::ddc_full());
    report(
        "mobilevit_xs.speedup",
        base.total_cycles as f64 / ddc.total_cycles as f64,
        "x over PIM baseline (conv layers FCC'd, attention on FC path)",
    );
    report("mobilevit_xs.latency_ms", ddc.latency_ms(), "ms (DDC)");

    let ctx = ReportCtx::new("artifacts");
    println!("\n{}", table5::render(&ctx));
}
