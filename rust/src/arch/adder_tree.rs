//! Adder trees of the reconfigurable unit.
//!
//! Each adder tree accumulates the AND results of 16 compartments for
//! one bit position; an adder unit pairs two trees whose outputs are
//! either kept separate (two output channels) or combined (one channel
//! spanning 32 compartments) — paper §III-C2.

/// Sum `n` one-bit inputs (population count) — one tree evaluation.
pub fn tree_sum(bits: &[bool]) -> u32 {
    bits.iter().map(|&b| b as u32).sum()
}

/// Logic depth of a balanced binary adder tree over `n` inputs (used by
/// the cost model for the critical path).
pub fn tree_depth(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        (n as f64).log2().ceil() as u32
    }
}

/// One adder unit: two 16-input trees + the combining mux.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdderOut {
    /// Split: two independent partial sums (two output channels).
    Split(u32, u32),
    /// Combined: one partial sum over all 32 inputs (one channel).
    Combined(u32),
}

/// Evaluate an adder unit over the 32 compartment results for one bit
/// position.  `combine` selects the mux path.
pub fn adder_unit(lo16: &[bool], hi16: &[bool], combine: bool) -> AdderOut {
    debug_assert_eq!(lo16.len(), 16);
    debug_assert_eq!(hi16.len(), 16);
    let a = tree_sum(lo16);
    let b = tree_sum(hi16);
    if combine {
        AdderOut::Combined(a + b)
    } else {
        AdderOut::Split(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_bits(rng: &mut Rng, n: usize) -> Vec<bool> {
        (0..n).map(|_| rng.below(2) == 1).collect()
    }

    #[test]
    fn tree_sum_is_popcount() {
        assert_eq!(tree_sum(&[true, false, true, true]), 3);
        assert_eq!(tree_sum(&[]), 0);
    }

    #[test]
    fn depth_16() {
        assert_eq!(tree_depth(16), 4);
        assert_eq!(tree_depth(32), 5);
        assert_eq!(tree_depth(1), 0);
    }

    #[test]
    fn combined_equals_sum_of_split() {
        forall(
            41,
            200,
            |r| (rand_bits(r, 16), rand_bits(r, 16)),
            |(lo, hi)| {
                let split = adder_unit(lo, hi, false);
                let comb = adder_unit(lo, hi, true);
                match (split, comb) {
                    (AdderOut::Split(a, b), AdderOut::Combined(c)) => a + b == c,
                    _ => false,
                }
            },
        );
    }
}
