//! Compartment: 16 DBMUs + dual-broadcast input structure + readout DFFs
//! (Fig. 6(c)).
//!
//! A compartment stores `rows x 16` bits = `rows` wordlines of two 8-bit
//! weights each.  Per compute cycle one row activates and the DBIS
//! broadcasts one INP bit and one INN bit to all 16 LPUs; the readout
//! block latches 16 (regular) or 32 (double) AND results.

use super::dbmu::Dbmu;
use super::lpu::Mode;

/// Readout of one compartment compute cycle: per-column AND results for
/// the Q path and (double mode) the Q̄ path, latched by the 16 readout
/// DFFs — modelled as packed bitmasks (bit i = column i), which is both
/// the faithful circuit view and allocation-free on the simulation hot
/// path (§Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CompartmentOut {
    /// Left-path (Q AND INP) results, bit per column.
    pub q_mask: u16,
    /// Right-path (Q̄ AND INN) results (0 in regular mode).
    pub qbar_mask: u16,
}

impl CompartmentOut {
    pub fn q(&self, col: usize) -> bool {
        (self.q_mask >> col) & 1 == 1
    }

    pub fn qbar(&self, col: usize) -> bool {
        (self.qbar_mask >> col) & 1 == 1
    }
}

/// One compartment.
#[derive(Debug, Clone)]
pub struct Compartment {
    dbmus: Vec<Dbmu>,
    rows: usize,
}

impl Compartment {
    pub fn new(rows: usize, dbmus: usize) -> Self {
        Compartment {
            dbmus: (0..dbmus).map(|_| Dbmu::new(rows)).collect(),
            rows,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.dbmus.len()
    }

    /// Normal-SRAM-mode write of one full row (16 bits).
    pub fn write_row(&mut self, row: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.dbmus.len());
        for (c, &b) in bits.iter().enumerate() {
            self.dbmus[c].write(row, b);
        }
    }

    /// Write an 8-bit weight into weight slot `slot` (0 or 1) of `row`,
    /// LSB-first bit order (matches `SramArray::write_weight8`).
    pub fn write_weight8(&mut self, row: usize, slot: usize, w: i32) {
        for b in 0..8 {
            self.dbmus[slot * 8 + b].write(row, ((w as u32) >> b) & 1 == 1);
        }
    }

    /// Read back weight slot `slot` of `row` from the Q side.
    pub fn read_weight8(&self, row: usize, slot: usize) -> i32 {
        let mut v = 0u32;
        for b in 0..8 {
            if self.dbmus[slot * 8 + b].read_q(row) {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// One compute cycle: activate `row`, broadcast `(inp, inn)`.
    pub fn compute(&self, row: usize, inp: bool, inn: bool, mode: Mode) -> CompartmentOut {
        let mut out = CompartmentOut::default();
        for (c, d) in self.dbmus.iter().enumerate() {
            let o = d.compute(row, inp, inn, mode);
            out.q_mask |= (o.left as u16) << c;
            out.qbar_mask |= (o.right as u16) << c;
        }
        out
    }

    /// Weight slots per row (16 columns / 8 bits = 2).
    pub fn weight_slots(&self) -> usize {
        self.dbmus.len() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_roundtrip() {
        let mut c = Compartment::new(64, 16);
        c.write_weight8(5, 0, -6);
        c.write_weight8(5, 1, 77);
        assert_eq!(c.read_weight8(5, 0), -6);
        assert_eq!(c.read_weight8(5, 1), 77);
    }

    #[test]
    fn compute_regular_only_q_path() {
        let mut c = Compartment::new(4, 16);
        c.write_weight8(0, 0, 0b0101); // bits 0 and 2 set
        let o = c.compute(0, true, true, Mode::Regular);
        assert!(o.q(0) && !o.q(1) && o.q(2));
        assert_eq!(o.qbar_mask, 0);
    }

    #[test]
    fn compute_double_complementary_paths() {
        let mut c = Compartment::new(4, 16);
        c.write_weight8(1, 0, 0b0101);
        let o = c.compute(1, true, true, Mode::Double);
        // qbar path is the complement of the stored bits (INN = 1)
        for bit in 0..8 {
            assert_ne!(o.q(bit), o.qbar(bit));
        }
    }

    #[test]
    fn inp_inn_gate_paths_independently() {
        let mut c = Compartment::new(2, 16);
        c.write_weight8(0, 0, -1); // all Q bits set
        let o = c.compute(0, false, true, Mode::Double);
        assert_eq!(o.q_mask, 0); // INP = 0 kills left
        assert_eq!(o.qbar_mask & 0x00FF, 0); // Q̄ = 0 for -1
        // second slot holds 0 -> Q̄ = all ones there, INN = 1 passes
        assert_eq!(o.qbar_mask & 0xFF00, 0xFF00);
    }
}
