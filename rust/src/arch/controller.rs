//! Top controller: executes the assembled instruction stream (Fig. 5).
//!
//! This is the ISA-level twin of the plan-driven engine in
//! [`crate::sim::engine`]: it fetches words from instruction memory,
//! decodes them, charges cycles per opcode and tracks DRAM/merge state.
//! The two paths must agree on total busy cycles — a cross-check that
//! the ISA stream faithfully encodes the mapping plans (tested below and
//! in the integration suite).

use crate::isa::{Instr, Op};

/// Controller execution outcome.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ControllerStats {
    /// Busy cycles charged by LOADW/COMPUTE/MERGE.
    pub busy_cycles: u64,
    /// DRAM bytes requested by LOADW.
    pub dram_bytes: u64,
    /// Activation bytes moved by MOVE.
    pub move_bytes: u64,
    /// Layers completed (EndLayer markers seen).
    pub layers: u32,
    /// Instructions retired.
    pub retired: u64,
}

/// Decode + execute a full instruction stream.  Returns an error string
/// on an undecodable word or a stream that does not end with HALT.
pub fn execute(stream: &[u64]) -> Result<ControllerStats, String> {
    let mut st = ControllerStats::default();
    let mut halted = false;
    for (pc, &word) in stream.iter().enumerate() {
        if halted {
            return Err(format!("instruction after HALT at pc={pc}"));
        }
        let i = Instr::decode(word).ok_or_else(|| format!("bad word {word:#x} at pc={pc}"))?;
        st.retired += 1;
        match i.op {
            Op::Cfg => {}
            Op::LoadW => {
                st.busy_cycles += i.a as u64;
                st.dram_bytes += i.b as u64;
            }
            Op::Compute => {
                st.busy_cycles += i.b as u64;
            }
            Op::Merge => {
                st.busy_cycles += i.b as u64;
            }
            Op::Move => {
                st.move_bytes += i.b as u64;
            }
            Op::EndLayer => {
                st.layers += 1;
            }
            Op::Halt => {
                halted = true;
            }
        }
    }
    if !halted {
        return Err("stream missing HALT".into());
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, SimConfig};
    use crate::isa::assemble;
    use crate::mapping::plan_network;
    use crate::model::zoo;

    #[test]
    fn controller_agrees_with_plan_cycles() {
        let arch = ArchConfig::ddc_pim();
        let sim = SimConfig::ddc_full();
        let plans = plan_network(&zoo::mobilenet_v2(), &arch, &sim);
        let stream = assemble(&plans);
        let st = execute(&stream).expect("stream executes");
        let plan_busy: u64 = plans.iter().map(|p| p.pim_cycles()).sum();
        assert_eq!(st.busy_cycles, plan_busy, "ISA/plan cycle mismatch");
        assert_eq!(st.layers as usize, plans.len());
        let plan_dram: u64 = plans.iter().map(|p| p.dram_weight_bytes).sum();
        assert_eq!(st.dram_bytes, plan_dram);
    }

    #[test]
    fn rejects_missing_halt() {
        let arch = ArchConfig::ddc_pim();
        let plans = plan_network(&zoo::resnet18(), &arch, &SimConfig::baseline());
        let mut stream = assemble(&plans);
        stream.pop(); // drop HALT
        assert!(execute(&stream).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(execute(&[0u64]).is_err());
    }

    #[test]
    fn rejects_code_after_halt() {
        let halt = Instr {
            op: Op::Halt,
            mode: 0,
            a: 0,
            b: 0,
        }
        .encode();
        assert!(execute(&[halt, halt]).is_err());
    }
}
