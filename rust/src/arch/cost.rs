//! Area / power / energy model (Fig. 12, Table II).
//!
//! The paper's silicon numbers come from post-layout extraction (macro)
//! plus PCACTI (memories) plus DC/PTPX (digital).  Our substitution
//! (DESIGN.md §2) is an analytical model calibrated to the paper's
//! published constants: the macro breakdown fractions of Fig. 12(b), the
//! 0.0115 mm² 14 nm macro area, the 42.67 GOPS / 72.41 TOPS/W headline,
//! and the 0.918 mm² / 11.15 mW system.  Every derived metric in
//! Table II (densities, efficiencies, 28 nm normalization) is recomputed
//! from these constants, and ablation configs (baseline) scale the
//! model structurally (blocks that are absent cost nothing).

use crate::config::ArchConfig;

/// Fig. 12(b) macro area breakdown (fractions of the DDC macro).
pub const FRAC_PIM_BASE: f64 = 0.8652;
pub const FRAC_DFFS: f64 = 0.0524;
pub const FRAC_RECOVER: f64 = 0.0479;
pub const FRAC_ADDER: f64 = 0.0273;
pub const FRAC_OTHERS: f64 = 0.0072;

/// DDC-PIM macro area at 14 nm (paper Table II).
pub const MACRO_AREA_MM2_14NM: f64 = 0.0115;
/// Macro-level energy efficiency at 8b x 8b (paper Fig. 12 / Table II).
pub const MACRO_TOPS_PER_W: f64 = 72.41;
/// System total area / power (paper Fig. 12(a)).
pub const SYSTEM_AREA_MM2: f64 = 0.918;
pub const SYSTEM_POWER_MW: f64 = 11.15;
/// System-level energy efficiency (Fig. 12(a)).
pub const SYSTEM_TOPS_PER_W: f64 = 3.83;

/// Non-macro system area split (calibrated so the total matches the
/// paper's 0.918 mm²; PCACTI-style SRAM density at 14 nm).
pub const WEIGHT_MEM_AREA_MM2: f64 = 0.500; // 256 KB
pub const PINGPONG_AREA_MM2: f64 = 0.250; // 128 KB
pub const DIGITAL_AREA_MM2: f64 = 0.122; // controller + pre/post + merge

/// On-chip SRAM access energy (pJ/byte, 14 nm estimate).
pub const SRAM_PJ_PER_BYTE: f64 = 0.5;
/// Off-chip DRAM access energy (pJ/byte).
pub const DRAM_PJ_PER_BYTE: f64 = 20.0;

/// Area/energy model bound to an [`ArchConfig`].
#[derive(Debug, Clone)]
pub struct CostModel {
    pub cfg: ArchConfig,
}

impl CostModel {
    pub fn new(cfg: ArchConfig) -> Self {
        CostModel { cfg }
    }

    /// Area of one PIM macro (mm²) at the config's node.  Blocks that
    /// the ablation removes (DFFs for the Q̄ readout, the recover unit,
    /// the extra adder units) cost nothing when absent.
    pub fn macro_area_mm2(&self) -> f64 {
        // structural scale vs the paper's 32x64x16 geometry
        let cells = (self.cfg.compartments * self.cfg.rows * self.cfg.dbmus) as f64;
        let scale = cells / (32.0 * 64.0 * 16.0);
        let mut frac = FRAC_PIM_BASE + FRAC_OTHERS;
        if self.cfg.dbis {
            frac += FRAC_DFFS; // extra readout DFFs for the Q̄ results
            frac += FRAC_ADDER; // extra adder units in the reconfig unit
        }
        if self.cfg.recover {
            frac += FRAC_RECOVER; // ARU
        }
        MACRO_AREA_MM2_14NM * frac * scale * self.node_area_scale()
    }

    /// Area scale factor relative to 14 nm (quadratic in node).
    fn node_area_scale(&self) -> f64 {
        (self.cfg.node_nm / 14.0).powi(2)
    }

    /// Factor to normalize a density/efficiency metric to 28 nm
    /// (Table II's normalization divides area-derived metrics by
    /// `(28/node)²`).
    pub fn norm28_factor(&self) -> f64 {
        (28.0 / self.cfg.node_nm).powi(2)
    }

    /// Integration density: array size / macro area (Kb/mm²).
    pub fn integration_density(&self, norm28: bool) -> f64 {
        let d = self.cfg.macro_array_kb() / self.macro_area_mm2();
        if norm28 {
            d / self.norm28_factor()
        } else {
            d
        }
    }

    /// Weight density: weight capacity / macro area (Kb/mm²) — doubled
    /// capacity under DDC.
    pub fn weight_density(&self, norm28: bool) -> f64 {
        let d = self.cfg.macro_weight_capacity_kb() / self.macro_area_mm2();
        if norm28 {
            d / self.norm28_factor()
        } else {
            d
        }
    }

    /// Area efficiency: peak GOPS / total macro area (GOPS/mm²).
    pub fn area_efficiency(&self, norm28: bool) -> f64 {
        let total_macro_area = self.macro_area_mm2() * self.cfg.macros as f64;
        let e = self.cfg.peak_gops() / total_macro_area;
        if norm28 {
            e / self.norm28_factor()
        } else {
            e
        }
    }

    /// Macro-level energy efficiency (TOPS/W).  The ablated baseline
    /// loses the doubled parallelism but also the extra logic; the net
    /// (per [14], the PIM-base equivalent) lands at its published 27.38
    /// TOPS/W scaled to this node.
    pub fn energy_efficiency_tops_w(&self) -> f64 {
        if self.cfg.dbis && self.cfg.recover {
            MACRO_TOPS_PER_W * 14.0 / self.cfg.node_nm
        } else {
            // ISSCC'22 [14] baseline: 27.38 TOPS/W at 28 nm
            27.38 * 28.0 / self.cfg.node_nm
        }
    }

    /// Energy per 8b x 8b MAC in pJ (2 ops/MAC).
    pub fn mac_energy_pj(&self) -> f64 {
        2.0 / self.energy_efficiency_tops_w()
    }

    /// Total system area (mm²): macros + memories + digital.
    pub fn system_area_mm2(&self) -> f64 {
        self.macro_area_mm2() * self.cfg.macros as f64
            + (WEIGHT_MEM_AREA_MM2 * self.cfg.weight_mem_kb as f64 / 256.0
                + PINGPONG_AREA_MM2 * self.cfg.pingpong_kb as f64 / 128.0
                + DIGITAL_AREA_MM2)
                * self.node_area_scale()
    }

    /// Fig. 12(b): (name, fraction) area breakdown of the DDC macro.
    pub fn macro_breakdown(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("PIM-base", FRAC_PIM_BASE),
            ("DFFs", FRAC_DFFS),
            ("Recover Unit", FRAC_RECOVER),
            ("Adder Unit", FRAC_ADDER),
            ("Others", FRAC_OTHERS),
        ]
    }

    /// Energy of a simulated run (mJ) from its activity counts.
    pub fn run_energy_mj(
        &self,
        macs: u64,
        sram_bytes: u64,
        dram_bytes: u64,
    ) -> f64 {
        (macs as f64 * self.mac_energy_pj()
            + sram_bytes as f64 * SRAM_PJ_PER_BYTE
            + dram_bytes as f64 * DRAM_PJ_PER_BYTE)
            * 1e-9
    }

    /// Energy of one weight-reload pass (mJ): the bytes cross DRAM and
    /// are written into the weight SRAM once.  This is the marginal
    /// cost capacity pressure adds — every reload beyond the first
    /// residency pays it again, which is why the streaming planner
    /// packs as many layers per pass as the budget allows.
    pub fn reload_energy_mj(&self, bytes: u64) -> f64 {
        bytes as f64 * (DRAM_PJ_PER_BYTE + SRAM_PJ_PER_BYTE) * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ddc() -> CostModel {
        CostModel::new(ArchConfig::ddc_pim())
    }

    fn base() -> CostModel {
        CostModel::new(ArchConfig::baseline())
    }

    #[test]
    fn reload_energy_is_dram_plus_sram_write() {
        let c = ddc();
        // 1 KB reloaded: 1024 * (20.0 + 0.5) pJ = 20.992 nJ = 2.0992e-5 mJ
        let mj = c.reload_energy_mj(1024);
        assert!((mj - 1024.0 * 20.5 * 1e-9).abs() < 1e-15);
        // reloading is strictly more expensive than staying resident
        assert!(c.reload_energy_mj(4096) > c.reload_energy_mj(0));
    }

    #[test]
    fn breakdown_sums_to_one() {
        let s: f64 = ddc().macro_breakdown().iter().map(|(_, f)| f).sum();
        assert!((s - 1.0).abs() < 1e-9, "sum={s}");
    }

    #[test]
    fn macro_area_matches_paper() {
        assert!((ddc().macro_area_mm2() - 0.0115).abs() < 1e-6);
    }

    #[test]
    fn densities_match_table2() {
        let m = ddc();
        // Table II: 2783 Kb/mm² integration, 5565 Kb/mm² weight @ 14 nm
        assert!((m.integration_density(false) - 2783.0).abs() < 5.0,
                "{}", m.integration_density(false));
        assert!((m.weight_density(false) - 5565.0).abs() < 10.0);
        // normalized to 28 nm: 697 and 1391
        assert!((m.integration_density(true) - 696.0).abs() < 3.0);
        assert!((m.weight_density(true) - 1391.0).abs() < 5.0);
    }

    #[test]
    fn area_efficiency_matches_table2() {
        // 231.9 GOPS/mm² normalized to 28 nm
        let ae = ddc().area_efficiency(true);
        assert!((ae - 231.9).abs() < 2.0, "ae={ae}");
    }

    #[test]
    fn baseline_matches_isscc22_density() {
        // PIM-base alone should land near [14]'s 800 Kb/mm² @ 28 nm
        let d = base().integration_density(true);
        assert!((d - 800.0).abs() < 15.0, "d={d}");
        // baseline has no doubled capacity
        assert!((base().weight_density(true) - d).abs() < 1e-9);
    }

    #[test]
    fn weight_density_improvement_8_41x_vs_worst_prior() {
        // paper abstract: up to 8.41x weight density vs prior SRAM PIM —
        // the weakest prior in Table II is PIMCA at 165.4 Kb/mm²(28nm)
        let ratio = ddc().weight_density(true) / 165.4;
        assert!((ratio - 8.41).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn area_efficiency_improvement_vs_isscc22() {
        // paper §IV-C: ~1.74x over [14]'s 133.3 GOPS/mm²
        let ratio = ddc().area_efficiency(true) / 133.3;
        assert!((ratio - 1.74).abs() < 0.03, "ratio={ratio}");
    }

    #[test]
    fn system_area_matches_fig12() {
        let a = ddc().system_area_mm2();
        assert!((a - SYSTEM_AREA_MM2).abs() < 0.002, "a={a}");
    }

    #[test]
    fn mac_energy_positive_and_small() {
        let e = ddc().mac_energy_pj();
        assert!(e > 0.0 && e < 1.0, "e={e}");
        // baseline less efficient per op
        assert!(base().mac_energy_pj() > e);
    }
}
