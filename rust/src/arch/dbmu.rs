//! Double-Bitwise Multiply Unit: 64 6T cells sharing one LPU (Fig. 6(a)).
//!
//! A DBMU is one bit-column of a compartment: 64 stacked cells (SC#0–63)
//! whose selected row drives the shared LPU.  One row activates per cycle
//! (read-disturb rule), producing up to two AND results.

use super::lpu::{evaluate, LpuOut, Mode};
use super::sram::SramCell;

/// One DBMU column: 64 cells + the shared LPU.
#[derive(Debug, Clone)]
pub struct Dbmu {
    cells: Vec<SramCell>,
}

impl Dbmu {
    pub fn new(rows: usize) -> Self {
        Dbmu {
            cells: vec![SramCell::default(); rows],
        }
    }

    pub fn rows(&self) -> usize {
        self.cells.len()
    }

    pub fn write(&mut self, row: usize, bit: bool) {
        self.cells[row].write(bit);
    }

    pub fn read_q(&self, row: usize) -> bool {
        self.cells[row].q()
    }

    pub fn read_q_bar(&self, row: usize) -> bool {
        self.cells[row].q_bar()
    }

    /// One compute cycle: activate `row`, broadcast `(inp, inn)`, return
    /// the LPU output(s).
    pub fn compute(&self, row: usize, inp: bool, inn: bool, mode: Mode) -> LpuOut {
        evaluate(self.cells[row].q(), inp, inn, mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stores_64_rows() {
        let mut d = Dbmu::new(64);
        for r in 0..64 {
            d.write(r, r % 2 == 0);
        }
        for r in 0..64 {
            assert_eq!(d.read_q(r), r % 2 == 0);
            assert_eq!(d.read_q_bar(r), r % 2 != 0);
        }
    }

    #[test]
    fn compute_uses_selected_row_only() {
        let mut d = Dbmu::new(8);
        d.write(3, true);
        // row 3 holds 1: left = inp
        assert!(d.compute(3, true, false, Mode::Regular).left);
        // other rows hold 0
        assert!(!d.compute(2, true, false, Mode::Regular).left);
        // but their Q̄ path fires in double mode
        assert!(d.compute(2, false, true, Mode::Double).right);
    }

    #[test]
    fn double_mode_both_paths() {
        let mut d = Dbmu::new(4);
        d.write(0, true);
        let o = d.compute(0, true, true, Mode::Double);
        assert!(o.left);
        assert!(!o.right); // Q̄ = 0
    }
}
