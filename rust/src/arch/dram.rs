//! Off-chip DRAM model: fixed access latency + bandwidth, with the
//! layer-ahead prefetch the paper describes ("our system proactively
//! pre-fetches the weights for the subsequent layer, effectively masking
//! the latency typically associated with off-chip DRAM access").

/// DRAM transfer bookkeeping.
#[derive(Debug, Clone)]
pub struct Dram {
    /// Streaming bandwidth (paper config: 8 B/cycle).
    pub bytes_per_cycle: f64,
    /// Fixed setup latency per transfer (paper config: 100 cycles).
    pub latency_cycles: u64,
    /// Total bytes moved (traffic statistics; FCC halves conv weights).
    pub total_bytes: u64,
    /// Number of transfers issued.
    pub total_transfers: u64,
    /// Transfer cycles masked behind concurrent compute (prefetch).
    pub hidden_cycles: u64,
    /// Transfer cycles that stalled the fabric (nothing to hide behind).
    pub stalled_cycles: u64,
}

impl Dram {
    /// Model with the given bandwidth and setup latency; all traffic
    /// counters start at zero.
    pub fn new(bytes_per_cycle: f64, latency_cycles: u64) -> Self {
        Dram {
            bytes_per_cycle,
            latency_cycles,
            total_bytes: 0,
            total_transfers: 0,
            hidden_cycles: 0,
            stalled_cycles: 0,
        }
    }

    /// Cycles to move `bytes` (setup + streaming).
    pub fn transfer_cycles(&self, bytes: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Record a transfer and return its cycle cost.
    pub fn transfer(&mut self, bytes: usize) -> u64 {
        self.total_bytes += bytes as u64;
        self.total_transfers += 1;
        self.transfer_cycles(bytes)
    }

    /// Cycles of a transfer that remain *exposed* when `overlap_cycles`
    /// of compute run concurrently (prefetch masking).
    pub fn exposed_cycles(&self, transfer: u64, overlap_cycles: u64) -> u64 {
        transfer.saturating_sub(overlap_cycles)
    }

    /// Record a prefetched transfer: `bytes` move while `overlap_cycles`
    /// of compute run concurrently.  Splits the transfer into its hidden
    /// and exposed halves, accumulates both, and returns the exposed
    /// (stalling) cycles — the single entry point the engine uses so the
    /// overlap ratio is always consistent with the traffic counters.
    pub fn prefetched_transfer(&mut self, bytes: usize, overlap_cycles: u64) -> u64 {
        let transfer = self.transfer(bytes);
        let exposed = self.exposed_cycles(transfer, overlap_cycles);
        self.hidden_cycles += transfer - exposed;
        self.stalled_cycles += exposed;
        exposed
    }

    /// Fraction of all transfer cycles masked behind compute (0..=1);
    /// 1.0 when no traffic has moved (nothing was exposed).
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.hidden_cycles + self.stalled_cycles;
        if total == 0 {
            return 1.0;
        }
        self.hidden_cycles as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost() {
        let d = Dram::new(8.0, 100);
        assert_eq!(d.transfer_cycles(0), 0);
        assert_eq!(d.transfer_cycles(8), 101);
        assert_eq!(d.transfer_cycles(80), 110);
    }

    #[test]
    fn prefetch_masks_latency() {
        let d = Dram::new(8.0, 100);
        let t = d.transfer_cycles(800); // 200 cycles
        assert_eq!(d.exposed_cycles(t, 150), 50);
        assert_eq!(d.exposed_cycles(t, 500), 0); // fully hidden
    }

    #[test]
    fn traffic_accounting() {
        let mut d = Dram::new(8.0, 10);
        d.transfer(100);
        d.transfer(50);
        assert_eq!(d.total_bytes, 150);
        assert_eq!(d.total_transfers, 2);
    }

    #[test]
    fn prefetch_overlap_accounting() {
        let mut d = Dram::new(8.0, 100);
        // 800 B = 200 cycles; 150 hidden behind compute, 50 exposed
        let exposed = d.prefetched_transfer(800, 150);
        assert_eq!(exposed, 50);
        assert_eq!(d.hidden_cycles, 150);
        assert_eq!(d.stalled_cycles, 50);
        assert!((d.overlap_ratio() - 0.75).abs() < 1e-12);
        // a fully hidden transfer leaves no stall behind
        assert_eq!(d.prefetched_transfer(800, 10_000), 0);
        assert_eq!(d.stalled_cycles, 50);
        assert!(d.overlap_ratio() > 0.75);
    }

    #[test]
    fn overlap_ratio_is_one_with_no_traffic() {
        let d = Dram::new(8.0, 100);
        assert_eq!(d.overlap_ratio(), 1.0);
    }
}
