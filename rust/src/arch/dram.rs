//! Off-chip DRAM model: fixed access latency + bandwidth, with the
//! layer-ahead prefetch the paper describes ("our system proactively
//! pre-fetches the weights for the subsequent layer, effectively masking
//! the latency typically associated with off-chip DRAM access").

/// DRAM transfer bookkeeping.
#[derive(Debug, Clone)]
pub struct Dram {
    pub bytes_per_cycle: f64,
    pub latency_cycles: u64,
    /// Total bytes moved (traffic statistics; FCC halves conv weights).
    pub total_bytes: u64,
    pub total_transfers: u64,
}

impl Dram {
    pub fn new(bytes_per_cycle: f64, latency_cycles: u64) -> Self {
        Dram {
            bytes_per_cycle,
            latency_cycles,
            total_bytes: 0,
            total_transfers: 0,
        }
    }

    /// Cycles to move `bytes` (setup + streaming).
    pub fn transfer_cycles(&self, bytes: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Record a transfer and return its cycle cost.
    pub fn transfer(&mut self, bytes: usize) -> u64 {
        self.total_bytes += bytes as u64;
        self.total_transfers += 1;
        self.transfer_cycles(bytes)
    }

    /// Cycles of a transfer that remain *exposed* when `overlap_cycles`
    /// of compute run concurrently (prefetch masking).
    pub fn exposed_cycles(&self, transfer: u64, overlap_cycles: u64) -> u64 {
        transfer.saturating_sub(overlap_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost() {
        let d = Dram::new(8.0, 100);
        assert_eq!(d.transfer_cycles(0), 0);
        assert_eq!(d.transfer_cycles(8), 101);
        assert_eq!(d.transfer_cycles(80), 110);
    }

    #[test]
    fn prefetch_masks_latency() {
        let d = Dram::new(8.0, 100);
        let t = d.transfer_cycles(800); // 200 cycles
        assert_eq!(d.exposed_cycles(t, 150), 50);
        assert_eq!(d.exposed_cycles(t, 500), 0); // fully hidden
    }

    #[test]
    fn traffic_accounting() {
        let mut d = Dram::new(8.0, 10);
        d.transfer(100);
        d.transfer(50);
        assert_eq!(d.total_bytes, 150);
        assert_eq!(d.total_transfers, 2);
    }
}
