//! Bit-cell fault model + complementary-state integrity scrub.
//!
//! DDC-PIM stores a *pair* of filters in every 6T cell — Q is the even
//! filter's bit, Q̄ the complementary twin's — so a single stuck-at or
//! transient cell fault silently corrupts two filters at once.  This
//! module gives the fabric a way to model that (a seeded [`FaultPlan`]
//! with a configurable bit-error rate), to *detect* the corruption
//! (per-plane-word checksums against a write-intent ledger), and to
//! *survive* it (row quarantine + re-home onto spare rows, with
//! documented zeroization when the spares run out).
//!
//! ## Fault taxonomy
//!
//! Faults live at `(compartment, row, slot, weight-bit)` granularity —
//! one 6T cell of the weight array — in three kinds:
//!
//! * **stuck-at-0** — the cell reads 0 regardless of what was written;
//! * **stuck-at-1** — the cell reads 1 regardless;
//! * **transient** — a single-event upset that flips the *next* write
//!   landing on the cell, then clears (one-shot).
//!
//! All three manifest through the single weight-write path
//! ([`super::pim_core::PimCore::write_weight`]): the intended value is
//! recorded in the logical intent ledger, the masks corrupt the value,
//! and the corrupted value is stored in *both* coherent views (per-cell
//! array and bit-plane shadow) — so the cell/plane coherence invariant
//! survives fault injection, and the scalar oracle and bitsliced kernel
//! see the *same* corrupted array.  Cells that are never written hold
//! their reset state (0); a fault on an unwritten cell has no effect
//! until a write lands on it — a deliberate modeling choice that keeps
//! the zero-fault path byte-identical.
//!
//! ## The Q/Q̄ detection argument
//!
//! The 6T pair invariant means Q̄ is *derived*, never stored: the model
//! reads `q_bar() == !q` per cell and `!plane & lane_mask` per plane
//! word ([`super::sram`]).  A cell fault therefore corrupts Q and Q̄
//! *together, consistently* — there is no separate Q̄ state to check.
//! Checksumming the stored Q plane words against the intent ledger
//! consequently covers **both** polarities: any fault visible to either
//! the Q path or the Q̄ path of double-computing mode changes the stored
//! Q word and breaks its checksum.  Detection is per
//! `(row, slot, word)` unit — the same granularity the hot loop reads.
//!
//! ## Quarantine / re-home / degrade
//!
//! A row with any mismatching checksum is quarantined.  Repair re-plays
//! the row's intent through the (still faulted) write path onto a spare
//! physical row — a never-written row of the same macro — and verifies
//! the result; spares that fail verification (they carry stuck-ats of
//! their own) are marked dead and the next spare is tried.  The logical
//! → physical `row_map` then redirects every read.  When no clean spare
//! is left, the row is **zeroed**: intent and stored state are cleared,
//! modeling the periphery masking the row out, and the blast radius
//! (rows and nonzero stored weights lost — each stored weight carries
//! two logical filters in double mode) is reported instead of silently
//! serving corrupt data.

use super::pim_core::{MacroGeometry, WEIGHT_BITS};
use crate::util::rng::Rng;

/// What a faulty cell does to writes landing on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Cell reads 0 regardless of the written bit.
    StuckAt0,
    /// Cell reads 1 regardless of the written bit.
    StuckAt1,
    /// One-shot upset: the next write's bit is inverted, then the cell
    /// behaves normally.
    Transient,
}

/// One cell fault at `(compartment, row, slot, weight-bit)` — physical
/// coordinates (faults are silicon defects; they do not move when a
/// logical row is re-homed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fault {
    pub cmp: usize,
    pub row: usize,
    pub slot: usize,
    pub kw: usize,
    pub kind: FaultKind,
}

/// Knobs for seeded fault injection: a deterministic seed and a
/// per-cell bit-error rate in `[0, 1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    pub seed: u64,
    pub ber: f64,
}

impl FaultConfig {
    pub fn new(seed: u64, ber: f64) -> Self {
        assert!((0.0..=1.0).contains(&ber), "BER {ber} outside [0, 1]");
        FaultConfig { seed, ber }
    }

    /// Integer-friendly constructor: BER in parts-per-million (the form
    /// `BackendSpec` carries, since its derives require `Eq`).
    pub fn from_ppm(seed: u64, ppm: u32) -> Self {
        Self::new(seed, ppm as f64 / 1e6)
    }
}

/// Knobs for the deterministic retention-upset process: a seed and a
/// per-cell per-batch upset probability in `[0, 1]`.  Upsets are
/// scheduled against the core's **virtual batch clock** — every tick
/// draws from an RNG keyed on `(seed, tick)` alone, never wall time —
/// so a chaos soak replays bit for bit under any scheduling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UpsetConfig {
    pub seed: u64,
    pub per_batch_ber: f64,
}

impl UpsetConfig {
    pub fn new(seed: u64, per_batch_ber: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&per_batch_ber),
            "upset BER {per_batch_ber} outside [0, 1]"
        );
        UpsetConfig { seed, per_batch_ber }
    }

    /// Integer-friendly constructor mirroring [`FaultConfig::from_ppm`].
    pub fn from_ppm(seed: u64, ppm: u32) -> Self {
        Self::new(seed, ppm as f64 / 1e6)
    }
}

/// A set of cell faults to install into one core.  Either enumerated
/// explicitly ([`FaultPlan::from_faults`], tests) or sampled uniformly
/// over every cell of a geometry at the configured BER
/// ([`FaultPlan::seeded`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// No faults at all.  Installing this still routes every write
    /// through the interposed path — the property tests pin that the
    /// result is byte-identical to a core with no plan installed.
    pub fn empty() -> Self {
        FaultPlan::default()
    }

    /// An explicit fault list (test/chaos construction).
    pub fn from_faults(faults: Vec<Fault>) -> Self {
        FaultPlan { faults }
    }

    /// Sample every cell of `geom` independently at `cfg.ber`; `salt`
    /// decorrelates the streams of sibling cores (one per weight pass)
    /// sharing one config.  Deterministic in `(seed, salt, geom, ber)`.
    pub fn seeded(geom: MacroGeometry, cfg: &FaultConfig, salt: u64) -> Self {
        let mut rng = Rng::new(cfg.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut faults = Vec::new();
        if cfg.ber <= 0.0 {
            return FaultPlan { faults };
        }
        for cmp in 0..geom.compartments {
            for row in 0..geom.rows {
                for slot in 0..geom.slots() {
                    for kw in 0..WEIGHT_BITS {
                        if rng.f64() < cfg.ber {
                            let kind = match rng.below(3) {
                                0 => FaultKind::StuckAt0,
                                1 => FaultKind::StuckAt1,
                                _ => FaultKind::Transient,
                            };
                            faults.push(Fault { cmp, row, slot, kw, kind });
                        }
                    }
                }
            }
        }
        FaultPlan { faults }
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }
}

/// Per-cell-location fault masks over the 8 weight bits of one
/// `(cmp, row, slot)` byte.  Precedence on overlap: stuck-at-1 wins
/// over stuck-at-0 (`set` is OR-ed after `clear` is AND-ed out), the
/// transient flip applies last and once.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct FaultMasks {
    clear: u8,
    set: u8,
    flip: u8,
}

/// Running totals a faulted core accumulates across its lifetime
/// (injection at write time, detection/repair at scrub time).  The
/// runtime folds these into `metrics::ReliabilityStats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultTally {
    /// Weight bits actually corrupted at write time (benign stuck-ats
    /// that agree with the written bit are not counted).
    pub injected_bits: u64,
    /// Checksum units `(row, slot, word)` the scrub found corrupted.
    pub detected_words: u64,
    /// Quarantined rows re-homed onto a verified-clean spare.
    pub repaired_rows: u64,
    /// Rows quarantined in total (repaired + zeroed).
    pub quarantined_rows: u64,
    /// Quarantined rows zeroed for lack of clean spares.
    pub zeroed_rows: u64,
    /// Retention-upset bit flips landed on live rows by the virtual
    /// batch-clock process (disjoint from `injected_bits`, which counts
    /// write-time corruption).
    pub upset_bits: u64,
    /// Stored bits the scrub found diverged from intent on quarantined
    /// rows, counted before repair.  With a full-coverage scrub every
    /// batch, this reconciles exactly against `upset_bits`.
    pub corrupt_bits: u64,
}

impl FaultTally {
    pub fn merge(&mut self, other: &FaultTally) {
        self.injected_bits += other.injected_bits;
        self.detected_words += other.detected_words;
        self.repaired_rows += other.repaired_rows;
        self.quarantined_rows += other.quarantined_rows;
        self.zeroed_rows += other.zeroed_rows;
        self.upset_bits += other.upset_bits;
        self.corrupt_bits += other.corrupt_bits;
    }
}

/// Result of one integrity-scrub pass over a core.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Checksum units `(row, slot, word)` compared.
    pub checked_words: u64,
    /// Units whose stored checksum diverged from the intent ledger.
    pub detected_words: u64,
    /// Rows quarantined (any corrupt unit).
    pub quarantined_rows: u64,
    /// Quarantined rows re-homed onto a verified-clean spare row.
    pub repaired_rows: u64,
    /// Spare rows that failed post-repair verification (own faults).
    pub dead_spares: u64,
    /// Quarantined rows zeroed because no clean spare remained.
    pub zeroed_rows: u64,
    /// Nonzero stored weights lost to zeroization — the blast radius
    /// (double it for logical filters: every stored weight carries its
    /// complementary twin).
    pub zeroed_weights: u64,
    /// Stored bits that diverged from intent on the quarantined rows,
    /// counted by a full-row damage scan before any repair ran.
    pub corrupt_bits: u64,
}

impl ScrubReport {
    /// Whether the scrub found nothing wrong.
    pub fn is_clean(&self) -> bool {
        self.detected_words == 0
    }

    pub fn merge(&mut self, other: &ScrubReport) {
        self.checked_words += other.checked_words;
        self.detected_words += other.detected_words;
        self.quarantined_rows += other.quarantined_rows;
        self.repaired_rows += other.repaired_rows;
        self.dead_spares += other.dead_spares;
        self.zeroed_rows += other.zeroed_rows;
        self.zeroed_weights += other.zeroed_weights;
        self.corrupt_bits += other.corrupt_bits;
    }
}

/// Checksum of one `(row, slot, word)` unit: a 64-bit multiply-rotate
/// mix folded over the `WEIGHT_BITS` plane words.  Any single-word
/// change alters the digest; collisions need an adversarial 512-bit
/// input, far beyond what cell faults produce.
#[inline]
pub fn plane_checksum(words: &[u64]) -> u64 {
    let mut h = 0x6A09_E667_F3BC_C909u64;
    for &w in words {
        h = (h ^ w).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(13);
    }
    h
}

/// Live fault state of one core: physical fault masks, the logical
/// write-intent ledger the scrub checks against, the logical → physical
/// row map, and spare-row bookkeeping.  Owned by
/// [`super::pim_core::PimCore`]; `None` there means the entirely
/// untouched legacy write path runs.
#[derive(Debug, Clone)]
pub struct FaultState {
    cmps: usize,
    rows: usize,
    slots: usize,
    /// Physical `(cmp, row, slot)`-indexed corruption masks.
    masks: Vec<FaultMasks>,
    /// Logical `(cmp, row, slot)`-indexed written intent (reset = 0,
    /// which matches the cells' reset state).
    intent: Vec<i8>,
    /// Logical row → physical row (identity until a repair re-homes).
    row_map: Vec<u32>,
    /// Physical rows holding live data (write targets + claimed spares).
    row_used: Vec<bool>,
    /// Spare rows that failed repair verification.
    row_dead: Vec<bool>,
    /// Armed retention-upset process (None = no runtime upsets).
    upsets: Option<UpsetConfig>,
    /// Virtual batch clock the upset process is scheduled against —
    /// advanced once per batch boundary, never by wall time.
    batch_clock: u64,
    tally: FaultTally,
}

impl FaultState {
    pub fn new(cmps: usize, rows: usize, slots: usize, plan: &FaultPlan) -> Self {
        let mut masks = vec![FaultMasks::default(); cmps * rows * slots];
        for f in plan.faults() {
            assert!(
                f.cmp < cmps && f.row < rows && f.slot < slots && f.kw < WEIGHT_BITS,
                "fault {f:?} outside the {cmps}x{rows}x{slots} core"
            );
            let m = &mut masks[(f.cmp * rows + f.row) * slots + f.slot];
            let bit = 1u8 << f.kw;
            match f.kind {
                FaultKind::StuckAt0 => m.clear |= bit,
                FaultKind::StuckAt1 => m.set |= bit,
                FaultKind::Transient => m.flip |= bit,
            }
        }
        FaultState {
            cmps,
            rows,
            slots,
            masks,
            intent: vec![0; cmps * rows * slots],
            row_map: (0..rows as u32).collect(),
            row_used: vec![false; rows],
            row_dead: vec![false; rows],
            upsets: None,
            batch_clock: 0,
            tally: FaultTally::default(),
        }
    }

    /// Arm the retention-upset process.  Ticks before arming never
    /// happened: the batch clock starts (or restarts) at zero so a
    /// given `(seed, per_batch_ber)` always replays the same schedule.
    pub fn arm_upsets(&mut self, cfg: UpsetConfig) {
        self.upsets = Some(cfg);
        self.batch_clock = 0;
    }

    /// The armed upset process, if any.
    pub fn upsets(&self) -> Option<UpsetConfig> {
        self.upsets
    }

    /// Advance the virtual batch clock and return the tick that just
    /// elapsed (the value to key this boundary's upset draw on).
    pub fn next_upset_tick(&mut self) -> u64 {
        let t = self.batch_clock;
        self.batch_clock += 1;
        t
    }

    /// Whether a *physical* row holds live data.  The upset process
    /// only flips live rows: an upset on never-written, orphaned, or
    /// dead-spare surface is invisible to every read path and would
    /// break the injected-vs-detected reconciliation if booked.
    #[inline]
    pub fn row_live(&self, phys_row: usize) -> bool {
        self.row_used[phys_row]
    }

    /// Book retention-upset flips landed by the batch-clock process.
    pub fn book_upsets(&mut self, bits: u64) {
        self.tally.upset_bits += bits;
    }

    #[inline]
    fn loc(&self, cmp: usize, row: usize, slot: usize) -> usize {
        (cmp * self.rows + row) * self.slots + slot
    }

    /// Physical home of a logical row.
    #[inline]
    pub fn physical(&self, row: usize) -> usize {
        self.row_map[row] as usize
    }

    /// Record what the planner *meant* to store at a logical location.
    #[inline]
    pub fn record_intent(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        let loc = self.loc(cmp, row, slot);
        self.intent[loc] = w as i8;
    }

    /// Intended value at a logical location (0 if never written —
    /// matching the cells' reset state).
    #[inline]
    pub fn intent(&self, cmp: usize, row: usize, slot: usize) -> i32 {
        self.intent[self.loc(cmp, row, slot)] as i32
    }

    /// Push a write through the fault masks of a *physical* location:
    /// returns the value the cells actually latch, books the corrupted
    /// bits, consumes any pending transient, and marks the row live.
    pub fn corrupt(&mut self, cmp: usize, phys_row: usize, slot: usize, w: i32) -> i32 {
        self.row_used[phys_row] = true;
        let loc = self.loc(cmp, phys_row, slot);
        let m = &mut self.masks[loc];
        let bits = w as u8;
        let mut out = (bits & !m.clear) | m.set;
        out ^= m.flip;
        m.flip = 0;
        self.tally.injected_bits += (out ^ bits).count_ones() as u64;
        out as i8 as i32
    }

    /// Word `word` of the *intended* Q bit-plane of
    /// `(logical row, slot, kw)` — what the stored plane would hold on
    /// fault-free silicon.
    fn golden_word(&self, row: usize, slot: usize, kw: usize, word: usize) -> u64 {
        let lo = word * 64;
        let hi = ((word + 1) * 64).min(self.cmps);
        let mut w = 0u64;
        for lane in lo..hi {
            if (self.intent[self.loc(lane, row, slot)] as u8 >> kw) & 1 == 1 {
                w |= 1u64 << (lane - lo);
            }
        }
        w
    }

    /// Golden checksum of one `(logical row, slot, word)` unit, from the
    /// intent ledger — the reference the stored planes are compared to.
    pub fn golden_checksum(&self, row: usize, slot: usize, word: usize) -> u64 {
        let mut words = [0u64; WEIGHT_BITS];
        for (kw, w) in words.iter_mut().enumerate() {
            *w = self.golden_word(row, slot, kw, word);
        }
        plane_checksum(&words)
    }

    /// Claim the lowest-numbered clean spare (a physical row never
    /// written and not marked dead).  Ascending scan = deterministic
    /// quarantine behavior.
    pub fn claim_spare(&mut self) -> Option<usize> {
        let s = (0..self.rows).find(|&r| !self.row_used[r] && !self.row_dead[r])?;
        self.row_used[s] = true;
        Some(s)
    }

    /// Mark a spare dead after failed repair verification.
    pub fn mark_dead(&mut self, row: usize) {
        self.row_dead[row] = true;
    }

    /// Retire an orphaned physical row after its logical row re-homed:
    /// it holds no live data (the upset process skips it), and a later
    /// repair may reclaim it as a spare — verification gates reuse.
    pub fn retire_row(&mut self, phys_row: usize) {
        self.row_used[phys_row] = false;
    }

    /// Re-home a logical row onto a verified spare.
    pub fn map_row(&mut self, logical: usize, phys: usize) {
        self.row_map[logical] = phys as u32;
    }

    /// Zero a logical row's intent (graceful degradation); returns the
    /// number of nonzero stored weights lost.
    pub fn zero_intent_row(&mut self, row: usize) -> u64 {
        let mut lost = 0;
        for cmp in 0..self.cmps {
            for slot in 0..self.slots {
                let loc = self.loc(cmp, row, slot);
                if self.intent[loc] != 0 {
                    lost += 1;
                }
                self.intent[loc] = 0;
            }
        }
        lost
    }

    /// Fold a scrub's outcome into the lifetime tally.
    pub fn book_scrub(&mut self, report: &ScrubReport) {
        self.tally.detected_words += report.detected_words;
        self.tally.repaired_rows += report.repaired_rows;
        self.tally.quarantined_rows += report.quarantined_rows;
        self.tally.zeroed_rows += report.zeroed_rows;
        self.tally.corrupt_bits += report.corrupt_bits;
    }

    /// Lifetime injection/detection/repair totals.
    pub fn tally(&self) -> FaultTally {
        self.tally
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_deterministic_and_ber_scaled() {
        let geom = MacroGeometry::paper();
        let cfg = FaultConfig::new(42, 0.01);
        let a = FaultPlan::seeded(geom, &cfg, 3);
        let b = FaultPlan::seeded(geom, &cfg, 3);
        assert_eq!(a, b);
        // different salt decorrelates
        assert_ne!(a, FaultPlan::seeded(geom, &cfg, 4));
        // 32*64*2*8 = 32768 cells at 1% → expect ~328, allow wide slack
        let n = a.len();
        assert!((150..600).contains(&n), "implausible fault count {n}");
        // zero BER yields the empty plan without touching the RNG
        assert!(FaultPlan::seeded(geom, &FaultConfig::new(42, 0.0), 3).is_empty());
    }

    #[test]
    fn corrupt_applies_masks_and_counts_bits() {
        let plan = FaultPlan::from_faults(vec![
            Fault { cmp: 0, row: 1, slot: 0, kw: 0, kind: FaultKind::StuckAt1 },
            Fault { cmp: 0, row: 1, slot: 0, kw: 3, kind: FaultKind::StuckAt0 },
            Fault { cmp: 0, row: 1, slot: 0, kw: 7, kind: FaultKind::Transient },
        ]);
        let mut fs = FaultState::new(2, 4, 2, &plan);
        // 0b0000_1000 → stuck1 sets bit 0, stuck0 clears bit 3, transient
        // flips bit 7 (once): 0b1000_0001 = -127
        assert_eq!(fs.corrupt(0, 1, 0, 0b0000_1000), 0b1000_0001u8 as i8 as i32);
        assert_eq!(fs.tally().injected_bits, 3);
        // transient is spent; stuck-ats persist
        assert_eq!(fs.corrupt(0, 1, 0, 0b0000_1000), 0b0000_0001);
        assert_eq!(fs.tally().injected_bits, 5);
        // a benign write (agrees with both stuck-ats) injects nothing
        assert_eq!(fs.corrupt(0, 1, 0, 0b0000_0001), 0b0000_0001);
        assert_eq!(fs.tally().injected_bits, 5);
        // clean sibling location untouched
        assert_eq!(fs.corrupt(1, 1, 0, 0b0000_1000), 0b0000_1000);
    }

    #[test]
    fn golden_checksum_tracks_intent() {
        let mut fs = FaultState::new(96, 2, 2, &FaultPlan::empty());
        let before = fs.golden_checksum(0, 1, 1);
        fs.record_intent(70, 0, 1, -77); // lane 70 lives in word 1
        assert_ne!(fs.golden_checksum(0, 1, 1), before);
        assert_eq!(fs.golden_checksum(0, 1, 0), before); // word 0 untouched
        // golden word matches the two's-complement bit layout
        assert_eq!(fs.golden_word(0, 1, 0, 1), 1 << (70 - 64)); // -77 = ...0011
        assert_eq!(fs.golden_word(0, 1, 2, 1), 0);
    }

    #[test]
    fn spare_claiming_is_ascending_and_skips_dead() {
        let mut fs = FaultState::new(1, 4, 1, &FaultPlan::empty());
        fs.corrupt(0, 1, 0, 5); // row 1 in use
        assert_eq!(fs.claim_spare(), Some(0));
        fs.mark_dead(2);
        assert_eq!(fs.claim_spare(), Some(3));
        assert_eq!(fs.claim_spare(), None); // exhausted
    }

    #[test]
    fn checksum_sensitive_to_any_word() {
        let a = [1u64, 2, 3, 4, 5, 6, 7, 8];
        for i in 0..8 {
            let mut b = a;
            b[i] ^= 1 << 40;
            assert_ne!(plane_checksum(&a), plane_checksum(&b), "blind to word {i}");
        }
        // order matters too
        let mut c = a;
        c.swap(0, 7);
        assert_ne!(plane_checksum(&a), plane_checksum(&c));
    }

    #[test]
    fn upset_process_arms_and_ticks_deterministically() {
        let mut fs = FaultState::new(1, 4, 1, &FaultPlan::empty());
        assert!(fs.upsets().is_none());
        fs.arm_upsets(UpsetConfig::from_ppm(7, 500));
        assert_eq!(fs.upsets().map(|u| u.seed), Some(7));
        assert_eq!(fs.next_upset_tick(), 0);
        assert_eq!(fs.next_upset_tick(), 1);
        // re-arming restarts the virtual clock: same config → same schedule
        fs.arm_upsets(UpsetConfig::new(7, 0.0005));
        assert_eq!(fs.next_upset_tick(), 0);
        // only written rows are live upset targets
        fs.corrupt(0, 2, 0, 1);
        assert!(fs.row_live(2));
        assert!(!fs.row_live(0));
        fs.book_upsets(3);
        assert_eq!(fs.tally().upset_bits, 3);
    }

    #[test]
    fn zeroing_counts_blast_radius() {
        let mut fs = FaultState::new(3, 2, 2, &FaultPlan::empty());
        fs.record_intent(0, 1, 0, 9);
        fs.record_intent(2, 1, 1, -4);
        fs.record_intent(1, 0, 0, 7); // other row: untouched
        assert_eq!(fs.zero_intent_row(1), 2);
        assert_eq!(fs.intent(0, 1, 0), 0);
        assert_eq!(fs.intent(1, 0, 0), 7);
        assert_eq!(fs.zero_intent_row(1), 0); // idempotent
    }
}
