//! Multi-macro grid topology (scale-out view of the fabric).
//!
//! A real DDC-PIM chip is not one macro but a `rows × cols` array of
//! them sharing a mesh; the paper's system-level speedups assume conv
//! layers spread across that array.  [`GridShape`] is the CLI/spec-level
//! knob ("2x2"), [`MacroGrid`] the planner-facing topology object: it
//! pairs a shape with the per-macro [`MacroGeometry`] and hands the
//! shard planner ([`crate::mapping::shard`]) a balanced contiguous
//! partition of any work axis (output channels for std/pw convs, output
//! pixel rows for dw convs) across its tiles.
//!
//! The grid is purely a *planning* construct: every tile's shard is an
//! independent single-macro plan, executed across the session's
//! existing [`crate::mapping::ExecPool`], and the shard math is chosen
//! so grid execution is byte-identical to single-macro execution at
//! every shape (see the shard planner docs for the proof obligations;
//! `tests/grid_semantics.rs` pins them).

use std::fmt;
use std::ops::Range;
use std::str::FromStr;

use super::pim_core::MacroGeometry;

/// A `rows × cols` macro-grid shape.  `1x1` is the single-macro
/// degenerate case (and the [`Default`]); `0x0` ([`GridShape::AUTO`])
/// means "unset — resolve from the `DDC_GRID` environment variable,
/// then fall back to 1x1" (see [`resolve_grid`]), mirroring the
/// `threads == 0` convention of
/// [`resolve_threads`](crate::util::pool::resolve_threads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridShape {
    pub rows: usize,
    pub cols: usize,
}

/// Hard ceiling on grid tiles: shards beyond the work's unit count are
/// planned empty anyway, and the shard scatter is linear in tiles.
pub const MAX_TILES: usize = 256;

impl GridShape {
    /// The "resolve from `DDC_GRID`, then 1x1" sentinel.
    pub const AUTO: GridShape = GridShape { rows: 0, cols: 0 };

    /// Single-macro degenerate grid.
    pub const SINGLE: GridShape = GridShape { rows: 1, cols: 1 };

    pub fn new(rows: usize, cols: usize) -> GridShape {
        GridShape { rows, cols }
    }

    /// Total tile count (`rows * cols`).
    pub fn tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// True for the unset sentinel ([`GridShape::AUTO`]).
    pub fn is_auto(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }
}

impl Default for GridShape {
    fn default() -> Self {
        GridShape::AUTO
    }
}

impl fmt::Display for GridShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.rows, self.cols)
    }
}

impl FromStr for GridShape {
    type Err = String;

    /// Parse `"RxC"` (e.g. `"2x2"`, `"1x4"`); both dims must be >= 1
    /// and `R*C <= `[`MAX_TILES`].
    fn from_str(s: &str) -> Result<GridShape, String> {
        let err = || format!("bad grid shape {s:?} (want RxC, e.g. 2x2, tiles <= {MAX_TILES})");
        let (r, c) = s.trim().split_once(['x', 'X']).ok_or_else(err)?;
        let rows: usize = r.trim().parse().map_err(|_| err())?;
        let cols: usize = c.trim().parse().map_err(|_| err())?;
        if rows == 0 || cols == 0 || rows * cols > MAX_TILES {
            return Err(err());
        }
        Ok(GridShape { rows, cols })
    }
}

/// Resolve a requested grid shape: an explicit (non-AUTO) request wins,
/// else the `DDC_GRID` environment variable (`"RxC"`), else the
/// single-macro `1x1`.  An unparseable `DDC_GRID` is *warned about* and
/// treated as unset — never silently absorbed into a surprising shape
/// (the same contract as `DDC_THREADS` / `DDC_WORKERS`).
pub fn resolve_grid(requested: GridShape) -> GridShape {
    if !requested.is_auto() {
        return requested;
    }
    crate::util::env::resolve_env_knob("DDC_GRID", GridShape::SINGLE, "1x1", |raw| {
        raw.parse::<GridShape>()
    })
}

/// The planner-facing grid: shape + per-macro geometry + the balanced
/// partition every shard planner uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroGrid {
    shape: GridShape,
    geometry: MacroGeometry,
}

impl MacroGrid {
    /// Build a grid; AUTO shapes are resolved via [`resolve_grid`]
    /// first, so a `MacroGrid` always has concrete dims.
    pub fn new(shape: GridShape, geometry: MacroGeometry) -> MacroGrid {
        MacroGrid {
            shape: resolve_grid(shape),
            geometry,
        }
    }

    /// Single-macro grid at a given geometry.
    pub fn single(geometry: MacroGeometry) -> MacroGrid {
        MacroGrid {
            shape: GridShape::SINGLE,
            geometry,
        }
    }

    pub fn shape(&self) -> GridShape {
        self.shape
    }

    pub fn geometry(&self) -> MacroGeometry {
        self.geometry
    }

    pub fn tiles(&self) -> usize {
        self.shape.tiles()
    }

    /// Tile index -> `(row, col)` placement (row-major).
    pub fn tile_coords(&self, tile: usize) -> (usize, usize) {
        (tile / self.shape.cols, tile % self.shape.cols)
    }

    /// Balanced contiguous partition of `units` work units across the
    /// grid's tiles: every unit lands in exactly one range, ranges are
    /// sorted and disjoint, sizes differ by at most one, and tiles
    /// beyond the unit count get nothing (empty ranges are dropped, so
    /// a 2x4 grid sharding 5 channels yields 5 one-unit shards).  This
    /// is the single partition rule both shard planners use — the
    /// disjoint/covering property the grid tests pin is proved here
    /// once.
    pub fn partition(&self, units: usize) -> Vec<Range<usize>> {
        partition_units(units, self.tiles())
    }
}

/// Balanced contiguous partition of `0..units` into at most `tiles`
/// non-empty ranges (see [`MacroGrid::partition`]).
pub fn partition_units(units: usize, tiles: usize) -> Vec<Range<usize>> {
    let tiles = tiles.max(1);
    let take = tiles.min(units);
    if take == 0 {
        return Vec::new();
    }
    let base = units / take;
    let rem = units % take;
    let mut out = Vec::with_capacity(take);
    let mut start = 0;
    for t in 0..take {
        let len = base + usize::from(t < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, units);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grid_shapes() {
        assert_eq!("2x2".parse::<GridShape>().unwrap(), GridShape::new(2, 2));
        assert_eq!("1X4".parse::<GridShape>().unwrap(), GridShape::new(1, 4));
        assert_eq!(" 2 x 3 ".parse::<GridShape>().unwrap(), GridShape::new(2, 3));
        assert!("0x2".parse::<GridShape>().is_err());
        assert!("2".parse::<GridShape>().is_err());
        assert!("axb".parse::<GridShape>().is_err());
        assert!("1000x1000".parse::<GridShape>().is_err());
    }

    #[test]
    fn display_round_trips() {
        let g = GridShape::new(2, 4);
        assert_eq!(g.to_string().parse::<GridShape>().unwrap(), g);
    }

    #[test]
    fn partition_is_disjoint_covering_and_balanced() {
        for units in 0..40 {
            for tiles in 1..10 {
                let parts = partition_units(units, tiles);
                // covering + disjoint: concatenation is exactly 0..units
                let mut walk = 0;
                for r in &parts {
                    assert_eq!(r.start, walk, "gap or overlap at {r:?}");
                    assert!(!r.is_empty(), "empty shard emitted");
                    walk = r.end;
                }
                assert_eq!(walk, units);
                assert!(parts.len() <= tiles);
                // balanced: sizes differ by at most one
                if let (Some(mn), Some(mx)) = (
                    parts.iter().map(|r| r.len()).min(),
                    parts.iter().map(|r| r.len()).max(),
                ) {
                    assert!(mx - mn <= 1, "unbalanced partition {parts:?}");
                }
            }
        }
    }

    #[test]
    fn tile_coords_row_major() {
        let g = MacroGrid::new(GridShape::new(2, 3), MacroGeometry::paper());
        assert_eq!(g.tiles(), 6);
        assert_eq!(g.tile_coords(0), (0, 0));
        assert_eq!(g.tile_coords(2), (0, 2));
        assert_eq!(g.tile_coords(3), (1, 0));
        assert_eq!(g.tile_coords(5), (1, 2));
    }

    #[test]
    fn auto_resolves_without_env_to_single() {
        // process env is shared across the parallel test harness, so
        // exercise the explicit branch only (env behavior is covered by
        // the CLI smoke in CI)
        assert_eq!(resolve_grid(GridShape::new(2, 2)), GridShape::new(2, 2));
        assert!(!MacroGrid::new(GridShape::AUTO, MacroGeometry::paper())
            .shape()
            .is_auto());
    }
}
