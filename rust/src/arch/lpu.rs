//! Local Processing Unit — the dynamic-logic dual-AND paths of Fig. 6.
//!
//! Each LPU sits under a DBMU column and has two pull-down paths gated by
//! the dynamic-logic enables EN0..EN3:
//!
//! * left path:  `Q  AND INP`  (enabled in regular + double mode)
//! * right path: `Q̄ AND INN`  (enabled only in double mode)
//!
//! In regular computing mode only EN0/EN2 are grounded, so half the LPU
//! is active; in double computing mode all four enables are grounded and
//! the LPU produces two independent AND results per cycle — the circuit
//! mechanism behind the doubled parallelism.

/// PIM core operating mode (paper §III-C2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Plain SRAM read/write; LPU disabled.
    NormalSram,
    /// Regular computing: Q path only.
    Regular,
    /// Double computing: Q and Q̄ paths with dual-broadcast inputs.
    Double,
}

/// Result of one LPU evaluation cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LpuOut {
    /// `Q AND INP` (valid unless NormalSram).
    pub left: bool,
    /// `Q̄ AND INN` (valid only in Double mode; pre-charged high ->
    /// reads false when the path is disabled).
    pub right: bool,
}

/// Evaluate the LPU truth table (Fig. 6(b)) for one cell.
pub fn evaluate(q: bool, inp: bool, inn: bool, mode: Mode) -> LpuOut {
    match mode {
        Mode::NormalSram => LpuOut::default(),
        Mode::Regular => LpuOut {
            left: q & inp,
            right: false,
        },
        Mode::Double => LpuOut {
            left: q & inp,
            right: (!q) & inn,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_table_regular() {
        // O = w & INP; right path dark
        for q in [false, true] {
            for inp in [false, true] {
                let o = evaluate(q, inp, true, Mode::Regular);
                assert_eq!(o.left, q & inp);
                assert!(!o.right);
            }
        }
    }

    #[test]
    fn truth_table_double() {
        // Fig. 6(b): left = Q & INP, right = Q̄ & INN — all 8 rows
        for q in [false, true] {
            for inp in [false, true] {
                for inn in [false, true] {
                    let o = evaluate(q, inp, inn, Mode::Double);
                    assert_eq!(o.left, q & inp, "q={q} inp={inp}");
                    assert_eq!(o.right, !q & inn, "q={q} inn={inn}");
                }
            }
        }
    }

    #[test]
    fn normal_mode_inert() {
        let o = evaluate(true, true, true, Mode::NormalSram);
        assert_eq!(o, LpuOut::default());
    }

    #[test]
    fn double_mode_two_independent_ands() {
        // the headline: one cell, two simultaneous independent products
        let o = evaluate(true, true, true, Mode::Double);
        assert!(o.left && !o.right);
        let o = evaluate(false, true, true, Mode::Double);
        assert!(!o.left && o.right);
    }
}
