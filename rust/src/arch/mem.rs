//! On-chip memories: weight memory (256 KB), ping-pong activation memory
//! (128 KB), instruction memory (Fig. 5).
//!
//! These are capacity/occupancy models with byte-accurate bookkeeping;
//! the cycle engine charges access cycles, the coordinator uses the
//! occupancy to decide layer-by-layer weight staging and when the
//! prefetcher must spill to DRAM.

/// A simple capacity-tracked on-chip buffer.
#[derive(Debug, Clone)]
pub struct Buffer {
    pub name: &'static str,
    capacity_bytes: usize,
    used_bytes: usize,
}

impl Buffer {
    pub fn new(name: &'static str, capacity_kb: usize) -> Self {
        Buffer {
            name,
            capacity_bytes: capacity_kb * 1024,
            used_bytes: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_bytes
    }

    pub fn used(&self) -> usize {
        self.used_bytes
    }

    pub fn free(&self) -> usize {
        self.capacity_bytes - self.used_bytes
    }

    /// Try to reserve `bytes`; returns false if it does not fit.
    pub fn alloc(&mut self, bytes: usize) -> bool {
        if bytes <= self.free() {
            self.used_bytes += bytes;
            true
        } else {
            false
        }
    }

    pub fn release(&mut self, bytes: usize) {
        assert!(bytes <= self.used_bytes, "{}: over-release", self.name);
        self.used_bytes -= bytes;
    }

    pub fn reset(&mut self) {
        self.used_bytes = 0;
    }

    pub fn utilization(&self) -> f64 {
        self.used_bytes as f64 / self.capacity_bytes as f64
    }
}

/// Ping-pong activation memory: two half-capacity banks that swap roles
/// between layers (read current layer's inputs from one, write outputs
/// to the other — hides the writeback behind the next layer's compute).
#[derive(Debug, Clone)]
pub struct PingPong {
    banks: [Buffer; 2],
    active: usize,
}

impl PingPong {
    pub fn new(total_kb: usize) -> Self {
        PingPong {
            banks: [
                Buffer::new("pingpong.a", total_kb / 2),
                Buffer::new("pingpong.b", total_kb / 2),
            ],
            active: 0,
        }
    }

    /// Bank being read (current layer inputs).
    pub fn read_bank(&self) -> &Buffer {
        &self.banks[self.active]
    }

    /// Bank being written (current layer outputs).
    pub fn write_bank(&mut self) -> &mut Buffer {
        &mut self.banks[1 - self.active]
    }

    /// Swap roles at a layer boundary; the new write bank is cleared.
    pub fn swap(&mut self) {
        self.active = 1 - self.active;
        self.banks[1 - self.active].reset();
    }

    pub fn bank_capacity(&self) -> usize {
        self.banks[0].capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_alloc_release() {
        let mut b = Buffer::new("w", 1); // 1 KB
        assert!(b.alloc(512));
        assert!(b.alloc(512));
        assert!(!b.alloc(1)); // full
        b.release(256);
        assert!(b.alloc(256));
        assert_eq!(b.used(), 1024);
        assert!((b.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "over-release")]
    fn over_release_panics() {
        let mut b = Buffer::new("w", 1);
        b.release(1);
    }

    #[test]
    fn pingpong_swap_clears_new_write_bank() {
        let mut pp = PingPong::new(128);
        assert_eq!(pp.bank_capacity(), 64 * 1024);
        assert!(pp.write_bank().alloc(1000));
        pp.swap();
        // previous write bank is now the read bank and keeps its data
        assert_eq!(pp.read_bank().used(), 1000);
        // the new write bank (old read bank) was cleared
        assert_eq!(pp.write_bank().used(), 0);
    }
}
