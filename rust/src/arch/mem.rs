//! On-chip memories: weight memory (256 KB), ping-pong activation memory
//! (128 KB), instruction memory (Fig. 5).
//!
//! These are capacity/occupancy models with byte-accurate bookkeeping.
//! [`Buffer`] is the raw capacity counter (the cycle engine charges
//! access cycles against it); [`StagedBuffer`] layers named-region
//! staging with FIFO eviction on top, and is what the streaming session
//! in `runtime/reference.rs` uses to decide weight-reload passes: when
//! the next pass's weights do not fit, the oldest resident pass is
//! evicted and re-fetched from DRAM later (the reload the capacity
//! metrics count).  Occupancy, evictions and overflow events are all
//! observable, so capacity pressure is reported end to end
//! (`sim/stats.rs`, `selfcheck`, `serve`, the streaming bench case).

use std::collections::VecDeque;

/// A simple capacity-tracked on-chip buffer.
#[derive(Debug, Clone)]
pub struct Buffer {
    /// Human-readable name used in panic/diagnostic messages.
    pub name: &'static str,
    capacity_bytes: usize,
    used_bytes: usize,
}

impl Buffer {
    /// Buffer with a capacity given in whole KB (the config unit).
    pub fn new(name: &'static str, capacity_kb: usize) -> Self {
        Self::with_capacity_bytes(name, capacity_kb * 1024)
    }

    /// Buffer with an exact byte capacity (streaming budgets are not
    /// always KB-aligned).
    pub fn with_capacity_bytes(name: &'static str, capacity_bytes: usize) -> Self {
        Buffer {
            name,
            capacity_bytes,
            used_bytes: 0,
        }
    }

    /// Total capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity_bytes
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> usize {
        self.used_bytes
    }

    /// Bytes still available.
    pub fn free(&self) -> usize {
        self.capacity_bytes - self.used_bytes
    }

    /// Try to reserve `bytes`; returns false if it does not fit.
    pub fn alloc(&mut self, bytes: usize) -> bool {
        if bytes <= self.free() {
            self.used_bytes += bytes;
            true
        } else {
            false
        }
    }

    /// Return `bytes` to the free pool; panics on over-release (an
    /// accounting bug, not a recoverable condition).
    pub fn release(&mut self, bytes: usize) {
        assert!(bytes <= self.used_bytes, "{}: over-release", self.name);
        self.used_bytes -= bytes;
    }

    /// Drop every allocation.
    pub fn reset(&mut self) {
        self.used_bytes = 0;
    }

    /// Fraction of capacity in use (0..=1).
    pub fn utilization(&self) -> f64 {
        self.used_bytes as f64 / self.capacity_bytes as f64
    }
}

/// Outcome of one [`StagedBuffer::stage`] call: what had to happen to
/// make room.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StageOutcome {
    /// Regions evicted (oldest-first) to make the new region fit.
    pub evicted: usize,
    /// Bytes those evictions freed.
    pub evicted_bytes: usize,
    /// The region is larger than the whole capacity: it was staged
    /// anyway (execution must proceed) but occupancy exceeds 1.0 —
    /// the over-budget-single-pass case the streaming tests pin.
    pub overflowed: bool,
}

/// A [`Buffer`] that tracks *which* regions occupy it, evicting the
/// oldest resident region (FIFO, the exemplar shape of the gpt2_sim
/// SRAM model) when a new one does not fit.
///
/// This is the bookkeeping half of weight streaming: each weight-reload
/// pass stages its footprint under a stable id, later passes evict
/// earlier ones, and the counters ([`StagedBuffer::evictions`],
/// [`StagedBuffer::overflows`], [`StagedBuffer::peak_used`]) feed the
/// capacity-pressure metrics.
#[derive(Debug, Clone)]
pub struct StagedBuffer {
    buf: Buffer,
    /// Resident regions, oldest first.
    regions: VecDeque<(u64, usize)>,
    evictions: u64,
    evicted_bytes: u64,
    overflows: u64,
    peak_used: usize,
}

impl StagedBuffer {
    /// Staging buffer with an exact byte capacity.
    pub fn new(name: &'static str, capacity_bytes: usize) -> Self {
        StagedBuffer {
            buf: Buffer::with_capacity_bytes(name, capacity_bytes),
            regions: VecDeque::new(),
            evictions: 0,
            evicted_bytes: 0,
            overflows: 0,
            peak_used: 0,
        }
    }

    /// Total capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Bytes occupied by resident regions.
    pub fn used(&self) -> usize {
        self.buf.used()
    }

    /// Whether region `id` is currently resident.
    pub fn contains(&self, id: u64) -> bool {
        self.regions.iter().any(|&(rid, _)| rid == id)
    }

    /// Stage a region: evict oldest residents (FIFO) until it fits,
    /// then account it.  A region bigger than the whole capacity still
    /// stages (flagged `overflowed`) — the model must keep executing,
    /// it just reports occupancy > 1.  Re-staging a resident id first
    /// releases the old copy (a reload, not a duplicate).
    pub fn stage(&mut self, id: u64, bytes: usize) -> StageOutcome {
        let mut outcome = StageOutcome::default();
        if self.contains(id) {
            self.release(id);
        }
        while self.used() + bytes > self.capacity() && !self.regions.is_empty() {
            let (_, freed) = self.regions.pop_front().expect("non-empty");
            self.buf.release(freed);
            self.evictions += 1;
            self.evicted_bytes += freed as u64;
            outcome.evicted += 1;
            outcome.evicted_bytes += freed;
        }
        if !self.buf.alloc(bytes) {
            // single region over capacity: force-stage and flag it
            self.buf.used_bytes += bytes;
            self.overflows += 1;
            outcome.overflowed = true;
        }
        self.regions.push_back((id, bytes));
        self.peak_used = self.peak_used.max(self.buf.used());
        outcome
    }

    /// Release region `id` if resident (idempotent).
    pub fn release(&mut self, id: u64) {
        if let Some(pos) = self.regions.iter().position(|&(rid, _)| rid == id) {
            let (_, bytes) = self.regions.remove(pos).expect("position valid");
            // an overflowed region may exceed nominal accounting; the
            // saturating release keeps the books consistent
            self.buf.used_bytes = self.buf.used_bytes.saturating_sub(bytes);
        }
    }

    /// Fraction of capacity in use; exceeds 1.0 after an overflow.
    pub fn occupancy(&self) -> f64 {
        self.used() as f64 / self.capacity().max(1) as f64
    }

    /// High-water mark of [`StagedBuffer::used`] over the lifetime.
    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    /// Total regions evicted to make room for later ones.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Total bytes freed by evictions.
    pub fn evicted_bytes(&self) -> u64 {
        self.evicted_bytes
    }

    /// Times a single region exceeded the whole capacity.
    pub fn overflows(&self) -> u64 {
        self.overflows
    }
}

/// Ping-pong activation memory: two half-capacity banks that swap roles
/// between layers (read current layer's inputs from one, write outputs
/// to the other — hides the writeback behind the next layer's compute).
#[derive(Debug, Clone)]
pub struct PingPong {
    banks: [Buffer; 2],
    active: usize,
}

impl PingPong {
    /// Two banks of `total_kb / 2` each.
    pub fn new(total_kb: usize) -> Self {
        PingPong {
            banks: [
                Buffer::new("pingpong.a", total_kb / 2),
                Buffer::new("pingpong.b", total_kb / 2),
            ],
            active: 0,
        }
    }

    /// Bank being read (current layer inputs).
    pub fn read_bank(&self) -> &Buffer {
        &self.banks[self.active]
    }

    /// Bank being written (current layer outputs).
    pub fn write_bank(&mut self) -> &mut Buffer {
        &mut self.banks[1 - self.active]
    }

    /// Swap roles at a layer boundary; the new write bank is cleared.
    pub fn swap(&mut self) {
        self.active = 1 - self.active;
        self.banks[1 - self.active].reset();
    }

    /// Capacity of one bank in bytes.
    pub fn bank_capacity(&self) -> usize {
        self.banks[0].capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_alloc_release() {
        let mut b = Buffer::new("w", 1); // 1 KB
        assert!(b.alloc(512));
        assert!(b.alloc(512));
        assert!(!b.alloc(1)); // full
        b.release(256);
        assert!(b.alloc(256));
        assert_eq!(b.used(), 1024);
        assert!((b.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "over-release")]
    fn over_release_panics() {
        let mut b = Buffer::new("w", 1);
        b.release(1);
    }

    #[test]
    fn buffer_overflow_edges() {
        // exact fit succeeds; one byte over is refused and leaves the
        // occupancy untouched (a refused alloc must not leak)
        let mut b = Buffer::with_capacity_bytes("w", 100);
        assert!(!b.alloc(101), "over-capacity alloc must fail");
        assert_eq!(b.used(), 0, "refused alloc leaked occupancy");
        assert!(b.alloc(100), "exact-fit alloc must succeed");
        assert_eq!(b.free(), 0);
        assert!(!b.alloc(1));
        // zero-byte alloc is always admissible, even when full
        assert!(b.alloc(0));
        b.release(100);
        assert_eq!(b.used(), 0);
    }

    #[test]
    fn pingpong_swap_clears_new_write_bank() {
        let mut pp = PingPong::new(128);
        assert_eq!(pp.bank_capacity(), 64 * 1024);
        assert!(pp.write_bank().alloc(1000));
        pp.swap();
        // previous write bank is now the read bank and keeps its data
        assert_eq!(pp.read_bank().used(), 1000);
        // the new write bank (old read bank) was cleared
        assert_eq!(pp.write_bank().used(), 0);
    }

    #[test]
    fn staged_buffer_evicts_oldest_first() {
        let mut s = StagedBuffer::new("wm", 100);
        assert_eq!(s.stage(1, 40), StageOutcome::default());
        assert_eq!(s.stage(2, 40), StageOutcome::default());
        // 40 + 40 + 40 > 100: region 1 (oldest) must go
        let o = s.stage(3, 40);
        assert_eq!(o.evicted, 1);
        assert_eq!(o.evicted_bytes, 40);
        assert!(!o.overflowed);
        assert!(!s.contains(1));
        assert!(s.contains(2) && s.contains(3));
        assert_eq!(s.used(), 80);
        assert_eq!(s.evictions(), 1);
        assert_eq!(s.evicted_bytes(), 40);
        assert_eq!(s.peak_used(), 80);
    }

    #[test]
    fn staged_buffer_evicts_multiple_when_needed() {
        let mut s = StagedBuffer::new("wm", 100);
        s.stage(1, 30);
        s.stage(2, 30);
        s.stage(3, 30);
        // 90 resident; 80 more evicts regions until it fits — after
        // two evictions 30 + 80 still exceeds 100, so all three go
        let o = s.stage(4, 80);
        assert_eq!(o.evicted, 3);
        assert_eq!(o.evicted_bytes, 90);
        assert!(!o.overflowed);
        assert!(!s.contains(1) && !s.contains(2) && !s.contains(3));
        assert!(s.contains(4));
        assert_eq!(s.used(), 80);
    }

    #[test]
    fn staged_buffer_overflow_single_region() {
        // one region larger than the whole capacity: everything else is
        // evicted, the region stages anyway, occupancy exceeds 1.0
        let mut s = StagedBuffer::new("wm", 100);
        s.stage(1, 50);
        let o = s.stage(2, 150);
        assert_eq!(o.evicted, 1);
        assert!(o.overflowed);
        assert!(s.contains(2));
        assert_eq!(s.used(), 150);
        assert!(s.occupancy() > 1.0);
        assert_eq!(s.overflows(), 1);
        // releasing the overflowed region restores a consistent zero
        s.release(2);
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn staged_buffer_restage_is_reload_not_duplicate() {
        let mut s = StagedBuffer::new("wm", 100);
        s.stage(7, 60);
        // staging the same id again replaces the copy: no eviction of
        // *other* regions, no double-counting
        let o = s.stage(7, 60);
        assert_eq!(o.evicted, 0);
        assert_eq!(s.used(), 60);
        assert!(s.contains(7));
    }

    #[test]
    fn staged_buffer_release_is_idempotent() {
        let mut s = StagedBuffer::new("wm", 100);
        s.stage(1, 10);
        s.release(1);
        s.release(1); // second release of an absent id is a no-op
        assert_eq!(s.used(), 0);
        assert!(!s.contains(1));
    }

    #[test]
    fn staged_buffer_exact_fit_does_not_evict() {
        let mut s = StagedBuffer::new("wm", 100);
        s.stage(1, 60);
        let o = s.stage(2, 40); // exactly fills the buffer
        assert_eq!(o.evicted, 0);
        assert!(!o.overflowed);
        assert_eq!(s.used(), 100);
        assert!((s.occupancy() - 1.0).abs() < 1e-12);
    }
}
