//! Merge unit: shift-&-add + Accumulate-and-Recover Unit (Fig. 8).
//!
//! The shift-&-add unit recombines adder-tree outputs across weight-bit
//! positions and bit-serial input cycles (two's complement: the MSB of
//! each operand carries negative weight).  The ARU implements Eq. 7:
//!
//! ```text
//! O = Σ(I * f^c) + (ΣI) · M
//! ```
//!
//! recovering the convolution result of *both* twins of a filter pair
//! from the stored psum, the complementary psum and the input sum.  FC
//! layers bypass the recover stage (paper §III-C3).

/// Two's-complement significance of bit `k` in a `bits`-wide operand.
#[inline]
pub fn bit_weight(k: usize, bits: usize) -> i64 {
    if k == bits - 1 {
        -(1i64 << k)
    } else {
        1i64 << k
    }
}

/// Shift-&-add accumulation: fold one adder-tree output (`tree_sum`, the
/// count of set AND results) for input-bit `ki` and weight-bit `kw` into
/// a partial sum.
#[inline]
pub fn shift_add(psum: &mut i64, tree_sum: u32, ki: usize, kw: usize, bits: usize) {
    *psum += tree_sum as i64 * bit_weight(ki, bits) * bit_weight(kw, bits);
}

/// ARU recovery for one FCC filter pair (double computing mode).
///
/// * `psum_q`    — Σ INP·w       (stored even comp filter)
/// * `psum_qbar` — Σ INN·(!w)    (free odd comp filter)
/// * `sum_p`/`sum_n` — ΣI on the INP / INN streams (equal for std/pw
///   where both streams carry the same vector; distinct for dw)
/// * `m` — the pair mean
///
/// Returns `(out_even, out_odd)`.
pub fn aru_recover(psum_q: i64, psum_qbar: i64, sum_p: i64, sum_n: i64, m: i64) -> (i64, i64) {
    (psum_q + sum_p * m, psum_qbar + sum_n * m)
}

/// FC-layer path: recover unit disabled, psum passes through.
pub fn aru_bypass(psum_q: i64) -> i64 {
    psum_q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn bit_weights_8b() {
        assert_eq!(bit_weight(0, 8), 1);
        assert_eq!(bit_weight(6, 8), 64);
        assert_eq!(bit_weight(7, 8), -128);
    }

    #[test]
    fn shift_add_reconstructs_product() {
        // one "compartment": x * w must emerge from the 64 bit-plane terms
        forall(
            51,
            300,
            |r| (r.int8() as i64, r.int8() as i64),
            |&(x, w)| {
                let mut psum = 0i64;
                for ki in 0..8 {
                    let xb = ((x as u8) >> ki) & 1;
                    for kw in 0..8 {
                        let wb = ((w as u8) >> kw) & 1;
                        shift_add(&mut psum, (xb & wb) as u32, ki, kw, 8);
                    }
                }
                psum == x * w
            },
        );
    }

    #[test]
    fn aru_eq7() {
        // direct check of Eq. 7 against integer conv on a tiny vector:
        // I = [2, -1], f0^c = [3, -6], M = 1
        // psum_q = 2*3 + (-1)(-6) = 12; f1^c = !f0^c = [-4, 5]
        // psum_qbar = 2*(-4) + (-1)(5) = -13; ΣI = 1
        let (even, odd) = aru_recover(12, -13, 1, 1, 1);
        // f0^bc = f0^c + M = [4, -5] -> O_even = 2*4 + (-1)(-5) = 13
        assert_eq!(even, 13);
        // f1^bc = f1^c + M = [-3, 6] -> O_odd = 2*(-3) + (-1)(6) = -12
        assert_eq!(odd, -12);
    }

    #[test]
    fn aru_identity_property() {
        // for random x, w, M: recover(psum(w^c)) == psum(w^c + M)
        forall(
            52,
            200,
            |r| {
                let l = 1 + r.below(12) as usize;
                let xs: Vec<i64> = (0..l).map(|_| r.int8() as i64).collect();
                let ws: Vec<i64> = (0..l).map(|_| r.range_i64(-100, 100) as i64).collect();
                let m = r.range_i64(-20, 21) as i64;
                (xs, ws, m)
            },
            |(xs, ws, m)| {
                let psum_q: i64 = xs.iter().zip(ws).map(|(x, w)| x * w).sum();
                let psum_qbar: i64 = xs.iter().zip(ws).map(|(x, w)| x * (-w - 1)).sum();
                let si: i64 = xs.iter().sum();
                let (even, odd) = aru_recover(psum_q, psum_qbar, si, si, *m);
                let direct_even: i64 = xs.iter().zip(ws).map(|(x, w)| x * (w + m)).sum();
                let direct_odd: i64 =
                    xs.iter().zip(ws).map(|(x, w)| x * (-w - 1 + m)).sum();
                even == direct_even && odd == direct_odd
            },
        );
    }

    #[test]
    fn fc_bypass() {
        assert_eq!(aru_bypass(42), 42);
    }
}
