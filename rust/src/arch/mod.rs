//! Cycle-accurate / functional model of the DDC-PIM hardware (Fig. 5–8).
//!
//! Two views of the same fabric:
//!
//! * **Functional** ([`sram`], [`lpu`], [`dbmu`], [`compartment`],
//!   [`adder_tree`], [`reconfig`], [`pim_core`], [`pim_macro`],
//!   [`merge`]) — bit-true models of each circuit block, composed into a
//!   macro executor whose outputs are verified against the direct-conv
//!   oracle.  This is how we prove the Q/Q̄-doubling produces correct
//!   numerics (the paper's Fig. 6 truth table and Eq. 7).
//! * **Timing/energy** ([`mem`], [`dram`], [`prepost`], [`cost`]) —
//!   resource models consumed by the cycle engine in [`crate::sim`].
//!
//! [`fault`] cuts across the functional view: seeded bit-cell fault
//! injection on the single weight-write path plus the integrity scrub
//! that detects/repairs the damage (quarantine + spare-row re-home).
//!
//! [`grid`] scales the functional view out: a `rows × cols`
//! [`grid::MacroGrid`] of macros that the shard planner
//! ([`crate::mapping::shard`]) splits conv layers across, byte-identical
//! to the single-macro plans at every grid shape.

pub mod adder_tree;
pub mod compartment;
pub mod controller;
pub mod cost;
pub mod dbmu;
pub mod dram;
pub mod fault;
pub mod grid;
pub mod lpu;
pub mod mem;
pub mod merge;
pub mod pim_core;
pub mod pim_macro;
pub mod prepost;
pub mod reconfig;
pub mod sram;
