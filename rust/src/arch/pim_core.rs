//! PIM core: 32 compartments + mode control (Fig. 6(c)).
//!
//! The core exposes exactly the operations the top controller issues:
//! normal-SRAM row writes (weight load), and one-row-per-cycle compute
//! with per-compartment vector inputs on the INP/INN broadcast pairs.
//! Spatial accumulation across compartments is the reconfigurable unit's
//! job ([`super::reconfig`]).

use super::compartment::{Compartment, CompartmentOut};
use super::lpu::Mode;

/// One PIM core.
#[derive(Debug, Clone)]
pub struct PimCore {
    compartments: Vec<Compartment>,
    rows: usize,
    dbmus: usize,
}

impl PimCore {
    pub fn new(compartments: usize, rows: usize, dbmus: usize) -> Self {
        PimCore {
            compartments: (0..compartments)
                .map(|_| Compartment::new(rows, dbmus))
                .collect(),
            rows,
            dbmus,
        }
    }

    /// Paper geometry: 32 compartments x 64 rows x 16 columns.
    pub fn paper() -> Self {
        Self::new(32, 64, 16)
    }

    pub fn num_compartments(&self) -> usize {
        self.compartments.len()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Weight slots per row per compartment (2 for 16 columns).
    pub fn slots(&self) -> usize {
        self.dbmus / 8
    }

    /// Normal-SRAM-mode weight write.
    pub fn write_weight(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        self.compartments[cmp].write_weight8(row, slot, w);
    }

    /// Read back (Q side) — test/debug path.
    pub fn read_weight(&self, cmp: usize, row: usize, slot: usize) -> i32 {
        self.compartments[cmp].read_weight8(row, slot)
    }

    /// One compute cycle: activate `row` in every compartment, drive the
    /// per-compartment INP/INN bits, collect all readouts.
    ///
    /// `inp_bits`/`inn_bits` are indexed by compartment (the vector-wise
    /// input of §III-D1); within a compartment the bit is broadcast to
    /// all 16 LPUs by the DBIS.
    pub fn compute_cycle(
        &self,
        row: usize,
        inp_bits: &[bool],
        inn_bits: &[bool],
        mode: Mode,
    ) -> Vec<CompartmentOut> {
        assert_eq!(inp_bits.len(), self.compartments.len());
        assert_eq!(inn_bits.len(), self.compartments.len());
        self.compartments
            .iter()
            .enumerate()
            .map(|(i, c)| c.compute(row, inp_bits[i], inn_bits[i], mode))
            .collect()
    }

    /// Array size in bits.
    pub fn size_bits(&self) -> usize {
        self.compartments.len() * self.rows * self.dbmus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_core_is_32kb() {
        let core = PimCore::paper();
        assert_eq!(core.size_bits(), 32 * 1024);
        assert_eq!(core.slots(), 2);
    }

    #[test]
    fn weight_write_read() {
        let mut core = PimCore::new(4, 8, 16);
        core.write_weight(2, 3, 1, -77);
        assert_eq!(core.read_weight(2, 3, 1), -77);
        assert_eq!(core.read_weight(2, 3, 0), 0);
    }

    #[test]
    fn compute_cycle_per_compartment_inputs() {
        let mut core = PimCore::new(2, 2, 16);
        core.write_weight(0, 0, 0, 1); // bit 0 set in cmp 0
        core.write_weight(1, 0, 0, 1); // bit 0 set in cmp 1
        let outs = core.compute_cycle(0, &[true, false], &[false, false], Mode::Regular);
        assert!(outs[0].q(0)); // cmp 0 sees INP=1
        assert!(!outs[1].q(0)); // cmp 1 sees INP=0
    }
}
