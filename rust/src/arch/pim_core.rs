//! PIM core: 32 compartments + mode control (Fig. 6(c)).
//!
//! The core exposes exactly the operations the top controller issues:
//! normal-SRAM row writes (weight load), and one-row-per-cycle compute
//! with per-compartment vector inputs on the INP/INN broadcast pairs.
//! Spatial accumulation across compartments is the reconfigurable unit's
//! job ([`super::reconfig`]).
//!
//! Storage is kept twice, coherently, by the single write path
//! ([`PimCore::write_weight`]):
//!
//! * per-cell ([`Compartment`]/DBMU/6T) — the faithful circuit view used
//!   by the scalar oracle ([`PimCore::compute_cycle`]) and readback;
//! * per-bit-plane ([`WeightPlanes`]) — one `[u64; ceil(cmps/64)]`
//!   multi-word plane per (row, slot, weight-bit) packing that bit
//!   across all compartments, plus per-word nonzero summaries of both
//!   polarities, so the bitsliced hot path in [`super::pim_macro`]
//!   reduces a whole adder-tree column with one AND + `count_ones` per
//!   word — and skips the columns whose plane is dark.
pub use super::sram::WeightPlanes;

use super::compartment::{Compartment, CompartmentOut};
use super::lpu::Mode;

/// Weight precision of a row slot (8 columns per INT8 weight).
pub const WEIGHT_BITS: usize = 8;

/// Macro geometry knob for planners and sessions: compartment (lane)
/// count, rows, and per-compartment columns.  [`MacroGeometry::paper`]
/// is the published 32×64×16 configuration; compartment counts above 64
/// are packed as multi-word [`WeightPlanes`] by the bitsliced fabric,
/// so the scaled-up configs of the density argument plan and execute
/// like any other geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroGeometry {
    pub compartments: usize,
    pub rows: usize,
    pub dbmus: usize,
}

impl MacroGeometry {
    /// The published geometry: 32 compartments × 64 rows × 16 columns.
    pub fn paper() -> Self {
        MacroGeometry {
            compartments: PimCore::PAPER_COMPARTMENTS,
            rows: PimCore::PAPER_ROWS,
            dbmus: PimCore::PAPER_DBMUS,
        }
    }

    /// Paper rows/columns at a scaled compartment count.
    pub fn with_compartments(compartments: usize) -> Self {
        MacroGeometry {
            compartments,
            ..Self::paper()
        }
    }

    /// Weight slots per row per compartment (2 for 16 columns).
    pub fn slots(&self) -> usize {
        self.dbmus / WEIGHT_BITS
    }
}

impl Default for MacroGeometry {
    fn default() -> Self {
        Self::paper()
    }
}

/// One PIM core.
#[derive(Debug, Clone)]
pub struct PimCore {
    compartments: Vec<Compartment>,
    planes: WeightPlanes,
    rows: usize,
    dbmus: usize,
    weight_writes: u64,
}

impl PimCore {
    pub fn new(compartments: usize, rows: usize, dbmus: usize) -> Self {
        assert!(
            dbmus % WEIGHT_BITS == 0,
            "dbmus {dbmus} not a multiple of the {WEIGHT_BITS}-bit weight slot"
        );
        let slots = dbmus / WEIGHT_BITS;
        PimCore {
            compartments: (0..compartments)
                .map(|_| Compartment::new(rows, dbmus))
                .collect(),
            planes: WeightPlanes::new(compartments, rows, slots, WEIGHT_BITS),
            rows,
            dbmus,
            weight_writes: 0,
        }
    }

    /// Paper geometry: 32 compartments x 64 rows x 16 columns.
    /// (Constants exposed so planners can size pass schedules without
    /// building a throwaway cell array.)
    pub const PAPER_COMPARTMENTS: usize = 32;
    pub const PAPER_ROWS: usize = 64;
    pub const PAPER_DBMUS: usize = 16;

    /// A core at the paper geometry.
    pub fn paper() -> Self {
        Self::with_geometry(MacroGeometry::paper())
    }

    /// A core at an explicit [`MacroGeometry`].
    pub fn with_geometry(geom: MacroGeometry) -> Self {
        Self::new(geom.compartments, geom.rows, geom.dbmus)
    }

    pub fn num_compartments(&self) -> usize {
        self.compartments.len()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Weight slots per row per compartment (2 for 16 columns).
    pub fn slots(&self) -> usize {
        self.dbmus / WEIGHT_BITS
    }

    /// Normal-SRAM-mode weight write (updates both the per-cell array and
    /// the bit-plane shadow — the only weight write path).
    pub fn write_weight(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        self.compartments[cmp].write_weight8(row, slot, w);
        self.planes.record(cmp, row, slot, w);
        self.weight_writes += 1;
    }

    /// Total normal-SRAM weight writes since construction.  The planned
    /// executors expose this so tests can assert that a session writes
    /// its weights exactly once (at plan-build time) and never again on
    /// the `&self` execute path.
    pub fn weight_writes(&self) -> u64 {
        self.weight_writes
    }

    /// Read back (Q side) — test/debug path.
    pub fn read_weight(&self, cmp: usize, row: usize, slot: usize) -> i32 {
        self.compartments[cmp].read_weight8(row, slot)
    }

    /// The packed per-weight-bit view of the stored array (hot path).
    #[inline]
    pub fn weight_planes(&self) -> &WeightPlanes {
        &self.planes
    }

    /// One compute cycle: activate `row` in every compartment, drive the
    /// per-compartment INP/INN bits, collect all readouts.
    ///
    /// `inp_bits`/`inn_bits` are indexed by compartment (the vector-wise
    /// input of §III-D1); within a compartment the bit is broadcast to
    /// all 16 LPUs by the DBIS.
    ///
    /// This is the per-cell circuit walk — the differential-testing
    /// oracle for the word-parallel planes; the hot executors go through
    /// [`super::pim_macro::PimMacro::mvm_row_into`] instead.
    pub fn compute_cycle(
        &self,
        row: usize,
        inp_bits: &[bool],
        inn_bits: &[bool],
        mode: Mode,
    ) -> Vec<CompartmentOut> {
        assert_eq!(inp_bits.len(), self.compartments.len());
        assert_eq!(inn_bits.len(), self.compartments.len());
        self.compartments
            .iter()
            .enumerate()
            .map(|(i, c)| c.compute(row, inp_bits[i], inn_bits[i], mode))
            .collect()
    }

    /// Array size in bits.
    pub fn size_bits(&self) -> usize {
        self.compartments.len() * self.rows * self.dbmus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_core_is_32kb() {
        let core = PimCore::paper();
        assert_eq!(core.size_bits(), 32 * 1024);
        assert_eq!(core.slots(), 2);
    }

    #[test]
    fn weight_write_read() {
        let mut core = PimCore::new(4, 8, 16);
        core.write_weight(2, 3, 1, -77);
        assert_eq!(core.read_weight(2, 3, 1), -77);
        assert_eq!(core.read_weight(2, 3, 0), 0);
    }

    #[test]
    fn compute_cycle_per_compartment_inputs() {
        let mut core = PimCore::new(2, 2, 16);
        core.write_weight(0, 0, 0, 1); // bit 0 set in cmp 0
        core.write_weight(1, 0, 0, 1); // bit 0 set in cmp 1
        let outs = core.compute_cycle(0, &[true, false], &[false, false], Mode::Regular);
        assert!(outs[0].q(0)); // cmp 0 sees INP=1
        assert!(!outs[1].q(0)); // cmp 1 sees INP=0
    }

    #[test]
    fn planes_stay_coherent_with_cells() {
        use crate::util::rng::Rng;
        // 96 compartments = 2 plane words: the coherence walk crosses
        // the word seam (cmp 64) as well as the partial last word
        let (cmps, rows) = (96usize, 4usize);
        let mut rng = Rng::new(17);
        let mut core = PimCore::new(cmps, rows, 16);
        // random writes, including overwrites of the same (cmp, row, slot)
        for _ in 0..600 {
            let cmp = rng.below(cmps as u64) as usize;
            let row = rng.below(rows as u64) as usize;
            let slot = rng.below(2) as usize;
            core.write_weight(cmp, row, slot, rng.int8() as i32);
        }
        // every plane bit must equal the corresponding cell's Q
        for row in 0..rows {
            for slot in 0..2 {
                for kw in 0..WEIGHT_BITS {
                    for cmp in 0..cmps {
                        let plane = core.weight_planes().plane(row, slot, kw, cmp / 64);
                        let w = core.read_weight(cmp, row, slot);
                        let q = (w as u32 >> kw) & 1 == 1;
                        assert_eq!(
                            (plane >> (cmp % 64)) & 1 == 1,
                            q,
                            "plane/cell drift at cmp={cmp} row={row} slot={slot} kw={kw}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn geometry_builds_matching_core() {
        let geom = MacroGeometry::with_compartments(128);
        assert_eq!(geom.slots(), 2);
        let core = PimCore::with_geometry(geom);
        assert_eq!(core.num_compartments(), 128);
        assert_eq!(core.rows(), PimCore::PAPER_ROWS);
        assert_eq!(core.weight_planes().nwords(), 2);
        assert_eq!(MacroGeometry::default(), MacroGeometry::paper());
    }
}
