//! PIM core: 32 compartments + mode control (Fig. 6(c)).
//!
//! The core exposes exactly the operations the top controller issues:
//! normal-SRAM row writes (weight load), and one-row-per-cycle compute
//! with per-compartment vector inputs on the INP/INN broadcast pairs.
//! Spatial accumulation across compartments is the reconfigurable unit's
//! job ([`super::reconfig`]).
//!
//! Storage is kept twice, coherently, by the single write path
//! ([`PimCore::write_weight`]):
//!
//! * per-cell ([`Compartment`]/DBMU/6T) — the faithful circuit view used
//!   by the scalar oracle ([`PimCore::compute_cycle`]) and readback;
//! * per-bit-plane ([`WeightPlanes`]) — one `[u64; ceil(cmps/64)]`
//!   multi-word plane per (row, slot, weight-bit) packing that bit
//!   across all compartments, plus per-word nonzero summaries of both
//!   polarities, so the bitsliced hot path in [`super::pim_macro`]
//!   reduces a whole adder-tree column with one AND + `count_ones` per
//!   word — and skips the columns whose plane is dark.
//!
//! Reliability: an optional [`FaultState`] (installed from a
//! [`FaultPlan`] before any weight is written) interposes on the same
//! single write path — intended values go to a logical intent ledger,
//! corrupted values go to both storage views — and [`PimCore::scrub`]
//! detects/repairs the damage.  With no plan installed the legacy path
//! runs untouched, byte for byte.
pub use super::sram::WeightPlanes;

use super::compartment::{Compartment, CompartmentOut};
use super::fault::{plane_checksum, FaultPlan, FaultState, FaultTally, ScrubReport, UpsetConfig};
use super::lpu::Mode;
use crate::util::rng::Rng;

/// Weight precision of a row slot (8 columns per INT8 weight).
pub const WEIGHT_BITS: usize = 8;

/// Macro geometry knob for planners and sessions: compartment (lane)
/// count, rows, and per-compartment columns.  [`MacroGeometry::paper`]
/// is the published 32×64×16 configuration; compartment counts above 64
/// are packed as multi-word [`WeightPlanes`] by the bitsliced fabric,
/// so the scaled-up configs of the density argument plan and execute
/// like any other geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroGeometry {
    pub compartments: usize,
    pub rows: usize,
    pub dbmus: usize,
}

impl MacroGeometry {
    /// The published geometry: 32 compartments × 64 rows × 16 columns.
    pub fn paper() -> Self {
        MacroGeometry {
            compartments: PimCore::PAPER_COMPARTMENTS,
            rows: PimCore::PAPER_ROWS,
            dbmus: PimCore::PAPER_DBMUS,
        }
    }

    /// Paper rows/columns at a scaled compartment count.
    pub fn with_compartments(compartments: usize) -> Self {
        MacroGeometry {
            compartments,
            ..Self::paper()
        }
    }

    /// Weight slots per row per compartment (2 for 16 columns).
    pub fn slots(&self) -> usize {
        self.dbmus / WEIGHT_BITS
    }
}

impl Default for MacroGeometry {
    fn default() -> Self {
        Self::paper()
    }
}

/// One PIM core.
#[derive(Debug, Clone)]
pub struct PimCore {
    compartments: Vec<Compartment>,
    planes: WeightPlanes,
    rows: usize,
    dbmus: usize,
    weight_writes: u64,
    /// Fault model + integrity state; `None` = the untouched legacy
    /// write/read path (the zero-fault byte-identity guarantee).
    faults: Option<FaultState>,
}

impl PimCore {
    pub fn new(compartments: usize, rows: usize, dbmus: usize) -> Self {
        assert!(
            dbmus % WEIGHT_BITS == 0,
            "dbmus {dbmus} not a multiple of the {WEIGHT_BITS}-bit weight slot"
        );
        let slots = dbmus / WEIGHT_BITS;
        PimCore {
            compartments: (0..compartments)
                .map(|_| Compartment::new(rows, dbmus))
                .collect(),
            planes: WeightPlanes::new(compartments, rows, slots, WEIGHT_BITS),
            rows,
            dbmus,
            weight_writes: 0,
            faults: None,
        }
    }

    /// Paper geometry: 32 compartments x 64 rows x 16 columns.
    /// (Constants exposed so planners can size pass schedules without
    /// building a throwaway cell array.)
    pub const PAPER_COMPARTMENTS: usize = 32;
    pub const PAPER_ROWS: usize = 64;
    pub const PAPER_DBMUS: usize = 16;

    /// A core at the paper geometry.
    pub fn paper() -> Self {
        Self::with_geometry(MacroGeometry::paper())
    }

    /// A core at an explicit [`MacroGeometry`].
    pub fn with_geometry(geom: MacroGeometry) -> Self {
        Self::new(geom.compartments, geom.rows, geom.dbmus)
    }

    pub fn num_compartments(&self) -> usize {
        self.compartments.len()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Weight slots per row per compartment (2 for 16 columns).
    pub fn slots(&self) -> usize {
        self.dbmus / WEIGHT_BITS
    }

    /// Normal-SRAM-mode weight write (updates both the per-cell array and
    /// the bit-plane shadow — the only weight write path).
    ///
    /// With a fault plan installed, `row` is a *logical* row: the intent
    /// ledger records `w`, the write lands on the mapped physical row,
    /// and the physical location's fault masks corrupt the stored value
    /// — identically in both storage views, so cell/plane coherence
    /// holds under injection.
    pub fn write_weight(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        match &mut self.faults {
            None => {
                self.compartments[cmp].write_weight8(row, slot, w);
                self.planes.record(cmp, row, slot, w);
            }
            Some(fs) => {
                fs.record_intent(cmp, row, slot, w);
                let phys = fs.physical(row);
                let fw = fs.corrupt(cmp, phys, slot, w);
                self.compartments[cmp].write_weight8(phys, slot, fw);
                self.planes.record(cmp, phys, slot, fw);
            }
        }
        self.weight_writes += 1;
    }

    /// Install a bit-cell fault plan.  Must precede every weight write
    /// (faults manifest through the write path; retrofitting a plan onto
    /// a loaded core would miss the writes that already happened).
    pub fn install_fault_plan(&mut self, plan: &FaultPlan) {
        assert_eq!(
            self.weight_writes, 0,
            "fault plan must be installed on a fresh core, before any weight write"
        );
        self.faults = Some(FaultState::new(
            self.compartments.len(),
            self.rows,
            self.slots(),
            plan,
        ));
    }

    /// Physical home of a logical row (identity without a fault plan or
    /// before any quarantine re-homed a row).  Every row-addressed read
    /// path maps through this.
    #[inline]
    pub fn physical_row(&self, row: usize) -> usize {
        match &self.faults {
            Some(fs) => fs.physical(row),
            None => row,
        }
    }

    /// Lifetime fault-injection/detection/repair totals (all-zero when
    /// no plan is installed).
    pub fn fault_tally(&self) -> FaultTally {
        self.faults.as_ref().map(|f| f.tally()).unwrap_or_default()
    }

    /// Integrity scrub: verify every `(row, slot, word)` unit's stored
    /// Q-plane checksum against the write-intent ledger (the Q̄ polarity
    /// is derived from Q, so this covers both — see [`super::fault`]),
    /// quarantine corrupt rows, re-home them onto clean spare rows
    /// through the still-faulted write path, and zero rows for which no
    /// clean spare remains.  No-op returning an empty report when no
    /// fault plan is installed.  Scrub writes are maintenance, not
    /// weight loads: `weight_writes` is unchanged.
    pub fn scrub(&mut self) -> ScrubReport {
        self.scrub_window(0, self.stripe_count())
    }

    /// Number of `(row, slot, word)` checksum stripes in this core —
    /// the unit the incremental scrub scheduler budgets over.  Stripe
    /// `s` decodes as `row = s / (slots*nwords)`,
    /// `slot = (s % (slots*nwords)) / nwords`, `word = s % nwords`.
    pub fn stripe_count(&self) -> usize {
        self.rows * self.slots() * self.planes.nwords()
    }

    /// Incremental integrity scrub over the stripe window
    /// `[start, start+len)` (clamped to [`Self::stripe_count`]).  The
    /// first corrupt stripe of a row triggers a full-row damage scan
    /// (booking every divergent stripe and the pre-repair corrupt-bit
    /// blast radius) and repairs the row immediately, so later stripes
    /// of the same row verify clean — a full pass in any window
    /// partition books exactly what one monolithic [`Self::scrub`]
    /// does.  No-op returning an empty report when no fault plan is
    /// installed.
    pub fn scrub_window(&mut self, start: usize, len: usize) -> ScrubReport {
        let mut report = ScrubReport::default();
        let Some(mut fs) = self.faults.take() else {
            return report;
        };
        let nwords = self.planes.nwords();
        let per_row = self.slots() * nwords;
        let total = self.rows * per_row;
        let end = (start.saturating_add(len)).min(total);
        for s in start.min(total)..end {
            let row = s / per_row;
            let slot = (s % per_row) / nwords;
            let wi = s % nwords;
            report.checked_words += 1;
            let phys = fs.physical(row);
            let stored = plane_checksum(self.planes.word_planes(phys, slot, wi).0);
            if stored != fs.golden_checksum(row, slot, wi) {
                self.quarantine_and_repair(&mut fs, row, &mut report);
            }
        }
        fs.book_scrub(&report);
        self.faults = Some(fs);
        report
    }

    /// Damage-scan, quarantine, and repair one corrupt logical row.
    /// Repair is in-place first: replaying the row's intent through the
    /// still-faulted write path at its *current* home clears pure
    /// retention upsets without consuming a spare; only a home that
    /// fails post-replay verification (persistent stuck-ats) falls to
    /// the spare re-home / zeroize pipeline.
    fn quarantine_and_repair(
        &mut self,
        fs: &mut FaultState,
        row: usize,
        report: &mut ScrubReport,
    ) {
        let slots = self.slots();
        let nwords = self.planes.nwords();
        let phys = fs.physical(row);
        // pre-repair damage scan: every divergent stripe of the row,
        // and the stored-vs-intent bit blast radius the upset tally
        // reconciles against
        for slot in 0..slots {
            for wi in 0..nwords {
                let stored = plane_checksum(self.planes.word_planes(phys, slot, wi).0);
                if stored != fs.golden_checksum(row, slot, wi) {
                    report.detected_words += 1;
                }
            }
        }
        for cmp in 0..self.compartments.len() {
            for slot in 0..slots {
                let stored = self.compartments[cmp].read_weight8(phys, slot) as u8;
                let meant = fs.intent(cmp, row, slot) as u8;
                report.corrupt_bits += (stored ^ meant).count_ones() as u64;
            }
        }
        report.quarantined_rows += 1;
        // in-place replay through the (still faulted) write path
        for cmp in 0..self.compartments.len() {
            for slot in 0..slots {
                let w = fs.intent(cmp, row, slot);
                let fw = fs.corrupt(cmp, phys, slot, w);
                self.compartments[cmp].write_weight8(phys, slot, fw);
                self.planes.record(cmp, phys, slot, fw);
            }
        }
        if self.row_matches_intent(fs, row, phys) {
            report.repaired_rows += 1;
            return;
        }
        let mut repaired = false;
        while let Some(spare) = fs.claim_spare() {
            // replay the row's intent through the (faulted) write
            // path at the spare's physical location
            for cmp in 0..self.compartments.len() {
                for slot in 0..slots {
                    let w = fs.intent(cmp, row, slot);
                    let fw = fs.corrupt(cmp, spare, slot, w);
                    self.compartments[cmp].write_weight8(spare, slot, fw);
                    self.planes.record(cmp, spare, slot, fw);
                }
            }
            if self.row_matches_intent(fs, row, spare) {
                fs.map_row(row, spare);
                fs.retire_row(phys);
                report.repaired_rows += 1;
                repaired = true;
                break;
            }
            // the spare carries stuck-ats of its own: retire it
            fs.mark_dead(spare);
            report.dead_spares += 1;
        }
        if !repaired {
            // graceful degradation: the periphery masks the row out
            // — model both intent and storage as all-zero, and
            // report the blast radius instead of serving corrupt
            // data
            report.zeroed_weights += fs.zero_intent_row(row);
            for cmp in 0..self.compartments.len() {
                for slot in 0..slots {
                    self.compartments[cmp].write_weight8(phys, slot, 0);
                    self.planes.record(cmp, phys, slot, 0);
                }
            }
            report.zeroed_rows += 1;
        }
    }

    /// Whether the stored planes at physical row `phys` match logical
    /// row `row`'s intent checksums stripe for stripe.
    fn row_matches_intent(&self, fs: &FaultState, row: usize, phys: usize) -> bool {
        let nwords = self.planes.nwords();
        (0..self.slots()).all(|slot| {
            (0..nwords).all(|wi| {
                plane_checksum(self.planes.word_planes(phys, slot, wi).0)
                    == fs.golden_checksum(row, slot, wi)
            })
        })
    }

    /// Arm the deterministic retention-upset process.  Requires an
    /// installed fault plan: upsets reconcile against the intent
    /// ledger, which only exists once [`Self::install_fault_plan`] ran
    /// (a zero-BER plan is the upsets-only configuration).
    pub fn arm_upsets(&mut self, cfg: UpsetConfig) {
        match &mut self.faults {
            Some(fs) => fs.arm_upsets(cfg),
            None => panic!(
                "upsets require an installed fault plan (the intent ledger is the golden reference)"
            ),
        }
    }

    /// Advance the virtual batch clock one tick and land this tick's
    /// retention upsets on the stored planes: per live `(cmp, row,
    /// slot)` byte one seeded draw decides whether a single bit flips
    /// (both storage views stay coherent; the intent ledger is
    /// untouched — it is the golden reference the scrub repairs
    /// toward).  Returns the number of bits flipped.  Deterministic in
    /// `(seed, tick)` alone; a no-op when no upset process is armed.
    /// Upset writes are maintenance, not weight loads: `weight_writes`
    /// is unchanged.
    pub fn tick_upsets(&mut self) -> u64 {
        let Some(mut fs) = self.faults.take() else {
            return 0;
        };
        let mut flipped = 0u64;
        if let Some(cfg) = fs.upsets() {
            let tick = fs.next_upset_tick();
            if cfg.per_batch_ber > 0.0 {
                let mut rng = Rng::new(cfg.seed ^ tick.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let slots = self.slots();
                // one draw per byte (≤1 flip per cell per tick), scaled
                // so the per-bit rate matches the configured BER
                let p_byte = (cfg.per_batch_ber * WEIGHT_BITS as f64).min(1.0);
                for row in 0..self.rows {
                    let phys = fs.physical(row);
                    if !fs.row_live(phys) {
                        continue;
                    }
                    for cmp in 0..self.compartments.len() {
                        for slot in 0..slots {
                            if rng.f64() >= p_byte {
                                continue;
                            }
                            let kw = rng.below(WEIGHT_BITS as u64) as usize;
                            let cur = self.compartments[cmp].read_weight8(phys, slot) as u8;
                            let upset = (cur ^ (1u8 << kw)) as i8 as i32;
                            self.compartments[cmp].write_weight8(phys, slot, upset);
                            self.planes.record(cmp, phys, slot, upset);
                            flipped += 1;
                        }
                    }
                }
            }
            fs.book_upsets(flipped);
        }
        self.faults = Some(fs);
        flipped
    }

    /// Total normal-SRAM weight writes since construction.  The planned
    /// executors expose this so tests can assert that a session writes
    /// its weights exactly once (at plan-build time) and never again on
    /// the `&self` execute path.
    pub fn weight_writes(&self) -> u64 {
        self.weight_writes
    }

    /// Read back (Q side) — test/debug path.  Logical row: a quarantined
    /// row reads from its spare home.
    pub fn read_weight(&self, cmp: usize, row: usize, slot: usize) -> i32 {
        let row = self.physical_row(row);
        self.compartments[cmp].read_weight8(row, slot)
    }

    /// The packed per-weight-bit view of the stored array (hot path).
    #[inline]
    pub fn weight_planes(&self) -> &WeightPlanes {
        &self.planes
    }

    /// One compute cycle: activate `row` in every compartment, drive the
    /// per-compartment INP/INN bits, collect all readouts.
    ///
    /// `inp_bits`/`inn_bits` are indexed by compartment (the vector-wise
    /// input of §III-D1); within a compartment the bit is broadcast to
    /// all 16 LPUs by the DBIS.
    ///
    /// This is the per-cell circuit walk — the differential-testing
    /// oracle for the word-parallel planes; the hot executors go through
    /// [`super::pim_macro::PimMacro::mvm_row_into`] instead.
    pub fn compute_cycle(
        &self,
        row: usize,
        inp_bits: &[bool],
        inn_bits: &[bool],
        mode: Mode,
    ) -> Vec<CompartmentOut> {
        assert_eq!(inp_bits.len(), self.compartments.len());
        assert_eq!(inn_bits.len(), self.compartments.len());
        let row = self.physical_row(row);
        self.compartments
            .iter()
            .enumerate()
            .map(|(i, c)| c.compute(row, inp_bits[i], inn_bits[i], mode))
            .collect()
    }

    /// Array size in bits.
    pub fn size_bits(&self) -> usize {
        self.compartments.len() * self.rows * self.dbmus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_core_is_32kb() {
        let core = PimCore::paper();
        assert_eq!(core.size_bits(), 32 * 1024);
        assert_eq!(core.slots(), 2);
    }

    #[test]
    fn weight_write_read() {
        let mut core = PimCore::new(4, 8, 16);
        core.write_weight(2, 3, 1, -77);
        assert_eq!(core.read_weight(2, 3, 1), -77);
        assert_eq!(core.read_weight(2, 3, 0), 0);
    }

    #[test]
    fn compute_cycle_per_compartment_inputs() {
        let mut core = PimCore::new(2, 2, 16);
        core.write_weight(0, 0, 0, 1); // bit 0 set in cmp 0
        core.write_weight(1, 0, 0, 1); // bit 0 set in cmp 1
        let outs = core.compute_cycle(0, &[true, false], &[false, false], Mode::Regular);
        assert!(outs[0].q(0)); // cmp 0 sees INP=1
        assert!(!outs[1].q(0)); // cmp 1 sees INP=0
    }

    #[test]
    fn planes_stay_coherent_with_cells() {
        use crate::util::rng::Rng;
        // 96 compartments = 2 plane words: the coherence walk crosses
        // the word seam (cmp 64) as well as the partial last word
        let (cmps, rows) = (96usize, 4usize);
        let mut rng = Rng::new(17);
        let mut core = PimCore::new(cmps, rows, 16);
        // random writes, including overwrites of the same (cmp, row, slot)
        for _ in 0..600 {
            let cmp = rng.below(cmps as u64) as usize;
            let row = rng.below(rows as u64) as usize;
            let slot = rng.below(2) as usize;
            core.write_weight(cmp, row, slot, rng.int8() as i32);
        }
        // every plane bit must equal the corresponding cell's Q
        for row in 0..rows {
            for slot in 0..2 {
                for kw in 0..WEIGHT_BITS {
                    for cmp in 0..cmps {
                        let plane = core.weight_planes().plane(row, slot, kw, cmp / 64);
                        let w = core.read_weight(cmp, row, slot);
                        let q = (w as u32 >> kw) & 1 == 1;
                        assert_eq!(
                            (plane >> (cmp % 64)) & 1 == 1,
                            q,
                            "plane/cell drift at cmp={cmp} row={row} slot={slot} kw={kw}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_fault_plan_is_byte_identical() {
        use crate::arch::fault::FaultPlan;
        use crate::util::rng::Rng;
        // the interposed (intent + map + corrupt) write path with an
        // all-clean fault state must store exactly what the legacy path
        // stores — cells, planes, write counter, compute outputs
        let (cmps, rows) = (96usize, 8usize);
        let mut plain = PimCore::new(cmps, rows, 16);
        let mut faulted = PimCore::new(cmps, rows, 16);
        faulted.install_fault_plan(&FaultPlan::empty());
        let mut rng = Rng::new(71);
        for _ in 0..500 {
            let cmp = rng.below(cmps as u64) as usize;
            let row = rng.below(rows as u64) as usize;
            let slot = rng.below(2) as usize;
            let w = rng.int8() as i32;
            plain.write_weight(cmp, row, slot, w);
            faulted.write_weight(cmp, row, slot, w);
        }
        assert_eq!(plain.weight_writes(), faulted.weight_writes());
        assert_eq!(faulted.fault_tally().injected_bits, 0);
        for row in 0..rows {
            assert_eq!(faulted.physical_row(row), row);
            for slot in 0..2 {
                for cmp in 0..cmps {
                    assert_eq!(
                        plain.read_weight(cmp, row, slot),
                        faulted.read_weight(cmp, row, slot)
                    );
                }
                for kw in 0..WEIGHT_BITS {
                    for wi in 0..2 {
                        assert_eq!(
                            plain.weight_planes().plane(row, slot, kw, wi),
                            faulted.weight_planes().plane(row, slot, kw, wi)
                        );
                    }
                }
            }
        }
        // and a scrub over the clean state detects nothing, changes nothing
        let report = faulted.scrub();
        assert!(report.is_clean());
        assert_eq!(report.quarantined_rows, 0);
        assert!(report.checked_words > 0);
    }

    #[test]
    fn stuck_at_fault_detected_and_repaired_onto_spare() {
        use crate::arch::fault::{Fault, FaultKind, FaultPlan};
        let mut core = PimCore::new(4, 8, 16);
        // bit 6 of (cmp 0, row 2, slot 0) reads 1 no matter what
        core.install_fault_plan(&FaultPlan::from_faults(vec![Fault {
            cmp: 0,
            row: 2,
            slot: 0,
            kw: 6,
            kind: FaultKind::StuckAt1,
        }]));
        // load rows 0..4, leaving 4..8 as spares
        for row in 0..4 {
            for cmp in 0..4 {
                for slot in 0..2 {
                    core.write_weight(cmp, row, slot, (10 * cmp + row) as i32);
                }
            }
        }
        // the fault is live: stored value diverges from intent
        assert_eq!(core.read_weight(0, 2, 0), 2 | 0x40);
        assert!(core.fault_tally().injected_bits > 0);
        let report = core.scrub();
        assert_eq!(report.detected_words, 1);
        assert_eq!(report.quarantined_rows, 1);
        assert_eq!(report.repaired_rows, 1);
        assert_eq!(report.zeroed_rows, 0);
        // row 2 now lives on the first spare (row 4), reads repaired
        assert_eq!(core.physical_row(2), 4);
        for cmp in 0..4 {
            for slot in 0..2 {
                assert_eq!(core.read_weight(cmp, 2, slot), (10 * cmp + 2) as i32);
            }
        }
        // untouched rows still identity-mapped and intact
        assert_eq!(core.physical_row(1), 1);
        assert_eq!(core.read_weight(3, 1, 1), 31);
        // a second scrub finds a clean array (repair is stable)
        assert!(core.scrub().is_clean());
        // maintenance writes did not count as weight loads
        assert_eq!(core.weight_writes(), 4 * 4 * 2);
    }

    #[test]
    fn exhausted_spares_zero_the_quarantined_row() {
        use crate::arch::fault::{Fault, FaultKind, FaultPlan};
        let mut core = PimCore::new(2, 2, 16);
        core.install_fault_plan(&FaultPlan::from_faults(vec![Fault {
            cmp: 1,
            row: 1,
            slot: 1,
            kw: 0,
            kind: FaultKind::StuckAt0,
        }]));
        // every row written: no spare rows exist
        for row in 0..2 {
            for cmp in 0..2 {
                for slot in 0..2 {
                    core.write_weight(cmp, row, slot, 7);
                }
            }
        }
        assert_eq!(core.read_weight(1, 1, 1), 6); // bit 0 stuck at 0
        let report = core.scrub();
        assert_eq!(report.quarantined_rows, 1);
        assert_eq!(report.repaired_rows, 0);
        assert_eq!(report.zeroed_rows, 1);
        assert_eq!(report.zeroed_weights, 4); // 2 cmps x 2 slots, all nonzero
        // the whole quarantined row reads zero; the clean row survives
        for cmp in 0..2 {
            for slot in 0..2 {
                assert_eq!(core.read_weight(cmp, 1, slot), 0);
                assert_eq!(core.read_weight(cmp, 0, slot), 7);
            }
        }
        // degradation is stable across scrubs
        assert!(core.scrub().is_clean());
    }

    #[test]
    fn faulted_writes_keep_planes_coherent_with_cells() {
        use crate::arch::fault::{FaultConfig, FaultPlan};
        use crate::util::rng::Rng;
        // under a dense random fault plan the two storage views must
        // still agree bit-for-bit (corruption is applied before both)
        let (cmps, rows) = (96usize, 4usize);
        let geom = MacroGeometry {
            compartments: cmps,
            rows,
            dbmus: 16,
        };
        let mut core = PimCore::with_geometry(geom);
        core.install_fault_plan(&FaultPlan::seeded(geom, &FaultConfig::new(9, 0.02), 0));
        let mut rng = Rng::new(72);
        for _ in 0..600 {
            let cmp = rng.below(cmps as u64) as usize;
            let row = rng.below(rows as u64) as usize;
            let slot = rng.below(2) as usize;
            core.write_weight(cmp, row, slot, rng.int8() as i32);
        }
        assert!(core.fault_tally().injected_bits > 0, "plan never fired");
        for row in 0..rows {
            let pr = core.physical_row(row);
            for slot in 0..2 {
                for kw in 0..WEIGHT_BITS {
                    for cmp in 0..cmps {
                        let plane = core.weight_planes().plane(pr, slot, kw, cmp / 64);
                        let w = core.read_weight(cmp, row, slot);
                        assert_eq!(
                            (plane >> (cmp % 64)) & 1 == 1,
                            (w as u32 >> kw) & 1 == 1,
                            "faulted plane/cell drift at cmp={cmp} row={row} slot={slot} kw={kw}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn runtime_upsets_replay_and_scrub_reconciles() {
        use crate::arch::fault::{FaultConfig, FaultPlan, UpsetConfig};
        let geom = MacroGeometry {
            compartments: 8,
            rows: 8,
            dbmus: 16,
        };
        let build = || {
            let mut core = PimCore::with_geometry(geom);
            // zero-BER plan = upsets-only configuration: intent ledger
            // exists, no write-time corruption
            core.install_fault_plan(&FaultPlan::seeded(geom, &FaultConfig::new(3, 0.0), 0));
            for row in 0..6 {
                for cmp in 0..8 {
                    for slot in 0..2 {
                        core.write_weight(cmp, row, slot, (cmp * 16 + row * 2 + slot) as i32 - 64);
                    }
                }
            }
            core.arm_upsets(UpsetConfig::from_ppm(0xC0DE, 20_000));
            core
        };
        let mut a = build();
        let mut b = build();
        let writes = a.weight_writes();
        let (mut landed, mut found) = (0u64, 0u64);
        for _ in 0..5 {
            let fa = a.tick_upsets();
            assert_eq!(fa, b.tick_upsets(), "virtual batch clock must replay");
            landed += fa;
            // scrub every boundary: one tick outstanding, ≤1 flip per
            // cell → no double-flip cancellation, exact reconciliation
            let report = a.scrub();
            let rb = b.scrub();
            assert_eq!(report, rb);
            found += report.corrupt_bits;
            assert_eq!(report.repaired_rows, report.quarantined_rows);
            assert_eq!(report.zeroed_rows, 0);
        }
        assert!(landed > 0, "upset process never fired");
        assert_eq!(found, landed, "every landed upset bit must be found");
        let t = a.fault_tally();
        assert_eq!(t.upset_bits, landed);
        assert_eq!(t.corrupt_bits, landed);
        assert_eq!(t.injected_bits, 0, "in-place replay re-corrupts nothing at zero BER");
        // repaired state matches intent everywhere; maintenance did not
        // count as weight loads
        assert!(a.scrub().is_clean());
        for row in 0..6 {
            for cmp in 0..8 {
                for slot in 0..2 {
                    assert_eq!(
                        a.read_weight(cmp, row, slot),
                        (cmp * 16 + row * 2 + slot) as i32 - 64
                    );
                }
            }
        }
        assert_eq!(a.weight_writes(), writes);
    }

    #[test]
    fn windowed_scrub_covers_like_a_full_pass() {
        use crate::arch::fault::{Fault, FaultKind, FaultPlan};
        let mut core = PimCore::new(4, 8, 16);
        core.install_fault_plan(&FaultPlan::from_faults(vec![Fault {
            cmp: 1,
            row: 2,
            slot: 1,
            kw: 3,
            kind: FaultKind::Transient,
        }]));
        for row in 0..4 {
            for cmp in 0..4 {
                for slot in 0..2 {
                    core.write_weight(cmp, row, slot, 5);
                }
            }
        }
        let total = core.stripe_count();
        assert_eq!(total, 8 * 2); // rows × slots × 1 plane word
        // scrub in 3-stripe windows: the union of ⌈total/K⌉ windows
        // books exactly what one monolithic pass does
        let mut merged = ScrubReport::default();
        let mut start = 0;
        while start < total {
            merged.merge(&core.scrub_window(start, 3));
            start += 3;
        }
        assert_eq!(merged.checked_words, total as u64);
        assert_eq!(merged.detected_words, 1);
        assert_eq!(merged.quarantined_rows, 1);
        assert_eq!(merged.repaired_rows, 1);
        assert_eq!(merged.corrupt_bits, 1);
        // a consumed transient repairs in place: no spare consumed
        assert_eq!(core.physical_row(2), 2);
        assert_eq!(core.read_weight(1, 2, 1), 5);
        assert!(core.scrub().is_clean());
    }

    #[test]
    #[should_panic(expected = "fresh core")]
    fn fault_plan_rejected_after_writes() {
        use crate::arch::fault::FaultPlan;
        let mut core = PimCore::new(2, 2, 16);
        core.write_weight(0, 0, 0, 1);
        core.install_fault_plan(&FaultPlan::empty());
    }

    #[test]
    fn geometry_builds_matching_core() {
        let geom = MacroGeometry::with_compartments(128);
        assert_eq!(geom.slots(), 2);
        let core = PimCore::with_geometry(geom);
        assert_eq!(core.num_compartments(), 128);
        assert_eq!(core.rows(), PimCore::PAPER_ROWS);
        assert_eq!(core.weight_planes().nwords(), 2);
        assert_eq!(MacroGeometry::default(), MacroGeometry::paper());
    }
}
