//! PIM macro: core + reconfigurable unit + merge pipeline — the
//! functional (bit-true) executor.
//!
//! [`PimMacro::mvm_row_into`] performs one full bit-serial row
//! computation: 8 input-bit cycles through the core, adder-tree
//! reduction per weight-bit position, shift-&-add recombination —
//! writing the per-slot partial-sum pairs `(Σ INP·w, Σ INN·!w)` that the
//! ARU consumes into a caller-provided [`MvmScratch`].  This is the
//! model that *proves* the DDC numerics; the timing engine never
//! recomputes values, it only counts the cycles this executor implies.
//!
//! Two implementations of the same semantics:
//!
//! * **bitsliced** (default hot path) — input bits are packed into
//!   `ceil(lanes/64)` `u64` words per bit-cycle (bit = compartment),
//!   weight bits come from the precomputed multi-word
//!   [`WeightPlanes`][crate::arch::sram::WeightPlanes] shadow, and every
//!   adder-tree column reduces to `(plane & inputs).count_ones()` per
//!   word.  Sparsity is skipped on *both* operands: all-zero input
//!   bit-planes never enter the loop (value-level input skip), and the
//!   per-word nonzero summaries of the stored planes drop dark
//!   adder-tree columns — independently for the Q and Q̄ polarities,
//!   because a Q plane with no stored 1s is a Q̄ plane that is *fully*
//!   lit (the software twin of the zero bit-column skip in the
//!   bit-level-sparsity PIM lines of work).
//! * **scalar** ([`PimMacro::mvm_row_scalar`]) — the original per-cell
//!   circuit walk, retained as the differential-testing oracle.  The
//!   `scalar-fabric` cargo feature forces it as the `mvm_row_into`
//!   implementation so any divergence can be bisected by flipping one
//!   flag.

use super::lpu::Mode;
use super::merge::bit_weight;
use super::pim_core::{MacroGeometry, PimCore, WEIGHT_BITS};
use super::reconfig::{reduce, Grouping};

/// Partial-sum pair for one (group, slot): the stored-filter psum (Q
/// path) and the complementary-filter psum (Q̄ path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PsumPair {
    pub q: i64,
    pub qbar: i64,
}

/// Caller-owned scratch for [`PimMacro::mvm_row_into`]: the psum
/// accumulators plus the packed input bit-planes, reused across calls so
/// the hot loop performs no allocation.  Create one per executor (or per
/// thread) and pass it to every row-step; buffers grow on first use and
/// are reset — never reallocated — afterwards.
#[derive(Debug, Clone, Default)]
pub struct MvmScratch {
    psums: Vec<PsumPair>,
    /// Packed input planes, plane-major: `[ki * nwords + word]`.
    inp_planes: Vec<u64>,
    inn_planes: Vec<u64>,
    /// Per-(group, word) lane masks of the active grouping:
    /// `[g * nwords + word]`.
    gmasks: Vec<u64>,
    ngroups: usize,
    slots: usize,
    nwords: usize,
}

impl MvmScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size for `ngroups * slots` psums and `input_bits * nwords` input
    /// plane words, zeroing all of them (allocation-free once capacity
    /// exists).
    fn reset(&mut self, ngroups: usize, slots: usize, input_bits: usize, nwords: usize) {
        self.ngroups = ngroups;
        self.slots = slots;
        self.nwords = nwords;
        self.psums.clear();
        self.psums.resize(ngroups * slots, PsumPair::default());
        self.inp_planes.clear();
        self.inp_planes.resize(input_bits * nwords, 0);
        self.inn_planes.clear();
        self.inn_planes.resize(input_bits * nwords, 0);
        self.gmasks.clear();
        self.gmasks.resize(ngroups * nwords, 0);
    }

    /// Pre-grow to a geometry (same resize discipline as the internal
    /// reset) so the *first* `mvm_row_into` call on a worker thread
    /// performs no allocation — the parallel executors warm every
    /// per-lane scratch on the caller thread before dispatching.
    /// `lanes` is the compartment count of the macro the scratch will
    /// serve (it sizes the plane words).
    pub fn warm(&mut self, ngroups: usize, slots: usize, input_bits: usize, lanes: usize) {
        self.reset(ngroups, slots, input_bits, lanes.div_ceil(64));
    }

    /// Result of the last `mvm_row_into` call for (group, slot).
    #[inline]
    pub fn psum(&self, group: usize, slot: usize) -> PsumPair {
        self.psums[group * self.slots + slot]
    }

    pub fn ngroups(&self) -> usize {
        self.ngroups
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Copy the psums out in the legacy `psums[group][slot]` shape
    /// (allocates — test/compat convenience, not the hot path).
    pub fn to_vecs(&self) -> Vec<Vec<PsumPair>> {
        (0..self.ngroups)
            .map(|g| (0..self.slots).map(|s| self.psum(g, s)).collect())
            .collect()
    }
}

/// Pack per-lane INT8 values into per-bit multi-word planes: bit
/// `lane % 64` of `planes[ki * nwords + lane / 64]` is bit `ki` of
/// `inputs[lane]` (two's complement, low 8 bits — identical to the
/// `(x as u8) >> ki` view of the scalar path).
#[inline]
fn pack_input_planes(planes: &mut [u64], nwords: usize, inputs: &[i32]) {
    let nbits = planes.len() / nwords;
    for (lane, &x) in inputs.iter().enumerate() {
        let (word, bit) = (lane / 64, lane % 64);
        let mut v = (x as u8) as u64;
        while v != 0 {
            let ki = v.trailing_zeros() as usize;
            if ki >= nbits {
                break; // input precision below 8 bits truncates high bits
            }
            planes[ki * nwords + word] |= 1u64 << bit;
            v &= v - 1;
        }
    }
}

/// One PIM macro.
#[derive(Debug, Clone)]
pub struct PimMacro {
    pub core: PimCore,
    input_bits: usize,
    weight_bits: usize,
}

impl PimMacro {
    /// `weight_bits` must equal the storage slot width
    /// ([`WEIGHT_BITS`] = 8: the column layout is fixed by the macro
    /// geometry); `input_bits` may be reduced below 8 (bit-serial cycles
    /// simply stop early) — both implementations honor it identically.
    pub fn new(core: PimCore, input_bits: usize, weight_bits: usize) -> Self {
        assert_eq!(
            weight_bits, WEIGHT_BITS,
            "weight precision is fixed by the {WEIGHT_BITS}-bit slot layout"
        );
        assert!(
            (1..=8).contains(&input_bits),
            "input precision must be 1..=8 bits, got {input_bits}"
        );
        PimMacro {
            core,
            input_bits,
            weight_bits,
        }
    }

    pub fn paper() -> Self {
        Self::new(PimCore::paper(), 8, 8)
    }

    /// A macro at an explicit [`MacroGeometry`], full INT8 precision on
    /// both operands — the constructor the geometry-parameterized
    /// planners use.
    pub fn with_geometry(geom: MacroGeometry) -> Self {
        Self::new(PimCore::with_geometry(geom), 8, 8)
    }

    /// Load one stored weight (normal SRAM mode).
    pub fn load_weight(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        assert!((-128..=127).contains(&w), "weight {w} out of INT8 range");
        self.core.write_weight(cmp, row, slot, w);
    }

    /// Total weight writes this macro has performed (see
    /// [`super::pim_core::PimCore::weight_writes`]).
    pub fn weight_writes(&self) -> u64 {
        self.core.weight_writes()
    }

    /// Full bit-serial MVM over one activated row, into caller scratch.
    ///
    /// * `inputs_p[cmp]` / `inputs_n[cmp]` — signed INT8 vector elements
    ///   on the INP / INN broadcast of each compartment.  Slices shorter
    ///   than the compartment count are zero-extended (absent lanes
    ///   drive no input), so executors can stream im2col slices without
    ///   copying into padded buffers.
    /// * `mode` — Regular (Q path only) or Double.
    /// * `grouping` — Combined (std/pw) or Split (dw two-stage).
    ///
    /// Results land in `scratch.psum(group, slot)`.
    pub fn mvm_row_into(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
        scratch: &mut MvmScratch,
    ) {
        if cfg!(feature = "scalar-fabric") {
            self.mvm_row_scalar_into(row, inputs_p, inputs_n, mode, grouping, scratch);
        } else {
            self.mvm_row_bitsliced_into(row, inputs_p, inputs_n, mode, grouping, scratch);
        }
    }

    /// The word-parallel bit-plane kernel (see module docs).
    fn mvm_row_bitsliced_into(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
        scratch: &mut MvmScratch,
    ) {
        let ncmp = self.core.num_compartments();
        assert!(inputs_p.len() <= ncmp, "INP vector wider than the core");
        assert!(inputs_n.len() <= ncmp, "INN vector wider than the core");
        // logical → physical row map (identity without a fault plan; the
        // scalar path maps inside `compute_cycle`)
        let row = self.core.physical_row(row);
        let slots = self.core.slots();
        let ngroups = grouping.ngroups();
        let planes = self.core.weight_planes();
        let nwords = planes.nwords();
        scratch.reset(ngroups, slots, self.input_bits, nwords);
        if mode == Mode::NormalSram {
            return; // LPU disabled: all psums stay zero, like the silicon
        }
        debug_assert_eq!(
            planes.wbits(),
            self.weight_bits,
            "weight precision is fixed by the 8-bit slot layout"
        );
        let MvmScratch {
            psums,
            inp_planes,
            inn_planes,
            gmasks,
            ..
        } = scratch;
        pack_input_planes(inp_planes, nwords, inputs_p);
        if mode == Mode::Double {
            pack_input_planes(inn_planes, nwords, inputs_n);
        }
        for wi in 0..nwords {
            let m = grouping.lane_masks_word(ncmp, wi);
            for (g, &gm) in m.iter().take(ngroups).enumerate() {
                gmasks[g * nwords + wi] = gm;
            }
        }
        for ki in 0..self.input_bits {
            let ip = &inp_planes[ki * nwords..(ki + 1) * nwords];
            let inn = &inn_planes[ki * nwords..(ki + 1) * nwords]; // zero in Regular
            if ip.iter().zip(inn).all(|(&p, &n)| p == 0 && n == 0) {
                continue; // zero input bit-plane: nothing fires this cycle
            }
            let wki = bit_weight(ki, self.input_bits);
            for g in 0..ngroups {
                let gm = &gmasks[g * nwords..(g + 1) * nwords];
                for wi in 0..nwords {
                    let pg = ip[wi] & gm[wi];
                    let ng = inn[wi] & gm[wi];
                    if pg == 0 && ng == 0 {
                        continue;
                    }
                    for s in 0..slots {
                        // one AND + popcount per *lit* weight bit = one
                        // adder-tree column; the nonzero summaries drop
                        // the dark columns of this word without reading
                        // their planes
                        let (ws, nz_q, nz_qbar) = planes.word_planes(row, s, wi);
                        let mut q_acc = 0i64;
                        let mut qbar_acc = 0i64;
                        if pg != 0 {
                            let mut lit = nz_q as u32;
                            while lit != 0 {
                                let kw = lit.trailing_zeros() as usize;
                                lit &= lit - 1;
                                q_acc += (ws[kw] & pg).count_ones() as i64
                                    * bit_weight(kw, self.weight_bits);
                            }
                        }
                        if ng != 0 {
                            // independent polarity: Q̄ = !plane & mask is
                            // lit exactly where Q has stored zeros, so a
                            // Q-sparse plane is Q̄-dense and vice versa
                            let mut lit = nz_qbar as u32;
                            while lit != 0 {
                                let kw = lit.trailing_zeros() as usize;
                                lit &= lit - 1;
                                qbar_acc += (!ws[kw] & ng).count_ones() as i64
                                    * bit_weight(kw, self.weight_bits);
                            }
                        }
                        if q_acc != 0 || qbar_acc != 0 {
                            let pair = &mut psums[g * slots + s];
                            pair.q += q_acc * wki;
                            pair.qbar += qbar_acc * wki;
                        }
                    }
                }
            }
        }
    }

    /// Scalar-oracle adapter: zero-extend to core width, run the per-cell
    /// walk, copy into scratch (the `scalar-fabric` dispatch target).
    fn mvm_row_scalar_into(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
        scratch: &mut MvmScratch,
    ) {
        let ncmp = self.core.num_compartments();
        let mut p = inputs_p.to_vec();
        p.resize(ncmp, 0);
        let mut n = inputs_n.to_vec();
        n.resize(ncmp, 0);
        let psums = self.mvm_row_scalar(row, &p, &n, mode, grouping);
        scratch.reset(
            psums.len(),
            self.core.slots(),
            self.input_bits,
            ncmp.div_ceil(64),
        );
        for (g, group) in psums.iter().enumerate() {
            for (s, &pair) in group.iter().enumerate() {
                scratch.psums[g * scratch.slots + s] = pair;
            }
        }
    }

    /// Legacy allocating API: runs [`Self::mvm_row_into`] on a fresh
    /// scratch and returns `psums[group][slot]`.
    pub fn mvm_row(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
    ) -> Vec<Vec<PsumPair>> {
        let mut scratch = MvmScratch::new();
        self.mvm_row_into(row, inputs_p, inputs_n, mode, grouping, &mut scratch);
        scratch.to_vecs()
    }

    /// The per-cell scalar fabric: every compartment's LPUs evaluated
    /// individually, adder trees as explicit popcount loops
    /// ([`reduce`]).  Bit-true by construction against Fig. 6; kept as
    /// the differential-testing oracle for the bitsliced kernel (and as
    /// the `mvm_row_into` implementation under `--features
    /// scalar-fabric`).  Requires full-width input slices.
    pub fn mvm_row_scalar(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
    ) -> Vec<Vec<PsumPair>> {
        let ncmp = self.core.num_compartments();
        assert_eq!(inputs_p.len(), ncmp);
        assert_eq!(inputs_n.len(), ncmp);
        let slots = self.core.slots();
        let ngroups = grouping.ngroups();
        let mut psums = vec![vec![PsumPair::default(); slots]; ngroups];

        for ki in 0..self.input_bits {
            let inp_bits: Vec<bool> =
                inputs_p.iter().map(|&x| ((x as u8) >> ki) & 1 == 1).collect();
            let inn_bits: Vec<bool> =
                inputs_n.iter().map(|&x| ((x as u8) >> ki) & 1 == 1).collect();
            let outs = self.core.compute_cycle(row, &inp_bits, &inn_bits, mode);
            let sums = reduce(&outs, grouping, slots, self.weight_bits);
            // shift-&-add with the *configured* operand widths (the MSB
            // of each operand carries negative weight) — the same terms
            // the bitsliced kernel accumulates, in the same widths
            let wki = bit_weight(ki, self.input_bits);
            for g in 0..ngroups {
                for s in 0..slots {
                    for kw in 0..self.weight_bits {
                        let bw = bit_weight(kw, self.weight_bits) * wki;
                        psums[g][s].q += sums.q[g][s][kw] as i64 * bw;
                        psums[g][s].qbar += sums.qbar[g][s][kw] as i64 * bw;
                    }
                }
            }
        }
        psums
    }

    /// Convenience: sum of an INT8 input vector (the ΣI the pre-process
    /// unit computes for the ARU).
    pub fn input_sum(inputs: &[i32]) -> i64 {
        inputs.iter().map(|&x| x as i64).sum()
    }

    /// Two's-complement value check helper for tests.
    pub fn expected_psum(inputs: &[i32], weights: &[i32]) -> i64 {
        inputs
            .iter()
            .zip(weights)
            .map(|(&x, &w)| x as i64 * w as i64)
            .sum()
    }

    #[allow(dead_code)]
    fn msb_weight(&self) -> i64 {
        bit_weight(self.input_bits - 1, self.input_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn load_column(m: &mut PimMacro, slot: usize, ws: &[i32]) {
        for (cmp, &w) in ws.iter().enumerate() {
            m.load_weight(cmp, 0, slot, w);
        }
    }

    #[test]
    fn regular_mode_matches_dense_mvm() {
        let mut rng = Rng::new(61);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &[0; 32], Mode::Regular, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &ws));
        assert_eq!(psums[0][0].qbar, 0); // Q̄ path dark in regular mode
    }

    #[test]
    fn double_mode_qbar_is_complement_psum() {
        let mut rng = Rng::new(62);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xn: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &xn, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &ws));
        let wbar: Vec<i32> = ws.iter().map(|&w| !w).collect();
        assert_eq!(psums[0][0].qbar, PimMacro::expected_psum(&xn, &wbar));
    }

    #[test]
    fn both_slots_independent() {
        let mut rng = Rng::new(63);
        let mut m = PimMacro::paper();
        let w0: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let w1: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &w0);
        load_column(&mut m, 1, &w1);
        let psums = m.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &w0));
        assert_eq!(psums[0][1].q, PimMacro::expected_psum(&xs, &w1));
    }

    #[test]
    fn split_grouping_two_independent_halves() {
        let mut rng = Rng::new(64);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &[0; 32], Mode::Regular, Grouping::Split);
        assert_eq!(psums.len(), 2);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs[..16], &ws[..16]));
        assert_eq!(psums[1][0].q, PimMacro::expected_psum(&xs[16..], &ws[16..]));
        // split halves sum to the combined result
        let comb = m.mvm_row(0, &xs, &[0; 32], Mode::Regular, Grouping::Combined);
        assert_eq!(psums[0][0].q + psums[1][0].q, comb[0][0].q);
    }

    #[test]
    fn extreme_int8_values() {
        let mut m = PimMacro::paper();
        let ws = vec![-128i32; 32];
        let xs = vec![-128i32; 32];
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, 32 * 128 * 128);
        // !(-128) = 127
        assert_eq!(psums[0][0].qbar, 32 * (-128i64) * 127);
    }

    #[test]
    #[should_panic(expected = "out of INT8 range")]
    fn rejects_oversized_weight() {
        let mut m = PimMacro::paper();
        m.load_weight(0, 0, 0, 300);
    }

    #[test]
    fn bitsliced_matches_scalar_oracle() {
        // the in-module smoke version of the full differential property
        // test in tests/differential_fabric.rs
        let mut rng = Rng::new(65);
        let mut m = PimMacro::paper();
        for row in 0..4 {
            for cmp in 0..32 {
                for slot in 0..2 {
                    m.load_weight(cmp, row, slot, rng.int8() as i32);
                }
            }
        }
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xn: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let mut scratch = MvmScratch::new();
        for row in 0..4 {
            for mode in [Mode::Regular, Mode::Double, Mode::NormalSram] {
                for grouping in [Grouping::Combined, Grouping::Split] {
                    m.mvm_row_into(row, &xs, &xn, mode, grouping, &mut scratch);
                    let want = m.mvm_row_scalar(row, &xs, &xn, mode, grouping);
                    assert_eq!(
                        scratch.to_vecs(),
                        want,
                        "divergence at row {row} {mode:?} {grouping:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduced_input_precision_matches_scalar() {
        // input_bits < 8: both implementations must read the same low
        // bits and give the reduced MSB the same negative significance
        let mut rng = Rng::new(68);
        for input_bits in [1usize, 4, 7] {
            let mut m = PimMacro::new(PimCore::new(16, 2, 16), input_bits, 8);
            for cmp in 0..16 {
                for slot in 0..2 {
                    m.load_weight(cmp, 1, slot, rng.int8() as i32);
                }
            }
            let xs: Vec<i32> = (0..16).map(|_| rng.int8() as i32).collect();
            let xn: Vec<i32> = (0..16).map(|_| rng.int8() as i32).collect();
            let mut scratch = MvmScratch::new();
            for grouping in [Grouping::Combined, Grouping::Split] {
                m.mvm_row_into(1, &xs, &xn, Mode::Double, grouping, &mut scratch);
                let want = m.mvm_row_scalar(1, &xs, &xn, Mode::Double, grouping);
                assert_eq!(scratch.to_vecs(), want, "divergence at input_bits={input_bits}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "slot layout")]
    fn rejects_non_slot_weight_precision() {
        PimMacro::new(PimCore::new(2, 2, 16), 8, 4);
    }

    #[test]
    fn short_inputs_zero_extend() {
        let mut rng = Rng::new(66);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let xs: Vec<i32> = (0..20).map(|_| rng.int8() as i32).collect();
        let mut padded = xs.clone();
        padded.resize(32, 0);
        let mut scratch = MvmScratch::new();
        m.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
        let want = m.mvm_row(0, &padded, &padded, Mode::Double, Grouping::Combined);
        assert_eq!(scratch.to_vecs(), want);
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // a dirty scratch from a previous (larger) call must not leak
        // into the next result
        let mut rng = Rng::new(67);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let mut scratch = MvmScratch::new();
        m.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Split, &mut scratch);
        m.mvm_row_into(0, &xs, &xs, Mode::Double, Grouping::Combined, &mut scratch);
        let fresh = m.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined);
        assert_eq!(scratch.to_vecs(), fresh);
        assert_eq!(scratch.ngroups(), 1);
    }

    #[test]
    fn pack_input_planes_is_bit_transpose() {
        let mut planes = vec![0u64; 8];
        pack_input_planes(&mut planes, 1, &[0b0101, -1, 0]);
        assert_eq!(planes[0], 0b011); // lanes 0 and 1 have bit 0 set
        assert_eq!(planes[1], 0b010); // only lane 1 (-1 = all bits)
        assert_eq!(planes[2], 0b011);
        assert_eq!(planes[7], 0b010);
    }

    #[test]
    fn pack_input_planes_crosses_word_seams() {
        // 70 lanes = 2 words: lane 64 must land in word 1, bit 0
        let mut inputs = vec![0i32; 70];
        inputs[63] = 1;
        inputs[64] = 0b10;
        inputs[69] = -1;
        let mut planes = vec![0u64; 8 * 2];
        pack_input_planes(&mut planes, 2, &inputs);
        assert_eq!(planes[0], 1 << 63); // ki=0 word 0
        assert_eq!(planes[1], 1 << 5); // ki=0 word 1: only lane 69
        assert_eq!(planes[2], 0); // ki=1 word 0
        assert_eq!(planes[3], (1 << 0) | (1 << 5)); // ki=1 word 1
        assert_eq!(planes[15], 1 << 5); // ki=7 word 1
    }

    #[test]
    fn wide_macro_matches_scalar_oracle() {
        // >64 compartments (the multi-word plane path), in-module smoke
        // of the full differential suite in tests/differential_fabric.rs
        let mut rng = Rng::new(69);
        for ncmp in [65usize, 128] {
            let mut m = PimMacro::with_geometry(MacroGeometry::with_compartments(ncmp));
            for cmp in 0..ncmp {
                for slot in 0..2 {
                    m.load_weight(cmp, 0, slot, rng.int8() as i32);
                }
            }
            let xs: Vec<i32> = (0..ncmp).map(|_| rng.int8() as i32).collect();
            let xn: Vec<i32> = (0..ncmp).map(|_| rng.int8() as i32).collect();
            let mut scratch = MvmScratch::new();
            for mode in [Mode::Regular, Mode::Double] {
                for grouping in [Grouping::Combined, Grouping::Split] {
                    m.mvm_row_into(0, &xs, &xn, mode, grouping, &mut scratch);
                    let want = m.mvm_row_scalar(0, &xs, &xn, mode, grouping);
                    assert_eq!(
                        scratch.to_vecs(),
                        want,
                        "divergence at ncmp {ncmp} {mode:?} {grouping:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_weight_planes_match_scalar_oracle() {
        // weights whose bit-planes are mostly dark on one polarity: the
        // summary-driven skip must change nothing but the work done
        let mut rng = Rng::new(70);
        let mut m = PimMacro::paper();
        for cmp in 0..32 {
            m.load_weight(cmp, 0, 0, rng.below(2) as i32); // Q planes 1..7 dark
            m.load_weight(cmp, 0, 1, -1 - rng.below(2) as i32); // Q̄ planes 1..7 dark
        }
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xn: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let mut scratch = MvmScratch::new();
        for mode in [Mode::Regular, Mode::Double] {
            for grouping in [Grouping::Combined, Grouping::Split] {
                m.mvm_row_into(0, &xs, &xn, mode, grouping, &mut scratch);
                let want = m.mvm_row_scalar(0, &xs, &xn, mode, grouping);
                assert_eq!(scratch.to_vecs(), want, "sparse drift {mode:?} {grouping:?}");
            }
        }
    }
}
