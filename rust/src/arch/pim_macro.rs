//! PIM macro: core + reconfigurable unit + merge pipeline — the
//! functional (bit-true) executor.
//!
//! `mvm_row` performs one full bit-serial row computation: 8 input-bit
//! cycles through the core, adder-tree reduction per weight-bit position,
//! shift-&-add recombination — returning the per-slot partial-sum pairs
//! `(Σ INP·w, Σ INN·!w)` that the ARU consumes.  This is the model that
//! *proves* the DDC numerics; the timing engine never recomputes values,
//! it only counts the cycles this executor implies.

use super::lpu::Mode;
use super::merge::{bit_weight, shift_add};
use super::pim_core::PimCore;
use super::reconfig::{reduce, Grouping};

/// Partial-sum pair for one (group, slot): the stored-filter psum (Q
/// path) and the complementary-filter psum (Q̄ path).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PsumPair {
    pub q: i64,
    pub qbar: i64,
}

/// One PIM macro.
#[derive(Debug, Clone)]
pub struct PimMacro {
    pub core: PimCore,
    input_bits: usize,
    weight_bits: usize,
}

impl PimMacro {
    pub fn new(core: PimCore, input_bits: usize, weight_bits: usize) -> Self {
        PimMacro {
            core,
            input_bits,
            weight_bits,
        }
    }

    pub fn paper() -> Self {
        Self::new(PimCore::paper(), 8, 8)
    }

    /// Load one stored weight (normal SRAM mode).
    pub fn load_weight(&mut self, cmp: usize, row: usize, slot: usize, w: i32) {
        assert!(
            (-128..=127).contains(&w),
            "weight {w} out of INT8 range"
        );
        self.core.write_weight(cmp, row, slot, w);
    }

    /// Full bit-serial MVM over one activated row.
    ///
    /// * `inputs_p[cmp]` / `inputs_n[cmp]` — signed INT8 vector elements
    ///   on the INP / INN broadcast of each compartment.
    /// * `mode` — Regular (Q path only) or Double.
    /// * `grouping` — Combined (std/pw) or Split (dw two-stage).
    ///
    /// Returns `psums[group][slot]`.
    pub fn mvm_row(
        &self,
        row: usize,
        inputs_p: &[i32],
        inputs_n: &[i32],
        mode: Mode,
        grouping: Grouping,
    ) -> Vec<Vec<PsumPair>> {
        let ncmp = self.core.num_compartments();
        assert_eq!(inputs_p.len(), ncmp);
        assert_eq!(inputs_n.len(), ncmp);
        let slots = self.core.slots();
        let ngroups = match grouping {
            Grouping::Combined => 1,
            Grouping::Split => 2,
        };
        let mut psums = vec![vec![PsumPair::default(); slots]; ngroups];

        for ki in 0..self.input_bits {
            let inp_bits: Vec<bool> = inputs_p
                .iter()
                .map(|&x| ((x as u8) >> ki) & 1 == 1)
                .collect();
            let inn_bits: Vec<bool> = inputs_n
                .iter()
                .map(|&x| ((x as u8) >> ki) & 1 == 1)
                .collect();
            let outs = self.core.compute_cycle(row, &inp_bits, &inn_bits, mode);
            let sums = reduce(&outs, grouping, slots, self.weight_bits);
            for g in 0..ngroups {
                for s in 0..slots {
                    for kw in 0..self.weight_bits {
                        shift_add(&mut psums[g][s].q, sums.q[g][s][kw], ki, kw, 8);
                        shift_add(&mut psums[g][s].qbar, sums.qbar[g][s][kw], ki, kw, 8);
                    }
                }
            }
        }
        // bit-serial input MSB carries negative weight: shift_add applied
        // bit_weight(ki) per input bit via the ki term above, so nothing
        // further to correct here.
        psums
    }

    /// Convenience: sum of an INT8 input vector (the ΣI the pre-process
    /// unit computes for the ARU).
    pub fn input_sum(inputs: &[i32]) -> i64 {
        inputs.iter().map(|&x| x as i64).sum()
    }

    /// Two's-complement value check helper for tests.
    pub fn expected_psum(inputs: &[i32], weights: &[i32]) -> i64 {
        inputs
            .iter()
            .zip(weights)
            .map(|(&x, &w)| x as i64 * w as i64)
            .sum()
    }

    #[allow(dead_code)]
    fn msb_weight(&self) -> i64 {
        bit_weight(self.input_bits - 1, self.input_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn load_column(m: &mut PimMacro, slot: usize, ws: &[i32]) {
        for (cmp, &w) in ws.iter().enumerate() {
            m.load_weight(cmp, 0, slot, w);
        }
    }

    #[test]
    fn regular_mode_matches_dense_mvm() {
        let mut rng = Rng::new(61);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &vec![0; 32], Mode::Regular, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &ws));
        assert_eq!(psums[0][0].qbar, 0); // Q̄ path dark in regular mode
    }

    #[test]
    fn double_mode_qbar_is_complement_psum() {
        let mut rng = Rng::new(62);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xn: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &xn, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &ws));
        let wbar: Vec<i32> = ws.iter().map(|&w| !w).collect();
        assert_eq!(psums[0][0].qbar, PimMacro::expected_psum(&xn, &wbar));
    }

    #[test]
    fn both_slots_independent() {
        let mut rng = Rng::new(63);
        let mut m = PimMacro::paper();
        let w0: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let w1: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &w0);
        load_column(&mut m, 1, &w1);
        let psums = m.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs, &w0));
        assert_eq!(psums[0][1].q, PimMacro::expected_psum(&xs, &w1));
    }

    #[test]
    fn split_grouping_two_independent_halves() {
        let mut rng = Rng::new(64);
        let mut m = PimMacro::paper();
        let ws: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        let xs: Vec<i32> = (0..32).map(|_| rng.int8() as i32).collect();
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &vec![0; 32], Mode::Regular, Grouping::Split);
        assert_eq!(psums.len(), 2);
        assert_eq!(psums[0][0].q, PimMacro::expected_psum(&xs[..16], &ws[..16]));
        assert_eq!(psums[1][0].q, PimMacro::expected_psum(&xs[16..], &ws[16..]));
        // split halves sum to the combined result
        let comb = m.mvm_row(0, &xs, &vec![0; 32], Mode::Regular, Grouping::Combined);
        assert_eq!(psums[0][0].q + psums[1][0].q, comb[0][0].q);
    }

    #[test]
    fn extreme_int8_values() {
        let mut m = PimMacro::paper();
        let ws = vec![-128i32; 32];
        let xs = vec![-128i32; 32];
        load_column(&mut m, 0, &ws);
        let psums = m.mvm_row(0, &xs, &xs, Mode::Double, Grouping::Combined);
        assert_eq!(psums[0][0].q, 32 * 128 * 128);
        // !(-128) = 127
        assert_eq!(psums[0][0].qbar, 32 * (-128i64) * 127);
    }

    #[test]
    #[should_panic(expected = "out of INT8 range")]
    fn rejects_oversized_weight() {
        let mut m = PimMacro::paper();
        m.load_weight(0, 0, 0, 300);
    }
}
