//! Pre-process and post-process units (Fig. 5).
//!
//! Pre-process: converts activations to bit-serial form, computes the
//! per-window ΣI the ARU needs, and drives the INP/INN broadcasts.
//! Post-process: requantization, ReLU and pooling on the recovered
//! outputs before writeback to the ping-pong memory.

/// Bit-serial conversion: the `ki`-th bit plane of an INT8 vector.
pub fn bit_plane(xs: &[i32], ki: usize) -> Vec<bool> {
    xs.iter().map(|&x| ((x as u8) >> ki) & 1 == 1).collect()
}

/// ΣI over a window (computed once, reused for every filter pair — the
/// pre-process unit keeps a running sum alongside the bit-serial stream).
pub fn input_sum(xs: &[i32]) -> i64 {
    xs.iter().map(|&x| x as i64).sum()
}

/// Requantize an i64 accumulator back to INT8 with a float scale
/// (multiply-truncate, symmetric).
pub fn requantize(acc: i64, scale: f64) -> i32 {
    ((acc as f64 * scale).round() as i64).clamp(-128, 127) as i32
}

/// ReLU on the integer domain.
pub fn relu(x: i32) -> i32 {
    x.max(0)
}

/// 2x2/2 average pooling over a `[h, w]` i32 feature map (row-major).
pub fn avg_pool_2x2(map: &[i32], h: usize, w: usize) -> Vec<i32> {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = Vec::with_capacity(oh * ow);
    for oy in 0..oh {
        for ox in 0..ow {
            let s: i32 = (0..2)
                .flat_map(|dy| (0..2).map(move |dx| (dy, dx)))
                .map(|(dy, dx)| map[(2 * oy + dy) * w + (2 * ox + dx)])
                .sum();
            out.push(s.div_euclid(4));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn bit_plane_roundtrip() {
        forall(
            71,
            200,
            |r| r.int8_vec(16).iter().map(|&v| v as i32).collect::<Vec<_>>(),
            |xs| {
                // reassembling all 8 planes with two's-complement weights
                // reconstructs the values
                xs.iter().enumerate().all(|(i, &x)| {
                    let mut v: i64 = 0;
                    for ki in 0..8 {
                        let b = bit_plane(xs, ki)[i] as i64;
                        v += b * if ki == 7 { -128 } else { 1 << ki };
                    }
                    v == x as i64
                })
            },
        );
    }

    #[test]
    fn requantize_clamps() {
        assert_eq!(requantize(1_000_000, 1.0), 127);
        assert_eq!(requantize(-1_000_000, 1.0), -128);
        assert_eq!(requantize(100, 0.5), 50);
    }

    #[test]
    fn relu_works() {
        assert_eq!(relu(-5), 0);
        assert_eq!(relu(5), 5);
    }

    #[test]
    fn pool_averages() {
        // 2x2 map of [4, 4, 8, 8] -> mean 6
        let out = avg_pool_2x2(&[4, 4, 8, 8], 2, 2);
        assert_eq!(out, vec![6]);
        // 4x2 -> two windows
        let out = avg_pool_2x2(&[1, 1, 1, 1, 2, 2, 2, 2], 4, 2);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn input_sum_matches() {
        assert_eq!(input_sum(&[1, -2, 3]), 2);
    }
}
