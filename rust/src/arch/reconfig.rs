//! Reconfigurable unit: 4 adder units x 2 adder trees + output mux
//! (paper §III-C2).
//!
//! For std/pw-conv the unit *combines* the two trees of an adder unit so
//! one partial sum spans all 32 compartments; for dw-conv it *splits*
//! them so each 16-compartment half produces an independent channel, and
//! alternates adder units across the two computation stages.

use super::compartment::CompartmentOut;

/// Accumulation grouping selected by the per-layer configuration signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Grouping {
    /// One group of 32 compartments (std/pw-conv).
    Combined,
    /// Two groups of 16 compartments (dw-conv two-stage operation).
    Split,
}

impl Grouping {
    /// Number of independent accumulation groups.
    pub fn ngroups(self) -> usize {
        match self {
            Grouping::Combined => 1,
            Grouping::Split => 2,
        }
    }

    /// Per-word lane masks of each accumulation group over `lanes`
    /// packed compartments, for arbitrary lane counts — the word-level
    /// view of the adder-unit combine/split mux used by the bitsliced
    /// hot path.  Word `word` covers lanes `[64*word, 64*word + 64)`.
    /// Combined is one full-width group (second mask 0); Split is the
    /// low/high compartment halves around `lanes / 2`, matching exactly
    /// the `..half` / `half..` slicing of the scalar [`reduce`].
    pub fn lane_masks_word(self, lanes: usize, word: usize) -> [u64; 2] {
        let first = word * 64;
        debug_assert!(lanes >= 1 && first < lanes);
        let n = (lanes - first).min(64);
        let full = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
        match self {
            Grouping::Combined => [full, 0],
            Grouping::Split => {
                // lanes of the low half that fall inside this word
                let in_lo = (lanes / 2).saturating_sub(first).min(n);
                let lo = if in_lo == 64 {
                    u64::MAX
                } else {
                    (1u64 << in_lo) - 1
                };
                [lo, full & !lo]
            }
        }
    }

    /// Single-word view for `lanes <= 64` (word 0 of
    /// [`Grouping::lane_masks_word`]).
    pub fn lane_masks(self, lanes: usize) -> [u64; 2] {
        debug_assert!((1..=64).contains(&lanes));
        self.lane_masks_word(lanes, 0)
    }
}

/// Tree sums for one compute cycle, per (group, weight slot, weight bit):
/// `sums[group][slot][kw]` = number of set AND results.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeSums {
    pub q: Vec<Vec<Vec<u32>>>,
    pub qbar: Vec<Vec<Vec<u32>>>,
}

/// Reduce the per-compartment readouts of one cycle.
///
/// `slots` = weights per row (2), `wbits` = weight precision (8).
pub fn reduce(outs: &[CompartmentOut], grouping: Grouping, slots: usize, wbits: usize) -> TreeSums {
    let groups: Vec<&[CompartmentOut]> = match grouping {
        Grouping::Combined => vec![outs],
        Grouping::Split => {
            let half = outs.len() / 2;
            vec![&outs[..half], &outs[half..]]
        }
    };
    let mut q = Vec::with_capacity(groups.len());
    let mut qbar = Vec::with_capacity(groups.len());
    for g in groups {
        let mut gq = Vec::with_capacity(slots);
        let mut gqbar = Vec::with_capacity(slots);
        for s in 0..slots {
            let mut sq = Vec::with_capacity(wbits);
            let mut sqbar = Vec::with_capacity(wbits);
            for kw in 0..wbits {
                let col = s * wbits + kw;
                // adder tree = popcount of the column across the group
                let mut cq = 0u32;
                let mut cb = 0u32;
                for o in g.iter() {
                    cq += ((o.q_mask >> col) & 1) as u32;
                    cb += ((o.qbar_mask >> col) & 1) as u32;
                }
                sq.push(cq);
                sqbar.push(cb);
            }
            gq.push(sq);
            gqbar.push(sqbar);
        }
        q.push(gq);
        qbar.push(gqbar);
    }
    TreeSums { q, qbar }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outs_with_bit0(n: usize, set: &[usize]) -> Vec<CompartmentOut> {
        (0..n)
            .map(|i| CompartmentOut {
                q_mask: set.contains(&i) as u16,
                qbar_mask: 0,
            })
            .collect()
    }

    #[test]
    fn combined_counts_all_32() {
        let outs = outs_with_bit0(32, &[0, 5, 20, 31]);
        let sums = reduce(&outs, Grouping::Combined, 2, 8);
        assert_eq!(sums.q.len(), 1);
        assert_eq!(sums.q[0][0][0], 4);
        assert_eq!(sums.q[0][1][0], 0); // slot 1 untouched
    }

    #[test]
    fn split_counts_halves() {
        let outs = outs_with_bit0(32, &[0, 5, 20, 31]);
        let sums = reduce(&outs, Grouping::Split, 2, 8);
        assert_eq!(sums.q.len(), 2);
        assert_eq!(sums.q[0][0][0], 2); // cmps 0, 5
        assert_eq!(sums.q[1][0][0], 2); // cmps 20, 31
    }

    #[test]
    fn split_sum_equals_combined() {
        let outs = outs_with_bit0(32, &[1, 2, 3, 17, 30]);
        let c = reduce(&outs, Grouping::Combined, 2, 8);
        let s = reduce(&outs, Grouping::Split, 2, 8);
        assert_eq!(c.q[0][0][0], s.q[0][0][0] + s.q[1][0][0]);
    }

    #[test]
    fn lane_masks_cover_and_partition() {
        for lanes in [1usize, 2, 16, 32, 63, 64] {
            let full = if lanes == 64 { u64::MAX } else { (1u64 << lanes) - 1 };
            let [c0, c1] = Grouping::Combined.lane_masks(lanes);
            assert_eq!(c0, full);
            assert_eq!(c1, 0);
            let [s0, s1] = Grouping::Split.lane_masks(lanes);
            assert_eq!(s0 | s1, full, "split must cover all {lanes} lanes");
            assert_eq!(s0 & s1, 0, "split groups must be disjoint");
            assert_eq!(s0.count_ones() as usize, lanes / 2);
        }
    }

    #[test]
    fn lane_masks_word_cover_and_partition_wide_lanes() {
        // multi-word geometries: every word's masks must partition that
        // word's populated lanes, and the per-lane group assignment
        // must match the scalar reduce's `..half` / `half..` slicing
        for lanes in [65usize, 96, 127, 128, 130, 200] {
            let nwords = lanes.div_ceil(64);
            let half = lanes / 2;
            let mut lo_lanes = 0usize;
            for wi in 0..nwords {
                let n = (lanes - wi * 64).min(64);
                let full = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
                let [c0, c1] = Grouping::Combined.lane_masks_word(lanes, wi);
                assert_eq!(c0, full);
                assert_eq!(c1, 0);
                let [s0, s1] = Grouping::Split.lane_masks_word(lanes, wi);
                assert_eq!(s0 | s1, full, "split must cover word {wi} of {lanes} lanes");
                assert_eq!(s0 & s1, 0, "split groups must be disjoint in word {wi}");
                lo_lanes += s0.count_ones() as usize;
                for bit in 0..n {
                    let lane = wi * 64 + bit;
                    assert_eq!((s0 >> bit) & 1 == 1, lane < half, "lane {lane} of {lanes}");
                }
            }
            assert_eq!(lo_lanes, half, "low half must hold lanes/2 lanes at {lanes}");
        }
    }

    #[test]
    fn lane_masks_match_scalar_group_slicing() {
        // the mask halves must select exactly the compartment ranges the
        // scalar `reduce` slices (`..half` / `half..`)
        let lanes = 32;
        let [s0, s1] = Grouping::Split.lane_masks(lanes);
        for cmp in 0..lanes {
            let in_lo = cmp < lanes / 2;
            assert_eq!((s0 >> cmp) & 1 == 1, in_lo);
            assert_eq!((s1 >> cmp) & 1 == 1, !in_lo);
        }
    }

    #[test]
    fn qbar_path_reduced_independently() {
        let outs: Vec<CompartmentOut> = (0..32)
            .map(|i| CompartmentOut {
                q_mask: 0,
                qbar_mask: ((i < 10) as u16) << 8, // slot 1, bit 0
            })
            .collect();
        let sums = reduce(&outs, Grouping::Combined, 2, 8);
        assert_eq!(sums.qbar[0][1][0], 10);
        assert_eq!(sums.q[0][1][0], 0);
    }
}
