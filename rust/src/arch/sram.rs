//! 6T SRAM bitcell and cell array with explicit complementary states.
//!
//! The entire DDC-PIM idea rests on the observation that a 6T cell's two
//! cross-coupled inverters hold a *pair* of complementary states (Q, Q̄):
//! conventional designs use only Q per computation, DDC-PIM treats Q̄ as
//! a second, free, bitwise-complementary weight bit.  The model keeps
//! both nodes explicit so the invariant `q_bar == !q` is structural.

/// One 6T bitcell.  Physically stores a single bit as a complementary
/// node pair; `q_bar` is derived, never stored separately — exactly like
/// the silicon.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SramCell {
    q: bool,
}

impl SramCell {
    pub fn write(&mut self, bit: bool) {
        self.q = bit;
    }

    /// Read the Q node (BLP side).
    pub fn q(&self) -> bool {
        self.q
    }

    /// Read the Q̄ node (BLN side) — the "free" complementary bit.
    pub fn q_bar(&self) -> bool {
        !self.q
    }
}

/// A rows x cols array of 6T cells (one compartment's storage is a
/// 64 x 16 instance).  Row-major.
#[derive(Debug, Clone)]
pub struct SramArray {
    cells: Vec<SramCell>,
    pub rows: usize,
    pub cols: usize,
}

impl SramArray {
    pub fn new(rows: usize, cols: usize) -> Self {
        SramArray {
            cells: vec![SramCell::default(); rows * cols],
            rows,
            cols,
        }
    }

    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Normal-SRAM-mode row write (one wordline activation).
    pub fn write_row(&mut self, row: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.cols, "row width mismatch");
        for (c, &b) in bits.iter().enumerate() {
            let i = self.idx(row, c);
            self.cells[i].write(b);
        }
    }

    /// Normal-SRAM-mode row read via the BL pairs (Q side).
    pub fn read_row(&self, row: usize) -> Vec<bool> {
        (0..self.cols).map(|c| self.cells[self.idx(row, c)].q()).collect()
    }

    /// Complementary row read (Q̄ side).
    pub fn read_row_bar(&self, row: usize) -> Vec<bool> {
        (0..self.cols)
            .map(|c| self.cells[self.idx(row, c)].q_bar())
            .collect()
    }

    pub fn cell(&self, row: usize, col: usize) -> SramCell {
        self.cells[self.idx(row, col)]
    }

    /// Write an 8-bit two's-complement weight into columns
    /// `[col8*8, col8*8+8)` of `row`, LSB first.
    pub fn write_weight8(&mut self, row: usize, col8: usize, w: i32) {
        for b in 0..8 {
            let i = self.idx(row, col8 * 8 + b);
            self.cells[i].write(((w as u32) >> b) & 1 == 1);
        }
    }

    /// Read back the 8-bit weight at (row, col8) from the Q side.
    pub fn read_weight8(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Read the complementary weight (Q̄ side) — by construction this is
    /// `!w` in 8-bit two's complement.
    pub fn read_weight8_bar(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q_bar() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Total bits stored (array size).
    pub fn size_bits(&self) -> usize {
        self.rows * self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn cell_complementary_invariant() {
        let mut c = SramCell::default();
        c.write(true);
        assert!(c.q() && !c.q_bar());
        c.write(false);
        assert!(!c.q() && c.q_bar());
    }

    #[test]
    fn row_roundtrip() {
        let mut a = SramArray::new(4, 16);
        let bits: Vec<bool> = (0..16).map(|i| i % 3 == 0).collect();
        a.write_row(2, &bits);
        assert_eq!(a.read_row(2), bits);
        let bar = a.read_row_bar(2);
        assert!(bits.iter().zip(&bar).all(|(&b, &nb)| b != nb));
    }

    #[test]
    fn weight8_roundtrip_and_complement() {
        forall(
            31,
            300,
            |r| r.int8() as i32,
            |&w| {
                let mut a = SramArray::new(1, 16);
                a.write_weight8(0, 1, w);
                a.read_weight8(0, 1) == w && a.read_weight8_bar(0, 1) == !w
            },
        );
    }

    #[test]
    fn paper_fig9_bit_pattern() {
        // w^c = -6 = 0b11111010; the Q̄ side must read 5 = 0b00000101
        let mut a = SramArray::new(1, 8);
        a.write_weight8(0, 0, -6);
        assert_eq!(a.read_weight8(0, 0), -6);
        assert_eq!(a.read_weight8_bar(0, 0), 5);
    }

    #[test]
    fn array_size() {
        // one compartment: 64 rows x 16 cols = 1 Kb
        let a = SramArray::new(64, 16);
        assert_eq!(a.size_bits(), 1024);
    }
}
