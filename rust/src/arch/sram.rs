//! 6T SRAM bitcell and cell array with explicit complementary states.
//!
//! The entire DDC-PIM idea rests on the observation that a 6T cell's two
//! cross-coupled inverters hold a *pair* of complementary states (Q, Q̄):
//! conventional designs use only Q per computation, DDC-PIM treats Q̄ as
//! a second, free, bitwise-complementary weight bit.  The model keeps
//! both nodes explicit so the invariant `q_bar == !q` is structural.

/// One 6T bitcell.  Physically stores a single bit as a complementary
/// node pair; `q_bar` is derived, never stored separately — exactly like
/// the silicon.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SramCell {
    q: bool,
}

impl SramCell {
    pub fn write(&mut self, bit: bool) {
        self.q = bit;
    }

    /// Read the Q node (BLP side).
    pub fn q(&self) -> bool {
        self.q
    }

    /// Read the Q̄ node (BLN side) — the "free" complementary bit.
    pub fn q_bar(&self) -> bool {
        !self.q
    }
}

/// A rows x cols array of 6T cells (one compartment's storage is a
/// 64 x 16 instance).  Row-major.
#[derive(Debug, Clone)]
pub struct SramArray {
    cells: Vec<SramCell>,
    pub rows: usize,
    pub cols: usize,
}

impl SramArray {
    pub fn new(rows: usize, cols: usize) -> Self {
        SramArray {
            cells: vec![SramCell::default(); rows * cols],
            rows,
            cols,
        }
    }

    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Normal-SRAM-mode row write (one wordline activation).
    pub fn write_row(&mut self, row: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.cols, "row width mismatch");
        for (c, &b) in bits.iter().enumerate() {
            let i = self.idx(row, c);
            self.cells[i].write(b);
        }
    }

    /// Normal-SRAM-mode row read via the BL pairs (Q side).
    pub fn read_row(&self, row: usize) -> Vec<bool> {
        (0..self.cols).map(|c| self.cells[self.idx(row, c)].q()).collect()
    }

    /// Complementary row read (Q̄ side).
    pub fn read_row_bar(&self, row: usize) -> Vec<bool> {
        (0..self.cols)
            .map(|c| self.cells[self.idx(row, c)].q_bar())
            .collect()
    }

    pub fn cell(&self, row: usize, col: usize) -> SramCell {
        self.cells[self.idx(row, col)]
    }

    /// Write an 8-bit two's-complement weight into columns
    /// `[col8*8, col8*8+8)` of `row`, LSB first.
    pub fn write_weight8(&mut self, row: usize, col8: usize, w: i32) {
        for b in 0..8 {
            let i = self.idx(row, col8 * 8 + b);
            self.cells[i].write(((w as u32) >> b) & 1 == 1);
        }
    }

    /// Read back the 8-bit weight at (row, col8) from the Q side.
    pub fn read_weight8(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Read the complementary weight (Q̄ side) — by construction this is
    /// `!w` in 8-bit two's complement.
    pub fn read_weight8_bar(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q_bar() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Total bits stored (array size).
    pub fn size_bits(&self) -> usize {
        self.rows * self.cols
    }
}

/// Bit-plane shadow of a core's weight storage: for every
/// (row, slot, weight-bit) one `u64` word packs that weight bit across up
/// to 64 lanes (compartments).
///
/// Built incrementally at weight-load time (the cold path), so the
/// compute hot loop is one AND + `count_ones` per word instead of a
/// per-cell walk.  The Q̄ plane is never stored: it is
/// `!plane & lane_mask` — the 6T complementary-pair invariant lifted to
/// word level, exactly as [`SramCell::q_bar`] derives it per cell.
#[derive(Debug, Clone)]
pub struct WeightPlanes {
    /// `rows * slots * wbits` words; bit `lane` of
    /// `planes[(row * slots + slot) * wbits + kw]` is weight bit `kw` of
    /// lane `lane`'s slot-`slot` weight at `row`.
    planes: Vec<u64>,
    rows: usize,
    slots: usize,
    wbits: usize,
    lane_mask: u64,
}

impl WeightPlanes {
    pub fn new(lanes: usize, rows: usize, slots: usize, wbits: usize) -> Self {
        assert!(
            (1..=64).contains(&lanes),
            "bit-plane packing supports 1..=64 lanes, got {lanes}"
        );
        WeightPlanes {
            planes: vec![0; rows * slots * wbits],
            rows,
            slots,
            wbits,
            lane_mask: if lanes == 64 { u64::MAX } else { (1u64 << lanes) - 1 },
        }
    }

    fn idx(&self, row: usize, slot: usize, kw: usize) -> usize {
        debug_assert!(row < self.rows && slot < self.slots && kw < self.wbits);
        (row * self.slots + slot) * self.wbits + kw
    }

    /// Record lane `lane`'s weight at (row, slot) into all `wbits` planes
    /// (two's complement, LSB-first — matches [`SramArray::write_weight8`]).
    pub fn record(&mut self, lane: usize, row: usize, slot: usize, w: i32) {
        let bit = 1u64 << lane;
        debug_assert!(bit & self.lane_mask != 0, "lane {lane} out of range");
        for kw in 0..self.wbits {
            let i = self.idx(row, slot, kw);
            if (w as u32 >> kw) & 1 == 1 {
                self.planes[i] |= bit;
            } else {
                self.planes[i] &= !bit;
            }
        }
    }

    /// Q bit-plane of (row, slot, weight-bit): bit `lane` = stored Q bit.
    #[inline]
    pub fn plane(&self, row: usize, slot: usize, kw: usize) -> u64 {
        self.planes[self.idx(row, slot, kw)]
    }

    /// Q̄ bit-plane — the free complementary word of the 6T pair.
    #[inline]
    pub fn plane_bar(&self, row: usize, slot: usize, kw: usize) -> u64 {
        !self.plane(row, slot, kw) & self.lane_mask
    }

    /// All `wbits` planes of (row, slot) as one contiguous slice — the
    /// hot-path access pattern (one bounds check per row-step).
    #[inline]
    pub fn row_slot_planes(&self, row: usize, slot: usize) -> &[u64] {
        let i = self.idx(row, slot, 0);
        &self.planes[i..i + self.wbits]
    }

    /// Mask of the populated lane bits.
    #[inline]
    pub fn lane_mask(&self) -> u64 {
        self.lane_mask
    }

    pub fn wbits(&self) -> usize {
        self.wbits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn cell_complementary_invariant() {
        let mut c = SramCell::default();
        c.write(true);
        assert!(c.q() && !c.q_bar());
        c.write(false);
        assert!(!c.q() && c.q_bar());
    }

    #[test]
    fn row_roundtrip() {
        let mut a = SramArray::new(4, 16);
        let bits: Vec<bool> = (0..16).map(|i| i % 3 == 0).collect();
        a.write_row(2, &bits);
        assert_eq!(a.read_row(2), bits);
        let bar = a.read_row_bar(2);
        assert!(bits.iter().zip(&bar).all(|(&b, &nb)| b != nb));
    }

    #[test]
    fn weight8_roundtrip_and_complement() {
        forall(
            31,
            300,
            |r| r.int8() as i32,
            |&w| {
                let mut a = SramArray::new(1, 16);
                a.write_weight8(0, 1, w);
                a.read_weight8(0, 1) == w && a.read_weight8_bar(0, 1) == !w
            },
        );
    }

    #[test]
    fn paper_fig9_bit_pattern() {
        // w^c = -6 = 0b11111010; the Q̄ side must read 5 = 0b00000101
        let mut a = SramArray::new(1, 8);
        a.write_weight8(0, 0, -6);
        assert_eq!(a.read_weight8(0, 0), -6);
        assert_eq!(a.read_weight8_bar(0, 0), 5);
    }

    #[test]
    fn array_size() {
        // one compartment: 64 rows x 16 cols = 1 Kb
        let a = SramArray::new(64, 16);
        assert_eq!(a.size_bits(), 1024);
    }

    #[test]
    fn weight_planes_match_cell_bits() {
        // the bit-plane shadow must agree bit-for-bit with the per-cell
        // array for random weights (both sides written identically)
        forall(
            33,
            200,
            |r| (r.below(4) as usize, r.below(2) as usize, r.int8() as i32),
            |&(row, slot, w)| {
                let mut a = SramArray::new(4, 16);
                a.write_weight8(row, slot, w);
                let mut p = WeightPlanes::new(1, 4, 2, 8);
                p.record(0, row, slot, w);
                (0..8).all(|kw| {
                    let q = a.cell(row, slot * 8 + kw).q();
                    let qb = a.cell(row, slot * 8 + kw).q_bar();
                    (p.plane(row, slot, kw) & 1 == 1) == q
                        && (p.plane_bar(row, slot, kw) & 1 == 1) == qb
                })
            },
        );
    }

    #[test]
    fn weight_planes_pack_lanes() {
        let mut p = WeightPlanes::new(32, 2, 2, 8);
        p.record(0, 1, 0, 0b0101);
        p.record(5, 1, 0, 0b0001);
        p.record(31, 1, 0, -1); // all bits set
        // kw=0: lanes 0, 5, 31
        assert_eq!(p.plane(1, 0, 0), (1 << 0) | (1 << 5) | (1 << 31));
        // kw=2: lanes 0, 31
        assert_eq!(p.plane(1, 0, 2), (1 << 0) | (1 << 31));
        // complementary plane is the inverse within the 32 lanes
        assert_eq!(p.plane_bar(1, 0, 0), !p.plane(1, 0, 0) & 0xFFFF_FFFF);
        // untouched (row, slot) stays all-zero / all-complement
        assert_eq!(p.plane(0, 1, 3), 0);
        assert_eq!(p.plane_bar(0, 1, 3), 0xFFFF_FFFF);
    }

    #[test]
    fn weight_planes_overwrite_clears_stale_bits() {
        let mut p = WeightPlanes::new(8, 1, 1, 8);
        p.record(3, 0, 0, -1);
        p.record(3, 0, 0, 0);
        for kw in 0..8 {
            assert_eq!(p.plane(0, 0, kw), 0, "stale bit left in plane {kw}");
        }
    }

    #[test]
    fn weight_planes_row_slot_slice() {
        let mut p = WeightPlanes::new(64, 2, 2, 8);
        p.record(63, 1, 1, 0b1000_0001u32 as i32);
        let ws = p.row_slot_planes(1, 1);
        assert_eq!(ws.len(), 8);
        assert_eq!(ws[0], 1 << 63);
        assert_eq!(ws[7], 1 << 63);
        assert_eq!(ws[3], 0);
        assert_eq!(p.lane_mask(), u64::MAX);
    }
}
