//! 6T SRAM bitcell and cell array with explicit complementary states.
//!
//! The entire DDC-PIM idea rests on the observation that a 6T cell's two
//! cross-coupled inverters hold a *pair* of complementary states (Q, Q̄):
//! conventional designs use only Q per computation, DDC-PIM treats Q̄ as
//! a second, free, bitwise-complementary weight bit.  The model keeps
//! both nodes explicit so the invariant `q_bar == !q` is structural.

/// One 6T bitcell.  Physically stores a single bit as a complementary
/// node pair; `q_bar` is derived, never stored separately — exactly like
/// the silicon.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SramCell {
    q: bool,
}

impl SramCell {
    pub fn write(&mut self, bit: bool) {
        self.q = bit;
    }

    /// Read the Q node (BLP side).
    pub fn q(&self) -> bool {
        self.q
    }

    /// Read the Q̄ node (BLN side) — the "free" complementary bit.
    pub fn q_bar(&self) -> bool {
        !self.q
    }
}

/// A rows x cols array of 6T cells (one compartment's storage is a
/// 64 x 16 instance).  Row-major.
#[derive(Debug, Clone)]
pub struct SramArray {
    cells: Vec<SramCell>,
    pub rows: usize,
    pub cols: usize,
}

impl SramArray {
    pub fn new(rows: usize, cols: usize) -> Self {
        SramArray {
            cells: vec![SramCell::default(); rows * cols],
            rows,
            cols,
        }
    }

    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    /// Normal-SRAM-mode row write (one wordline activation).
    pub fn write_row(&mut self, row: usize, bits: &[bool]) {
        assert_eq!(bits.len(), self.cols, "row width mismatch");
        for (c, &b) in bits.iter().enumerate() {
            let i = self.idx(row, c);
            self.cells[i].write(b);
        }
    }

    /// Normal-SRAM-mode row read via the BL pairs (Q side).
    pub fn read_row(&self, row: usize) -> Vec<bool> {
        (0..self.cols).map(|c| self.cells[self.idx(row, c)].q()).collect()
    }

    /// Complementary row read (Q̄ side).
    pub fn read_row_bar(&self, row: usize) -> Vec<bool> {
        (0..self.cols)
            .map(|c| self.cells[self.idx(row, c)].q_bar())
            .collect()
    }

    pub fn cell(&self, row: usize, col: usize) -> SramCell {
        self.cells[self.idx(row, col)]
    }

    /// Write an 8-bit two's-complement weight into columns
    /// `[col8*8, col8*8+8)` of `row`, LSB first.
    pub fn write_weight8(&mut self, row: usize, col8: usize, w: i32) {
        for b in 0..8 {
            let i = self.idx(row, col8 * 8 + b);
            self.cells[i].write(((w as u32) >> b) & 1 == 1);
        }
    }

    /// Read back the 8-bit weight at (row, col8) from the Q side.
    pub fn read_weight8(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Read the complementary weight (Q̄ side) — by construction this is
    /// `!w` in 8-bit two's complement.
    pub fn read_weight8_bar(&self, row: usize, col8: usize) -> i32 {
        let mut v: u32 = 0;
        for b in 0..8 {
            if self.cell(row, col8 * 8 + b).q_bar() {
                v |= 1 << b;
            }
        }
        (v as u8) as i8 as i32
    }

    /// Total bits stored (array size).
    pub fn size_bits(&self) -> usize {
        self.rows * self.cols
    }
}

/// Bit-plane shadow of a core's weight storage: for every
/// (row, slot, weight-bit) one *multi-word* plane `[u64; W]` with
/// `W = ceil(lanes / 64)` packs that weight bit across all lanes
/// (compartments); word `wi` covers lanes `[64*wi, 64*wi + 64)`.
///
/// Built incrementally at weight-load time (the cold path) together
/// with a per-(row, slot, word) **nonzero summary** — one bitmask over
/// the `wbits` weight bits per polarity — so the compute hot loop
/// visits only the planes that can contribute:
///
/// * `nz_q` bit `kw` set ⇔ the Q plane word holds any stored 1;
/// * `nz_qbar` bit `kw` set ⇔ the Q̄ word (`!plane & mask`) holds any
///   stored 0.
///
/// The polarities are independent — a plane that is all-zero on Q is
/// all-ones on Q̄ and vice versa — so a skip that consulted only the Q
/// summary would silently drop Q̄-path work in double-computing mode.
///
/// The Q̄ plane is never stored: it is `!plane & lane_mask(word)` — the
/// 6T complementary-pair invariant lifted to word level, exactly as
/// [`SramCell::q_bar`] derives it per cell.
#[derive(Debug, Clone)]
pub struct WeightPlanes {
    /// `rows * slots * nwords * wbits` words; bit `lane % 64` of
    /// `planes[((row * slots + slot) * nwords + lane / 64) * wbits + kw]`
    /// is weight bit `kw` of lane `lane`'s slot-`slot` weight at `row`.
    /// Word-major so the `wbits` planes of one (row, slot, word) are
    /// contiguous — the hot-path access pattern.
    planes: Vec<u64>,
    /// Per-(row, slot, word) bitmask over `kw`: Q plane word nonzero.
    nz_q: Vec<u8>,
    /// Per-(row, slot, word) bitmask over `kw`: Q̄ plane word nonzero.
    nz_qbar: Vec<u8>,
    rows: usize,
    slots: usize,
    wbits: usize,
    nwords: usize,
    /// Populated-lane mask per word (only the last word can be partial).
    lane_masks: Vec<u64>,
}

impl WeightPlanes {
    pub fn new(lanes: usize, rows: usize, slots: usize, wbits: usize) -> Self {
        assert!(lanes >= 1, "bit-plane packing needs at least one lane");
        assert!(
            (1..=8).contains(&wbits),
            "nonzero summaries are u8 masks: wbits must be 1..=8, got {wbits}"
        );
        let nwords = lanes.div_ceil(64);
        let lane_masks = (0..nwords)
            .map(|wi| {
                let n = (lanes - wi * 64).min(64);
                if n == 64 { u64::MAX } else { (1u64 << n) - 1 }
            })
            .collect();
        // all-zero planes: every Q plane is dark and every Q̄ plane is
        // fully lit (each stored 0 contributes a complement 1)
        let full = ((1u16 << wbits) - 1) as u8;
        WeightPlanes {
            planes: vec![0; rows * slots * nwords * wbits],
            nz_q: vec![0; rows * slots * nwords],
            nz_qbar: vec![full; rows * slots * nwords],
            rows,
            slots,
            wbits,
            nwords,
            lane_masks,
        }
    }

    #[inline]
    fn summary_idx(&self, row: usize, slot: usize, word: usize) -> usize {
        debug_assert!(row < self.rows && slot < self.slots && word < self.nwords);
        (row * self.slots + slot) * self.nwords + word
    }

    #[inline]
    fn word_base(&self, row: usize, slot: usize, word: usize) -> usize {
        self.summary_idx(row, slot, word) * self.wbits
    }

    /// Record lane `lane`'s weight at (row, slot) into all `wbits` planes
    /// (two's complement, LSB-first — matches [`SramArray::write_weight8`])
    /// and refresh the nonzero summaries of the touched word — the
    /// maintenance invariant that keeps summary and plane views coherent
    /// through the single `write_weight` path.
    pub fn record(&mut self, lane: usize, row: usize, slot: usize, w: i32) {
        let word = lane / 64;
        assert!(
            word < self.nwords && (self.lane_masks[word] >> (lane % 64)) & 1 == 1,
            "lane {lane} out of range"
        );
        let bit = 1u64 << (lane % 64);
        let mask = self.lane_masks[word];
        let base = self.word_base(row, slot, word);
        let si = self.summary_idx(row, slot, word);
        for kw in 0..self.wbits {
            let plane = &mut self.planes[base + kw];
            if (w as u32 >> kw) & 1 == 1 {
                *plane |= bit;
            } else {
                *plane &= !bit;
            }
            let kbit = 1u8 << kw;
            if *plane != 0 {
                self.nz_q[si] |= kbit;
            } else {
                self.nz_q[si] &= !kbit;
            }
            if !*plane & mask != 0 {
                self.nz_qbar[si] |= kbit;
            } else {
                self.nz_qbar[si] &= !kbit;
            }
        }
    }

    /// Word `word` of the Q bit-plane of (row, slot, weight-bit): bit
    /// `lane % 64` = lane `64*word + lane%64`'s stored Q bit.
    #[inline]
    pub fn plane(&self, row: usize, slot: usize, kw: usize, word: usize) -> u64 {
        debug_assert!(kw < self.wbits);
        self.planes[self.word_base(row, slot, word) + kw]
    }

    /// Word `word` of the Q̄ bit-plane — the free complementary word of
    /// the 6T pair.
    #[inline]
    pub fn plane_bar(&self, row: usize, slot: usize, kw: usize, word: usize) -> u64 {
        !self.plane(row, slot, kw, word) & self.lane_masks[word]
    }

    /// All `wbits` planes of (row, slot, word) as one contiguous slice,
    /// plus the two polarity summaries — the hot-path access pattern
    /// (one bounds check per (row, slot, word) step).
    #[inline]
    pub fn word_planes(&self, row: usize, slot: usize, word: usize) -> (&[u64], u8, u8) {
        let si = self.summary_idx(row, slot, word);
        let base = si * self.wbits;
        (&self.planes[base..base + self.wbits], self.nz_q[si], self.nz_qbar[si])
    }

    /// Populated-lane mask of each word.
    #[inline]
    pub fn lane_masks(&self) -> &[u64] {
        &self.lane_masks
    }

    /// Words per plane (`ceil(lanes / 64)`).
    #[inline]
    pub fn nwords(&self) -> usize {
        self.nwords
    }

    pub fn wbits(&self) -> usize {
        self.wbits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn cell_complementary_invariant() {
        let mut c = SramCell::default();
        c.write(true);
        assert!(c.q() && !c.q_bar());
        c.write(false);
        assert!(!c.q() && c.q_bar());
    }

    #[test]
    fn row_roundtrip() {
        let mut a = SramArray::new(4, 16);
        let bits: Vec<bool> = (0..16).map(|i| i % 3 == 0).collect();
        a.write_row(2, &bits);
        assert_eq!(a.read_row(2), bits);
        let bar = a.read_row_bar(2);
        assert!(bits.iter().zip(&bar).all(|(&b, &nb)| b != nb));
    }

    #[test]
    fn weight8_roundtrip_and_complement() {
        forall(
            31,
            300,
            |r| r.int8() as i32,
            |&w| {
                let mut a = SramArray::new(1, 16);
                a.write_weight8(0, 1, w);
                a.read_weight8(0, 1) == w && a.read_weight8_bar(0, 1) == !w
            },
        );
    }

    #[test]
    fn paper_fig9_bit_pattern() {
        // w^c = -6 = 0b11111010; the Q̄ side must read 5 = 0b00000101
        let mut a = SramArray::new(1, 8);
        a.write_weight8(0, 0, -6);
        assert_eq!(a.read_weight8(0, 0), -6);
        assert_eq!(a.read_weight8_bar(0, 0), 5);
    }

    #[test]
    fn array_size() {
        // one compartment: 64 rows x 16 cols = 1 Kb
        let a = SramArray::new(64, 16);
        assert_eq!(a.size_bits(), 1024);
    }

    #[test]
    fn weight_planes_match_cell_bits() {
        // the bit-plane shadow must agree bit-for-bit with the per-cell
        // array for random weights (both sides written identically)
        forall(
            33,
            200,
            |r| (r.below(4) as usize, r.below(2) as usize, r.int8() as i32),
            |&(row, slot, w)| {
                let mut a = SramArray::new(4, 16);
                a.write_weight8(row, slot, w);
                let mut p = WeightPlanes::new(1, 4, 2, 8);
                p.record(0, row, slot, w);
                (0..8).all(|kw| {
                    let q = a.cell(row, slot * 8 + kw).q();
                    let qb = a.cell(row, slot * 8 + kw).q_bar();
                    (p.plane(row, slot, kw, 0) & 1 == 1) == q
                        && (p.plane_bar(row, slot, kw, 0) & 1 == 1) == qb
                })
            },
        );
    }

    #[test]
    fn weight_planes_pack_lanes() {
        let mut p = WeightPlanes::new(32, 2, 2, 8);
        p.record(0, 1, 0, 0b0101);
        p.record(5, 1, 0, 0b0001);
        p.record(31, 1, 0, -1); // all bits set
        // kw=0: lanes 0, 5, 31
        assert_eq!(p.plane(1, 0, 0, 0), (1 << 0) | (1 << 5) | (1 << 31));
        // kw=2: lanes 0, 31
        assert_eq!(p.plane(1, 0, 2, 0), (1 << 0) | (1 << 31));
        // complementary plane is the inverse within the 32 lanes
        assert_eq!(p.plane_bar(1, 0, 0, 0), !p.plane(1, 0, 0, 0) & 0xFFFF_FFFF);
        // untouched (row, slot) stays all-zero / all-complement
        assert_eq!(p.plane(0, 1, 3, 0), 0);
        assert_eq!(p.plane_bar(0, 1, 3, 0), 0xFFFF_FFFF);
    }

    #[test]
    fn weight_planes_overwrite_clears_stale_bits() {
        let mut p = WeightPlanes::new(8, 1, 1, 8);
        p.record(3, 0, 0, -1);
        p.record(3, 0, 0, 0);
        for kw in 0..8 {
            assert_eq!(p.plane(0, 0, kw, 0), 0, "stale bit left in plane {kw}");
        }
        // and the summaries followed the overwrite back to dark-Q
        let (_, nz_q, nz_qbar) = p.word_planes(0, 0, 0);
        assert_eq!(nz_q, 0);
        assert_eq!(nz_qbar, 0xFF);
    }

    #[test]
    fn weight_planes_word_slice() {
        let mut p = WeightPlanes::new(64, 2, 2, 8);
        p.record(63, 1, 1, 0b1000_0001u32 as i32);
        let (ws, nz_q, nz_qbar) = p.word_planes(1, 1, 0);
        assert_eq!(ws.len(), 8);
        assert_eq!(ws[0], 1 << 63);
        assert_eq!(ws[7], 1 << 63);
        assert_eq!(ws[3], 0);
        assert_eq!(nz_q, 0b1000_0001);
        assert_eq!(nz_qbar, 0xFF); // 63 stored zeros light every Q̄ plane
        assert_eq!(p.lane_masks(), &[u64::MAX]);
    }

    #[test]
    fn weight_planes_multiword_lanes() {
        // 130 lanes = 3 words, last word holding 2 lanes
        let mut p = WeightPlanes::new(130, 1, 1, 8);
        assert_eq!(p.nwords(), 3);
        assert_eq!(p.lane_masks(), &[u64::MAX, u64::MAX, 0b11]);
        p.record(64, 0, 0, 0b0100);
        p.record(129, 0, 0, 0b0100);
        assert_eq!(p.plane(0, 0, 2, 0), 0);
        assert_eq!(p.plane(0, 0, 2, 1), 1 << 0);
        assert_eq!(p.plane(0, 0, 2, 2), 1 << 1);
        // Q̄ within the partial word respects the populated-lane mask
        assert_eq!(p.plane_bar(0, 0, 2, 2), 0b01);
        assert_eq!(p.plane_bar(0, 0, 0, 2), 0b11);
    }

    #[test]
    fn weight_planes_summaries_track_both_polarities() {
        use crate::util::rng::Rng;
        // random writes + overwrites on a multi-word geometry: the
        // summaries must equal a from-scratch recomputation of "is this
        // plane word nonzero" for both polarities, always
        let mut rng = Rng::new(35);
        let (lanes, rows, slots) = (96usize, 2usize, 2usize);
        let mut p = WeightPlanes::new(lanes, rows, slots, 8);
        for _ in 0..500 {
            let lane = rng.below(lanes as u64) as usize;
            let row = rng.below(rows as u64) as usize;
            let slot = rng.below(slots as u64) as usize;
            p.record(lane, row, slot, rng.int8() as i32);
        }
        for row in 0..rows {
            for slot in 0..slots {
                for wi in 0..p.nwords() {
                    let (ws, nz_q, nz_qbar) = p.word_planes(row, slot, wi);
                    let mask = p.lane_masks()[wi];
                    for (kw, &w) in ws.iter().enumerate() {
                        assert_eq!(
                            (nz_q >> kw) & 1 == 1,
                            w != 0,
                            "stale Q summary at ({row},{slot},{wi},{kw})"
                        );
                        assert_eq!(
                            (nz_qbar >> kw) & 1 == 1,
                            !w & mask != 0,
                            "stale Q̄ summary at ({row},{slot},{wi},{kw})"
                        );
                    }
                }
            }
        }
    }
}
