//! `bench-diff` — the bench-trajectory gate (ROADMAP "Bench trajectory
//! automation").
//!
//! Diffs two `ddc-pim-bench-v1` JSON files (see `util/benchkit`) and
//! fails when any shared case's `mean_ns` regressed by more than the
//! threshold.  Files carrying `"estimated": true` or `"quick": true`
//! are **hard-rejected**: projected or smoke-run timings must never
//! gate regressions — regenerate the baseline with `make bench` on a
//! toolchain host first.
//!
//!     bench-diff <baseline.json> <candidate.json> [--max-regress PCT]
//!     bench-diff --self-check
//!
//! Exit codes:
//!
//! * `0` — ok, no regression;
//! * `1` — regression found (a shared case's `mean_ns` grew past the
//!   threshold);
//! * `2` — usage / structural error (bad flags, unreadable file, wrong
//!   schema, missing `results`/`mean_ns`, non-finite means);
//! * `3` — **document unfit to gate**: baseline or candidate carries
//!   `"estimated": true` / `"quick": true`.  Distinct from `1` so CI
//!   can tell "the code regressed" from "the checked-in baseline was
//!   never a real measurement — regenerate it with `make bench`".

use ddc_pim::util::json::Json;

/// Default regression threshold (percent increase of `mean_ns`).
const DEFAULT_MAX_REGRESS_PCT: f64 = 10.0;

/// Exit code for regressions.
const EXIT_REGRESSION: i32 = 1;
/// Exit code for usage / structural errors.
const EXIT_USAGE: i32 = 2;
/// Exit code for estimated/quick documents (unfit to gate anything).
const EXIT_UNFIT: i32 = 3;

/// One compared bench case.
#[derive(Debug, Clone, PartialEq)]
struct DiffLine {
    name: String,
    base_ns: f64,
    new_ns: f64,
    /// Percent change of mean_ns (positive = slower).
    delta_pct: f64,
}

/// Why a document cannot gate a diff: structurally broken (exit 2) vs
/// carrying untrusted timings (exit 3 — regenerate the baseline).
#[derive(Debug, Clone, PartialEq)]
enum Unfit {
    /// Wrong schema or malformed document.
    Structural(String),
    /// `"estimated": true` / `"quick": true` — timings are projections
    /// or smoke runs, never gates.
    Untrusted(String),
}

impl Unfit {
    fn message(&self) -> &str {
        match self {
            Unfit::Structural(m) | Unfit::Untrusted(m) => m,
        }
    }

    fn exit_code(&self) -> i32 {
        match self {
            Unfit::Structural(_) => EXIT_USAGE,
            Unfit::Untrusted(_) => EXIT_UNFIT,
        }
    }
}

/// Reject non-`ddc-pim-bench-v1` documents and any document whose
/// timings are not trustworthy gates (`estimated`/`quick`).
fn check_fit(doc: &Json, role: &str) -> Result<(), Unfit> {
    match doc.get("schema").and_then(Json::as_str) {
        Some("ddc-pim-bench-v1") => {}
        other => return Err(Unfit::Structural(format!("{role}: unsupported schema {other:?}"))),
    }
    for key in ["estimated", "quick"] {
        if doc.get(key).and_then(Json::as_bool) == Some(true) {
            return Err(Unfit::Untrusted(format!(
                "{role}: carries \"{key}\": true — projected or smoke-run timings must \
                 never gate regressions; regenerate with `make bench` on a toolchain host"
            )));
        }
    }
    Ok(())
}

/// Compare the `results` maps case by case (cases present in both).
fn diff(base: &Json, new: &Json) -> Result<Vec<DiffLine>, String> {
    let bres = base
        .get("results")
        .and_then(Json::as_obj)
        .ok_or("baseline: missing results object")?;
    let nres = new
        .get("results")
        .and_then(Json::as_obj)
        .ok_or("candidate: missing results object")?;
    let mut lines = Vec::new();
    for (name, bcase) in bres {
        let Some(ncase) = nres.get(name) else {
            continue; // dropped case: reported by the caller
        };
        let base_ns = bcase
            .get("mean_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("baseline: {name}: missing mean_ns"))?;
        let new_ns = ncase
            .get("mean_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("candidate: {name}: missing mean_ns"))?;
        // a zero/negative/NaN mean on either side is a broken
        // measurement, not a result — reject, never "pass"
        if !base_ns.is_finite() || base_ns <= 0.0 {
            return Err(format!("baseline: {name}: unusable mean_ns {base_ns}"));
        }
        if !new_ns.is_finite() || new_ns <= 0.0 {
            return Err(format!("candidate: {name}: unusable mean_ns {new_ns}"));
        }
        lines.push(DiffLine {
            name: name.clone(),
            base_ns,
            new_ns,
            delta_pct: 100.0 * (new_ns - base_ns) / base_ns,
        });
    }
    Ok(lines)
}

/// Case names present in `a.results` but absent from `b.results`.
fn missing_cases(a: &Json, b: &Json) -> Vec<String> {
    let ares = a.get("results").and_then(Json::as_obj);
    let bres = b.get("results").and_then(Json::as_obj);
    match (ares, bres) {
        (Some(am), Some(bm)) => am.keys().filter(|k| !bm.contains_key(*k)).cloned().collect(),
        _ => Vec::new(),
    }
}

/// The full gate on parsed documents: fit checks, diff, threshold.
/// Returns the offending lines on regression.
fn gate(base: &Json, new: &Json, max_regress_pct: f64) -> Result<Vec<DiffLine>, String> {
    check_fit(base, "baseline").map_err(|u| u.message().to_string())?;
    check_fit(new, "candidate").map_err(|u| u.message().to_string())?;
    let lines = diff(base, new)?;
    Ok(lines
        .into_iter()
        .filter(|l| l.delta_pct > max_regress_pct)
        .collect())
}

fn run_files(base_path: &str, new_path: &str, max_regress_pct: f64) -> i32 {
    let load = |path: &str| -> Result<Json, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        Json::parse(text.trim()).map_err(|e| format!("parsing {path}: {e}"))
    };
    let (base, new) = match (load(base_path), load(new_path)) {
        (Ok(b), Ok(n)) => (b, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-diff: {e}");
            return EXIT_USAGE;
        }
    };
    if let Err(u) = check_fit(&base, &format!("baseline {base_path}")) {
        eprintln!("bench-diff: {}", u.message());
        return u.exit_code();
    }
    if let Err(u) = check_fit(&new, &format!("candidate {new_path}")) {
        eprintln!("bench-diff: {}", u.message());
        return u.exit_code();
    }
    let lines = match diff(&base, &new) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("bench-diff: {e}");
            return EXIT_USAGE;
        }
    };
    for l in &lines {
        println!(
            "diff {:<48} {:>12.1} -> {:>12.1} ns/iter ({:+.1}%)",
            l.name, l.base_ns, l.new_ns, l.delta_pct
        );
    }
    for name in missing_cases(&base, &new) {
        println!("note: case {name} missing from candidate (dropped?)");
    }
    for name in missing_cases(&new, &base) {
        println!("note: case {name} is new (no baseline)");
    }
    let regressions: Vec<&DiffLine> =
        lines.iter().filter(|l| l.delta_pct > max_regress_pct).collect();
    if regressions.is_empty() {
        println!(
            "bench-diff OK: {} case(s) within {max_regress_pct}% of baseline",
            lines.len()
        );
        0
    } else {
        for l in &regressions {
            eprintln!(
                "REGRESSION {:<48} {:+.1}% (> {max_regress_pct}%)",
                l.name, l.delta_pct
            );
        }
        eprintln!("bench-diff: {} regression(s)", regressions.len());
        EXIT_REGRESSION
    }
}

/// Fixture documents for the self-check (and the unit tests).
fn fixture(schema: &str, flags: &str, cases: &[(&str, f64)]) -> Json {
    let results: Vec<String> = cases
        .iter()
        .map(|(name, ns)| format!("\"{name}\": {{\"mean_ns\": {ns}, \"iters\": 100}}"))
        .collect();
    let doc = format!(
        "{{\"schema\": \"{schema}\"{flags}, \"results\": {{{}}}}}",
        results.join(", ")
    );
    Json::parse(&doc).expect("fixture json")
}

/// Prove the gate's reject/flag behavior on synthetic documents —
/// run by CI so the reject-estimated contract can never silently rot.
fn self_check() -> Result<(), String> {
    let clean = fixture("ddc-pim-bench-v1", "", &[("case.a", 100.0), ("case.b", 50.0)]);
    let slower = fixture("ddc-pim-bench-v1", "", &[("case.a", 115.0), ("case.b", 52.0)]);
    let estimated = fixture("ddc-pim-bench-v1", ", \"estimated\": true", &[("case.a", 100.0)]);
    let quick = fixture("ddc-pim-bench-v1", ", \"quick\": true", &[("case.a", 100.0)]);
    let alien = fixture("other-schema", "", &[("case.a", 100.0)]);

    // 1. estimated baselines are hard-rejected
    if gate(&estimated, &clean, 10.0).is_ok() {
        return Err("estimated baseline was accepted".into());
    }
    // 2. quick (smoke-run) documents are hard-rejected on either side
    if gate(&clean, &quick, 10.0).is_ok() {
        return Err("quick candidate was accepted".into());
    }
    if gate(&quick, &clean, 10.0).is_ok() {
        return Err("quick baseline was accepted".into());
    }
    // 3. unknown schemas are rejected
    if gate(&alien, &clean, 10.0).is_ok() {
        return Err("unknown schema was accepted".into());
    }
    // 4. a >10% regression is flagged, smaller drift is not
    let flagged = gate(&clean, &slower, 10.0)?;
    if flagged.len() != 1 || flagged[0].name != "case.a" {
        return Err(format!("expected exactly case.a flagged, got {flagged:?}"));
    }
    if !gate(&clean, &slower, 20.0)?.is_empty() {
        return Err("15% drift flagged at a 20% threshold".into());
    }
    // 5. identical runs pass clean
    if !gate(&clean, &clean, 10.0)?.is_empty() {
        return Err("identical runs flagged".into());
    }
    // 6. a broken candidate measurement (mean_ns <= 0) is rejected,
    //    not reported as a miraculous speedup
    let broken = fixture("ddc-pim-bench-v1", "", &[("case.a", 0.0), ("case.b", 52.0)]);
    if gate(&clean, &broken, 10.0).is_ok() {
        return Err("zero-mean candidate was accepted".into());
    }
    // 7. exit-code classification: estimated/quick docs are "unfit"
    //    (exit 3 — regenerate the baseline), structural breakage is a
    //    usage error (exit 2); CI's gate step branches on this
    match check_fit(&estimated, "baseline") {
        Err(u) if u.exit_code() == EXIT_UNFIT => {}
        other => return Err(format!("estimated doc misclassified: {other:?}")),
    }
    match check_fit(&quick, "candidate") {
        Err(u) if u.exit_code() == EXIT_UNFIT => {}
        other => return Err(format!("quick doc misclassified: {other:?}")),
    }
    match check_fit(&alien, "baseline") {
        Err(u) if u.exit_code() == EXIT_USAGE => {}
        other => return Err(format!("alien schema misclassified: {other:?}")),
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-check") {
        match self_check() {
            Ok(()) => {
                println!("bench-diff self-check OK (estimated/quick rejection + threshold gate)");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("bench-diff self-check FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
    let mut paths = Vec::new();
    let mut max_regress = DEFAULT_MAX_REGRESS_PCT;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--max-regress" => {
                i += 1;
                max_regress = args
                    .get(i)
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| {
                        eprintln!("bench-diff: --max-regress needs a numeric percent");
                        std::process::exit(2);
                    });
            }
            other if !other.starts_with("--") => paths.push(other.to_string()),
            other => {
                eprintln!("bench-diff: unknown flag {other:?}");
                std::process::exit(2);
            }
        }
        i += 1;
    }
    if paths.len() != 2 {
        eprintln!(
            "usage: bench-diff <baseline.json> <candidate.json> [--max-regress PCT]\n\
             \n       bench-diff --self-check"
        );
        std::process::exit(2);
    }
    std::process::exit(run_files(&paths[0], &paths[1], max_regress));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_check_passes() {
        self_check().expect("bench-diff self-check");
    }

    #[test]
    fn estimated_and_quick_are_rejected() {
        let clean = fixture("ddc-pim-bench-v1", "", &[("c", 10.0)]);
        for flag in ["\"estimated\": true", "\"quick\": true"] {
            let bad = fixture("ddc-pim-bench-v1", &format!(", {flag}"), &[("c", 10.0)]);
            assert!(gate(&bad, &clean, 10.0).is_err(), "{flag} baseline accepted");
            assert!(gate(&clean, &bad, 10.0).is_err(), "{flag} candidate accepted");
        }
        // explicit false flags are fine
        let ok = fixture(
            "ddc-pim-bench-v1",
            ", \"estimated\": false, \"quick\": false",
            &[("c", 10.0)],
        );
        assert!(gate(&ok, &clean, 10.0).is_ok());
    }

    #[test]
    fn threshold_is_exclusive_and_signed() {
        let base = fixture("ddc-pim-bench-v1", "", &[("c", 100.0), ("faster", 100.0)]);
        let new = fixture("ddc-pim-bench-v1", "", &[("c", 110.0), ("faster", 10.0)]);
        // exactly +10% is not > 10%; a 10x speedup never trips the gate
        assert!(gate(&base, &new, 10.0).unwrap().is_empty());
        let flagged = gate(&base, &new, 9.9).unwrap();
        assert_eq!(flagged.len(), 1);
        assert_eq!(flagged[0].name, "c");
    }

    #[test]
    fn unfit_exit_code_is_distinct_from_regression_and_usage() {
        assert_ne!(EXIT_UNFIT, EXIT_REGRESSION);
        assert_ne!(EXIT_UNFIT, EXIT_USAGE);
        let est = fixture("ddc-pim-bench-v1", ", \"estimated\": true", &[("c", 1.0)]);
        assert_eq!(check_fit(&est, "b").unwrap_err().exit_code(), EXIT_UNFIT);
        let alien = fixture("other-schema", "", &[("c", 1.0)]);
        assert_eq!(check_fit(&alien, "b").unwrap_err().exit_code(), EXIT_USAGE);
    }

    #[test]
    fn checked_in_baseline_parses_and_classifies() {
        // cargo runs package tests with cwd = rust/, so the repo-root
        // baseline sits one level up.  Either it is a real measured
        // run (gate live) or it still carries estimated/quick and must
        // classify as UNFIT — anything else means the gate can neither
        // diff nor fail loudly.
        let text = std::fs::read_to_string("../BENCH_pim_fabric.json")
            .expect("checked-in BENCH_pim_fabric.json readable");
        let doc = Json::parse(text.trim()).expect("baseline is valid JSON");
        match check_fit(&doc, "baseline") {
            Ok(()) => {} // real baseline: CI diffs it
            Err(u) => assert_eq!(
                u.exit_code(),
                EXIT_UNFIT,
                "baseline neither usable nor cleanly unfit: {}",
                u.message()
            ),
        }
    }

    #[test]
    fn disjoint_cases_are_noted_not_fatal() {
        let base = fixture("ddc-pim-bench-v1", "", &[("old", 10.0), ("both", 10.0)]);
        let new = fixture("ddc-pim-bench-v1", "", &[("new", 10.0), ("both", 10.0)]);
        assert_eq!(missing_cases(&base, &new), vec!["old".to_string()]);
        assert_eq!(missing_cases(&new, &base), vec!["new".to_string()]);
        assert_eq!(gate(&base, &new, 10.0).unwrap(), vec![]);
    }
}
