//! `ddc-lint` — repo-invariant static analysis + interleaving checks.
//!
//! ```text
//! ddc-lint                         # lint rust/src + 1000-seed shuttle
//! ddc-lint --no-shuttle            # static rules only
//! ddc-lint --shuttle 5000          # more schedules
//! ddc-lint --file F.rs --as a/b.rs # lint one file under a pretend path
//! ddc-lint --self-check            # fixtures must each trip their rule
//! ```
//!
//! Exit codes (the `bench-diff` convention): **0** clean, **1**
//! findings or invariant violations, **2** usage/environment error.
//! See `docs/linting.md`.

use std::path::PathBuf;
use std::process::ExitCode;

use ddc_pim::util::lint::{self, manifest, shuttle, Config};

/// Seeds explored per protocol when `--shuttle` is not given; the
/// acceptance floor is 1000 per protocol.
const DEFAULT_SEEDS: u64 = 1000;

struct Args {
    src: PathBuf,
    manifest: PathBuf,
    shuttle_seeds: Option<u64>,
    self_check: bool,
    file: Option<PathBuf>,
    file_as: Option<String>,
}

fn usage() -> &'static str {
    "usage: ddc-lint [--src DIR] [--manifest FILE] [--shuttle N | --no-shuttle] \
     [--file F --as REL] [--self-check]"
}

fn parse_args() -> Result<Args, String> {
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut args = Args {
        src: manifest_dir.join("src"),
        manifest: manifest_dir.join("../lint-hotpaths.toml"),
        shuttle_seeds: Some(DEFAULT_SEEDS),
        self_check: false,
        file: None,
        file_as: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut take = |name: &str| {
            it.next().ok_or_else(|| format!("{name} needs a value\n{}", usage()))
        };
        match a.as_str() {
            "--src" => args.src = PathBuf::from(take("--src")?),
            "--manifest" => args.manifest = PathBuf::from(take("--manifest")?),
            "--shuttle" => {
                let v = take("--shuttle")?;
                args.shuttle_seeds = Some(
                    v.parse()
                        .map_err(|_| format!("--shuttle wants a number, got {v:?}"))?,
                );
            }
            "--no-shuttle" => args.shuttle_seeds = None,
            "--self-check" => args.self_check = true,
            "--file" => args.file = Some(PathBuf::from(take("--file")?)),
            "--as" => args.file_as = Some(take("--as")?),
            "--help" | "-h" => return Err(usage().to_string()),
            other => return Err(format!("unknown flag {other:?}\n{}", usage())),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    let manifest_text = match std::fs::read_to_string(&args.manifest) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("ddc-lint: cannot read manifest {}: {e}", args.manifest.display());
            return ExitCode::from(2);
        }
    };
    let man = match manifest::parse(&manifest_text) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("ddc-lint: bad manifest {}: {e}", args.manifest.display());
            return ExitCode::from(2);
        }
    };
    let cfg = Config::from_manifest(&man);

    if args.self_check {
        let fixtures = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures");
        return match lint::self_check(&fixtures, &cfg) {
            Ok(()) => {
                println!(
                    "ddc-lint self-check: {} fixtures each tripped exactly their rule",
                    lint::FIXTURE_EXPECTATIONS.len()
                );
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("ddc-lint self-check FAILED: {e}");
                ExitCode::from(1)
            }
        };
    }

    if let Some(file) = &args.file {
        let rel = match &args.file_as {
            Some(r) => r.clone(),
            None => file.to_string_lossy().into_owned(),
        };
        let src = match std::fs::read_to_string(file) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("ddc-lint: cannot read {}: {e}", file.display());
                return ExitCode::from(2);
            }
        };
        let findings = lint::lint_source(&rel, &src, &cfg);
        for f in &findings {
            println!("{f}");
        }
        return if findings.is_empty() {
            println!("ddc-lint: {} clean", rel);
            ExitCode::SUCCESS
        } else {
            eprintln!("ddc-lint: {} findings", findings.len());
            ExitCode::from(1)
        };
    }

    // full run: static pass over the tree, then the shuttle models
    let findings = lint::lint_tree(&args.src, &cfg);
    for f in &findings {
        println!("{f}");
    }
    let mut failed = !findings.is_empty();
    if failed {
        eprintln!("ddc-lint: {} findings in {}", findings.len(), args.src.display());
    } else {
        println!("ddc-lint: static pass clean ({})", args.src.display());
    }

    if let Some(seeds) = args.shuttle_seeds {
        let steal = shuttle::check_steal_protocol(seeds, 4, 24);
        let gate = shuttle::check_admission_gate(seeds, 6, 2);
        for v in steal.violations.iter() {
            println!("shuttle[steal]: {v}");
        }
        for v in gate.violations.iter() {
            println!("shuttle[admission]: {v}");
        }
        println!(
            "ddc-lint shuttle: steal {} schedules / {} steps, admission {} schedules / {} steps",
            steal.schedules, steal.steps, gate.schedules, gate.steps
        );
        if !steal.ok() || !gate.ok() {
            eprintln!("ddc-lint: interleaving invariant violations");
            failed = true;
        }
    }

    if failed {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
