//! Architecture + simulation configuration.
//!
//! Defaults reproduce the paper's hardware implementation (§IV-A): four
//! 4 KB PIM macros (32 compartments x 16 DBMUs x 64 cells), 256 KB weight
//! memory, 128 KB ping-pong memory, 333 MHz @ 0.7 V on 14 nm.
//! The ablation switches (`ddc`, `reconfig`, `recover`, `fcc`) express
//! both the PIM baseline (§IV-A "PIM baseline") and the Fig. 13 ablation
//! ladder.

/// Hardware/architecture parameters of one DDC-PIM instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Number of PIM macros (paper: 4).
    pub macros: usize,
    /// Compartments per PIM core (paper: 32).
    pub compartments: usize,
    /// SRAM rows per compartment (64 cells per DBMU column).
    pub rows: usize,
    /// DBMUs (bit columns) per compartment (paper: 16 = two 8b weights).
    pub dbmus: usize,
    /// Weight precision in bits (paper: signed INT8).
    pub weight_bits: usize,
    /// Input precision in bits (bit-serial, paper: signed INT8).
    pub input_bits: usize,
    /// Clock frequency (paper: 333 MHz).
    pub freq_mhz: f64,
    /// Dual-broadcast input structure present (INP/INN) -> double
    /// computing mode available.
    pub dbis: bool,
    /// Reconfigurable unit (4 adder units, 2-stage dw alternation).
    pub reconfig: bool,
    /// Accumulate-and-recover unit (ARU) present -> FCC layers supported.
    pub recover: bool,
    /// Weight memory capacity (KB).
    pub weight_mem_kb: usize,
    /// Ping-pong (activation) memory capacity (KB).
    pub pingpong_kb: usize,
    /// Off-chip DRAM effective bandwidth in bytes/cycle (per §III-D the
    /// prefetcher masks most of this latency).
    pub dram_bytes_per_cycle: f64,
    /// Fixed DRAM access setup latency (cycles).
    pub dram_latency_cycles: u64,
    /// SRAM row writes per cycle per macro when loading weights.
    pub load_rows_per_cycle: usize,
    /// Technology node (nm) — used by the cost model.
    pub node_nm: f64,
}

impl ArchConfig {
    /// The paper's DDC-PIM configuration.
    pub fn ddc_pim() -> Self {
        ArchConfig {
            macros: 4,
            compartments: 32,
            rows: 64,
            dbmus: 16,
            weight_bits: 8,
            input_bits: 8,
            freq_mhz: 333.0,
            dbis: true,
            reconfig: true,
            recover: true,
            weight_mem_kb: 256,
            pingpong_kb: 128,
            dram_bytes_per_cycle: 8.0,
            dram_latency_cycles: 100,
            load_rows_per_cycle: 1,
            node_nm: 14.0,
        }
    }

    /// The PIM baseline of §IV-A: no DBIS, no reconfigurable unit, no
    /// recover unit; regular computing mode only.  Everything else equal.
    pub fn baseline() -> Self {
        ArchConfig {
            dbis: false,
            reconfig: false,
            recover: false,
            ..Self::ddc_pim()
        }
    }

    /// Stored 8-bit weights per SRAM row (16 DBMU columns / 8 bits).
    pub fn weights_per_row(&self) -> usize {
        self.dbmus / self.weight_bits
    }

    /// Array size of one macro in bits (cells). Paper: 32 Kb.
    pub fn macro_array_kb(&self) -> f64 {
        (self.compartments * self.dbmus * self.rows) as f64 / 1024.0
    }

    /// Equivalent weight capacity of one macro in Kb: doubled when the
    /// complementary states are exploited (DDC).
    pub fn macro_weight_capacity_kb(&self) -> f64 {
        if self.dbis && self.recover {
            2.0 * self.macro_array_kb()
        } else {
            self.macro_array_kb()
        }
    }

    /// Stored-weight slots per macro (8-bit weights physically written).
    pub fn macro_weight_slots(&self) -> usize {
        self.compartments * self.rows * self.weights_per_row()
    }

    /// 8b x 8b MACs completed per cycle at peak, whole chip (paper:
    /// 42.67 GOPS / 333 MHz / 2 ops = 64 MACs/cycle for DDC).
    pub fn peak_macs_per_cycle(&self) -> f64 {
        let per_row_step = self.compartments as f64
            * self.weights_per_row() as f64
            * if self.dbis { 2.0 } else { 1.0 };
        per_row_step * self.macros as f64 / self.input_bits as f64
    }

    /// Peak GOPS at 8b x 8b (2 ops per MAC).
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.peak_macs_per_cycle() * self.freq_mhz * 1e6 / 1e9
    }

    /// Cycle time in nanoseconds.
    pub fn cycle_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self::ddc_pim()
    }
}

/// Workload-level simulation options.
#[derive(Debug, Clone, Default)]
pub struct SimConfig {
    /// Apply FCC to std/pw conv layers.
    pub fcc_std_pw: bool,
    /// Apply FCC (+DBIS pairing) to dw conv layers.
    pub fcc_dw: bool,
    /// Effective scope S(i): FCC only on conv layers with more than
    /// `scope_threshold` filters. 0 = all conv layers.
    pub scope_threshold: usize,
    /// Batch size of the simulated inference.
    pub batch: usize,
}

impl SimConfig {
    pub fn ddc_full() -> Self {
        SimConfig {
            fcc_std_pw: true,
            fcc_dw: true,
            scope_threshold: 0,
            batch: 1,
        }
    }

    pub fn baseline() -> Self {
        SimConfig {
            fcc_std_pw: false,
            fcc_dw: false,
            scope_threshold: 0,
            batch: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let c = ArchConfig::ddc_pim();
        assert_eq!(c.weights_per_row(), 2);
        // 32 compartments x 16 columns x 64 rows = 32 Kb per macro
        assert_eq!(c.macro_array_kb(), 32.0);
        assert_eq!(c.macro_weight_capacity_kb(), 64.0); // doubled
        assert_eq!(c.macro_weight_slots(), 4096);
    }

    #[test]
    fn baseline_capacity_not_doubled() {
        let b = ArchConfig::baseline();
        assert_eq!(b.macro_weight_capacity_kb(), 32.0);
    }

    #[test]
    fn peak_gops_matches_fig12() {
        // paper Fig. 12(a): 42.67 GOPS at 8b x 8b, 333 MHz
        let c = ArchConfig::ddc_pim();
        assert!((c.peak_macs_per_cycle() - 64.0).abs() < 1e-9);
        assert!((c.peak_gops() - 42.67).abs() < 0.05, "gops={}", c.peak_gops());
        // baseline has half the parallelism
        let b = ArchConfig::baseline();
        assert!((b.peak_gops() - 21.33).abs() < 0.05);
    }

    #[test]
    fn macro_is_4kb() {
        let c = ArchConfig::ddc_pim();
        assert_eq!(c.macro_array_kb() / 8.0, 4.0); // 32 Kb = 4 KB
    }
}
