//! Dynamic request batcher.
//!
//! Groups pending requests into batches of at most `max_batch`, flushing
//! either when full or when the oldest request has waited `max_wait`.
//! The serving path compiles one executable per batch size (b1 / b8), so
//! the batcher also picks the artifact: full batches go to the wide
//! executable, stragglers to the narrow one.

use std::time::{Duration, Instant};

/// A queued item with its arrival time.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Accumulates items and decides when to flush.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: Vec<Pending<T>>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            queue: Vec::new(),
            policy,
        }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push(Pending {
            item,
            arrived: Instant::now(),
        });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be cut now.
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.first() {
            Some(p) => now.duration_since(p.arrived) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Cut a batch of up to `max_batch` items (FIFO) into a caller-owned
    /// buffer: `sink` is cleared and refilled, so a worker reusing one
    /// sink across flushes allocates nothing on the steady-state path.
    pub fn cut_into(&mut self, sink: &mut Vec<T>) {
        let n = self.queue.len().min(self.policy.max_batch);
        sink.clear();
        sink.extend(self.queue.drain(..n).map(|p| p.item));
    }

    /// Cut a batch of up to `max_batch` items (FIFO).  Allocating
    /// wrapper over [`Batcher::cut_into`].
    pub fn cut(&mut self) -> Vec<T> {
        let mut out = Vec::new();
        self.cut_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(ms),
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(policy(2, 1000));
        b.push(1);
        assert!(!b.should_flush(Instant::now()));
        b.push(2);
        assert!(b.should_flush(Instant::now()));
        assert_eq!(b.cut(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b = Batcher::new(policy(8, 0));
        b.push(7);
        assert!(b.should_flush(Instant::now()));
    }

    #[test]
    fn cut_is_fifo_and_bounded() {
        let mut b = Batcher::new(policy(2, 0));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.cut(), vec![0, 1]);
        assert_eq!(b.cut(), vec![2, 3]);
        assert_eq!(b.cut(), vec![4]);
    }

    #[test]
    fn cut_into_reuses_and_overwrites_the_sink() {
        let mut b = Batcher::new(policy(2, 0));
        let mut sink = vec![99, 98, 97];
        for i in 0..3 {
            b.push(i);
        }
        b.cut_into(&mut sink);
        assert_eq!(sink, vec![0, 1], "stale sink contents must be dropped");
        b.cut_into(&mut sink);
        assert_eq!(sink, vec![2]);
        b.cut_into(&mut sink);
        assert!(sink.is_empty());
    }

    #[test]
    fn empty_never_flushes() {
        let b: Batcher<i32> = Batcher::new(policy(1, 0));
        assert!(!b.should_flush(Instant::now()));
    }
}
