//! Dynamic request batcher.
//!
//! Groups pending requests into batches of at most `max_batch`, flushing
//! either when full or when the oldest request has waited `max_wait`.
//! The serving path compiles one executable per batch size (b1 / b8), so
//! the batcher also picks the artifact: full batches go to the wide
//! executable, stragglers to the narrow one.

use std::time::{Duration, Instant};

/// A queued item with its arrival time.
#[derive(Debug)]
pub struct Pending<T> {
    pub item: T,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Accumulates items and decides when to flush.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: Vec<Pending<T>>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            queue: Vec::new(),
            policy,
        }
    }

    pub fn push(&mut self, item: T) {
        self.push_arrived(item, Instant::now());
    }

    /// Push with an explicit arrival time.  Requeue paths (a batch
    /// bounced off a panicked session) use the item's *original*
    /// arrival so its flush deadline and latency accounting are
    /// preserved — an already-overdue item makes the queue immediately
    /// flushable rather than waiting a fresh `max_wait`.
    pub fn push_arrived(&mut self, item: T, arrived: Instant) {
        self.queue.push(Pending { item, arrived });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be cut now.
    pub fn should_flush(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.first() {
            Some(p) => now.duration_since(p.arrived) >= self.policy.max_wait,
            None => false,
        }
    }

    /// The instant at which the *oldest* queued item's `max_wait`
    /// elapses — the moment [`Batcher::should_flush`] turns true for a
    /// non-full queue.  `None` when the queue is empty (nothing will
    /// ever become due, so a worker may block indefinitely).
    ///
    /// Workers should sleep exactly until this deadline instead of
    /// polling on a fixed tick: a lone straggler then flushes the
    /// moment its wait expires, never a tick later (and an idle queue
    /// costs no wake-ups at all).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.first().map(|p| p.arrived + self.policy.max_wait)
    }

    /// Cut a batch of up to `max_batch` items (FIFO) into a caller-owned
    /// buffer: `sink` is cleared and refilled, so a worker reusing one
    /// sink across flushes allocates nothing on the steady-state path.
    pub fn cut_into(&mut self, sink: &mut Vec<T>) {
        let n = self.queue.len().min(self.policy.max_batch);
        sink.clear();
        sink.extend(self.queue.drain(..n).map(|p| p.item));
    }

    /// Cut a batch of up to `max_batch` items (FIFO).  Allocating
    /// wrapper over [`Batcher::cut_into`].
    pub fn cut(&mut self) -> Vec<T> {
        let mut out = Vec::new();
        self.cut_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(ms),
        }
    }

    #[test]
    fn flushes_when_full() {
        let mut b = Batcher::new(policy(2, 1000));
        b.push(1);
        assert!(!b.should_flush(Instant::now()));
        b.push(2);
        assert!(b.should_flush(Instant::now()));
        assert_eq!(b.cut(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b = Batcher::new(policy(8, 0));
        b.push(7);
        assert!(b.should_flush(Instant::now()));
    }

    #[test]
    fn cut_is_fifo_and_bounded() {
        let mut b = Batcher::new(policy(2, 0));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.cut(), vec![0, 1]);
        assert_eq!(b.cut(), vec![2, 3]);
        assert_eq!(b.cut(), vec![4]);
    }

    #[test]
    fn cut_into_reuses_and_overwrites_the_sink() {
        let mut b = Batcher::new(policy(2, 0));
        let mut sink = vec![99, 98, 97];
        for i in 0..3 {
            b.push(i);
        }
        b.cut_into(&mut sink);
        assert_eq!(sink, vec![0, 1], "stale sink contents must be dropped");
        b.cut_into(&mut sink);
        assert_eq!(sink, vec![2]);
        b.cut_into(&mut sink);
        assert!(sink.is_empty());
    }

    #[test]
    fn push_arrived_preserves_the_original_deadline() {
        // a requeued item keeps its old arrival: already overdue, so
        // the queue is immediately flushable (no fresh max_wait)
        let w = Duration::from_millis(50);
        let mut b = Batcher::new(policy(8, 50));
        let past = Instant::now() - w;
        b.push_arrived(7, past);
        assert!(b.should_flush(Instant::now()), "overdue requeue must flush now");
        assert_eq!(b.next_deadline(), Some(past + w));
    }

    #[test]
    fn empty_never_flushes() {
        let b: Batcher<i32> = Batcher::new(policy(1, 0));
        assert!(!b.should_flush(Instant::now()));
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn deadline_is_oldest_arrival_plus_max_wait() {
        // mocked-clock check: the push happened inside [before, after],
        // so the deadline must sit inside [before + w, after + w] — and
        // should_flush must agree with it exactly
        let w = Duration::from_millis(40);
        let mut b = Batcher::new(policy(8, 40));
        let before = Instant::now();
        b.push(1);
        let after = Instant::now();
        let d = b.next_deadline().expect("deadline for a queued item");
        assert!(d >= before + w, "deadline earlier than arrival + max_wait");
        assert!(d <= after + w, "deadline later than arrival + max_wait");
        assert!(b.should_flush(d), "not flushable at its own deadline");
        assert!(!b.should_flush(before), "flushable before max_wait elapsed");
        // a second, younger item must not move the deadline (the
        // straggler guarantee is for the oldest request)
        b.push(2);
        assert_eq!(b.next_deadline(), Some(d));
        // cutting the queue clears the deadline
        b.cut();
        assert_eq!(b.next_deadline(), None);
    }

    #[test]
    fn zero_wait_deadline_is_immediately_due() {
        let mut b = Batcher::new(policy(8, 0));
        b.push(5);
        let d = b.next_deadline().unwrap();
        assert!(b.should_flush(d));
        assert!(b.should_flush(Instant::now()));
    }
}
