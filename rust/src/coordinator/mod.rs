//! L3 coordinator: the serving half of the reproduction.
//!
//! * [`batcher`] — dynamic request batching (full batches ride the wide
//!   executable, stragglers are padded);
//! * [`scheduler`] — prefetch-aware layer timeline;
//! * [`service`] — the threaded request loop that owns the execution
//!   [`crate::runtime::Backend`] (reference by default, PJRT/AOT
//!   artifacts behind the `pjrt` feature).

pub mod batcher;
pub mod scheduler;
pub mod service;

pub use batcher::{BatchPolicy, Batcher};
pub use service::{InferenceResult, InferenceService, ServiceStats, IMG_ELEMS, NUM_CLASSES};
