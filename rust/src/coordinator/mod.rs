//! L3 coordinator: the serving half of the reproduction.
//!
//! * [`batcher`] — dynamic request batching (full batches ride the wide
//!   executable, stragglers are padded);
//! * [`scheduler`] — prefetch-aware layer timeline;
//! * [`service`] — the threaded request loop that prepares one
//!   [`crate::runtime::Session`] (weights resident for the worker's
//!   lifetime; reference by default, PJRT/AOT artifacts behind the
//!   `pjrt` feature) and executes batches through it zero-alloc.
//!   Serving is fail-soft: batch panics are caught and retried on a
//!   rebuilt session, clients get typed timeouts
//!   ([`service::ServiceError`]), and the session's fault/scrub
//!   counters ride along in [`service::ServiceStats`].

pub mod batcher;
pub mod scheduler;
pub mod service;

pub use batcher::{BatchPolicy, Batcher};
// shape constants come straight from the runtime (single definition);
// re-exported here for the service's callers
pub use crate::runtime::{IMG_ELEMS, NUM_CLASSES};
pub use service::{
    InferenceResult, InferenceService, ServiceError, ServiceStats, DEFAULT_INFER_TIMEOUT,
};
