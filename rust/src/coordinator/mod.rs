//! L3 coordinator: the serving half of the reproduction.
//!
//! * [`batcher`] — dynamic request batching (full batches ride the wide
//!   executable, stragglers are padded);
//! * [`scheduler`] — prefetch-aware layer timeline;
//! * [`service`] — the threaded request loop that owns the PJRT runtime
//!   and serves the AOT model artifacts.

pub mod batcher;
pub mod scheduler;
pub mod service;

pub use batcher::{BatchPolicy, Batcher};
pub use service::{InferenceResult, InferenceService, ServiceStats};
