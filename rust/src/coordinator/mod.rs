//! L3 coordinator: the serving half of the reproduction.
//!
//! * [`batcher`] — dynamic request batching (full batches ride the wide
//!   executable, stragglers are padded);
//! * [`scheduler`] — prefetch-aware layer timeline;
//! * [`service`] — the serving tier: a batching dispatcher in front of
//!   N worker threads, each owning its own resident
//!   [`crate::runtime::Session`] (reference by default, PJRT/AOT
//!   artifacts behind the `pjrt` feature) and executing batches
//!   zero-alloc.  Admission control sheds load at the door with the
//!   typed [`service::ServiceError::Overloaded`] when the in-flight
//!   depth hits [`service::ServiceConfig::max_queue_depth`].  Serving
//!   is fail-soft: batch panics are caught and retried on a rebuilt
//!   session, clients get typed timeouts
//!   ([`service::ServiceError`]), and SLO percentiles (p50/p95/p99),
//!   admission counters and the sessions' fault/scrub counters ride
//!   along in [`service::ServiceStats`].

pub mod batcher;
pub mod scheduler;
pub mod service;

pub use batcher::{BatchPolicy, Batcher};
// shape constants come straight from the runtime (single definition);
// re-exported here for the service's callers
pub use crate::runtime::{IMG_ELEMS, NUM_CLASSES};
pub use service::{
    resolve_workers, InferenceResult, InferenceService, ServiceConfig, ServiceError, ServiceStats,
    DEFAULT_INFER_TIMEOUT, MAX_WORKERS,
};
