//! Layer scheduler: turns a network plan into a prefetch-aware timeline.
//!
//! The top controller executes layers strictly in order, but DRAM weight
//! transfers for layer `i+1` are issued as soon as layer `i` starts
//! computing (the paper's §III-D prefetch).  The scheduler materializes
//! the resulting timeline: per-layer start/end cycles and the exposed
//! stall, which the reports and the e2e example visualize.

use crate::arch::dram::Dram;
use crate::config::ArchConfig;
use crate::mapping::LayerPlan;

/// One scheduled layer.
#[derive(Debug, Clone)]
pub struct Slot {
    pub name: String,
    pub start: u64,
    pub end: u64,
    /// Cycles stalled waiting on DRAM (not hidden by prefetch).
    pub stall: u64,
}

/// Schedule a plan sequence; returns the timeline and the makespan.
pub fn schedule(plans: &[LayerPlan], arch: &ArchConfig, input_bytes: u64) -> (Vec<Slot>, u64) {
    let dram = Dram::new(arch.dram_bytes_per_cycle, arch.dram_latency_cycles);
    let mut slots = Vec::with_capacity(plans.len());
    let mut clock: u64 = 0;
    // DRAM "front": the cycle at which the weight stream for the next
    // layer finishes arriving.
    let mut dram_ready: u64 = dram.transfer_cycles(input_bytes as usize);

    for plan in plans {
        let transfer = dram.transfer_cycles(plan.dram_weight_bytes as usize);
        // weights for THIS layer finish at dram_ready + its own transfer
        let weights_at = dram_ready + transfer;
        let start = clock.max(weights_at);
        let stall = start - clock;
        let busy = plan.pim_cycles();
        let end = start + busy;
        slots.push(Slot {
            name: plan.name.clone(),
            start,
            end,
            stall,
        });
        // next layer's weights start streaming as soon as this layer's
        // arrived (the DRAM channel is busy until then)
        dram_ready = weights_at;
        clock = end;
    }
    (slots, clock)
}

/// Total stall cycles across the timeline (prefetch effectiveness).
pub fn total_stall(slots: &[Slot]) -> u64 {
    slots.iter().map(|s| s.stall).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::mapping::plan_network;
    use crate::model::zoo;

    #[test]
    fn timeline_is_monotone() {
        let arch = ArchConfig::ddc_pim();
        let plans = plan_network(&zoo::mobilenet_v2(), &arch, &SimConfig::ddc_full());
        let (slots, makespan) = schedule(&plans, &arch, 3072);
        assert_eq!(slots.len(), plans.len());
        let mut prev = 0;
        for s in &slots {
            assert!(s.start >= prev, "{} starts before prev ends", s.name);
            assert!(s.end >= s.start);
            prev = s.end;
        }
        assert_eq!(makespan, slots.last().unwrap().end);
    }

    #[test]
    fn prefetch_hides_most_traffic() {
        // with the paper's bandwidth, stalls should be a small fraction
        // of the makespan for MobileNetV2
        let arch = ArchConfig::ddc_pim();
        let plans = plan_network(&zoo::mobilenet_v2(), &arch, &SimConfig::ddc_full());
        let (slots, makespan) = schedule(&plans, &arch, 3072);
        let stall = total_stall(&slots);
        assert!(
            (stall as f64) < 0.35 * makespan as f64,
            "stall {stall} vs makespan {makespan}"
        );
    }

    #[test]
    fn zero_bandwidth_starves() {
        let mut arch = ArchConfig::ddc_pim();
        arch.dram_bytes_per_cycle = 0.001;
        let plans = plan_network(&zoo::resnet18(), &arch, &SimConfig::ddc_full());
        let (slots, makespan) = schedule(&plans, &arch, 3072);
        // DRAM-bound: stalls dominate
        assert!(total_stall(&slots) > makespan / 2);
    }
}
