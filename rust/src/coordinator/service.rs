//! Inference service: the request loop that owns the execution session.
//!
//! A dedicated worker thread owns the [`Session`] (PJRT handles are not
//! `Send`-safe by contract, so the backend is constructed — and its
//! session prepared — inside the thread and never leaves it).  Clients
//! submit CIFAR-shaped images over a channel; the batcher groups them
//! (the worker sleeps exactly to [`Batcher::next_deadline`], so a lone
//! straggler flushes the moment its `max_wait` expires); the session
//! executes the whole batch with a real batch dimension (the PJRT
//! backend pads stragglers up to its wide executable, the reference
//! backend folds the batch into its MVM row dimension — on the
//! bit-sliced fabric through the session's parallel exec pool, width
//! chosen by `BackendSpec::threads` / `--threads` / `DDC_THREADS`).
//!
//! Weights are resident for the worker's lifetime: the backend is
//! prepared exactly once, and every per-batch buffer (the pending-cut
//! sink, the packed input, the logits) is persistent, so the
//! steady-state execute path performs no per-batch heap allocation.
//! (The per-request `mpsc` response send is the one remaining
//! allocation, and the response itself is client-owned by design.)
//!
//! Alongside the functional result, each request is annotated with the
//! *simulated* DDC-PIM latency of the model so the serving path reports
//! both wall-clock and modelled-hardware numbers.  When the backend
//! spec carries a weight-streaming budget (`BackendSpec::stream_kb`),
//! [`ServiceStats`] additionally carries the session's
//! [`CapacityPressure`] counters, refreshed whenever stats are queried.

use std::sync::mpsc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ArchConfig, SimConfig};
use crate::metrics::{CapacityPressure, LatencyHistogram};
use crate::model::zoo;
use crate::runtime::{Backend, BackendKind, BackendSpec, Session, IMG_ELEMS, NUM_CLASSES};
use crate::sim::simulate_network;

use super::batcher::{BatchPolicy, Batcher};

/// One inference request.
struct Request {
    input: Vec<f32>,
    resp: mpsc::Sender<Result<InferenceResult, String>>,
    submitted: Instant,
}

/// The answer a client gets back.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Classifier logits (fixed-size: no per-request heap allocation).
    pub logits: [f32; NUM_CLASSES],
    pub argmax: usize,
    /// Wall-clock service latency.
    pub latency: Duration,
    /// Batch this request rode in.
    pub batch_size: usize,
    /// Modelled DDC-PIM latency for the whole model (ms, from the cycle
    /// simulator; amortized per batch).
    pub simulated_ms: f64,
    /// Which backend executed the request ("reference" / "pjrt").
    pub backend: &'static str,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Log-bucketed latency distribution (p50/p99 queries).
    pub latency_hist: LatencyHistogram,
    /// Weight-streaming capacity pressure reported by the session
    /// (all-zero when the backend runs without a streaming budget —
    /// `CapacityPressure::default()` means "everything resident").
    pub capacity: CapacityPressure,
}

impl ServiceStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn p50(&self) -> Duration {
        self.latency_hist.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.latency_hist.percentile(99.0)
    }
}

enum Msg {
    Infer(Request),
    Stats(mpsc::Sender<ServiceStats>),
    Shutdown,
}

/// Handle to a running service.
pub struct InferenceService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Start the worker thread with automatic backend selection (PJRT
    /// when compiled in and artifacts exist, else the reference backend).
    pub fn start(artifact_dir: String, policy: BatchPolicy) -> InferenceService {
        Self::start_with(BackendKind::Auto, artifact_dir, policy)
    }

    /// Start the worker thread with an explicit backend choice.
    pub fn start_with(
        kind: BackendKind,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        Self::start_spec(BackendSpec::new(kind), artifact_dir, policy)
    }

    /// Start the worker thread with a full backend spec (kind + knobs
    /// such as the reference backend's fabric choice).
    pub fn start_spec(
        spec: BackendSpec,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = thread::spawn(move || worker_loop(spec, artifact_dir, policy, rx));
        InferenceService {
            tx,
            worker: Some(worker),
        }
    }

    /// Submit an image; returns a receiver for the result.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<InferenceResult, String>> {
        let (rtx, rrx) = mpsc::channel();
        // reject malformed inputs here, before batching, so one bad
        // request can never fail the valid requests batched with it
        if input.len() != IMG_ELEMS {
            let _ = rtx.send(Err(format!(
                "bad input size {} (want {IMG_ELEMS})",
                input.len()
            )));
            return rrx;
        }
        let req = Request {
            input,
            resp: rtx,
            submitted: Instant::now(),
        };
        // if the worker died the receiver will simply disconnect
        let _ = self.tx.send(Msg::Infer(req));
        rrx
    }

    /// Blocking convenience call.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult, String> {
        self.submit(input)
            .recv()
            .map_err(|e| format!("service dropped request: {e}"))?
    }

    pub fn stats(&self) -> Option<ServiceStats> {
        let (stx, srx) = mpsc::channel();
        self.tx.send(Msg::Stats(stx)).ok()?;
        srx.recv().ok()
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// NaN-robust argmax over a logit slice: `f32::total_cmp` gives NaN a
/// fixed place in the order (positive NaN above +inf) instead of
/// panicking mid-batch — a single NaN logit must never kill the worker
/// thread.
fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(k, _)| k)
        .unwrap_or(0)
}

fn worker_loop(
    spec: BackendSpec,
    artifact_dir: String,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
) {
    // drain helper: fail every request with an init error; exit on
    // Shutdown (Drop joins this thread, so it must terminate)
    let drain_with_error = |rx: mpsc::Receiver<Msg>, err: String| {
        for msg in rx {
            match msg {
                Msg::Infer(req) => {
                    let _ = req.resp.send(Err(err.clone()));
                }
                Msg::Stats(stx) => {
                    let _ = stx.send(ServiceStats::default());
                }
                Msg::Shutdown => break,
            }
        }
    };
    let backend = match spec.create(&artifact_dir) {
        Ok(b) => b,
        Err(e) => return drain_with_error(rx, format!("backend init failed: {e:#}")),
    };
    let backend_name = backend.name();
    // prepare once: weights become resident for the worker's lifetime
    let mut session = match backend.prepare() {
        Ok(s) => s,
        Err(e) => return drain_with_error(rx, format!("session prepare failed: {e:#}")),
    };
    drop(backend); // the session owns everything execution needs

    // modelled hardware latency (once; amortized per batch below)
    let sim_ms = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    )
    .latency_ms();

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut stats = ServiceStats::default();
    let mut open = true;
    // persistent per-batch buffers: the cut sink, the packed input and
    // the logits live for the worker's lifetime, so the steady-state
    // path below allocates nothing per batch
    let mut pending: Vec<Request> = Vec::new();
    let mut input_buf: Vec<f32> = Vec::new();
    let mut logits_buf: Vec<f32> = Vec::new();

    while open || !batcher.is_empty() {
        // ingest until a batch is due.  An idle queue blocks on the
        // channel outright (no wake-ups); a non-empty queue sleeps
        // *exactly* to the oldest request's deadline, so a lone
        // straggler flushes the moment its max_wait elapses — never a
        // poll tick later (the fixed-tick loop this replaces stalled
        // stragglers by up to a tick past the deadline, and burned a
        // wake-up every tick while idle)
        while open && !batcher.should_flush(Instant::now()) {
            let msg = match batcher.next_deadline() {
                // empty queue: nothing can ever become due
                None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
                Some(deadline) => {
                    rx.recv_timeout(deadline.saturating_duration_since(Instant::now()))
                }
            };
            match msg {
                Ok(Msg::Infer(r)) => batcher.push(r),
                Ok(Msg::Stats(stx)) => {
                    stats.capacity = session.capacity_pressure().unwrap_or_default();
                    let _ = stx.send(stats.clone());
                }
                Ok(Msg::Shutdown) => open = false,
                // deadline hit: the loop condition cuts the batch now
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain without blocking
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Infer(r) => batcher.push(r),
                    Msg::Stats(stx) => {
                        stats.capacity = session.capacity_pressure().unwrap_or_default();
                        let _ = stx.send(stats.clone());
                    }
                    Msg::Shutdown => open = false,
                }
            }
        }
        if batcher.is_empty() {
            continue;
        }
        batcher.cut_into(&mut pending);
        let bsize = pending.len();
        stats.batches += 1;
        // pack the cut directly into the persistent input buffer (each
        // byte written exactly once; capacity is retained across cuts)
        input_buf.clear();
        for req in &pending {
            // submit() already rejected malformed inputs; a violation
            // here is a programming error, and must never fail
            // co-batched requests (the no-poison invariant)
            debug_assert_eq!(req.input.len(), IMG_ELEMS, "unvalidated request reached batcher");
            input_buf.extend_from_slice(&req.input);
        }
        debug_assert_eq!(input_buf.len(), bsize * IMG_ELEMS);
        logits_buf.clear();
        logits_buf.resize(bsize * NUM_CLASSES, 0.0);
        match session.infer_batch_into(&input_buf, bsize, &mut logits_buf) {
            Ok(()) => {
                for (i, req) in pending.drain(..).enumerate() {
                    let mut logits = [0f32; NUM_CLASSES];
                    logits.copy_from_slice(&logits_buf[i * NUM_CLASSES..(i + 1) * NUM_CLASSES]);
                    let latency = req.submitted.elapsed();
                    stats.requests += 1;
                    stats.total_latency += latency;
                    stats.max_latency = stats.max_latency.max(latency);
                    stats.latency_hist.record(latency);
                    let _ = req.resp.send(Ok(InferenceResult {
                        logits,
                        argmax: argmax(&logits),
                        latency,
                        batch_size: bsize,
                        simulated_ms: sim_ms / bsize as f64,
                        backend: backend_name,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for req in pending.drain(..) {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FabricChoice;

    #[test]
    fn serves_without_artifacts_via_reference_backend() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let r = svc.infer(vec![0.0; IMG_ELEMS]).expect("reference inference");
        assert_eq!(r.logits.len(), NUM_CLASSES);
        assert_eq!(r.backend, "reference");
    }

    #[test]
    fn rejects_bad_input_size() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; 3]);
        assert!(res.is_err());
    }

    #[test]
    fn explicit_reference_kind() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        assert!(svc.infer(vec![0.1; IMG_ELEMS]).is_ok());
    }

    #[test]
    fn bitsliced_fabric_spec_serves_identical_logits() {
        let dense = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let fabric = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                threads: 2,
                stream_kb: 0,
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let img = vec![0.3f32; IMG_ELEMS];
        let a = dense.infer(img.clone()).expect("dense");
        let b = fabric.infer(img).expect("fabric");
        // at these layer sizes the i32 kernels cannot overflow, so the
        // bit-sliced macro path and the dense kernel agree exactly
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn streamed_service_reports_capacity_pressure() {
        // a 2 KiB budget cannot hold conv2's 2304 B: the worker session
        // streams, and stats() surfaces its pressure counters
        let svc = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::DenseReference,
                threads: 1,
                stream_kb: 2,
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        svc.infer(vec![0.1; IMG_ELEMS]).expect("streamed inference");
        svc.infer(vec![0.2; IMG_ELEMS]).expect("streamed inference");
        let stats = svc.stats().expect("stats");
        let p = stats.capacity;
        assert_eq!(p.capacity_bytes, 2048);
        assert!(p.staged_bytes > 0, "no staging recorded");
        assert!(p.reloads > 0, "second request must re-stage the passes");
        // an unbudgeted service stays all-zero ("everything resident")
        let resident =
            InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        resident.infer(vec![0.1; IMG_ELEMS]).expect("inference");
        assert_eq!(
            resident.stats().expect("stats").capacity,
            CapacityPressure::default()
        );
    }

    #[test]
    fn lone_straggler_is_served_at_its_deadline() {
        // a single request in a wide-batch policy must be flushed by
        // the deadline sleep (never stranded waiting for a full batch)
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(10),
            },
        );
        let r = svc.infer(vec![0.2; IMG_ELEMS]).expect("straggler served");
        assert_eq!(r.batch_size, 1);
    }

    #[test]
    fn queued_stragglers_drain_on_shutdown() {
        // requests still queued when the service drops must be executed
        // (drain path), not dropped on the floor
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(3600),
            },
        );
        let rx = svc.submit(vec![0.1; IMG_ELEMS]);
        drop(svc); // shutdown while the straggler is still queued
        let r = rx.recv().expect("response after shutdown").expect("served");
        assert_eq!(r.logits.len(), NUM_CLASSES);
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // regression: partial_cmp().unwrap() panicked (and killed the
        // worker thread) on any NaN logit.  In the total order positive
        // NaN sits above +inf, so a NaN deterministically wins.
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN, f32::NAN]), 2);
        assert_eq!(argmax(&[0.0, f32::NEG_INFINITY, 3.0, f32::NAN]), 3);
        assert_eq!(argmax(&[0.5, 1.0, 0.25]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
