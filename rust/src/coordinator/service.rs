//! Inference service: the request loop that owns the PJRT runtime.
//!
//! A dedicated worker thread owns the [`Runtime`] (PJRT handles are not
//! `Send`-safe by contract, so they never leave the thread).  Clients
//! submit CIFAR-shaped images over a channel; the batcher groups them;
//! full batches run on the wide executable (`model_b8`), stragglers are
//! padded.  Alongside the functional result, each request is annotated
//! with the *simulated* DDC-PIM latency of the model so the serving path
//! reports both wall-clock and modelled-hardware numbers.

use std::sync::mpsc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ArchConfig, SimConfig};
use crate::metrics::LatencyHistogram;
use crate::model::zoo;
use crate::runtime::Runtime;
use crate::sim::simulate_network;

use super::batcher::{BatchPolicy, Batcher};

pub const IMG_ELEMS: usize = 32 * 32 * 3;
pub const NUM_CLASSES: usize = 10;
const WIDE_BATCH: usize = 8;

/// One inference request.
struct Request {
    input: Vec<f32>,
    resp: mpsc::Sender<Result<InferenceResult, String>>,
    submitted: Instant,
}

/// The answer a client gets back.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// Wall-clock service latency.
    pub latency: Duration,
    /// Batch this request rode in.
    pub batch_size: usize,
    /// Modelled DDC-PIM latency for the whole model (ms, from the cycle
    /// simulator; amortized per batch).
    pub simulated_ms: f64,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Log-bucketed latency distribution (p50/p99 queries).
    pub latency_hist: LatencyHistogram,
}

impl ServiceStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn p50(&self) -> Duration {
        self.latency_hist.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.latency_hist.percentile(99.0)
    }
}

enum Msg {
    Infer(Request),
    Stats(mpsc::Sender<ServiceStats>),
    Shutdown,
}

/// Handle to a running service.
pub struct InferenceService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Start the worker thread; compiles artifacts on first use.
    pub fn start(artifact_dir: String, policy: BatchPolicy) -> InferenceService {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = thread::spawn(move || worker_loop(artifact_dir, policy, rx));
        InferenceService {
            tx,
            worker: Some(worker),
        }
    }

    /// Submit an image; returns a receiver for the result.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<InferenceResult, String>> {
        let (rtx, rrx) = mpsc::channel();
        let req = Request {
            input,
            resp: rtx,
            submitted: Instant::now(),
        };
        // if the worker died the receiver will simply disconnect
        let _ = self.tx.send(Msg::Infer(req));
        rrx
    }

    /// Blocking convenience call.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult, String> {
        self.submit(input)
            .recv()
            .map_err(|e| format!("service dropped request: {e}"))?
    }

    pub fn stats(&self) -> Option<ServiceStats> {
        let (stx, srx) = mpsc::channel();
        self.tx.send(Msg::Stats(stx)).ok()?;
        srx.recv().ok()
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(artifact_dir: String, policy: BatchPolicy, rx: mpsc::Receiver<Msg>) {
    let init = Runtime::cpu(&artifact_dir).and_then(|rt| {
        let w = crate::runtime::artifacts::load_model_weights(&artifact_dir)?;
        Ok((rt, w))
    });
    let (mut runtime, weights) = match init {
        Ok(r) => r,
        Err(e) => {
            // drain: fail every request with the init error; exit on
            // Shutdown (Drop joins this thread, so it must terminate)
            for msg in rx {
                match msg {
                    Msg::Infer(req) => {
                        let _ =
                            req.resp.send(Err(format!("runtime init failed: {e}")));
                    }
                    Msg::Stats(stx) => {
                        let _ = stx.send(ServiceStats::default());
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    // modelled hardware latency (once; amortized per batch below)
    let sim_ms = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    )
    .latency_ms();

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut stats = ServiceStats::default();
    let mut open = true;

    while open || !batcher.is_empty() {
        // pull at least one message (with timeout so timed flushes fire)
        if open {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(Msg::Infer(r)) => batcher.push(r),
                Ok(Msg::Stats(stx)) => {
                    let _ = stx.send(stats.clone());
                }
                Ok(Msg::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain without blocking
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Infer(r) => batcher.push(r),
                    Msg::Stats(stx) => {
                        let _ = stx.send(stats.clone());
                    }
                    Msg::Shutdown => open = false,
                }
            }
        }
        if batcher.is_empty() {
            continue;
        }
        if !batcher.should_flush(Instant::now()) && open {
            continue;
        }
        let batch = batcher.cut();
        let bsize = batch.len();
        stats.batches += 1;
        let result = run_batch(&mut runtime, &weights, &batch);
        match result {
            Ok(all_logits) => {
                for (i, req) in batch.into_iter().enumerate() {
                    let logits =
                        all_logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec();
                    let argmax = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(k, _)| k)
                        .unwrap_or(0);
                    let latency = req.submitted.elapsed();
                    stats.requests += 1;
                    stats.total_latency += latency;
                    stats.max_latency = stats.max_latency.max(latency);
                    stats.latency_hist.record(latency);
                    let _ = req.resp.send(Ok(InferenceResult {
                        logits,
                        argmax,
                        latency,
                        batch_size: bsize,
                        simulated_ms: sim_ms / bsize as f64,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for req in batch {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

fn run_batch(
    runtime: &mut Runtime,
    weights: &crate::runtime::artifacts::ModelWeights,
    batch: &[Request],
) -> Result<Vec<f32>> {
    // pick the artifact: wide for full batches, narrow otherwise (pad)
    let (name, eff) = if batch.len() == WIDE_BATCH {
        ("model_b8", WIDE_BATCH)
    } else if batch.len() == 1 {
        ("model_b1", 1)
    } else {
        ("model_b8", WIDE_BATCH) // pad partial batches up to the wide size
    };
    let mut input = vec![0f32; eff * IMG_ELEMS];
    for (i, req) in batch.iter().enumerate() {
        anyhow::ensure!(
            req.input.len() == IMG_ELEMS,
            "bad input size {} (want {IMG_ELEMS})",
            req.input.len()
        );
        input[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&req.input);
    }
    runtime.run_model(name, &input, &[eff as i64, 32, 32, 3], weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_reports_error_without_artifacts() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; IMG_ELEMS]);
        assert!(res.is_err());
    }

    #[test]
    fn rejects_bad_input_size() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; 3]);
        assert!(res.is_err());
    }
}
