//! Inference service: the request loop that owns the execution session.
//!
//! A dedicated worker thread owns the [`Session`] (PJRT handles are not
//! `Send`-safe by contract, so the backend is constructed — and its
//! session prepared — inside the thread and never leaves it).  Clients
//! submit CIFAR-shaped images over a channel; the batcher groups them
//! (the worker sleeps exactly to [`Batcher::next_deadline`], so a lone
//! straggler flushes the moment its `max_wait` expires); the session
//! executes the whole batch with a real batch dimension (the PJRT
//! backend pads stragglers up to its wide executable, the reference
//! backend folds the batch into its MVM row dimension — on the
//! bit-sliced fabric through the session's parallel exec pool, width
//! chosen by `BackendSpec::threads` / `--threads` / `DDC_THREADS`).
//!
//! Weights are resident for the worker's lifetime: the backend is
//! prepared exactly once, and every per-batch buffer (the pending-cut
//! sink, the packed input, the logits) is persistent, so the
//! steady-state execute path performs no per-batch heap allocation.
//! (The per-request `mpsc` response send is the one remaining
//! allocation, and the response itself is client-owned by design.)
//!
//! Alongside the functional result, each request is annotated with the
//! *simulated* DDC-PIM latency of the model so the serving path reports
//! both wall-clock and modelled-hardware numbers.  When the backend
//! spec carries a weight-streaming budget (`BackendSpec::stream_kb`),
//! [`ServiceStats`] additionally carries the session's
//! [`CapacityPressure`] counters, refreshed whenever stats are queried.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ArchConfig, SimConfig};
use crate::metrics::{CapacityPressure, LatencyHistogram, ReliabilityStats};
use crate::model::zoo;
use crate::runtime::{Backend, BackendKind, BackendSpec, Session, IMG_ELEMS, NUM_CLASSES};
use crate::sim::simulate_network;

use super::batcher::{BatchPolicy, Batcher};

/// Default client-side deadline for [`InferenceService::infer`] — far
/// above any sane batch time, so it only fires when the worker is
/// wedged (hung session, dead thread), never on a slow-but-live batch.
pub const DEFAULT_INFER_TIMEOUT: Duration = Duration::from_secs(30);

/// How often a panicked worker retries rebuilding its session before
/// giving up on the pending batch.
const REBUILD_ATTEMPTS: u32 = 3;

/// Typed client-visible failure: lets callers distinguish "my deadline
/// elapsed" (retryable elsewhere) from "the service rejected or failed
/// this request" without parsing strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The client-side deadline elapsed before a response arrived.  The
    /// request may still complete inside the worker; the response is
    /// discarded when the receiver drops.
    Timeout,
    /// The worker dropped the response channel without answering
    /// (service shut down mid-request).
    Disconnected,
    /// The service answered with a validation or execution error.
    Failed(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::Timeout => write!(f, "inference timed out"),
            ServiceError::Disconnected => write!(f, "service dropped the request"),
            ServiceError::Failed(e) => write!(f, "inference failed: {e}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One inference request.
struct Request {
    input: Vec<f32>,
    resp: mpsc::Sender<Result<InferenceResult, String>>,
    submitted: Instant,
    /// Times this request has already ridden in a batch that panicked
    /// (bounds the requeue: one retry, then a terminal error).
    retries: u32,
}

/// The answer a client gets back.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Classifier logits (fixed-size: no per-request heap allocation).
    pub logits: [f32; NUM_CLASSES],
    pub argmax: usize,
    /// Wall-clock service latency.
    pub latency: Duration,
    /// Batch this request rode in.
    pub batch_size: usize,
    /// Modelled DDC-PIM latency for the whole model (ms, from the cycle
    /// simulator; amortized per batch).
    pub simulated_ms: f64,
    /// Which backend executed the request ("reference" / "pjrt").
    pub backend: &'static str,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Log-bucketed latency distribution (p50/p99 queries).
    pub latency_hist: LatencyHistogram,
    /// Weight-streaming capacity pressure reported by the session
    /// (all-zero when the backend runs without a streaming budget —
    /// `CapacityPressure::default()` means "everything resident").
    pub capacity: CapacityPressure,
    /// Fault-injection / fail-soft counters: the session's own tally
    /// (faults injected/detected/repaired, quarantined rows, stager
    /// fallbacks) plus the service-level `worker_rebuilds` and
    /// client-side `timed_out_requests`.  All-zero when nothing has
    /// gone wrong ([`ReliabilityStats::is_quiet`]).
    pub reliability: ReliabilityStats,
}

impl ServiceStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn p50(&self) -> Duration {
        self.latency_hist.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.latency_hist.percentile(99.0)
    }
}

enum Msg {
    Infer(Request),
    Stats(mpsc::Sender<ServiceStats>),
    Shutdown,
    /// Chaos hook: make the next batch execution panic (one-shot), so
    /// tests can prove the catch-unwind + session-rebuild path.
    DebugPanicNextBatch,
    /// Chaos hook: sleep this long before the next batch executes
    /// (one-shot), so tests can trip the client-side timeout.
    DebugHangNextBatch(Duration),
}

/// Handle to a running service.
pub struct InferenceService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    /// Client-side timeout count (requests whose deadline elapsed);
    /// merged into [`ServiceStats::reliability`] by
    /// [`InferenceService::stats`].
    timed_out: Arc<AtomicU64>,
}

impl InferenceService {
    /// Start the worker thread with automatic backend selection (PJRT
    /// when compiled in and artifacts exist, else the reference backend).
    pub fn start(artifact_dir: String, policy: BatchPolicy) -> InferenceService {
        Self::start_with(BackendKind::Auto, artifact_dir, policy)
    }

    /// Start the worker thread with an explicit backend choice.
    pub fn start_with(
        kind: BackendKind,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        Self::start_spec(BackendSpec::new(kind), artifact_dir, policy)
    }

    /// Start the worker thread with a full backend spec (kind + knobs
    /// such as the reference backend's fabric choice).
    pub fn start_spec(
        spec: BackendSpec,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = thread::spawn(move || worker_loop(spec, artifact_dir, policy, rx));
        InferenceService {
            tx,
            worker: Some(worker),
            timed_out: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Submit an image; returns a receiver for the result.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<InferenceResult, String>> {
        let (rtx, rrx) = mpsc::channel();
        // reject malformed inputs here, before batching, so one bad
        // request can never fail the valid requests batched with it
        if input.len() != IMG_ELEMS {
            let _ = rtx.send(Err(format!(
                "bad input size {} (want {IMG_ELEMS})",
                input.len()
            )));
            return rrx;
        }
        let req = Request {
            input,
            resp: rtx,
            submitted: Instant::now(),
            retries: 0,
        };
        // if the worker died the receiver will simply disconnect
        let _ = self.tx.send(Msg::Infer(req));
        rrx
    }

    /// Blocking convenience call with the default client-side deadline
    /// ([`DEFAULT_INFER_TIMEOUT`]): a wedged worker surfaces as
    /// [`ServiceError::Timeout`] instead of hanging the caller forever.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult, ServiceError> {
        self.infer_timeout(input, DEFAULT_INFER_TIMEOUT)
    }

    /// Blocking call with an explicit client-side deadline.  On
    /// [`ServiceError::Timeout`] the request is *not* cancelled — the
    /// worker may still execute it, and its response is discarded when
    /// this receiver drops — but the caller gets its thread back and
    /// the timeout is booked in
    /// [`ServiceStats::reliability`]`.timed_out_requests`.
    pub fn infer_timeout(
        &self,
        input: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferenceResult, ServiceError> {
        match self.submit(input).recv_timeout(timeout) {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(ServiceError::Failed(e)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.timed_out.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::Timeout)
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServiceError::Disconnected),
        }
    }

    pub fn stats(&self) -> Option<ServiceStats> {
        let (stx, srx) = mpsc::channel();
        self.tx.send(Msg::Stats(stx)).ok()?;
        let mut s = srx.recv().ok()?;
        s.reliability.timed_out_requests = self.timed_out.load(Ordering::Relaxed);
        Some(s)
    }

    /// Chaos hook (test-only): the next batch execution panics inside
    /// the worker, exercising catch-unwind + bounded session rebuild.
    #[doc(hidden)]
    pub fn debug_panic_next_batch(&self) {
        let _ = self.tx.send(Msg::DebugPanicNextBatch);
    }

    /// Chaos hook (test-only): the next batch stalls this long before
    /// executing, exercising the client-side timeout.
    #[doc(hidden)]
    pub fn debug_hang_next_batch(&self, delay: Duration) {
        let _ = self.tx.send(Msg::DebugHangNextBatch(delay));
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// NaN-robust argmax over a logit slice: `f32::total_cmp` gives NaN a
/// fixed place in the order (positive NaN above +inf) instead of
/// panicking mid-batch — a single NaN logit must never kill the worker
/// thread.
fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(k, _)| k)
        .unwrap_or(0)
}

fn worker_loop(
    spec: BackendSpec,
    artifact_dir: String,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
) {
    // drain helper: fail every request with an init error; exit on
    // Shutdown (Drop joins this thread, so it must terminate)
    let drain_with_error = |rx: mpsc::Receiver<Msg>, err: String| {
        for msg in rx {
            match msg {
                Msg::Infer(req) => {
                    let _ = req.resp.send(Err(err.clone()));
                }
                Msg::Stats(stx) => {
                    let _ = stx.send(ServiceStats::default());
                }
                Msg::Shutdown => break,
                Msg::DebugPanicNextBatch | Msg::DebugHangNextBatch(_) => {}
            }
        }
    };
    let backend = match spec.create(&artifact_dir) {
        Ok(b) => b,
        Err(e) => return drain_with_error(rx, format!("backend init failed: {e:#}")),
    };
    let backend_name = backend.name();
    // prepare once: weights become resident for the worker's lifetime
    let mut session = match backend.prepare() {
        Ok(s) => s,
        Err(e) => return drain_with_error(rx, format!("session prepare failed: {e:#}")),
    };
    drop(backend); // the session owns everything execution needs
    // scrub the freshly resident weights before serving: any bit-cell
    // fault the write path manifested is detected and repaired (or
    // quarantined) now, not discovered as wrong logits later.  A clean
    // fabric makes this a no-op, and sessions without a scrubbable
    // fabric return None.
    let _ = session.scrub();

    // modelled hardware latency (once; amortized per batch below)
    let sim_ms = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    )
    .latency_ms();

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut stats = ServiceStats::default();
    let mut open = true;
    // fail-soft state: sessions rebuilt after a caught panic, plus the
    // one-shot chaos hooks the debug messages arm
    let mut rebuilds: u64 = 0;
    let mut chaos_panic = false;
    let mut chaos_hang: Option<Duration> = None;
    // persistent per-batch buffers: the cut sink, the packed input and
    // the logits live for the worker's lifetime, so the steady-state
    // path below allocates nothing per batch
    let mut pending: Vec<Request> = Vec::new();
    let mut input_buf: Vec<f32> = Vec::new();
    let mut logits_buf: Vec<f32> = Vec::new();

    while open || !batcher.is_empty() {
        // ingest until a batch is due.  An idle queue blocks on the
        // channel outright (no wake-ups); a non-empty queue sleeps
        // *exactly* to the oldest request's deadline, so a lone
        // straggler flushes the moment its max_wait elapses — never a
        // poll tick later (the fixed-tick loop this replaces stalled
        // stragglers by up to a tick past the deadline, and burned a
        // wake-up every tick while idle)
        while open && !batcher.should_flush(Instant::now()) {
            let msg = match batcher.next_deadline() {
                // empty queue: nothing can ever become due
                None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
                Some(deadline) => {
                    rx.recv_timeout(deadline.saturating_duration_since(Instant::now()))
                }
            };
            match msg {
                Ok(Msg::Infer(r)) => batcher.push(r),
                Ok(Msg::Stats(stx)) => {
                    stats.capacity = session.capacity_pressure().unwrap_or_default();
                    stats.reliability = session.reliability().unwrap_or_default();
                    stats.reliability.worker_rebuilds = rebuilds;
                    let _ = stx.send(stats.clone());
                }
                Ok(Msg::Shutdown) => open = false,
                Ok(Msg::DebugPanicNextBatch) => chaos_panic = true,
                Ok(Msg::DebugHangNextBatch(d)) => chaos_hang = Some(d),
                // deadline hit: the loop condition cuts the batch now
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain without blocking
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Infer(r) => batcher.push(r),
                    Msg::Stats(stx) => {
                        stats.capacity = session.capacity_pressure().unwrap_or_default();
                        stats.reliability = session.reliability().unwrap_or_default();
                        stats.reliability.worker_rebuilds = rebuilds;
                        let _ = stx.send(stats.clone());
                    }
                    Msg::Shutdown => open = false,
                    Msg::DebugPanicNextBatch => chaos_panic = true,
                    Msg::DebugHangNextBatch(d) => chaos_hang = Some(d),
                }
            }
        }
        if batcher.is_empty() {
            continue;
        }
        batcher.cut_into(&mut pending);
        let bsize = pending.len();
        stats.batches += 1;
        // pack the cut directly into the persistent input buffer (each
        // byte written exactly once; capacity is retained across cuts)
        input_buf.clear();
        for req in &pending {
            // submit() already rejected malformed inputs; a violation
            // here is a programming error, and must never fail
            // co-batched requests (the no-poison invariant)
            debug_assert_eq!(req.input.len(), IMG_ELEMS, "unvalidated request reached batcher");
            input_buf.extend_from_slice(&req.input);
        }
        debug_assert_eq!(input_buf.len(), bsize * IMG_ELEMS);
        logits_buf.clear();
        logits_buf.resize(bsize * NUM_CLASSES, 0.0);
        // execute behind catch_unwind: a panicking session (or the
        // chaos hooks standing in for one) must never abort the worker
        // — the batch is requeued once onto a rebuilt session instead
        let panic_now = std::mem::take(&mut chaos_panic);
        let hang = chaos_hang.take();
        let exec = catch_unwind(AssertUnwindSafe(|| {
            if let Some(d) = hang {
                thread::sleep(d);
            }
            if panic_now {
                panic!("chaos hook: debug_panic_next_batch");
            }
            session.infer_batch_into(&input_buf, bsize, &mut logits_buf)
        }));
        let exec = match exec {
            Ok(r) => r,
            Err(_) => {
                eprintln!(
                    "[ddc-reliability] batch execution panicked; rebuilding the session \
                     ({} request(s) requeued)",
                    bsize
                );
                match rebuild_session(&spec, &artifact_dir) {
                    Some(s) => {
                        session = s;
                        // same post-prepare scrub as the first session
                        let _ = session.scrub();
                        rebuilds += 1;
                        // bounded requeue: each request rides a rebuilt
                        // batch at most once, keeping its original
                        // arrival time so it flushes immediately
                        for mut req in pending.drain(..) {
                            if req.retries == 0 {
                                req.retries = 1;
                                let arrived = req.submitted;
                                batcher.push_arrived(req, arrived);
                            } else {
                                let _ = req.resp.send(Err(
                                    "batch execution panicked twice; giving up".into(),
                                ));
                            }
                        }
                    }
                    None => {
                        let msg = format!(
                            "batch execution panicked and session rebuild failed \
                             after {REBUILD_ATTEMPTS} attempts"
                        );
                        for req in pending.drain(..) {
                            let _ = req.resp.send(Err(msg.clone()));
                        }
                    }
                }
                continue;
            }
        };
        match exec {
            Ok(()) => {
                for (i, req) in pending.drain(..).enumerate() {
                    let mut logits = [0f32; NUM_CLASSES];
                    logits.copy_from_slice(&logits_buf[i * NUM_CLASSES..(i + 1) * NUM_CLASSES]);
                    let latency = req.submitted.elapsed();
                    stats.requests += 1;
                    stats.total_latency += latency;
                    stats.max_latency = stats.max_latency.max(latency);
                    stats.latency_hist.record(latency);
                    let _ = req.resp.send(Ok(InferenceResult {
                        logits,
                        argmax: argmax(&logits),
                        latency,
                        batch_size: bsize,
                        simulated_ms: sim_ms / bsize as f64,
                        backend: backend_name,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for req in pending.drain(..) {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Rebuild the worker's session after a caught panic: fresh backend,
/// fresh prepare, bounded attempts with linear backoff.  `None` when
/// every attempt fails (the pending batch is then failed, not retried
/// forever).
fn rebuild_session(spec: &BackendSpec, artifact_dir: &str) -> Option<Box<dyn Session>> {
    for attempt in 1..=REBUILD_ATTEMPTS {
        thread::sleep(Duration::from_millis(10 * attempt as u64));
        match spec.create(artifact_dir).and_then(|b| b.prepare()) {
            Ok(s) => return Some(s),
            Err(e) => eprintln!(
                "[ddc-reliability] session rebuild attempt \
                 {attempt}/{REBUILD_ATTEMPTS} failed: {e:#}"
            ),
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FabricChoice;

    #[test]
    fn serves_without_artifacts_via_reference_backend() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let r = svc.infer(vec![0.0; IMG_ELEMS]).expect("reference inference");
        assert_eq!(r.logits.len(), NUM_CLASSES);
        assert_eq!(r.backend, "reference");
    }

    #[test]
    fn rejects_bad_input_size() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; 3]);
        assert!(res.is_err());
    }

    #[test]
    fn explicit_reference_kind() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        assert!(svc.infer(vec![0.1; IMG_ELEMS]).is_ok());
    }

    #[test]
    fn bitsliced_fabric_spec_serves_identical_logits() {
        let dense = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let fabric = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                threads: 2,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let img = vec![0.3f32; IMG_ELEMS];
        let a = dense.infer(img.clone()).expect("dense");
        let b = fabric.infer(img).expect("fabric");
        // at these layer sizes the i32 kernels cannot overflow, so the
        // bit-sliced macro path and the dense kernel agree exactly
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn streamed_service_reports_capacity_pressure() {
        // a 2 KiB budget cannot hold conv2's 2304 B: the worker session
        // streams, and stats() surfaces its pressure counters
        let svc = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::DenseReference,
                threads: 1,
                stream_kb: 2,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        svc.infer(vec![0.1; IMG_ELEMS]).expect("streamed inference");
        svc.infer(vec![0.2; IMG_ELEMS]).expect("streamed inference");
        let stats = svc.stats().expect("stats");
        let p = stats.capacity;
        assert_eq!(p.capacity_bytes, 2048);
        assert!(p.staged_bytes > 0, "no staging recorded");
        assert!(p.reloads > 0, "second request must re-stage the passes");
        // an unbudgeted service stays all-zero ("everything resident")
        let resident =
            InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        resident.infer(vec![0.1; IMG_ELEMS]).expect("inference");
        assert_eq!(
            resident.stats().expect("stats").capacity,
            CapacityPressure::default()
        );
    }

    #[test]
    fn lone_straggler_is_served_at_its_deadline() {
        // a single request in a wide-batch policy must be flushed by
        // the deadline sleep (never stranded waiting for a full batch)
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(10),
            },
        );
        let r = svc.infer(vec![0.2; IMG_ELEMS]).expect("straggler served");
        assert_eq!(r.batch_size, 1);
    }

    #[test]
    fn queued_stragglers_drain_on_shutdown() {
        // requests still queued when the service drops must be executed
        // (drain path), not dropped on the floor
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(3600),
            },
        );
        let rx = svc.submit(vec![0.1; IMG_ELEMS]);
        drop(svc); // shutdown while the straggler is still queued
        let r = rx.recv().expect("response after shutdown").expect("served");
        assert_eq!(r.logits.len(), NUM_CLASSES);
    }

    #[test]
    fn hung_worker_trips_the_client_timeout() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        // warm up so the session is prepared before the chaos hook arms
        svc.infer(vec![0.1; IMG_ELEMS]).expect("warm-up");
        svc.debug_hang_next_batch(Duration::from_millis(400));
        let r = svc.infer_timeout(vec![0.2; IMG_ELEMS], Duration::from_millis(30));
        assert_eq!(r, Err(ServiceError::Timeout));
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.reliability.timed_out_requests, 1);
        // the worker was stalled, not wedged: it serves again afterwards
        assert!(svc.infer(vec![0.3; IMG_ELEMS]).is_ok());
    }

    #[test]
    fn worker_panic_rebuilds_the_session_and_retries_the_batch() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let baseline = svc.infer(vec![0.2; IMG_ELEMS]).expect("baseline");
        svc.debug_panic_next_batch();
        // the batch bounces off the panicking execution, the worker
        // rebuilds its session, and the same request is served by the
        // retry — degraded (slower) but correct, never a hung recv
        let retried = svc.infer(vec![0.2; IMG_ELEMS]).expect("served after panic");
        assert_eq!(retried.logits, baseline.logits, "rebuilt session must agree");
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.reliability.worker_rebuilds, 1);
        assert!(svc.infer(vec![0.4; IMG_ELEMS]).is_ok(), "service stays up");
    }

    #[test]
    fn faulted_service_scrubs_at_prepare_and_reports_reliability() {
        // nonzero BER on the bit-sliced fabric: the worker's
        // post-prepare scrub detects and repairs the injected damage,
        // and the counters surface through stats()
        let svc = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                fault_ber_ppm: 2000,
                fault_seed: 11,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        svc.infer(vec![0.3; IMG_ELEMS]).expect("faulted fabric serves");
        let r = svc.stats().expect("stats").reliability;
        assert!(r.faults_injected > 0, "no faults manifested at this BER");
        assert!(r.faults_detected > 0, "scrub missed the injected faults");
        assert!(r.quarantined_rows > 0, "no rows quarantined");
        // an unfaulted service stays quiet
        let clean = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        clean.infer(vec![0.3; IMG_ELEMS]).expect("clean");
        assert!(clean.stats().expect("stats").reliability.is_quiet());
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // regression: partial_cmp().unwrap() panicked (and killed the
        // worker thread) on any NaN logit.  In the total order positive
        // NaN sits above +inf, so a NaN deterministically wins.
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN, f32::NAN]), 2);
        assert_eq!(argmax(&[0.0, f32::NEG_INFINITY, 3.0, f32::NAN]), 3);
        assert_eq!(argmax(&[0.5, 1.0, 0.25]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
