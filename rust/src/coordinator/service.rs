//! Inference service: the request loop that owns the execution backend.
//!
//! A dedicated worker thread owns the [`Backend`] (PJRT handles are not
//! `Send`-safe by contract, so the backend is constructed inside the
//! thread and never leaves it).  Clients submit CIFAR-shaped images over
//! a channel; the batcher groups them; the backend executes the batch
//! (the PJRT backend pads stragglers up to its wide executable, the
//! reference backend takes any batch natively).  Alongside the
//! functional result, each request is annotated with the *simulated*
//! DDC-PIM latency of the model so the serving path reports both
//! wall-clock and modelled-hardware numbers.

use std::sync::mpsc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ArchConfig, SimConfig};
use crate::metrics::LatencyHistogram;
use crate::model::zoo;
use crate::runtime::{create_backend, Backend, BackendKind};
use crate::sim::simulate_network;

use super::batcher::{BatchPolicy, Batcher};

pub use crate::runtime::{IMG_ELEMS, NUM_CLASSES};

/// One inference request.
struct Request {
    input: Vec<f32>,
    resp: mpsc::Sender<Result<InferenceResult, String>>,
    submitted: Instant,
}

/// The answer a client gets back.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub logits: Vec<f32>,
    pub argmax: usize,
    /// Wall-clock service latency.
    pub latency: Duration,
    /// Batch this request rode in.
    pub batch_size: usize,
    /// Modelled DDC-PIM latency for the whole model (ms, from the cycle
    /// simulator; amortized per batch).
    pub simulated_ms: f64,
    /// Which backend executed the request ("reference" / "pjrt").
    pub backend: &'static str,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Log-bucketed latency distribution (p50/p99 queries).
    pub latency_hist: LatencyHistogram,
}

impl ServiceStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn p50(&self) -> Duration {
        self.latency_hist.percentile(50.0)
    }

    pub fn p99(&self) -> Duration {
        self.latency_hist.percentile(99.0)
    }
}

enum Msg {
    Infer(Request),
    Stats(mpsc::Sender<ServiceStats>),
    Shutdown,
}

/// Handle to a running service.
pub struct InferenceService {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
}

impl InferenceService {
    /// Start the worker thread with automatic backend selection (PJRT
    /// when compiled in and artifacts exist, else the reference backend).
    pub fn start(artifact_dir: String, policy: BatchPolicy) -> InferenceService {
        Self::start_with(BackendKind::Auto, artifact_dir, policy)
    }

    /// Start the worker thread with an explicit backend choice.
    pub fn start_with(
        kind: BackendKind,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = thread::spawn(move || worker_loop(kind, artifact_dir, policy, rx));
        InferenceService {
            tx,
            worker: Some(worker),
        }
    }

    /// Submit an image; returns a receiver for the result.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<InferenceResult, String>> {
        let (rtx, rrx) = mpsc::channel();
        // reject malformed inputs here, before batching, so one bad
        // request can never fail the valid requests batched with it
        if input.len() != IMG_ELEMS {
            let _ = rtx.send(Err(format!(
                "bad input size {} (want {IMG_ELEMS})",
                input.len()
            )));
            return rrx;
        }
        let req = Request {
            input,
            resp: rtx,
            submitted: Instant::now(),
        };
        // if the worker died the receiver will simply disconnect
        let _ = self.tx.send(Msg::Infer(req));
        rrx
    }

    /// Blocking convenience call.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult, String> {
        self.submit(input)
            .recv()
            .map_err(|e| format!("service dropped request: {e}"))?
    }

    pub fn stats(&self) -> Option<ServiceStats> {
        let (stx, srx) = mpsc::channel();
        self.tx.send(Msg::Stats(stx)).ok()?;
        srx.recv().ok()
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    kind: BackendKind,
    artifact_dir: String,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
) {
    let mut backend = match create_backend(kind, &artifact_dir) {
        Ok(b) => b,
        Err(e) => {
            // drain: fail every request with the init error; exit on
            // Shutdown (Drop joins this thread, so it must terminate)
            for msg in rx {
                match msg {
                    Msg::Infer(req) => {
                        let _ = req.resp.send(Err(format!("backend init failed: {e:#}")));
                    }
                    Msg::Stats(stx) => {
                        let _ = stx.send(ServiceStats::default());
                    }
                    Msg::Shutdown => break,
                }
            }
            return;
        }
    };
    let backend_name = backend.name();
    // modelled hardware latency (once; amortized per batch below)
    let sim_ms = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    )
    .latency_ms();

    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut stats = ServiceStats::default();
    let mut open = true;

    while open || !batcher.is_empty() {
        // pull at least one message (with timeout so timed flushes fire)
        if open {
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(Msg::Infer(r)) => batcher.push(r),
                Ok(Msg::Stats(stx)) => {
                    let _ = stx.send(stats.clone());
                }
                Ok(Msg::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain without blocking
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Infer(r) => batcher.push(r),
                    Msg::Stats(stx) => {
                        let _ = stx.send(stats.clone());
                    }
                    Msg::Shutdown => open = false,
                }
            }
        }
        if batcher.is_empty() {
            continue;
        }
        if !batcher.should_flush(Instant::now()) && open {
            continue;
        }
        let batch = batcher.cut();
        let bsize = batch.len();
        stats.batches += 1;
        let result = run_batch(backend.as_mut(), &batch);
        match result {
            Ok(all_logits) => {
                for (i, req) in batch.into_iter().enumerate() {
                    let logits =
                        all_logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec();
                    let argmax = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(k, _)| k)
                        .unwrap_or(0);
                    let latency = req.submitted.elapsed();
                    stats.requests += 1;
                    stats.total_latency += latency;
                    stats.max_latency = stats.max_latency.max(latency);
                    stats.latency_hist.record(latency);
                    let _ = req.resp.send(Ok(InferenceResult {
                        logits,
                        argmax,
                        latency,
                        batch_size: bsize,
                        simulated_ms: sim_ms / bsize as f64,
                        backend: backend_name,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for req in batch {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

fn run_batch(backend: &mut dyn Backend, batch: &[Request]) -> Result<Vec<f32>> {
    let mut input = vec![0f32; batch.len() * IMG_ELEMS];
    for (i, req) in batch.iter().enumerate() {
        // submit() already rejected malformed inputs; a violation here
        // is a programming error, and must never fail co-batched
        // requests (the no-poison invariant)
        debug_assert_eq!(req.input.len(), IMG_ELEMS, "unvalidated request reached batcher");
        input[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&req.input);
    }
    backend.infer_batch(&input, batch.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_without_artifacts_via_reference_backend() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let r = svc.infer(vec![0.0; IMG_ELEMS]).expect("reference inference");
        assert_eq!(r.logits.len(), NUM_CLASSES);
        assert_eq!(r.backend, "reference");
    }

    #[test]
    fn rejects_bad_input_size() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; 3]);
        assert!(res.is_err());
    }

    #[test]
    fn explicit_reference_kind() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        assert!(svc.infer(vec![0.1; IMG_ELEMS]).is_ok());
    }
}
