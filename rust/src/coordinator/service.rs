//! Inference service: a batching dispatcher in front of N worker
//! sessions.
//!
//! One **dispatcher** thread owns the ingest channel and the
//! [`Batcher`]: clients submit CIFAR-shaped images, the batcher groups
//! them (the dispatcher sleeps exactly to [`Batcher::next_deadline`],
//! so a lone straggler flushes the moment its `max_wait` expires), and
//! each cut batch is handed to a pool of **worker** threads over a
//! shared channel.  Every worker owns its *own* [`Session`] (PJRT
//! handles are not `Send`-safe by contract, so each backend is
//! constructed — and its session prepared — inside its worker thread
//! and never leaves it); because sessions are deterministic, any
//! worker may serve any batch and the logits are byte-identical to a
//! single-worker deployment.  Worker count comes from
//! [`ServiceConfig::workers`] / `DDC_WORKERS` (default 1, the exact
//! single-worker shape this service had before scale-out).
//!
//! **Admission control**: [`ServiceConfig::max_queue_depth`] bounds
//! the in-flight depth (queued + executing).  A request arriving at a
//! full queue is rejected *synchronously* with the typed
//! [`ServiceError::Overloaded`] — load is shed at the door, with
//! backpressure accounting in [`ServiceStats::admission`], never by
//! unbounded queue growth.  Depth 0 (the default) disables shedding.
//!
//! Weights are resident for each worker's lifetime: its backend is
//! prepared exactly once, and every per-batch buffer (the packed
//! input, the logits) is persistent, so the steady-state execute path
//! performs no per-batch heap allocation inside the session.  Batch
//! carriers (`Vec<Request>`) are recycled back to the dispatcher over
//! a return channel instead of reallocated per cut.
//!
//! Alongside the functional result, each request is annotated with the
//! *simulated* DDC-PIM latency of the model so the serving path reports
//! both wall-clock and modelled-hardware numbers.  [`ServiceStats`]
//! carries SLO-grade latency percentiles (p50/p95/p99 from the
//! log-bucketed [`LatencyHistogram`]), the merged per-worker
//! [`CapacityPressure`] and [`ReliabilityStats`] snapshots, and the
//! admission counters — all readable synchronously, even while every
//! worker is busy.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::config::{ArchConfig, SimConfig};
use crate::metrics::{
    AdmissionStats, CapacityPressure, HealthStats, LatencyHistogram, ReliabilityStats, WorkerHealth,
};
use crate::model::zoo;
use crate::runtime::{BackendKind, BackendSpec, Session, IMG_ELEMS, NUM_CLASSES};
use crate::sim::simulate_network;

use super::batcher::{BatchPolicy, Batcher};

/// Default client-side deadline for [`InferenceService::infer`] — far
/// above any sane batch time, so it only fires when the worker is
/// wedged (hung session, dead thread), never on a slow-but-live batch.
pub const DEFAULT_INFER_TIMEOUT: Duration = Duration::from_secs(30);

/// How often a panicked worker retries rebuilding its session before
/// giving up on the pending batch.
const REBUILD_ATTEMPTS: u32 = 3;

/// Repaired-row churn in one batch window at or above which a worker is
/// assessed [`WorkerHealth::Degraded`]: still serving (every batch it
/// answers is scrub-verified), but running hot enough on repairs that
/// the operator should look at it.  A clean window recovers it.
const DEGRADE_REPAIR_CHURN: u64 = 1;

/// Session rebuilds since the last clean rejoin at which a worker is
/// assessed [`WorkerHealth::Quarantined`]: it parks (stops pulling
/// batches), runs full scrub cycles until one comes back clean, and
/// only then rejoins the pool.
const QUARANTINE_REBUILDS: u64 = 2;

/// Pure health assessment from one batch window's reliability deltas.
/// Zeroed rows (spares exhausted: data was irrecoverably masked out) or
/// repeated rebuilds quarantine outright; repair churn degrades; a
/// quiet window recovers a degraded worker.  `Quarantined` is sticky —
/// only the parked clean-scrub rejoin path (which resets the rebuild
/// baseline) leaves it.
fn assess_health(
    prev: WorkerHealth,
    repaired_delta: u64,
    zeroed_delta: u64,
    rebuilds_since_rejoin: u64,
) -> WorkerHealth {
    if zeroed_delta > 0 || rebuilds_since_rejoin >= QUARANTINE_REBUILDS {
        WorkerHealth::Quarantined
    } else if prev == WorkerHealth::Quarantined {
        WorkerHealth::Quarantined
    } else if repaired_delta >= DEGRADE_REPAIR_CHURN {
        WorkerHealth::Degraded
    } else {
        WorkerHealth::Healthy
    }
}

/// Hard ceiling on worker sessions: each worker owns a full resident
/// session (weights + buffers + exec pool), so the useful count is
/// bounded by memory and cores long before this.
pub const MAX_WORKERS: usize = 32;

/// Resolve a requested worker count.  Precedence (same contract as
/// `DDC_THREADS` / `DDC_GRID`): an explicit `requested >= 1` wins, `0`
/// means "unset" and falls back to the `DDC_WORKERS` environment
/// variable, then to 1 (the single-worker path).  An unparseable
/// `DDC_WORKERS` is *warned about* on stderr and treated as unset —
/// never silently ignored.  The result is clamped to
/// `1..=`[`MAX_WORKERS`].
pub fn resolve_workers(requested: usize) -> usize {
    let n = if requested > 0 {
        requested
    } else {
        crate::util::env::resolve_env_knob("DDC_WORKERS", 1, "1", crate::util::env::parse_positive)
    };
    n.clamp(1, MAX_WORKERS)
}

/// Serving-tier shape: how many worker sessions drain the batch queue,
/// and how deep the ingress queue may grow before load is shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServiceConfig {
    /// Worker sessions behind the batcher (`0` = resolve through the
    /// `DDC_WORKERS` environment variable, then 1 — see
    /// [`resolve_workers`]).
    pub workers: usize,
    /// In-flight request bound (queued + executing) enforced at
    /// [`InferenceService::submit`]; a request beyond it is rejected
    /// with [`ServiceError::Overloaded`].  `0` (the default) disables
    /// admission control: nothing is ever shed.
    pub max_queue_depth: usize,
}

/// Typed client-visible failure: lets callers distinguish "my deadline
/// elapsed" (retryable elsewhere) from "the service shed my request"
/// (retryable after backoff) from "the service rejected or failed this
/// request" without parsing strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The client-side deadline elapsed before a response arrived.  The
    /// request may still complete inside the worker; the response is
    /// discarded when the receiver drops.
    Timeout,
    /// The worker dropped the response channel without answering
    /// (service shut down mid-request).
    Disconnected,
    /// Admission control shed this request at the door: the in-flight
    /// depth was at [`ServiceConfig::max_queue_depth`].  The request
    /// was never queued; retry after backoff.
    Overloaded,
    /// The service answered with a validation or execution error.
    Failed(String),
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::Timeout => write!(f, "inference timed out"),
            ServiceError::Disconnected => write!(f, "service dropped the request"),
            ServiceError::Overloaded => write!(f, "service overloaded: request shed at admission"),
            ServiceError::Failed(e) => write!(f, "inference failed: {e}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One inference request.
struct Request {
    input: Vec<f32>,
    resp: mpsc::Sender<Result<InferenceResult, ServiceError>>,
    submitted: Instant,
    /// Client-side deadline, propagated so the dispatcher can drop an
    /// already-expired request at batch-cut time instead of spending a
    /// worker slot computing an answer nobody is waiting for.  `None`
    /// (bare [`InferenceService::submit`]) never expires.
    deadline: Option<Instant>,
}

/// The answer a client gets back.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Classifier logits (fixed-size: no per-request heap allocation).
    pub logits: [f32; NUM_CLASSES],
    pub argmax: usize,
    /// Wall-clock service latency.
    pub latency: Duration,
    /// Batch this request rode in.
    pub batch_size: usize,
    /// Modelled DDC-PIM latency for the whole model (ms, from the cycle
    /// simulator; amortized per batch).
    pub simulated_ms: f64,
    /// Which backend executed the request ("reference" / "pjrt").
    pub backend: &'static str,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub batches: u64,
    pub total_latency: Duration,
    pub max_latency: Duration,
    /// Log-bucketed latency distribution (p50/p95/p99 queries).
    pub latency_hist: LatencyHistogram,
    /// Weight-streaming capacity pressure, merged across all worker
    /// sessions (all-zero when the backend runs without a streaming
    /// budget — `CapacityPressure::default()` means "everything
    /// resident").
    pub capacity: CapacityPressure,
    /// Fault-injection / fail-soft counters: the merged sessions' tally
    /// (faults injected/detected/repaired, quarantined rows, stager
    /// fallbacks) plus the service-level `worker_rebuilds` and
    /// client-side `timed_out_requests`.  All-zero when nothing has
    /// gone wrong ([`ReliabilityStats::is_quiet`]).
    pub reliability: ReliabilityStats,
    /// Admission-control counters: admitted/shed requests, the depth
    /// bound in force, the peak in-flight depth, worker count, and
    /// deadline-expired drops at batch cut.
    pub admission: AdmissionStats,
    /// Worker-health census (how many workers are currently
    /// healthy/degraded/quarantined) plus lifetime quarantine and
    /// rejoin event counts.
    pub health: HealthStats,
}

impl ServiceStats {
    pub fn mean_latency(&self) -> Duration {
        if self.requests == 0 {
            Duration::ZERO
        } else {
            self.total_latency / self.requests as u32
        }
    }

    pub fn p50(&self) -> Duration {
        self.latency_hist.percentile(50.0)
    }

    pub fn p95(&self) -> Duration {
        self.latency_hist.percentile(95.0)
    }

    pub fn p99(&self) -> Duration {
        self.latency_hist.percentile(99.0)
    }
}

enum Msg {
    Infer(Request),
    Shutdown,
}

/// Core request/latency counters, folded in by workers under one lock
/// (one acquisition per batch, not per request-field).
#[derive(Default)]
struct CoreStats {
    requests: u64,
    batches: u64,
    total_latency: Duration,
    max_latency: Duration,
    hist: LatencyHistogram,
}

/// Per-worker session snapshot, overwritten after every batch (and
/// once after prepare+scrub).  Snapshots are *absolute* counters from
/// each session, so [`InferenceService::stats`] merges the latest
/// slot per worker instead of accumulating — re-reading never
/// double-counts.
#[derive(Default, Clone, Copy)]
struct WorkerSnapshot {
    capacity: CapacityPressure,
    reliability: ReliabilityStats,
    rebuilds: u64,
    health: WorkerHealth,
}

/// State shared between the client handle, the dispatcher and every
/// worker: admission atomics, stats, per-worker snapshots, chaos
/// hooks.
struct ServiceShared {
    core: Mutex<CoreStats>,
    snapshots: Mutex<Vec<WorkerSnapshot>>,
    /// Admitted requests not yet answered (queued + executing).
    in_flight: AtomicU64,
    peak_depth: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    /// Client-side timeout count (requests whose deadline elapsed).
    timed_out: AtomicU64,
    /// Requests dropped at batch-cut time because their propagated
    /// deadline had already expired.
    shed_expired: AtomicU64,
    /// Workers currently parked in quarantine (not pulling batches).
    /// Admission sheds `Overloaded` only when this covers the whole
    /// pool — a single healthy worker keeps the service accepting.
    quarantined_now: AtomicUsize,
    /// Lifetime Healthy/Degraded -> Quarantined transitions.
    quarantine_events: AtomicU64,
    /// Lifetime Quarantined -> Healthy rejoins (clean scrub cycle).
    rejoin_events: AtomicU64,
    /// Workers whose session is (or is still becoming) live.
    live_workers: AtomicUsize,
    /// First worker-init failure, for failing queued batches usefully
    /// when *every* worker is gone.
    init_error: Mutex<Option<String>>,
    /// Chaos hook: the next batch any worker picks up panics.
    chaos_panic: AtomicBool,
    /// Chaos hook: the next batch any worker picks up stalls this many
    /// ms first (0 = unarmed).
    chaos_hang_ms: AtomicU64,
    max_queue_depth: usize,
    workers: usize,
}

impl ServiceShared {
    fn new(workers: usize, max_queue_depth: usize) -> ServiceShared {
        ServiceShared {
            core: Mutex::new(CoreStats::default()),
            snapshots: Mutex::new(vec![WorkerSnapshot::default(); workers]),
            in_flight: AtomicU64::new(0),
            peak_depth: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            timed_out: AtomicU64::new(0),
            shed_expired: AtomicU64::new(0),
            quarantined_now: AtomicUsize::new(0),
            quarantine_events: AtomicU64::new(0),
            rejoin_events: AtomicU64::new(0),
            live_workers: AtomicUsize::new(workers),
            init_error: Mutex::new(None),
            chaos_panic: AtomicBool::new(false),
            chaos_hang_ms: AtomicU64::new(0),
            max_queue_depth,
            workers,
        }
    }

    /// Admit or shed: the one decision point of the admission state
    /// machine.  CAS loop so concurrent submitters can never push
    /// `in_flight` past the bound.
    fn try_admit(&self) -> bool {
        loop {
            let cur = self.in_flight.load(Ordering::Acquire);
            if self.max_queue_depth > 0 && cur >= self.max_queue_depth as u64 {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            if self
                .in_flight
                .compare_exchange(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                self.peak_depth.fetch_max(cur + 1, Ordering::Relaxed);
                return true;
            }
        }
    }

    /// One admitted request answered (successfully or not).
    fn finish_request(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Overwrite worker `id`'s session snapshot (called *before* the
    /// batch's responses are sent, so a client that got its answer
    /// always sees a stats view at least as fresh as that batch).
    fn update_snapshot(
        &self,
        id: usize,
        session: &dyn Session,
        rebuilds: u64,
        health: WorkerHealth,
    ) {
        if let Ok(mut snaps) = self.snapshots.lock() {
            snaps[id] = WorkerSnapshot {
                capacity: session.capacity_pressure().unwrap_or_default(),
                reliability: session.reliability().unwrap_or_default(),
                rebuilds,
                health,
            };
        }
    }

    fn record_init_error(&self, err: String) {
        if let Ok(mut slot) = self.init_error.lock() {
            slot.get_or_insert(err);
        }
    }

    fn init_error_msg(&self) -> String {
        self.init_error
            .lock()
            .ok()
            .and_then(|slot| slot.clone())
            .unwrap_or_else(|| "no live worker session".into())
    }
}

/// Fail every request of a batch with the same error, releasing their
/// admission slots.
fn fail_batch(batch: impl IntoIterator<Item = Request>, err: ServiceError, shared: &ServiceShared) {
    for req in batch {
        let _ = req.resp.send(Err(err.clone()));
        shared.finish_request();
    }
}

/// Handle to a running service.
pub struct InferenceService {
    tx: mpsc::Sender<Msg>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<ServiceShared>,
}

impl InferenceService {
    /// Start a single-worker service with automatic backend selection
    /// (PJRT when compiled in and artifacts exist, else the reference
    /// backend).
    pub fn start(artifact_dir: String, policy: BatchPolicy) -> InferenceService {
        Self::start_with(BackendKind::Auto, artifact_dir, policy)
    }

    /// Start a single-worker service with an explicit backend choice.
    pub fn start_with(
        kind: BackendKind,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        Self::start_spec(BackendSpec::new(kind), artifact_dir, policy)
    }

    /// Start a single-worker service with a full backend spec (kind +
    /// knobs such as the reference backend's fabric choice).
    pub fn start_spec(
        spec: BackendSpec,
        artifact_dir: String,
        policy: BatchPolicy,
    ) -> InferenceService {
        Self::start_cluster(
            spec,
            artifact_dir,
            policy,
            ServiceConfig {
                workers: 1,
                max_queue_depth: 0,
            },
        )
    }

    /// Start the full serving tier: a dispatcher plus
    /// [`ServiceConfig::workers`] worker sessions (each preparing its
    /// own session from `spec`), with admission control at
    /// [`ServiceConfig::max_queue_depth`].
    pub fn start_cluster(
        spec: BackendSpec,
        artifact_dir: String,
        policy: BatchPolicy,
        config: ServiceConfig,
    ) -> InferenceService {
        let nworkers = resolve_workers(config.workers);
        let shared = Arc::new(ServiceShared::new(nworkers, config.max_queue_depth));
        let (tx, rx) = mpsc::channel::<Msg>();
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let (recycle_tx, recycle_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let workers = (0..nworkers)
            .map(|id| {
                let spec = spec;
                let dir = artifact_dir.clone();
                let rx = batch_rx.clone();
                let recycle = recycle_tx.clone();
                let shared = shared.clone();
                thread::spawn(move || worker_loop(id, spec, dir, rx, recycle, shared))
            })
            .collect();
        drop(recycle_tx); // workers hold the only senders
        let dispatcher = {
            let shared = shared.clone();
            thread::spawn(move || dispatcher_loop(rx, policy, batch_tx, recycle_rx, shared))
        };
        InferenceService {
            tx,
            dispatcher: Some(dispatcher),
            workers,
            shared,
        }
    }

    /// Worker sessions this service was started with.
    pub fn worker_count(&self) -> usize {
        self.shared.workers
    }

    /// Submit an image; returns a receiver for the result.  Admission
    /// control runs *here*, synchronously: a malformed input or a full
    /// queue answers on the returned receiver immediately, without
    /// touching the dispatcher.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<InferenceResult, ServiceError>> {
        self.submit_with_deadline(input, None)
    }

    /// [`Self::submit`] with a propagated client deadline: a request
    /// whose deadline has already expired when its batch is cut is
    /// dropped by the dispatcher (booked as
    /// [`AdmissionStats::shed_expired`], answered [`ServiceError::Timeout`])
    /// instead of wasting a worker slot on an answer nobody is waiting
    /// for.  [`Self::infer_timeout`] routes through here.
    pub fn submit_with_deadline(
        &self,
        input: Vec<f32>,
        deadline: Option<Instant>,
    ) -> mpsc::Receiver<Result<InferenceResult, ServiceError>> {
        let (rtx, rrx) = mpsc::channel();
        // reject malformed inputs here, before batching, so one bad
        // request can never fail the valid requests batched with it
        if input.len() != IMG_ELEMS {
            let _ = rtx.send(Err(ServiceError::Failed(format!(
                "bad input size {} (want {IMG_ELEMS})",
                input.len()
            ))));
            return rrx;
        }
        // health steering at the door: with every worker parked in
        // quarantine there is nobody to serve — shed instead of letting
        // the queue grow against a fully parked pool.  Any healthy (or
        // merely degraded) worker keeps the service accepting; batches
        // steer to it naturally because parked workers don't pull.
        if self.shared.quarantined_now.load(Ordering::Acquire) >= self.shared.workers {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            let _ = rtx.send(Err(ServiceError::Overloaded));
            return rrx;
        }
        if !self.shared.try_admit() {
            let _ = rtx.send(Err(ServiceError::Overloaded));
            return rrx;
        }
        let req = Request {
            input,
            resp: rtx,
            submitted: Instant::now(),
            deadline,
        };
        // if the dispatcher died the receiver will simply disconnect;
        // release the admission slot so the depth stays truthful
        if self.tx.send(Msg::Infer(req)).is_err() {
            self.shared.finish_request();
        }
        rrx
    }

    /// Blocking convenience call with the default client-side deadline
    /// ([`DEFAULT_INFER_TIMEOUT`]): a wedged worker surfaces as
    /// [`ServiceError::Timeout`] instead of hanging the caller forever.
    pub fn infer(&self, input: Vec<f32>) -> Result<InferenceResult, ServiceError> {
        self.infer_timeout(input, DEFAULT_INFER_TIMEOUT)
    }

    /// Blocking call with an explicit client-side deadline.  On
    /// [`ServiceError::Timeout`] the request is *not* cancelled — the
    /// worker may still execute it, and its response is discarded when
    /// this receiver drops — but the caller gets its thread back and
    /// the timeout is booked in
    /// [`ServiceStats::reliability`]`.timed_out_requests`.
    pub fn infer_timeout(
        &self,
        input: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferenceResult, ServiceError> {
        let deadline = Instant::now().checked_add(timeout);
        match self
            .submit_with_deadline(input, deadline)
            .recv_timeout(timeout)
        {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(e),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.shared.timed_out.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::Timeout)
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(ServiceError::Disconnected),
        }
    }

    /// Read the aggregate service statistics, synchronously from the
    /// shared state — works even while every worker is mid-batch (the
    /// old message-round-trip design could not answer during a hang).
    pub fn stats(&self) -> Option<ServiceStats> {
        let core = self.shared.core.lock().ok()?;
        let mut s = ServiceStats {
            requests: core.requests,
            batches: core.batches,
            total_latency: core.total_latency,
            max_latency: core.max_latency,
            latency_hist: core.hist.clone(),
            ..Default::default()
        };
        drop(core);
        let mut rebuilds = 0;
        if let Ok(snaps) = self.shared.snapshots.lock() {
            for snap in snaps.iter() {
                s.capacity.merge(&snap.capacity);
                s.reliability.merge(&snap.reliability);
                s.health.count(snap.health);
                rebuilds += snap.rebuilds;
            }
        }
        s.reliability.worker_rebuilds = rebuilds;
        s.reliability.timed_out_requests = self.shared.timed_out.load(Ordering::Relaxed);
        s.health.quarantine_events = self.shared.quarantine_events.load(Ordering::Relaxed);
        s.health.rejoin_events = self.shared.rejoin_events.load(Ordering::Relaxed);
        s.admission = AdmissionStats {
            admitted: self.shared.admitted.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            max_queue_depth: self.shared.max_queue_depth as u64,
            peak_queue_depth: self.shared.peak_depth.load(Ordering::Relaxed),
            workers: self.shared.workers as u64,
            shed_expired: self.shared.shed_expired.load(Ordering::Relaxed),
        };
        Some(s)
    }

    /// Chaos hook (test-only): the next batch any worker picks up
    /// panics, exercising catch-unwind + bounded session rebuild.
    #[doc(hidden)]
    pub fn debug_panic_next_batch(&self) {
        self.shared.chaos_panic.store(true, Ordering::Release);
    }

    /// Chaos hook (test-only): the next batch any worker picks up
    /// stalls this long before executing, exercising the client-side
    /// timeout.
    #[doc(hidden)]
    pub fn debug_hang_next_batch(&self, delay: Duration) {
        self.shared
            .chaos_hang_ms
            .store(delay.as_millis().max(1) as u64, Ordering::Release);
    }
}

impl Drop for InferenceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // the dispatcher's exit dropped the batch sender; workers drain
        // what is queued and terminate
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// NaN-robust argmax over a logit slice: `f32::total_cmp` gives NaN a
/// fixed place in the order (positive NaN above +inf) instead of
/// panicking mid-batch — a single NaN logit must never kill the worker
/// thread.
fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(k, _)| k)
        .unwrap_or(0)
}

/// The ingest/batching half: owns the [`Batcher`], cuts batches, hands
/// them to the worker pool.  On shutdown it flushes everything still
/// queued before exiting (the drain contract — no request is dropped
/// on the floor).
fn dispatcher_loop(
    rx: mpsc::Receiver<Msg>,
    policy: BatchPolicy,
    batch_tx: mpsc::Sender<Vec<Request>>,
    recycle_rx: mpsc::Receiver<Vec<Request>>,
    shared: Arc<ServiceShared>,
) {
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut open = true;
    while open || !batcher.is_empty() {
        // ingest until a batch is due.  An idle queue blocks on the
        // channel outright (no wake-ups); a non-empty queue sleeps
        // *exactly* to the oldest request's deadline, so a lone
        // straggler flushes the moment its max_wait elapses — never a
        // poll tick later
        while open && !batcher.should_flush(Instant::now()) {
            let msg = match batcher.next_deadline() {
                // empty queue: nothing can ever become due
                None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
                Some(deadline) => {
                    rx.recv_timeout(deadline.saturating_duration_since(Instant::now()))
                }
            };
            match msg {
                Ok(Msg::Infer(r)) => batcher.push(r),
                Ok(Msg::Shutdown) => open = false,
                // deadline hit: the loop condition cuts the batch now
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            // opportunistically drain without blocking
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Infer(r) => batcher.push(r),
                    Msg::Shutdown => open = false,
                }
            }
        }
        if batcher.is_empty() {
            continue;
        }
        // reuse a carrier a worker sent back; allocate only when the
        // pool is still warming up
        let mut sink = recycle_rx.try_recv().unwrap_or_default();
        sink.clear();
        batcher.cut_into(&mut sink);
        // deadline propagation: a request whose client deadline already
        // expired while it sat in the batcher is answered (Timeout) and
        // dropped *here*, so the worker never spends a slot computing
        // logits nobody will read.  swap_remove is fine: requests in a
        // batch are independent rows, order carries no meaning.
        let now = Instant::now();
        let mut i = 0;
        while i < sink.len() {
            if sink[i].deadline.is_some_and(|d| d <= now) {
                let req = sink.swap_remove(i);
                shared.shed_expired.fetch_add(1, Ordering::Relaxed);
                let _ = req.resp.send(Err(ServiceError::Timeout));
                shared.finish_request();
            } else {
                i += 1;
            }
        }
        if let Err(mpsc::SendError(batch)) = batch_tx.send(sink) {
            // every worker is gone (init failure on all of them): fail
            // the batch with the recorded cause instead of a silent
            // hang
            fail_batch(
                batch,
                ServiceError::Failed(shared.init_error_msg()),
                &shared,
            );
        }
    }
}

/// One worker: builds its own backend + session, then drains batches
/// from the shared channel until the dispatcher closes it.
fn worker_loop(
    id: usize,
    spec: BackendSpec,
    artifact_dir: String,
    batch_rx: Arc<Mutex<mpsc::Receiver<Vec<Request>>>>,
    recycle_tx: mpsc::Sender<Vec<Request>>,
    shared: Arc<ServiceShared>,
) {
    // last worker out fails anything still queued (otherwise those
    // clients would see a bare disconnect with no cause)
    let exit = |shared: &ServiceShared, batch_rx: &Arc<Mutex<mpsc::Receiver<Vec<Request>>>>| {
        if shared.live_workers.fetch_sub(1, Ordering::AcqRel) == 1 {
            if let Ok(rx) = batch_rx.lock() {
                while let Ok(batch) = rx.try_recv() {
                    fail_batch(batch, ServiceError::Failed(shared.init_error_msg()), shared);
                }
            }
        }
    };
    let backend = match spec.create(&artifact_dir) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("[ddc-reliability] worker {id}: backend init failed: {e:#}");
            shared.record_init_error(format!("backend init failed: {e:#}"));
            return exit(&shared, &batch_rx);
        }
    };
    let backend_name = backend.name();
    // prepare once: weights become resident for the worker's lifetime
    let mut session = match backend.prepare() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[ddc-reliability] worker {id}: session prepare failed: {e:#}");
            shared.record_init_error(format!("session prepare failed: {e:#}"));
            return exit(&shared, &batch_rx);
        }
    };
    drop(backend); // the session owns everything execution needs
    // scrub the freshly resident weights before serving: any bit-cell
    // fault the write path manifested is detected and repaired (or
    // quarantined) now, not discovered as wrong logits later.  A clean
    // fabric makes this a no-op, and sessions without a scrubbable
    // fabric return None.
    let _ = session.scrub();
    let mut rebuilds: u64 = 0;
    let mut health = WorkerHealth::Healthy;
    // health baselines: deltas are measured per batch window against
    // the post-prepare-scrub state, and the rebuild count against the
    // last clean rejoin (so one quarantine doesn't re-trip forever)
    let mut prev_rel = session.reliability().unwrap_or_default();
    let mut rebuild_baseline: u64 = 0;
    shared.update_snapshot(id, &*session, rebuilds, health);

    // modelled hardware latency (once per worker; amortized per batch)
    let sim_ms = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    )
    .latency_ms();

    // persistent per-batch buffers: the packed input and the logits
    // live for the worker's lifetime, so the steady-state path below
    // allocates nothing per batch inside the session
    let mut input_buf: Vec<f32> = Vec::new();
    let mut logits_buf: Vec<f32> = Vec::new();

    loop {
        // shared-consumer recv: hold the lock while blocked — peers
        // queue on the mutex instead of the channel, which hands
        // batches out one-per-worker either way
        let batch = match batch_rx.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => break, // poisoned: a peer died holding it
        };
        let mut pending = match batch {
            Ok(b) => b,
            Err(_) => break, // dispatcher gone and queue drained
        };
        let bsize = pending.len();
        if bsize == 0 {
            let _ = recycle_tx.send(pending);
            continue;
        }
        if let Ok(mut core) = shared.core.lock() {
            core.batches += 1;
        }
        // pack the cut directly into the persistent input buffer (each
        // byte written exactly once; capacity is retained across cuts)
        input_buf.clear();
        for req in &pending {
            // submit() already rejected malformed inputs; a violation
            // here is a programming error, and must never fail
            // co-batched requests (the no-poison invariant)
            debug_assert_eq!(req.input.len(), IMG_ELEMS, "unvalidated request reached batcher");
            input_buf.extend_from_slice(&req.input);
        }
        debug_assert_eq!(input_buf.len(), bsize * IMG_ELEMS);
        logits_buf.clear();
        logits_buf.resize(bsize * NUM_CLASSES, 0.0);
        // execute behind catch_unwind: a panicking session (or the
        // chaos hooks standing in for one) must never abort the worker
        // — the batch is re-executed once on a rebuilt session instead
        let mut attempts = 0u32;
        let exec = loop {
            let panic_now = shared.chaos_panic.swap(false, Ordering::AcqRel);
            let hang_ms = shared.chaos_hang_ms.swap(0, Ordering::AcqRel);
            let res = catch_unwind(AssertUnwindSafe(|| {
                if hang_ms > 0 {
                    thread::sleep(Duration::from_millis(hang_ms));
                }
                if panic_now {
                    // ddc-lint: allow(no_panic) — deliberate chaos hook: the panic is
                    // the fault being injected, and it unwinds into this catch_unwind.
                    panic!("chaos hook: debug_panic_next_batch");
                }
                session.infer_batch_into(&input_buf, bsize, &mut logits_buf)
            }));
            match res {
                Ok(r) => break Some(r),
                Err(_) => {
                    attempts += 1;
                    eprintln!(
                        "[ddc-reliability] worker {id}: batch execution panicked; \
                         rebuilding the session ({bsize} request(s) held for retry)"
                    );
                    if attempts > 1 {
                        fail_batch(
                            pending.drain(..),
                            ServiceError::Failed(
                                "batch execution panicked twice; giving up".into(),
                            ),
                            &shared,
                        );
                        break None;
                    }
                    match rebuild_session(&spec, &artifact_dir) {
                        Some(s) => {
                            session = s;
                            // same post-prepare scrub as the first session
                            let _ = session.scrub();
                            rebuilds += 1;
                            // loop: re-execute the held batch in place
                        }
                        None => {
                            fail_batch(
                                pending.drain(..),
                                ServiceError::Failed(format!(
                                    "batch execution panicked and session rebuild failed \
                                     after {REBUILD_ATTEMPTS} attempts"
                                )),
                                &shared,
                            );
                            break None;
                        }
                    }
                }
            }
        };
        // assess health from this batch window's reliability deltas:
        // repair churn degrades, zeroed rows (spares exhausted) or
        // repeated rebuilds quarantine
        let rel = session.reliability().unwrap_or_default();
        let repaired_delta = rel.faults_repaired.saturating_sub(prev_rel.faults_repaired);
        let zeroed_delta = rel.zeroed_rows.saturating_sub(prev_rel.zeroed_rows);
        prev_rel = rel;
        let next = assess_health(
            health,
            repaired_delta,
            zeroed_delta,
            rebuilds.saturating_sub(rebuild_baseline),
        );
        if next == WorkerHealth::Quarantined && health != WorkerHealth::Quarantined {
            shared.quarantined_now.fetch_add(1, Ordering::AcqRel);
            shared.quarantine_events.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[ddc-reliability] worker {id}: quarantined \
                 (zeroed_delta={zeroed_delta}, rebuilds={rebuilds}); parking for a clean scrub"
            );
        }
        health = next;
        if health == WorkerHealth::Quarantined {
            // park: full scrub cycles until one comes back clean, then
            // rejoin.  Upsets advance on the virtual batch clock, so a
            // parked session accrues no new damage and this terminates:
            // one pass repairs (or zeroizes), the next verifies clean.
            // Peers keep pulling batches off the shared channel in the
            // meantime — steering needs no dispatcher routing.
            loop {
                let before = session.reliability().unwrap_or_default();
                let after = match session.scrub() {
                    Some(r) => r,
                    None => before, // nothing scrubbable = vacuously clean
                };
                if after.faults_detected == before.faults_detected {
                    break;
                }
            }
            prev_rel = session.reliability().unwrap_or_default();
            rebuild_baseline = rebuilds;
            health = WorkerHealth::Healthy;
            shared.quarantined_now.fetch_sub(1, Ordering::AcqRel);
            shared.rejoin_events.fetch_add(1, Ordering::Relaxed);
            eprintln!("[ddc-reliability] worker {id}: rejoined after a clean scrub cycle");
        }
        // snapshot *before* responding: a client holding its answer
        // must observe stats at least as fresh as its own batch
        shared.update_snapshot(id, &*session, rebuilds, health);
        match exec {
            Some(Ok(())) => {
                let mut core = match shared.core.lock() {
                    Ok(c) => c,
                    Err(p) => p.into_inner(),
                };
                for (i, req) in pending.drain(..).enumerate() {
                    let mut logits = [0f32; NUM_CLASSES];
                    logits.copy_from_slice(&logits_buf[i * NUM_CLASSES..(i + 1) * NUM_CLASSES]);
                    let latency = req.submitted.elapsed();
                    core.requests += 1;
                    core.total_latency += latency;
                    core.max_latency = core.max_latency.max(latency);
                    core.hist.record(latency);
                    let _ = req.resp.send(Ok(InferenceResult {
                        logits,
                        argmax: argmax(&logits),
                        latency,
                        batch_size: bsize,
                        simulated_ms: sim_ms / bsize as f64,
                        backend: backend_name,
                    }));
                    shared.finish_request();
                }
            }
            Some(Err(e)) => {
                fail_batch(
                    pending.drain(..),
                    ServiceError::Failed(format!("batch execution failed: {e:#}")),
                    &shared,
                );
            }
            None => {} // panic path already answered every request
        }
        let _ = recycle_tx.send(pending);
    }
    exit(&shared, &batch_rx);
}

/// Rebuild a worker's session after a caught panic: fresh backend,
/// fresh prepare, bounded attempts with linear backoff.  `None` when
/// every attempt fails (the pending batch is then failed, not retried
/// forever).
fn rebuild_session(spec: &BackendSpec, artifact_dir: &str) -> Option<Box<dyn Session>> {
    for attempt in 1..=REBUILD_ATTEMPTS {
        thread::sleep(Duration::from_millis(10 * attempt as u64));
        match spec.create(artifact_dir).and_then(|b| b.prepare()) {
            Ok(s) => return Some(s),
            Err(e) => eprintln!(
                "[ddc-reliability] session rebuild attempt \
                 {attempt}/{REBUILD_ATTEMPTS} failed: {e:#}"
            ),
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FabricChoice;

    #[test]
    fn serves_without_artifacts_via_reference_backend() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let r = svc.infer(vec![0.0; IMG_ELEMS]).expect("reference inference");
        assert_eq!(r.logits.len(), NUM_CLASSES);
        assert_eq!(r.backend, "reference");
    }

    #[test]
    fn rejects_bad_input_size() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        let res = svc.infer(vec![0.0; 3]);
        assert!(matches!(res, Err(ServiceError::Failed(_))));
        // malformed inputs are rejected before admission: not shed,
        // not admitted
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.admission.admitted, 0);
        assert_eq!(stats.admission.rejected, 0);
    }

    #[test]
    fn explicit_reference_kind() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        assert!(svc.infer(vec![0.1; IMG_ELEMS]).is_ok());
    }

    #[test]
    fn bitsliced_fabric_spec_serves_identical_logits() {
        let dense = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let fabric = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                threads: 2,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let img = vec![0.3f32; IMG_ELEMS];
        let a = dense.infer(img.clone()).expect("dense");
        let b = fabric.infer(img).expect("fabric");
        // at these layer sizes the i32 kernels cannot overflow, so the
        // bit-sliced macro path and the dense kernel agree exactly
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn multi_worker_cluster_serves_identical_logits() {
        // N independent sessions must be indistinguishable from one:
        // same seed, same deterministic plan, byte-identical logits
        let single = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let cluster = InferenceService::start_cluster(
            BackendSpec::new(BackendKind::Reference),
            "/nonexistent".into(),
            BatchPolicy::default(),
            ServiceConfig {
                workers: 3,
                max_queue_depth: 0,
            },
        );
        assert_eq!(cluster.worker_count(), 3);
        let img = vec![0.25f32; IMG_ELEMS];
        let want = single.infer(img.clone()).expect("single").logits;
        for _ in 0..6 {
            let got = cluster.infer(img.clone()).expect("cluster");
            assert_eq!(got.logits, want, "a worker session drifted");
        }
        let stats = cluster.stats().expect("stats");
        assert_eq!(stats.requests, 6);
        assert_eq!(stats.admission.admitted, 6);
        assert_eq!(stats.admission.workers, 3);
    }

    #[test]
    fn overload_is_shed_with_a_typed_rejection() {
        // depth 1 + an hour-long batch window: the first request sits
        // in the batcher, the second must bounce off admission
        let svc = InferenceService::start_cluster(
            BackendSpec::new(BackendKind::Reference),
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(3600),
            },
            ServiceConfig {
                workers: 1,
                max_queue_depth: 1,
            },
        );
        let rx_a = svc.submit(vec![0.1; IMG_ELEMS]);
        let shed = svc
            .submit(vec![0.2; IMG_ELEMS])
            .recv()
            .expect("synchronous rejection");
        assert!(matches!(shed, Err(ServiceError::Overloaded)));
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.admission.admitted, 1);
        assert_eq!(stats.admission.rejected, 1);
        assert_eq!(stats.admission.peak_queue_depth, 1);
        assert_eq!(stats.admission.max_queue_depth, 1);
        assert!((stats.admission.shed_ratio() - 0.5).abs() < 1e-12);
        // the admitted request still completes on shutdown drain, and
        // its slot frees up
        drop(svc);
        let r = rx_a.recv().expect("drained").expect("served");
        assert_eq!(r.logits.len(), NUM_CLASSES);
    }

    #[test]
    fn percentiles_flow_through_stats() {
        let svc = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        for i in 0..8 {
            svc.infer(vec![0.1 * i as f32; IMG_ELEMS]).expect("served");
        }
        let s = svc.stats().expect("stats");
        assert_eq!(s.latency_hist.count(), 8);
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() > Duration::ZERO);
    }

    #[test]
    fn streamed_service_reports_capacity_pressure() {
        // a 2 KiB budget cannot hold conv2's 2304 B: the worker session
        // streams, and stats() surfaces its pressure counters
        let svc = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::DenseReference,
                threads: 1,
                stream_kb: 2,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        svc.infer(vec![0.1; IMG_ELEMS]).expect("streamed inference");
        svc.infer(vec![0.2; IMG_ELEMS]).expect("streamed inference");
        let stats = svc.stats().expect("stats");
        let p = stats.capacity;
        assert_eq!(p.capacity_bytes, 2048);
        assert!(p.staged_bytes > 0, "no staging recorded");
        assert!(p.reloads > 0, "second request must re-stage the passes");
        // an unbudgeted service stays all-zero ("everything resident")
        let resident =
            InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        resident.infer(vec![0.1; IMG_ELEMS]).expect("inference");
        assert_eq!(
            resident.stats().expect("stats").capacity,
            CapacityPressure::default()
        );
    }

    #[test]
    fn lone_straggler_is_served_at_its_deadline() {
        // a single request in a wide-batch policy must be flushed by
        // the deadline sleep (never stranded waiting for a full batch)
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(10),
            },
        );
        let r = svc.infer(vec![0.2; IMG_ELEMS]).expect("straggler served");
        assert_eq!(r.batch_size, 1);
    }

    #[test]
    fn queued_stragglers_drain_on_shutdown() {
        // requests still queued when the service drops must be executed
        // (drain path), not dropped on the floor
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(3600),
            },
        );
        let rx = svc.submit(vec![0.1; IMG_ELEMS]);
        drop(svc); // shutdown while the straggler is still queued
        let r = rx.recv().expect("response after shutdown").expect("served");
        assert_eq!(r.logits.len(), NUM_CLASSES);
    }

    #[test]
    fn hung_worker_trips_the_client_timeout() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        // warm up so the session is prepared before the chaos hook arms
        svc.infer(vec![0.1; IMG_ELEMS]).expect("warm-up");
        svc.debug_hang_next_batch(Duration::from_millis(400));
        let r = svc.infer_timeout(vec![0.2; IMG_ELEMS], Duration::from_millis(30));
        assert!(matches!(r, Err(ServiceError::Timeout)));
        // stats stay readable mid-hang: they come from shared state,
        // not a worker round-trip
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.reliability.timed_out_requests, 1);
        // the worker was stalled, not wedged: it serves again afterwards
        assert!(svc.infer(vec![0.3; IMG_ELEMS]).is_ok());
    }

    #[test]
    fn worker_panic_rebuilds_the_session_and_retries_the_batch() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let baseline = svc.infer(vec![0.2; IMG_ELEMS]).expect("baseline");
        svc.debug_panic_next_batch();
        // the batch bounces off the panicking execution, the worker
        // rebuilds its session, and the same batch is re-executed in
        // place — degraded (slower) but correct, never a hung recv
        let retried = svc.infer(vec![0.2; IMG_ELEMS]).expect("served after panic");
        assert_eq!(retried.logits, baseline.logits, "rebuilt session must agree");
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.reliability.worker_rebuilds, 1);
        assert!(svc.infer(vec![0.4; IMG_ELEMS]).is_ok(), "service stays up");
    }

    #[test]
    fn faulted_service_scrubs_at_prepare_and_reports_reliability() {
        // nonzero BER on the bit-sliced fabric: the worker's
        // post-prepare scrub detects and repairs the injected damage,
        // and the counters surface through stats()
        let svc = InferenceService::start_spec(
            BackendSpec {
                kind: BackendKind::Reference,
                fabric: FabricChoice::BitSliced,
                fault_ber_ppm: 2000,
                fault_seed: 11,
                ..Default::default()
            },
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        svc.infer(vec![0.3; IMG_ELEMS]).expect("faulted fabric serves");
        let r = svc.stats().expect("stats").reliability;
        assert!(r.faults_injected > 0, "no faults manifested at this BER");
        assert!(r.faults_detected > 0, "scrub missed the injected faults");
        assert!(r.quarantined_rows > 0, "no rows quarantined");
        // an unfaulted service stays quiet
        let clean = InferenceService::start("/nonexistent".into(), BatchPolicy::default());
        clean.infer(vec![0.3; IMG_ELEMS]).expect("clean");
        assert!(clean.stats().expect("stats").reliability.is_quiet());
    }

    #[test]
    fn assess_health_covers_the_documented_transitions() {
        use WorkerHealth::*;
        // quiet window: healthy stays healthy, degraded recovers
        assert_eq!(assess_health(Healthy, 0, 0, 0), Healthy);
        assert_eq!(assess_health(Degraded, 0, 0, 0), Healthy);
        // repair churn degrades (and keeps a degraded worker degraded)
        assert_eq!(assess_health(Healthy, DEGRADE_REPAIR_CHURN, 0, 0), Degraded);
        assert_eq!(assess_health(Degraded, 3, 0, 0), Degraded);
        // zeroed rows (spares exhausted) quarantine from any state
        assert_eq!(assess_health(Healthy, 0, 1, 0), Quarantined);
        assert_eq!(assess_health(Degraded, 2, 1, 1), Quarantined);
        // the rebuild threshold quarantines
        assert_eq!(assess_health(Healthy, 0, 0, QUARANTINE_REBUILDS), Quarantined);
        // quarantine is sticky: only the rejoin path (which resets the
        // rebuild baseline) leaves it
        assert_eq!(assess_health(Quarantined, 0, 0, 0), Quarantined);
    }

    #[test]
    fn expired_requests_are_shed_at_batch_cut() {
        let svc = InferenceService::start_cluster(
            BackendSpec::new(BackendKind::Reference),
            "/nonexistent".into(),
            BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(200),
            },
            ServiceConfig {
                workers: 1,
                max_queue_depth: 0,
            },
        );
        // a deadline already in the past when the batch cuts: the
        // dispatcher drops it (Timeout) without spending a worker slot,
        // and the co-batched live request is served normally
        let dead = svc.submit_with_deadline(vec![0.1; IMG_ELEMS], Some(Instant::now()));
        let live = svc.submit(vec![0.2; IMG_ELEMS]);
        let served = live.recv().expect("live response").expect("served");
        assert_eq!(served.logits.len(), NUM_CLASSES);
        let shed = dead.recv().expect("dead response");
        assert!(matches!(shed, Err(ServiceError::Timeout)), "got {shed:?}");
        let stats = svc.stats().expect("stats");
        assert_eq!(stats.admission.shed_expired, 1);
        assert_eq!(stats.admission.admitted, 2);
        assert_eq!(stats.requests, 1, "the expired request must never execute");
        // the admission slot was released: nothing left in flight
        assert_eq!(svc.shared.in_flight.load(Ordering::Acquire), 0);
    }

    #[test]
    fn repeated_panics_quarantine_then_rejoin_after_a_clean_scrub() {
        let svc = InferenceService::start_with(
            BackendKind::Reference,
            "/nonexistent".into(),
            BatchPolicy::default(),
        );
        let baseline = svc.infer(vec![0.2; IMG_ELEMS]).expect("warm-up");
        for _ in 0..QUARANTINE_REBUILDS {
            svc.debug_panic_next_batch();
            let r = svc.infer(vec![0.2; IMG_ELEMS]).expect("served through panic");
            assert_eq!(r.logits, baseline.logits, "rebuilt session drifted");
        }
        // the second rebuild crossed the threshold: the worker
        // quarantined, parked for a clean scrub cycle, and rejoined
        let s = svc.stats().expect("stats");
        assert_eq!(s.reliability.worker_rebuilds, QUARANTINE_REBUILDS);
        assert_eq!(s.health.quarantine_events, 1);
        assert_eq!(s.health.rejoin_events, 1);
        assert_eq!(s.health.healthy, 1, "worker must end healthy: {:?}", s.health);
        assert_eq!(s.health.quarantined, 0);
        assert!(svc.infer(vec![0.4; IMG_ELEMS]).is_ok(), "service stays up");
    }

    #[test]
    fn argmax_survives_nan_logits() {
        // regression: partial_cmp().unwrap() panicked (and killed the
        // worker thread) on any NaN logit.  In the total order positive
        // NaN sits above +inf, so a NaN deterministically wins.
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN, f32::NAN]), 2);
        assert_eq!(argmax(&[0.0, f32::NEG_INFINITY, 3.0, f32::NAN]), 3);
        assert_eq!(argmax(&[0.5, 1.0, 0.25]), 1);
        assert_eq!(argmax(&[]), 0);
    }
}
