//! Alg. 2 — Complementization.
//!
//! On integer *symmetric* filters, subtract 1 from the smaller twin of
//! every pair, turning the negation symmetry of Eq. 1 into the bitwise
//! complement relation of Eq. 3 (because `~x = -x - 1`, Eq. 4).

use super::FilterBank;

/// Alg. 2: `if f0 >= f1 { f1 -= 1 } else { f0 -= 1 }` elementwise.
pub fn complementize(sym: &FilterBank) -> FilterBank {
    let mut out = sym.clone();
    for p in 0..sym.pairs() {
        for i in 0..sym.l {
            let a = sym.filter(2 * p)[i];
            let b = sym.filter(2 * p + 1)[i];
            if a >= b {
                out.filter_mut(2 * p + 1)[i] = b - 1;
            } else {
                out.filter_mut(2 * p)[i] = a - 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::{is_biased_complementary, symmetrize_int};
    use crate::util::prop::forall;

    #[test]
    fn paper_example_fig4() {
        // symmetric: w00^s = -4, w01^s = 6, M = 1
        // smaller twin (-4) loses 1 -> w00^bc = -5, w01^bc = 6
        let sym = FilterBank::new(vec![-4, 6], 2, 1);
        let bc = complementize(&sym);
        assert_eq!(bc.data, vec![-5, 6]);
        assert!(is_biased_complementary(&bc, &[1]));
    }

    #[test]
    fn equal_twins() {
        // a == b: the "else" branch of Alg. 2 takes b - 1 via a >= b
        let sym = FilterBank::new(vec![5, 5], 2, 1);
        let bc = complementize(&sym);
        assert_eq!(bc.data, vec![5, 4]);
    }

    #[test]
    fn eq3_property() {
        forall(
            13,
            300,
            |r| {
                let l = 1 + r.below(25) as usize;
                let n = 2 * (1 + r.below(4) as usize);
                FilterBank::new(
                    (0..n * l).map(|_| r.range_i64(-128, 128) as i32).collect(),
                    n,
                    l,
                )
            },
            |b| {
                let (sym, m) = symmetrize_int(b);
                is_biased_complementary(&complementize(&sym), &m)
            },
        );
    }

    #[test]
    fn exactly_one_twin_changes() {
        let sym = FilterBank::new(vec![10, -4, 3, 3], 2, 2);
        let bc = complementize(&sym);
        for i in 0..2 {
            let changed = (sym.filter(0)[i] != bc.filter(0)[i]) as u32
                + (sym.filter(1)[i] != bc.filter(1)[i]) as u32;
            assert_eq!(changed, 1);
        }
    }
}
