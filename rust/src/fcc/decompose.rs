//! Decomposition of biased-comp filters into comp filters + means
//! (paper Fig. 9), and the deployable weight container.
//!
//! After `f^c = f^bc - M`, the twins of each pair are exact bitwise
//! complements, so only the even-indexed comp filters plus the `M`
//! vector are stored/transferred — the Q-bar side of the 6T array holds
//! the odd filters for free.  `O = Σ(I*f^c) + (ΣI)·M` (Eq. 7) recovers
//! the convolution results in the ARU.

use super::FilterBank;

/// Deployable FCC weights for one conv layer.
#[derive(Debug, Clone, PartialEq)]
pub struct FccWeights {
    /// Comp filters, all `N` of them `[N, L]` (odd rows are `!even`).
    pub comp: FilterBank,
    /// Per-pair means `M` (`N/2` entries).
    pub means: Vec<i32>,
}

impl FccWeights {
    /// The stored half: even-indexed comp filters, `[N/2, L]`.
    pub fn stored_even(&self) -> FilterBank {
        let l = self.comp.l;
        let mut data = Vec::with_capacity(self.comp.pairs() * l);
        for p in 0..self.comp.pairs() {
            data.extend_from_slice(self.comp.filter(2 * p));
        }
        FilterBank {
            data,
            n: self.comp.pairs().max(1),
            l,
        }
    }

    /// Reconstruct the full comp bank from the stored half (what the
    /// cross-coupled array does physically: `Q̄ = !Q`).
    pub fn reconstruct_from_even(even: &FilterBank, means: &[i32]) -> FccWeights {
        let l = even.l;
        let mut data = Vec::with_capacity(even.n * 2 * l);
        for p in 0..even.n {
            data.extend_from_slice(even.filter(p));
            data.extend(even.filter(p).iter().map(|&w| !w));
        }
        FccWeights {
            comp: FilterBank::new(data, even.n * 2, l),
            means: means.to_vec(),
        }
    }

    /// The stored half in the kernel's column-major `[L, N/2]` layout
    /// (`out[li * pairs + p] = comp_filter(2p)[li]`) — the `w_even`
    /// operand of the python `fcc_mvm` kernel and [`crate::runtime::Backend::fcc_mvm`].
    pub fn stored_even_cols(&self) -> Vec<i32> {
        let (l, pairs) = (self.comp.l, self.comp.pairs());
        let mut out = vec![0i32; l * pairs];
        for li in 0..l {
            for p in 0..pairs {
                out[li * pairs + p] = self.comp.filter(2 * p)[li];
            }
        }
        out
    }

    /// The full recomposed biased-comp bank in column-major `[L, N]`
    /// layout (`out[li * n + j] = comp_filter(j)[li] + M[j/2]`) — the
    /// dense-MVM oracle for the Eq. 7 recovery path.
    pub fn biased_comp_cols(&self) -> Vec<i32> {
        let (l, n) = (self.comp.l, self.comp.n);
        let mut out = vec![0i32; l * n];
        for li in 0..l {
            for j in 0..n {
                out[li * n + j] = self.comp.filter(j)[li] + self.means[j / 2];
            }
        }
        out
    }

    /// Bits that must be transferred off-chip for this layer (half the
    /// filters at 8 b/weight + one 8 b mean per pair) — the bandwidth
    /// bookkeeping behind the paper's "~2x equivalent transfer bandwidth".
    pub fn transfer_bits(&self) -> usize {
        self.comp.pairs() * self.comp.l * 8 + self.means.len() * 8
    }

    /// Bits a non-FCC INT8 layer of the same shape must transfer.
    pub fn dense_transfer_bits(&self) -> usize {
        self.comp.n * self.comp.l * 8
    }
}

/// `f^c = f^bc - M` (per pair).
pub fn decompose(bc: &FilterBank, means: &[i32]) -> FccWeights {
    assert_eq!(means.len(), bc.pairs());
    let mut comp = bc.clone();
    for p in 0..bc.pairs() {
        let m = means[p];
        for i in 0..bc.l {
            comp.filter_mut(2 * p)[i] -= m;
            comp.filter_mut(2 * p + 1)[i] -= m;
        }
    }
    FccWeights {
        comp,
        means: means.to_vec(),
    }
}

/// Inverse: `f^bc = f^c + M`.
pub fn recompose(fcc: &FccWeights) -> FilterBank {
    let mut bc = fcc.comp.clone();
    for p in 0..bc.pairs() {
        let m = fcc.means[p];
        for i in 0..bc.l {
            bc.filter_mut(2 * p)[i] += m;
            bc.filter_mut(2 * p + 1)[i] += m;
        }
    }
    bc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::{complementize, is_bitwise_complementary, symmetrize_int};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn paper_example_fig9() {
        // w00^bc = -5, w01^bc = 6, M = 1 -> w00^c = -6, w01^c = 5
        let bc = FilterBank::new(vec![-5, 6], 2, 1);
        let fcc = decompose(&bc, &[1]);
        assert_eq!(fcc.comp.data, vec![-6, 5]);
        // -6 = 0b11111010, 5 = 0b00000101 in 8-bit two's complement
        assert_eq!(fcc.comp.data[0] & 0xFF, 0b1111_1010);
        assert_eq!(fcc.comp.data[1] & 0xFF, 0b0000_0101);
        assert!(is_bitwise_complementary(&fcc.comp));
    }

    #[test]
    fn reconstruct_matches_original() {
        let mut rng = Rng::new(21);
        for _ in 0..30 {
            let l = 1 + rng.below(20) as usize;
            let n = 2 * (1 + rng.below(6) as usize);
            let bank = FilterBank::new(
                (0..n * l).map(|_| rng.range_i64(-128, 128) as i32).collect(),
                n,
                l,
            );
            let (sym, m) = symmetrize_int(&bank);
            let fcc = decompose(&complementize(&sym), &m);
            let rebuilt = FccWeights::reconstruct_from_even(&fcc.stored_even(), &m);
            assert_eq!(rebuilt.comp.data, fcc.comp.data);
        }
    }

    #[test]
    fn transfer_bits_half_plus_means() {
        let bc = FilterBank::new(vec![0; 8 * 9], 8, 9);
        let fcc = decompose(&bc, &[0; 4]);
        assert_eq!(fcc.dense_transfer_bits(), 8 * 9 * 8);
        assert_eq!(fcc.transfer_bits(), 4 * 9 * 8 + 4 * 8);
        // the paper's ~2x bandwidth claim: ratio just over 0.5
        let ratio = fcc.transfer_bits() as f64 / fcc.dense_transfer_bits() as f64;
        assert!(ratio < 0.6 && ratio > 0.5, "ratio={ratio}");
    }

    #[test]
    fn roundtrip_property() {
        forall(
            17,
            200,
            |r| {
                let l = 1 + r.below(30) as usize;
                let means: Vec<i32> =
                    (0..2).map(|_| r.range_i64(-50, 51) as i32).collect();
                let bc = FilterBank::new(
                    (0..4 * l).map(|_| r.range_i64(-100, 101) as i32).collect(),
                    4,
                    l,
                );
                (bc, means)
            },
            |(bc, means)| {
                let fcc = decompose(bc, means);
                recompose(&fcc).data == bc.data
            },
        );
    }
}
