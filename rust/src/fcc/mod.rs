//! FCC (Filter-wise Complementary Correlation) transforms — rust side.
//!
//! The training half of FCC lives in python (build-time).  This module
//! implements the *deployment* half used by the mapper, the functional
//! simulator and the verification suite: Alg. 1 (symmetrization), Alg. 2
//! (complementization), the biased-comp → comp + M decomposition, and
//! the invariant checks (Eqs. 1–5).
//!
//! Filters are `[N, L]` row-major (`N` even; adjacent rows pair up).

mod complementize;
mod decompose;
mod symmetrize;

pub use complementize::complementize;
pub use decompose::{decompose, recompose, FccWeights};
pub use symmetrize::{pair_means_int, symmetrize_int};

use crate::quant::{INT8_MAX, INT8_MIN};

/// A bank of INT8 filters in filter-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct FilterBank {
    /// `n * l` INT8 codes (i32 storage), row-major `[N, L]`.
    pub data: Vec<i32>,
    pub n: usize,
    pub l: usize,
}

impl FilterBank {
    pub fn new(data: Vec<i32>, n: usize, l: usize) -> Self {
        assert_eq!(data.len(), n * l, "shape mismatch");
        assert!(n % 2 == 0, "FCC needs an even filter count, got {n}");
        FilterBank { data, n, l }
    }

    pub fn filter(&self, j: usize) -> &[i32] {
        &self.data[j * self.l..(j + 1) * self.l]
    }

    pub fn filter_mut(&mut self, j: usize) -> &mut [i32] {
        &mut self.data[j * self.l..(j + 1) * self.l]
    }

    pub fn pairs(&self) -> usize {
        self.n / 2
    }
}

/// Check Eq. 1 (integer domain): `(w0 - M) == -(w1 - M)` elementwise.
pub fn is_symmetric(bank: &FilterBank, means: &[i32]) -> bool {
    assert_eq!(means.len(), bank.pairs());
    (0..bank.pairs()).all(|p| {
        let (f0, f1) = (bank.filter(2 * p), bank.filter(2 * p + 1));
        let m = means[p];
        f0.iter().zip(f1).all(|(&a, &b)| a - m == -(b - m))
    })
}

/// Check Eq. 3: `(w0 - M) == ~(w1 - M)`, i.e. `(w0-M) + (w1-M) == -1`.
pub fn is_biased_complementary(bank: &FilterBank, means: &[i32]) -> bool {
    assert_eq!(means.len(), bank.pairs());
    (0..bank.pairs()).all(|p| {
        let (f0, f1) = (bank.filter(2 * p), bank.filter(2 * p + 1));
        let m = means[p];
        f0.iter().zip(f1).all(|(&a, &b)| (a - m) + (b - m) == -1)
    })
}

/// Check Eq. 2: `w0 == !w1` elementwise (two's complement bitwise).
pub fn is_bitwise_complementary(bank: &FilterBank) -> bool {
    (0..bank.pairs()).all(|p| {
        let (f0, f1) = (bank.filter(2 * p), bank.filter(2 * p + 1));
        f0.iter().zip(f1).all(|(&a, &b)| a == !b)
    })
}

/// Check all values fit the signed INT8 range.
pub fn in_int8_range(bank: &FilterBank) -> bool {
    bank.data.iter().all(|&v| (INT8_MIN..=INT8_MAX).contains(&v))
}

/// Full FCC quantization pipeline on INT8 codes (paper Fig. 3 right):
/// symmetrize → complementize → decompose.  Returns the deployable
/// [`FccWeights`].
pub fn fcc_transform(bank: &FilterBank) -> FccWeights {
    let (sym, means) = symmetrize_int(bank);
    let bc = complementize(&sym);
    debug_assert!(is_biased_complementary(&bc, &means));
    decompose(&bc, &means)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_explain;
    use crate::util::rng::Rng;

    pub(crate) fn random_bank(rng: &mut Rng, n: usize, l: usize) -> FilterBank {
        FilterBank::new(
            (0..n * l).map(|_| rng.range_i64(-128, 128) as i32).collect(),
            n,
            l,
        )
    }

    #[test]
    fn full_pipeline_invariants() {
        let mut rng = Rng::new(42);
        let bank = random_bank(&mut rng, 8, 27);
        let fcc = fcc_transform(&bank);
        // stored even filters + recovered odd are exact complements
        assert!(is_bitwise_complementary(&fcc.comp));
        assert!(in_int8_range(&fcc.comp));
    }

    #[test]
    fn pipeline_property() {
        forall_explain(
            7,
            150,
            |r| {
                let n = 2 * (1 + r.below(8) as usize);
                let l = 1 + r.below(40) as usize;
                random_bank(r, n, l)
            },
            |bank| {
                let (sym, means) = symmetrize_int(bank);
                if !is_symmetric(&sym, &means) {
                    return Err("Eq.1 violated after symmetrize".into());
                }
                let bc = complementize(&sym);
                if !is_biased_complementary(&bc, &means) {
                    return Err("Eq.3 violated after complementize".into());
                }
                if !in_int8_range(&bc) {
                    return Err("int8 range violated".into());
                }
                let fcc = decompose(&bc, &means);
                if !is_bitwise_complementary(&fcc.comp) {
                    return Err("Eq.2 violated after decompose".into());
                }
                let back = recompose(&fcc);
                if back.data != bc.data {
                    return Err("recompose != original biased-comp".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "even filter count")]
    fn odd_filter_count_rejected() {
        FilterBank::new(vec![0; 9], 3, 3);
    }
}
