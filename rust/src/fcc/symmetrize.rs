//! Alg. 1 — Symmetrization (integer domain, deployment path).
//!
//! For each adjacent filter pair `(f_j, f_{j+1})` compute the rounded
//! pair mean `M_j`, then elementwise replace the twin *closer* to `M`
//! with the mirror image of the farther one, so that afterwards
//! `f0 - M = -(f1 - M)` (Eq. 1).  The deviation is clamped pairwise so
//! that both twins — including the later `-1` of Alg. 2 — stay inside
//! the signed INT8 range (see python `fcc/core.py`).

use super::FilterBank;
use crate::quant::{INT8_MAX, INT8_MIN};

/// Rounded per-pair means `M_j = round((Σf_j + Σf_{j+1}) / 2L)`.
pub fn pair_means_int(bank: &FilterBank) -> Vec<i32> {
    (0..bank.pairs())
        .map(|p| {
            let s: i64 = bank.filter(2 * p).iter().map(|&x| x as i64).sum::<i64>()
                + bank.filter(2 * p + 1).iter().map(|&x| x as i64).sum::<i64>();
            let denom = 2.0 * bank.l as f64;
            (s as f64 / denom).round() as i32
        })
        .collect()
}

/// Alg. 1 with INT8-safe pairwise deviation clamping.
/// Returns `(symmetric bank, means)`.
pub fn symmetrize_int(bank: &FilterBank) -> (FilterBank, Vec<i32>) {
    let means = pair_means_int(bank);
    let mut out = bank.clone();
    for p in 0..bank.pairs() {
        let m = means[p];
        // deviation clamp: M + dev <= INT8_MAX and M - dev - 1 >= INT8_MIN
        let dmax = (INT8_MAX - m).min(m - (INT8_MIN + 1)).max(0);
        for i in 0..bank.l {
            let a = bank.filter(2 * p)[i];
            let b = bank.filter(2 * p + 1)[i];
            // keep the twin farther from M, mirror the other
            let f0 = if (a - m).abs() >= (b - m).abs() {
                a
            } else {
                2 * m - b
            };
            let dev = (f0 - m).clamp(-dmax, dmax);
            out.filter_mut(2 * p)[i] = m + dev;
            out.filter_mut(2 * p + 1)[i] = m - dev;
        }
    }
    (out, means)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::is_symmetric;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn bank(data: Vec<i32>, n: usize, l: usize) -> FilterBank {
        FilterBank::new(data, n, l)
    }

    #[test]
    fn paper_example_fig4() {
        // quantized: w00 = -4-ish, w01 = 6, M = 1 (paper works the example
        // with L=1): mean((-4)+6)/2 = 1; farther twin is 6 -> w00^s = -4
        let b = bank(vec![-4, 6], 2, 1);
        let (sym, m) = symmetrize_int(&b);
        assert_eq!(m, vec![1]);
        assert_eq!(sym.data, vec![-4, 6]);
        assert!(is_symmetric(&sym, &m));
    }

    #[test]
    fn mirror_replaces_closer_twin() {
        // L=2: f0 = [10, 0], f1 = [2, 0] -> M = (10+0+2+0)/4 = 3.
        // position 0: 10 is farther from M, so 2 -> 2*3-10 = -4;
        // position 1: tie keeps f0's 0, mirrors f1 to 2*3-0 = 6.
        let b = bank(vec![10, 0, 2, 0], 2, 2);
        let (sym, m) = symmetrize_int(&b);
        assert_eq!(m, vec![3]);
        assert_eq!(sym.data, vec![10, 0, -4, 6]);
    }

    #[test]
    fn eq1_property_and_range() {
        forall(
            11,
            300,
            |r| {
                let l = 1 + r.below(30) as usize;
                FilterBank::new(
                    (0..2 * l).map(|_| r.range_i64(-128, 128) as i32).collect(),
                    2,
                    l,
                )
            },
            |b| {
                let (sym, m) = symmetrize_int(b);
                is_symmetric(&sym, &m)
                    && sym.data.iter().all(|&v| (-128..=127).contains(&v))
            },
        );
    }

    #[test]
    fn extreme_values_clamped() {
        let b = bank(vec![127, -128, 127, -128], 2, 2);
        let (sym, _m) = symmetrize_int(&b);
        // after the later -1, everything must still fit int8
        assert!(sym.data.iter().all(|&v| (-127..=127).contains(&v)));
    }

    #[test]
    fn kept_twin_preserved_when_in_range() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let l = 1 + rng.below(10) as usize;
            let b = FilterBank::new(
                (0..2 * l).map(|_| rng.range_i64(-60, 61) as i32).collect(),
                2,
                l,
            );
            let (sym, m) = symmetrize_int(&b);
            // small-range inputs never hit the clamp, so the farther twin
            // must be byte-identical to the original
            for i in 0..l {
                let (a, bb) = (b.filter(0)[i], b.filter(1)[i]);
                let far = if (a - m[0]).abs() >= (bb - m[0]).abs() { a } else { bb };
                assert!(sym.filter(0)[i] == far || sym.filter(1)[i] == far);
            }
        }
    }
}
