//! Per-layer instruction stream (Fig. 5: instruction memory + top
//! controller).
//!
//! The dataflow mapper emits one stream per network; the top controller
//! (cycle engine) decodes and executes it.  Encoding: one 64-bit word
//! per instruction — 4-bit opcode, 4-bit mode/config, 24-bit operand A,
//! 32-bit operand B.

use crate::mapping::{LayerPlan, PlanKind};

/// Opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Configure the PIM cores for a layer (mode, grouping, FCC).
    Cfg = 0x1,
    /// Load weight rows: A = rows (cycles), B = DRAM bytes to stage.
    LoadW = 0x2,
    /// Compute: A = row-steps, B = total cycles.
    Compute = 0x3,
    /// Merge/ARU flush: B = cycles.
    Merge = 0x4,
    /// Move activations through the ping-pong memory: B = bytes.
    Move = 0x5,
    /// End of layer marker: A = layer index.
    EndLayer = 0x6,
    /// End of network.
    Halt = 0xF,
}

/// Per-layer mode nibble.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CfgMode {
    Regular = 0x0,
    Double = 0x1,
    DwRegular = 0x2,
    DwDbis = 0x3,
    DwReconfig = 0x4,
    FcPath = 0x5,
    Bypass = 0x6,
}

/// A decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub mode: u8,
    pub a: u32, // 24-bit
    pub b: u32,
}

impl Instr {
    pub fn encode(&self) -> u64 {
        ((self.op as u64) << 60)
            | ((self.mode as u64 & 0xF) << 56)
            | ((self.a as u64 & 0xFF_FFFF) << 32)
            | self.b as u64
    }

    pub fn decode(word: u64) -> Option<Instr> {
        let op = match word >> 60 {
            0x1 => Op::Cfg,
            0x2 => Op::LoadW,
            0x3 => Op::Compute,
            0x4 => Op::Merge,
            0x5 => Op::Move,
            0x6 => Op::EndLayer,
            0xF => Op::Halt,
            _ => return None,
        };
        Some(Instr {
            op,
            mode: ((word >> 56) & 0xF) as u8,
            a: ((word >> 32) & 0xFF_FFFF) as u32,
            b: (word & 0xFFFF_FFFF) as u32,
        })
    }
}

fn cfg_mode(kind: PlanKind) -> CfgMode {
    match kind {
        PlanKind::StdRegular => CfgMode::Regular,
        PlanKind::StdDouble => CfgMode::Double,
        PlanKind::DwRegular => CfgMode::DwRegular,
        PlanKind::DwDbis => CfgMode::DwDbis,
        PlanKind::DwReconfig => CfgMode::DwReconfig,
        PlanKind::Fc => CfgMode::FcPath,
        PlanKind::PostProcess => CfgMode::Bypass,
    }
}

/// Lower a network plan to an instruction stream.
pub fn assemble(plans: &[LayerPlan]) -> Vec<u64> {
    let mut out = Vec::new();
    for (i, p) in plans.iter().enumerate() {
        let mode = cfg_mode(p.kind) as u8;
        let push = |v: &mut Vec<u64>, op: Op, a: u32, b: u32| {
            v.push(Instr { op, mode, a, b }.encode());
        };
        push(&mut out, Op::Cfg, i as u32, 0);
        if p.load_cycles > 0 {
            push(
                &mut out,
                Op::LoadW,
                p.load_cycles.min(u32::MAX as u64) as u32,
                p.dram_weight_bytes.min(u32::MAX as u64) as u32,
            );
        }
        if p.compute_cycles > 0 {
            push(
                &mut out,
                Op::Compute,
                (p.compute_cycles / 8).min(0xFF_FFFF) as u32,
                p.compute_cycles.min(u32::MAX as u64) as u32,
            );
            push(&mut out, Op::Merge, 0, p.merge_cycles as u32);
        }
        if p.sram_act_bytes > 0 {
            push(
                &mut out,
                Op::Move,
                0,
                p.sram_act_bytes.min(u32::MAX as u64) as u32,
            );
        }
        push(&mut out, Op::EndLayer, i as u32, 0);
    }
    out.push(
        Instr {
            op: Op::Halt,
            mode: 0,
            a: 0,
            b: 0,
        }
        .encode(),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchConfig, SimConfig};
    use crate::mapping::plan_network;
    use crate::model::zoo;

    #[test]
    fn encode_decode_roundtrip() {
        let i = Instr {
            op: Op::Compute,
            mode: CfgMode::Double as u8,
            a: 0x12_3456,
            b: 0xDEAD_BEEF,
        };
        assert_eq!(Instr::decode(i.encode()), Some(i));
    }

    #[test]
    fn decode_rejects_bad_opcode() {
        assert_eq!(Instr::decode(0x0), None);
        assert_eq!(Instr::decode(0x7 << 60), None);
    }

    #[test]
    fn assemble_ends_with_halt() {
        let plans = plan_network(
            &zoo::mobilenet_v2(),
            &ArchConfig::ddc_pim(),
            &SimConfig::ddc_full(),
        );
        let stream = assemble(&plans);
        let last = Instr::decode(*stream.last().unwrap()).unwrap();
        assert_eq!(last.op, Op::Halt);
        // every layer contributes an EndLayer
        let ends = stream
            .iter()
            .filter(|&&w| Instr::decode(w).map(|i| i.op) == Some(Op::EndLayer))
            .count();
        assert_eq!(ends, plans.len());
    }

    #[test]
    fn all_words_decode() {
        let plans = plan_network(
            &zoo::resnet18(),
            &ArchConfig::baseline(),
            &SimConfig::baseline(),
        );
        for w in assemble(&plans) {
            assert!(Instr::decode(w).is_some(), "word {w:#x} undecodable");
        }
    }
}
