//! # DDC-PIM
//!
//! Full-system reproduction of *"DDC-PIM: Efficient Algorithm/Architecture
//! Co-design for Doubling Data Capacity of SRAM-based Processing-In-Memory"*
//! (Duan et al., 2023).
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//!
//! * **L1/L2 (python, build-time only)** — the FCC training algorithm, a
//!   Pallas bit-serial PIM kernel and the quantized inference model,
//!   AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)** — the dataflow mapper, the cycle-accurate and
//!   bit-true functional simulators of the DDC-PIM architecture, the
//!   pluggable inference [`runtime`] (a hermetic pure-Rust reference
//!   backend by default; the PJRT path that serves the AOT artifacts
//!   behind the `pjrt` cargo feature), the inference coordinator, and
//!   the report generators that regenerate every table and figure of
//!   the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory, the experiment index and
//! the build/feature-flag instructions.

pub mod arch;
pub mod config;
pub mod coordinator;
pub mod fcc;
pub mod isa;
pub mod mapping;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
