//! `ddc-pim` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `info` — architecture + cost-model summary (Fig. 12 style);
//! * `simulate --model <name> [--baseline] [--batch N] [--scope i]` —
//!   cycle-accurate per-layer simulation of one network;
//! * `report <fig1|fig2|fig12|fig13|fig14|table2|table3|table4|table5|all>`
//!   — regenerate a paper table/figure;
//! * `selfcheck` — verify the active backend against the L1 kernel
//!   oracles (and replay the AOT goldens when artifacts are present);
//! * `serve [--requests N] [--batch N]` — run the inference service on
//!   synthetic requests and report latency/throughput.
//!
//! Global flags: `--artifacts <dir>` (default `artifacts`),
//! `--backend <auto|reference|pjrt>` (default `auto`).  Python never
//! runs here: all compute comes from the selected [`Backend`].

use std::collections::HashMap;

use ddc_pim::config::{ArchConfig, SimConfig};
use ddc_pim::coordinator::{BatchPolicy, InferenceService, ServiceConfig, ServiceError};
use ddc_pim::model::zoo;
use ddc_pim::report::{render_named, ReportCtx};
use ddc_pim::runtime::{
    artifacts, resolve_grid, verify_kernel_oracles, Backend, BackendKind, BackendSpec,
    FabricChoice, GridShape, IMG_ELEMS, NUM_CLASSES,
};
use ddc_pim::sim::simulate_network;
use ddc_pim::util::rng::Rng;
use ddc_pim::util::table::{f2, fp, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            // both `--flag value` and `--flag=value` spellings
            let (key, val) = if let Some((k, v)) = name.split_once('=') {
                (k.to_string(), v.to_string())
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                (name.to_string(), args[i].clone())
            } else {
                (name.to_string(), "true".to_string())
            };
            flags.insert(key, val);
        } else {
            pos.push(args[i].clone());
        }
        i += 1;
    }
    (pos, flags)
}

fn run(args: &[String]) -> i32 {
    let (pos, flags) = parse_flags(args);
    let artifact_dir = flags
        .get("artifacts")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let backend_kind = match flags.get("backend") {
        None => BackendKind::Auto,
        Some(v) => match v.parse::<BackendKind>() {
            Ok(k) => k,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let fabric = match flags.get("fabric") {
        None => FabricChoice::default(),
        Some(v) => match v.parse::<FabricChoice>() {
            Ok(f) => f,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let threads = match flags.get("threads") {
        None => 0, // resolve via DDC_THREADS, then 1
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("--threads needs an integer >= 1, got {v:?}");
                return 2;
            }
        },
    };
    let stream_kb = match flags.get("stream-kb") {
        None => 0, // no budget: every conv layer stays resident
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            _ => {
                eprintln!("--stream-kb needs an integer >= 0 (KiB), got {v:?}");
                return 2;
            }
        },
    };
    // fault injection: the CLI flag wins, then the env knob CI uses
    // (`DDC_FAULT_PPM`), then the pristine default
    let fault_ber_ppm = match flags
        .get("fault-ppm")
        .cloned()
        .or_else(|| std::env::var("DDC_FAULT_PPM").ok())
    {
        None => 0,
        Some(v) => match v.parse::<u32>() {
            Ok(n) if n <= 1_000_000 => n,
            _ => {
                eprintln!("--fault-ppm needs an integer in 0..=1000000 (ppm), got {v:?}");
                return 2;
            }
        },
    };
    let fault_seed = match flags
        .get("fault-seed")
        .cloned()
        .or_else(|| std::env::var("DDC_FAULT_SEED").ok())
    {
        None => 0xDDC7,
        Some(v) => match v.parse::<u64>() {
            Ok(n) => n,
            _ => {
                eprintln!("--fault-seed needs an integer, got {v:?}");
                return 2;
            }
        },
    };
    // runtime upsets + serving-time scrub: same precedence as the
    // fault knobs (CLI flag, then the env knob CI uses, then off)
    let upset_ppm = match flags
        .get("upset-ppm")
        .cloned()
        .or_else(|| std::env::var("DDC_UPSET_PPM").ok())
    {
        None => 0,
        Some(v) => match v.parse::<u32>() {
            Ok(n) if n <= 1_000_000 => n,
            _ => {
                eprintln!("--upset-ppm needs an integer in 0..=1000000 (ppm/batch), got {v:?}");
                return 2;
            }
        },
    };
    let scrub_stripes = match flags
        .get("scrub-stripes")
        .cloned()
        .or_else(|| std::env::var("DDC_SCRUB_STRIPES").ok())
    {
        None => 0,
        Some(v) => match v.parse::<u32>() {
            Ok(n) => n,
            _ => {
                eprintln!("--scrub-stripes needs an integer >= 0 (stripes/batch), got {v:?}");
                return 2;
            }
        },
    };
    let grid = match flags.get("grid") {
        None => GridShape::AUTO, // resolve via DDC_GRID, then 1x1
        Some(v) => match v.parse::<GridShape>() {
            Ok(g) => g,
            Err(e) => {
                eprintln!("--grid: {e}");
                return 2;
            }
        },
    };
    let spec = BackendSpec {
        kind: backend_kind,
        fabric,
        threads,
        stream_kb,
        fault_ber_ppm,
        fault_seed,
        upset_ppm,
        scrub_stripes,
        grid,
    };
    match pos.first().map(String::as_str) {
        Some("info") => cmd_info(),
        Some("simulate") => cmd_simulate(&flags),
        Some("report") => cmd_report(pos.get(1).map(String::as_str), &artifact_dir),
        Some("selfcheck") => cmd_selfcheck(&flags, &artifact_dir, spec),
        Some("serve") => cmd_serve(&flags, &artifact_dir, spec),
        _ => {
            eprintln!(
                "usage: ddc-pim <info|simulate|report|selfcheck|serve> [flags]\n\
                 \n  simulate --model <name> [--baseline] [--batch N] [--scope i]\
                 \n  report <fig1|fig2|fig12|fig13|fig14|table2|table3|table4|table5|all>\
                 \n  selfcheck [--chaos]  (--chaos adds the upset/panic/hang soak step)\
                 \n  serve [--requests N] [--batch N] [--workers N] [--queue-depth N]\
                 \n  flags: --artifacts <dir>  (default: artifacts)\
                 \n         --backend <auto|reference|pjrt>  (default: auto)\
                 \n         --fabric <dense|bitsliced>  (reference conv path; default: dense)\
                 \n         --threads <N>  (exec pool width; default: DDC_THREADS or 1)\
                 \n         --grid <RxC>  (macro grid for sharded convs, e.g. 2x2; default: DDC_GRID or 1x1)\
                 \n         --workers <N>  (serving worker sessions; default: DDC_WORKERS or 1)\
                 \n         --queue-depth <N>  (admission bound, 0 = unbounded; default: 0)\
                 \n         --stream-kb <N>  (weight-streaming budget in KiB; default: 0 = resident)\
                 \n         --fault-ppm <N>  (injected bit-error rate, cells per million; default: 0 = pristine)\
                 \n         --fault-seed <N>  (fault pattern seed; default: 0xDDC7)\
                 \n         --upset-ppm <N>  (runtime per-batch upset rate, bits per million; default: DDC_UPSET_PPM or 0)\
                 \n         --scrub-stripes <N>  (incremental scrub budget per batch, 0 = off; default: DDC_SCRUB_STRIPES or 0)\
                 \n  models: {}",
                zoo::ALL_MODELS.join(", ")
            );
            2
        }
    }
}

fn cmd_info() -> i32 {
    let cfg = ArchConfig::ddc_pim();
    let cost = ddc_pim::arch::cost::CostModel::new(cfg.clone());
    println!("DDC-PIM architecture (paper defaults)");
    println!(
        "  macros:          {} x {} KB array",
        cfg.macros,
        cfg.macro_array_kb() / 8.0
    );
    println!(
        "  geometry:        {} compartments x {} rows x {} DBMUs",
        cfg.compartments, cfg.rows, cfg.dbmus
    );
    println!(
        "  weight capacity: {} Kb/macro (doubled via Q/Q-bar)",
        cfg.macro_weight_capacity_kb()
    );
    println!("  frequency:       {} MHz", cfg.freq_mhz);
    println!("  peak:            {} GOPS (8bx8b)", f2(cfg.peak_gops()));
    println!(
        "  macro area:      {} mm2 @ {} nm",
        fp(cost.macro_area_mm2(), 4),
        cfg.node_nm
    );
    println!("  system area:     {} mm2", fp(cost.system_area_mm2(), 3));
    println!(
        "  weight density:  {} Kb/mm2 (28 nm-normalized)",
        f2(cost.weight_density(true))
    );
    println!(
        "  energy eff:      {} TOPS/W (macro)",
        f2(cost.energy_efficiency_tops_w())
    );
    0
}

fn cmd_simulate(flags: &HashMap<String, String>) -> i32 {
    let model = flags
        .get("model")
        .map(String::as_str)
        .unwrap_or("mobilenet_v2");
    let Some(net) = zoo::by_name(model) else {
        eprintln!("unknown model {model}; have: {}", zoo::ALL_MODELS.join(", "));
        return 2;
    };
    let baseline = flags.contains_key("baseline");
    let arch = if baseline {
        ArchConfig::baseline()
    } else {
        ArchConfig::ddc_pim()
    };
    let mut sim = if baseline {
        SimConfig::baseline()
    } else {
        SimConfig::ddc_full()
    };
    if let Some(b) = flags.get("batch").and_then(|v| v.parse().ok()) {
        sim.batch = b;
    }
    if let Some(s) = flags.get("scope").and_then(|v| v.parse().ok()) {
        sim.scope_threshold = s;
    }
    let run = simulate_network(&net, &arch, &sim);
    let mut t = Table::new(format!(
        "{model} on {} (batch {})",
        if baseline { "PIM baseline" } else { "DDC-PIM" },
        sim.batch.max(1)
    ))
    .header(&[
        "layer", "kind", "cycles", "compute", "load", "dram stall", "MACs", "FCC",
    ]);
    for l in &run.layers {
        if l.cycles == 0 {
            continue;
        }
        t.row(vec![
            l.name.clone(),
            format!("{:?}", l.kind),
            l.cycles.to_string(),
            l.compute_cycles.to_string(),
            l.load_cycles.to_string(),
            l.exposed_dram_cycles.to_string(),
            l.macs.to_string(),
            if l.fcc { "yes".into() } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    println!(
        "total: {} cycles = {} ms @ {} MHz | {} GOPS achieved | {} mJ | dw fraction {}%",
        run.total_cycles,
        fp(run.latency_ms(), 3),
        run.freq_mhz,
        f2(run.achieved_gops()),
        fp(run.total_energy_mj, 4),
        f2(100.0 * run.dw_fraction()),
    );
    0
}

fn cmd_report(name: Option<&str>, artifact_dir: &str) -> i32 {
    let ctx = ReportCtx::new(artifact_dir);
    match render_named(&ctx, name.unwrap_or("all")) {
        Some(s) => {
            println!("{s}");
            0
        }
        None => {
            eprintln!("unknown report {name:?}");
            2
        }
    }
}

/// One selfcheck step: run it, print PASS/FAIL, count failures.
fn check(failures: &mut u32, name: &str, result: anyhow::Result<()>) {
    match result {
        Ok(()) => println!("  {name}: OK"),
        Err(e) => {
            println!("  {name}: FAIL ({e:#})");
            *failures += 1;
        }
    }
}

fn cmd_selfcheck(flags: &HashMap<String, String>, artifact_dir: &str, spec: BackendSpec) -> i32 {
    println!("selfcheck: artifact dir = {artifact_dir}");
    let mut backend = match spec.create(artifact_dir) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("FAIL: backend: {e:#}");
            return 1;
        }
    };
    println!("backend: {}", backend.name());
    let mut failures = 0u32;

    // 1+2. integer kernels against the L1 oracles (dense MVM + Eq. 7
    //      recovery).  Arbitrary-shape checks only make sense on
    //      interpreter backends; AOT/PJRT executables are lowered at
    //      fixed shapes and are covered by the golden replay below.
    if backend.supports_arbitrary_kernel_shapes() {
        check(
            &mut failures,
            "kernel oracles (pim_mac + fcc_mvm vs Eq. 7)",
            verify_kernel_oracles(backend.as_mut()),
        );
    } else {
        println!(
            "  (skipping arbitrary-shape kernel oracles: {} executes fixed AOT shapes; \
             covered by golden replay)",
            backend.name()
        );
    }

    // 3. model path: shape + determinism
    check(&mut failures, "model shape + determinism", {
        let mut rng = Rng::new(303);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        backend.infer_batch(&img, 1).and_then(|a| {
            anyhow::ensure!(a.len() == NUM_CLASSES, "bad logit count {}", a.len());
            let b = backend.infer_batch(&img, 1)?;
            anyhow::ensure!(a == b, "nondeterministic logits");
            Ok(())
        })
    });

    // 4. weight streaming: a capacity-budgeted session must produce
    //    byte-identical logits to the resident path, and report its
    //    pressure counters (reference backend only; PJRT sessions do
    //    not stream)
    if spec.kind != BackendKind::Pjrt && backend.name() == "reference" {
        check(&mut failures, "weight streaming parity (2 KiB budget)", {
            let mut rng = Rng::new(304);
            let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
            let resident = backend.infer_batch(&img, 1);
            let streamed_spec = BackendSpec {
                stream_kb: 2,
                ..spec
            };
            resident.and_then(|want| {
                let streamed = streamed_spec.create(artifact_dir)?;
                let mut session = streamed.prepare()?;
                let mut got = vec![0f32; NUM_CLASSES];
                session.infer_batch_into(&img, 1, &mut got)?;
                session.infer_batch_into(&img, 1, &mut got)?;
                anyhow::ensure!(got == want, "streamed logits diverged from resident");
                let p = session
                    .capacity_pressure()
                    .ok_or_else(|| anyhow::anyhow!("streamed session reported no pressure"))?;
                anyhow::ensure!(p.staged_bytes > 0, "no staging recorded");
                println!(
                    "  streaming: reloads={} evictions={} peak occupancy={:.2} overlap={:.2}",
                    p.reloads,
                    p.evictions,
                    p.peak_occupancy(),
                    p.overlap_ratio(),
                );
                Ok(())
            })
        });
    }

    // 5. fault injection + integrity scrub: a zero-fault bit-sliced
    //    session books no reliability events, and a seeded-fault
    //    session serves without panicking, detects the damage via the
    //    Q/Q̄ checksum scrub, and quarantines the corrupt rows
    //    (reference backend only; PJRT has no fault model)
    if spec.kind != BackendKind::Pjrt && backend.name() == "reference" {
        check(&mut failures, "fault injection + integrity scrub", {
            (|| -> anyhow::Result<()> {
                let mut rng = Rng::new(305);
                let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
                let mut out = vec![0f32; NUM_CLASSES];
                let clean = BackendSpec {
                    fabric: FabricChoice::BitSliced,
                    fault_ber_ppm: 0,
                    ..spec
                }
                .create(artifact_dir)?;
                let mut s = clean.prepare()?;
                s.infer_batch_into(&img, 1, &mut out)?;
                let r = s
                    .reliability()
                    .ok_or_else(|| anyhow::anyhow!("reference session reported no reliability"))?;
                anyhow::ensure!(
                    r.is_quiet(),
                    "zero-fault session booked reliability events: {r:?}"
                );
                // seeded faults (the CLI/env BER, or a smoke default)
                let ppm = if spec.fault_ber_ppm > 0 { spec.fault_ber_ppm } else { 1500 };
                let faulted = BackendSpec {
                    fabric: FabricChoice::BitSliced,
                    fault_ber_ppm: ppm,
                    ..spec
                }
                .create(artifact_dir)?;
                let mut s = faulted.prepare()?;
                s.infer_batch_into(&img, 1, &mut out)?; // must not panic
                let before = s.reliability().unwrap_or_default();
                anyhow::ensure!(before.faults_injected > 0, "BER {ppm} ppm manifested no faults");
                let after = s
                    .scrub()
                    .ok_or_else(|| anyhow::anyhow!("faulted session cannot scrub"))?;
                anyhow::ensure!(
                    after.faults_detected > 0,
                    "scrub detected none of {} injected fault bits",
                    before.faults_injected
                );
                anyhow::ensure!(after.quarantined_rows > 0, "no corrupt rows quarantined");
                s.infer_batch_into(&img, 1, &mut out)?; // repaired fabric still serves
                println!(
                    "  faults ({ppm} ppm): injected={} detected={} repaired={} quarantined={} zeroed={}",
                    after.faults_injected,
                    after.faults_detected,
                    after.faults_repaired,
                    after.quarantined_rows,
                    after.zeroed_rows,
                );
                Ok(())
            })()
        });
    }

    // 6. multi-macro grid parity: sharding every conv across a 2x2
    //    macro grid must be byte-identical to the single-macro plan —
    //    the shard planner's disjoint-output proof, checked end to end
    //    (reference backend only; the grid shape is a reference knob)
    if spec.kind != BackendKind::Pjrt && backend.name() == "reference" {
        check(&mut failures, "macro-grid parity (2x2 vs single-macro)", {
            (|| -> anyhow::Result<()> {
                let mut rng = Rng::new(306);
                let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
                let mut want = vec![0f32; NUM_CLASSES];
                let mut got = vec![0f32; NUM_CLASSES];
                let single = BackendSpec {
                    fabric: FabricChoice::BitSliced,
                    grid: GridShape::SINGLE,
                    ..spec
                }
                .create(artifact_dir)?;
                single.prepare()?.infer_batch_into(&img, 1, &mut want)?;
                let gridded = BackendSpec {
                    fabric: FabricChoice::BitSliced,
                    grid: GridShape::new(2, 2),
                    ..spec
                }
                .create(artifact_dir)?;
                gridded.prepare()?.infer_batch_into(&img, 1, &mut got)?;
                anyhow::ensure!(got == want, "2x2 grid logits diverged from single-macro");
                Ok(())
            })()
        });
    }

    // 7. sharded serving tier: a deterministic overload must shed with
    //    the typed rejection (depth 1 + an hour-long batch window: the
    //    queued request blocks the only slot), and a 2-worker cluster
    //    must serve a burst with ordered SLO percentiles
    if spec.kind != BackendKind::Pjrt && backend.name() == "reference" {
        check(&mut failures, "sharded serving (admission + percentiles)", {
            (|| -> anyhow::Result<()> {
                let svc = InferenceService::start_cluster(
                    spec,
                    artifact_dir.to_string(),
                    BatchPolicy {
                        max_batch: 64,
                        max_wait: std::time::Duration::from_secs(3600),
                    },
                    ServiceConfig {
                        workers: 1,
                        max_queue_depth: 1,
                    },
                );
                let queued = svc.submit(vec![0.1; IMG_ELEMS]);
                let shed = svc.submit(vec![0.2; IMG_ELEMS]).recv()?;
                anyhow::ensure!(
                    matches!(shed, Err(ServiceError::Overloaded)),
                    "expected a typed Overloaded rejection, got {shed:?}"
                );
                let s = svc.stats().unwrap_or_default();
                anyhow::ensure!(
                    s.admission.rejected == 1 && s.admission.admitted == 1,
                    "admission accounting off: {:?}",
                    s.admission
                );
                drop(svc); // shutdown drains the queued request
                queued
                    .recv()?
                    .map_err(|e| anyhow::anyhow!("queued request not drained: {e}"))?;
                let cluster = InferenceService::start_cluster(
                    spec,
                    artifact_dir.to_string(),
                    BatchPolicy::default(),
                    ServiceConfig {
                        workers: 2,
                        max_queue_depth: 0,
                    },
                );
                let mut rng = Rng::new(307);
                for _ in 0..8 {
                    let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
                    cluster
                        .infer(img)
                        .map_err(|e| anyhow::anyhow!("cluster request failed: {e}"))?;
                }
                let s = cluster.stats().unwrap_or_default();
                anyhow::ensure!(s.requests == 8, "served {} of 8", s.requests);
                anyhow::ensure!(s.admission.workers == 2, "worker count not reported");
                anyhow::ensure!(
                    s.p50() <= s.p95() && s.p95() <= s.p99() && s.p99() > std::time::Duration::ZERO,
                    "percentiles out of order: p50={:?} p95={:?} p99={:?}",
                    s.p50(),
                    s.p95(),
                    s.p99()
                );
                Ok(())
            })()
        });
    }

    // 8. chaos soak (opt-in via --chaos): a seeded schedule of runtime
    //    upsets, worker panics and hangs against a 2-worker cluster
    //    with the incremental scrub at full coverage.  Every answer
    //    must be byte-identical to the fault-free oracle, the upset
    //    ledger must reconcile exactly, and the cluster must end with
    //    every worker healthy (quarantines resolved by clean rejoins).
    if flags.contains_key("chaos")
        && spec.kind != BackendKind::Pjrt
        && backend.name() == "reference"
    {
        check(&mut failures, "chaos soak (upsets + panics + hangs)", {
            (|| -> anyhow::Result<()> {
                let rounds = 30usize;
                let mut rng = Rng::new(309);
                let imgs: Vec<Vec<f32>> = (0..4)
                    .map(|_| (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect())
                    .collect();
                // fault-free oracle logits for every probe image
                let clean = BackendSpec {
                    fabric: FabricChoice::BitSliced,
                    fault_ber_ppm: 0,
                    upset_ppm: 0,
                    scrub_stripes: 0,
                    ..spec
                }
                .create(artifact_dir)?;
                let mut s = clean.prepare()?;
                let mut want = vec![vec![0f32; NUM_CLASSES]; imgs.len()];
                for (img, w) in imgs.iter().zip(want.iter_mut()) {
                    s.infer_batch_into(img, 1, w)?;
                }
                let svc = InferenceService::start_cluster(
                    BackendSpec {
                        fabric: FabricChoice::BitSliced,
                        // write-time BER has its own step (5); here the
                        // runtime upset process is the only damage
                        // source, so the ledger reconciles exactly
                        fault_ber_ppm: 0,
                        upset_ppm: if spec.upset_ppm > 0 { spec.upset_ppm } else { 20_000 },
                        scrub_stripes: u32::MAX, // full coverage every boundary
                        ..spec
                    },
                    artifact_dir.to_string(),
                    BatchPolicy::default(),
                    ServiceConfig {
                        workers: 2,
                        max_queue_depth: 0,
                    },
                );
                for round in 0..rounds {
                    match round % 10 {
                        // >= 3 panics over 2 workers: some worker takes
                        // two rebuilds and must quarantine + rejoin
                        3 => svc.debug_panic_next_batch(),
                        7 => svc.debug_hang_next_batch(std::time::Duration::from_millis(5)),
                        _ => {}
                    }
                    let img = &imgs[round % imgs.len()];
                    let r = svc
                        .infer(img.clone())
                        .map_err(|e| anyhow::anyhow!("round {round} failed: {e}"))?;
                    anyhow::ensure!(
                        r.logits[..] == want[round % imgs.len()][..],
                        "round {round}: served logits diverged from the fault-free oracle"
                    );
                }
                let st = svc.stats().unwrap_or_default();
                let r = st.reliability;
                anyhow::ensure!(r.upset_bits > 0, "no runtime upsets landed over {rounds} rounds");
                anyhow::ensure!(
                    r.upset_bits == r.corrupt_bits_found,
                    "upset ledger did not reconcile: landed {} found {}",
                    r.upset_bits,
                    r.corrupt_bits_found
                );
                anyhow::ensure!(
                    st.health.quarantine_events >= 1
                        && st.health.quarantine_events == st.health.rejoin_events,
                    "quarantine/rejoin mismatch: {:?}",
                    st.health
                );
                anyhow::ensure!(
                    st.health.healthy + st.health.degraded == st.admission.workers,
                    "cluster did not end serving-capable: {:?}",
                    st.health
                );
                println!(
                    "  chaos ({rounds} rounds): upsets={} found={} repaired_rows={} \
                     rebuilds={} quarantines={} rejoins={}",
                    r.upset_bits,
                    r.corrupt_bits_found,
                    r.faults_repaired,
                    r.worker_rebuilds,
                    st.health.quarantine_events,
                    st.health.rejoin_events,
                );
                Ok(())
            })()
        });
    }

    // 9. golden replay when the python AOT pass has produced artifacts
    //    (the integer kernels carry their shapes, so replay works on any
    //    backend; the model golden is PJRT-only).  Only a *missing*
    //    goldens.json skips; a present-but-unreadable one is a FAIL.
    let goldens_path = std::path::Path::new(artifact_dir).join("goldens.json");
    if !goldens_path.exists() {
        println!("  (no goldens.json — skipping artifact replay; run `make artifacts`)");
    } else {
        match artifacts::load_goldens(artifact_dir) {
            Ok(goldens) => replay_goldens(backend.as_mut(), &goldens, &mut failures),
            Err(e) => check(
                &mut failures,
                "goldens.json readable",
                Err(e.context("goldens.json exists but could not be loaded")),
            ),
        }
    }

    if failures == 0 {
        println!("selfcheck OK");
        0
    } else {
        eprintln!("selfcheck: {failures} failures");
        1
    }
}

/// Replay every artifact golden through the backend, counting FAILs.
fn replay_goldens(
    backend: &mut dyn Backend,
    goldens: &[(String, artifacts::Golden)],
    failures: &mut u32,
) {
    // malformed golden shapes become counted FAILs, not panics
    let dims = |shape: &[i64], want: usize| -> anyhow::Result<Vec<usize>> {
        anyhow::ensure!(
            shape.len() == want && shape.iter().all(|&d| d > 0),
            "bad golden shape {shape:?} (want rank {want})"
        );
        Ok(shape.iter().map(|&d| d as usize).collect())
    };
    for (name, g) in goldens {
        match name.as_str() {
            "pim_mac" => check(failures, "golden pim_mac", {
                dims(&g.x_shape, 2).and_then(|xs| {
                    let n = dims(&g.w_shape, 2)?[1];
                    let out = backend.pim_mac(&g.x_i32(), &g.w_i32(), xs[0], xs[1], n)?;
                    anyhow::ensure!(out == g.out_i32(), "output mismatch");
                    Ok(())
                })
            }),
            "fcc_mvm" => check(failures, "golden fcc_mvm", {
                dims(&g.x_shape, 2).and_then(|xs| {
                    let half = dims(&g.w_shape, 2)?[1];
                    let out =
                        backend.fcc_mvm(&g.x_i32(), &g.w_i32(), &g.m_i32(), xs[0], xs[1], half)?;
                    anyhow::ensure!(out == g.out_i32(), "output mismatch");
                    Ok(())
                })
            }),
            "model_b1" if backend.name() == "pjrt" => {
                check(failures, "golden model_b1", {
                    backend.infer_batch(&g.x_f32(), 1).and_then(|out| {
                        let want = g.out_f32();
                        anyhow::ensure!(out.len() == want.len(), "length mismatch");
                        let max_err = out
                            .iter()
                            .zip(&want)
                            .map(|(a, b)| (a - b).abs())
                            .fold(0f32, f32::max);
                        anyhow::ensure!(max_err < 1e-3, "max abs err {max_err}");
                        Ok(())
                    })
                })
            }
            _ => {}
        }
    }
}

fn cmd_serve(flags: &HashMap<String, String>, artifact_dir: &str, spec: BackendSpec) -> i32 {
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let max_batch: usize = flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let workers: usize = match flags.get("workers") {
        None => 0, // resolve via DDC_WORKERS, then 1
        Some(v) => match v.parse::<usize>() {
            Ok(w) if w >= 1 => w,
            _ => {
                eprintln!("--workers needs an integer >= 1, got {v:?}");
                return 2;
            }
        },
    };
    let queue_depth: usize = match flags.get("queue-depth") {
        None => 0, // unbounded: never shed
        Some(v) => match v.parse::<usize>() {
            Ok(d) => d,
            _ => {
                eprintln!("--queue-depth needs an integer >= 0, got {v:?}");
                return 2;
            }
        },
    };
    let policy = BatchPolicy {
        max_batch,
        ..Default::default()
    };
    let svc = InferenceService::start_cluster(
        spec,
        artifact_dir.to_string(),
        policy,
        ServiceConfig {
            workers,
            max_queue_depth: queue_depth,
        },
    );
    println!(
        "serving with {} worker(s), queue depth {}",
        svc.worker_count(),
        if queue_depth == 0 { "unbounded".to_string() } else { queue_depth.to_string() },
    );
    let mut rng = Rng::new(7);
    let start = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
            svc.submit(img)
        })
        .collect();
    let mut ok = 0usize;
    let mut shed = 0usize;
    for rx in rxs {
        // a real client-side deadline: a wedged worker surfaces as an
        // error line, never as a hung CLI
        match rx.recv_timeout(ddc_pim::coordinator::DEFAULT_INFER_TIMEOUT) {
            Ok(Ok(r)) => {
                ok += 1;
                if ok <= 3 {
                    println!(
                        "  req: class={} latency={:.2}ms batch={} sim={:.3}ms backend={}",
                        r.argmax,
                        r.latency.as_secs_f64() * 1e3,
                        r.batch_size,
                        r.simulated_ms,
                        r.backend,
                    );
                }
            }
            // under a bounded queue, shed load is an expected outcome
            // of the burst, not a serving failure: count it and go on
            Ok(Err(ServiceError::Overloaded)) => shed += 1,
            Ok(Err(e)) => {
                eprintln!("request failed: {e}");
                return 1;
            }
            Err(e) => {
                eprintln!("service dropped or timed out: {e}");
                return 1;
            }
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    let stats = svc.stats().unwrap_or_default();
    println!(
        "served {ok}/{n} requests in {:.2}s = {:.1} req/s | batches {} | mean latency {:.2}ms | max {:.2}ms",
        elapsed,
        n as f64 / elapsed,
        stats.batches,
        stats.mean_latency().as_secs_f64() * 1e3,
        stats.max_latency.as_secs_f64() * 1e3,
    );
    println!(
        "latency percentiles: p50 {:.2}ms | p95 {:.2}ms | p99 {:.2}ms",
        stats.p50().as_secs_f64() * 1e3,
        stats.p95().as_secs_f64() * 1e3,
        stats.p99().as_secs_f64() * 1e3,
    );
    let a = stats.admission;
    println!(
        "admission: admitted {} | rejected {} | shed ratio {:.3} | peak depth {} | workers {} | shed expired {}",
        a.admitted,
        a.rejected,
        a.shed_ratio(),
        a.peak_queue_depth,
        a.workers,
        a.shed_expired,
    );
    let h = stats.health;
    println!(
        "health: healthy {} | degraded {} | quarantined {} | quarantine events {} | rejoins {}",
        h.healthy, h.degraded, h.quarantined, h.quarantine_events, h.rejoin_events,
    );
    // modelled hardware latency: the cycle simulator's single-macro
    // number, and the Amdahl-style projection onto the active grid
    // (conv cycles split across tiles; FC/post-process stay serial)
    let grid = resolve_grid(spec.grid);
    let run = simulate_network(
        &zoo::mobilenet_v2(),
        &ArchConfig::ddc_pim(),
        &SimConfig::ddc_full(),
    );
    if grid.tiles() > 1 {
        println!(
            "modelled hw latency: {:.3}ms single-macro -> {:.3}ms on the {grid} grid",
            run.latency_ms(),
            run.grid_scaled_latency_ms(grid.tiles()),
        );
    } else {
        println!("modelled hw latency: {:.3}ms (single macro)", run.latency_ms());
    }
    let p = stats.capacity;
    if p.capacity_bytes > 0 {
        println!(
            "streaming: budget {} B | reloads {} | evictions {} | peak occupancy {:.2} | prefetch overlap {:.2} | exposed stall {:.2}ms",
            p.capacity_bytes,
            p.reloads,
            p.evictions,
            p.peak_occupancy(),
            p.overlap_ratio(),
            p.stall.as_secs_f64() * 1e3,
        );
    }
    let r = stats.reliability;
    if !r.is_quiet() {
        println!(
            "reliability: faults injected {} | detected {} | repaired {} | quarantined rows {} | \
             zeroed rows {} | stager fallbacks {} | worker rebuilds {} | timeouts {} | \
             upset bits {} | corrupt found {}",
            r.faults_injected,
            r.faults_detected,
            r.faults_repaired,
            r.quarantined_rows,
            r.zeroed_rows,
            r.stager_fallbacks,
            r.worker_rebuilds,
            r.timed_out_requests,
            r.upset_bits,
            r.corrupt_bits_found,
        );
    }
    if r.scrub_stripe_total > 0 {
        // coverage = full sweeps of the resident stripe space completed
        // by the incremental scheduler across all workers
        println!(
            "scrub: stripes checked {} / space {} | coverage x{:.1}",
            r.scrub_stripes_checked,
            r.scrub_stripe_total,
            r.scrub_stripes_checked as f64 / r.scrub_stripe_total as f64,
        );
    }
    0
}
