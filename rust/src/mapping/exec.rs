//! Functional layer executor: runs whole conv layers through the
//! bit-true [`PimMacro`] using the paper's mapping strategies, and
//! recovers outputs in the merge unit/ARU.
//!
//! This is the correctness proof of the co-design: for every mapping
//! mode the recovered outputs must equal the direct convolution with the
//! *full* (biased-comp) filter bank, even though only half the filters
//! were ever written into the array.

use crate::arch::lpu::Mode;
use crate::arch::merge::aru_recover;
use crate::arch::pim_macro::PimMacro;
use crate::arch::reconfig::Grouping;
use crate::fcc::FccWeights;

use super::im2col::{im2col, im2col_channel};

/// std/pw-conv in double computing mode with FCC weights (paper Fig. 10).
///
/// Only the even comp filters are loaded; INP and INN carry the same
/// vector-wise input; the ARU recovers both twins of every pair.
/// Returns `[P, N]` i64 outputs equal to conv with the biased-comp bank.
pub fn exec_std_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let l = k * k * c;
    assert_eq!(fcc.comp.l, l, "filter length mismatch");
    let n = fcc.comp.n;
    let pairs = n / 2;
    let (cols, oh, ow) = im2col(input, h, w, c, k, stride);
    let pixels = oh * ow;

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let slots = mac.core.slots();
    let rows = mac.core.rows();
    let l_tiles = l.div_ceil(cmp);
    let groups = pairs.div_ceil(slots);

    let mut out = vec![0i64; pixels * n];
    // iterate groups in row-capacity chunks (weight reload passes)
    let groups_per_pass = (rows / l_tiles).max(1);
    let mut g0 = 0;
    while g0 < groups {
        let g1 = (g0 + groups_per_pass).min(groups);
        // ---- load pass: write even comp filters (normal SRAM mode)
        for g in g0..g1 {
            for ti in 0..l_tiles {
                let row = (g - g0) * l_tiles + ti;
                for cc in 0..cmp {
                    let li = ti * cmp + cc;
                    for s in 0..slots {
                        let p = g * slots + s; // stored pair index
                        let wv = if p < pairs && li < l {
                            fcc.comp.filter(2 * p)[li]
                        } else {
                            0
                        };
                        mac.load_weight(cc, row, s, wv);
                    }
                }
            }
        }
        // ---- compute pass: stream all pixels (weight stationary)
        for px in 0..pixels {
            let window = &cols[px * l..(px + 1) * l];
            let sum_i: i64 = window.iter().map(|&x| x as i64).sum();
            for g in g0..g1 {
                let mut psum = vec![(0i64, 0i64); slots];
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    let inputs: Vec<i32> = (0..cmp)
                        .map(|cc| {
                            let li = ti * cmp + cc;
                            if li < l {
                                window[li]
                            } else {
                                0
                            }
                        })
                        .collect();
                    let ps = mac.mvm_row(row, &inputs, &inputs, Mode::Double, Grouping::Combined);
                    for s in 0..slots {
                        psum[s].0 += ps[0][s].q;
                        psum[s].1 += ps[0][s].qbar;
                    }
                }
                for s in 0..slots {
                    let p = g * slots + s;
                    if p >= pairs {
                        continue;
                    }
                    let m = fcc.means[p] as i64;
                    let (even, odd) = aru_recover(psum[s].0, psum[s].1, sum_i, sum_i, m);
                    out[px * n + 2 * p] = even;
                    out[px * n + 2 * p + 1] = odd;
                }
            }
        }
        g0 = g1;
    }
    out
}

/// std/pw-conv in regular computing mode (PIM baseline): full filter
/// bank loaded, Q path only, ARU bypassed.
pub fn exec_std_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [N, L]
    n: usize,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let l = k * k * c;
    let (cols, oh, ow) = im2col(input, h, w, c, k, stride);
    let pixels = oh * ow;

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let slots = mac.core.slots();
    let rows = mac.core.rows();
    let l_tiles = l.div_ceil(cmp);
    let groups = n.div_ceil(slots);
    let groups_per_pass = (rows / l_tiles).max(1);

    let mut out = vec![0i64; pixels * n];
    let zeros = vec![0i32; cmp];
    let mut g0 = 0;
    while g0 < groups {
        let g1 = (g0 + groups_per_pass).min(groups);
        for g in g0..g1 {
            for ti in 0..l_tiles {
                let row = (g - g0) * l_tiles + ti;
                for cc in 0..cmp {
                    let li = ti * cmp + cc;
                    for s in 0..slots {
                        let f = g * slots + s;
                        let wv = if f < n && li < l { filters[f * l + li] } else { 0 };
                        mac.load_weight(cc, row, s, wv);
                    }
                }
            }
        }
        for px in 0..pixels {
            let window = &cols[px * l..(px + 1) * l];
            for g in g0..g1 {
                let mut psum = vec![0i64; slots];
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    let inputs: Vec<i32> = (0..cmp)
                        .map(|cc| {
                            let li = ti * cmp + cc;
                            if li < l {
                                window[li]
                            } else {
                                0
                            }
                        })
                        .collect();
                    let ps = mac.mvm_row(row, &inputs, &zeros, Mode::Regular, Grouping::Combined);
                    for s in 0..slots {
                        psum[s] += ps[0][s].q;
                    }
                }
                for s in 0..slots {
                    let f = g * slots + s;
                    if f < n {
                        out[px * n + f] = psum[s];
                    }
                }
            }
        }
        g0 = g1;
    }
    out
}

/// dw-conv with FCC + DBIS (+ optionally the reconfigurable unit's
/// split-grouping / padded mapping, paper Fig. 11).
///
/// * `reconfig = false` — one channel *pair* per row-step: the stored
///   even comp filter occupies compartments `0..k*k`; INP carries the
///   even channel's window, INN the odd channel's (parallelism 9x1x16).
/// * `reconfig = true` — two pairs per row-step: pair A in compartments
///   `0..k*k`, pair B in `16..16+k*k`, two alternating stages over the
///   two weight slots (parallelism 18x1x16; 8 channels per stored row).
pub fn exec_dw_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
    k: usize,
    stride: usize,
    reconfig: bool,
) -> Vec<i64> {
    let taps = k * k;
    assert_eq!(fcc.comp.l, taps);
    assert_eq!(fcc.comp.n, c);
    let pairs = c / 2;
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pixels = oh * ow;

    // per-channel im2col windows
    let windows: Vec<Vec<i32>> = (0..c)
        .map(|ch| im2col_channel(input, h, w, c, ch, k, stride).0)
        .collect();

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let mut out = vec![0i64; pixels * c];

    if reconfig && 2 * taps <= cmp {
        // 4 pairs per stored row: (g0 slot0, g0 slot1, g1 slot0, g1 slot1)
        let half = cmp / 2;
        let row_groups = pairs.div_ceil(4);
        for rg in 0..row_groups {
            let row = rg % mac.core.rows();
            // load: group half g in {0,1}, slot s in {0,1}
            for cc in 0..cmp {
                for s in 0..2 {
                    let (ghalf, off) = if cc < half { (0, cc) } else { (1, cc - half) };
                    // layout: stage s selects slot s; half 0 computes
                    // pair (4rg+2s), half 1 pair (4rg+2s+1)
                    let p = rg * 4 + 2 * s + ghalf;
                    let wv = if p < pairs && off < taps {
                        fcc.comp.filter(2 * p)[off]
                    } else {
                        0
                    };
                    mac.load_weight(cc, row, s, wv);
                }
            }
            for px in 0..pixels {
                // two stages, alternating slots
                for s in 0..2 {
                    let pa = rg * 4 + 2 * s; // half 0 pair
                    let pb = rg * 4 + 2 * s + 1; // half 1 pair
                    let mut inp = vec![0i32; cmp];
                    let mut inn = vec![0i32; cmp];
                    for (half_id, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        for t in 0..taps {
                            let ccx = half_id * half + t;
                            inp[ccx] = windows[2 * p][px * taps + t];
                            inn[ccx] = windows[2 * p + 1][px * taps + t];
                        }
                    }
                    let ps = mac.mvm_row(row, &inp, &inn, Mode::Double, Grouping::Split);
                    for (ghalf, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        let m = fcc.means[p] as i64;
                        let sp: i64 = (0..taps)
                            .map(|t| windows[2 * p][px * taps + t] as i64)
                            .sum();
                        let sn: i64 = (0..taps)
                            .map(|t| windows[2 * p + 1][px * taps + t] as i64)
                            .sum();
                        let (even, odd) = aru_recover(ps[ghalf][s].q, ps[ghalf][s].qbar, sp, sn, m);
                        out[px * c + 2 * p] = even;
                        out[px * c + 2 * p + 1] = odd;
                    }
                }
            }
        }
    } else {
        // DBIS-only: one pair per row-step in compartments 0..taps
        for p in 0..pairs {
            let row = p % mac.core.rows();
            for cc in 0..cmp {
                let wv = if cc < taps { fcc.comp.filter(2 * p)[cc] } else { 0 };
                mac.load_weight(cc, row, 0, wv);
                mac.load_weight(cc, row, 1, 0);
            }
            for px in 0..pixels {
                let mut inp = vec![0i32; cmp];
                let mut inn = vec![0i32; cmp];
                for t in 0..taps {
                    inp[t] = windows[2 * p][px * taps + t];
                    inn[t] = windows[2 * p + 1][px * taps + t];
                }
                let ps = mac.mvm_row(row, &inp, &inn, Mode::Double, Grouping::Combined);
                let m = fcc.means[p] as i64;
                let sp: i64 = inp.iter().map(|&x| x as i64).sum();
                let sn: i64 = inn.iter().map(|&x| x as i64).sum();
                let (even, odd) = aru_recover(ps[0][0].q, ps[0][0].qbar, sp, sn, m);
                out[px * c + 2 * p] = even;
                out[px * c + 2 * p + 1] = odd;
            }
        }
    }
    out
}

/// dw-conv baseline: one channel per row-step, regular mode.
pub fn exec_dw_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [C, K*K]
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let taps = k * k;
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pixels = oh * ow;
    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let zeros = vec![0i32; cmp];
    let mut out = vec![0i64; pixels * c];
    for ch in 0..c {
        let row = ch % mac.core.rows();
        for cc in 0..cmp {
            let wv = if cc < taps { filters[ch * taps + cc] } else { 0 };
            mac.load_weight(cc, row, 0, wv);
            mac.load_weight(cc, row, 1, 0);
        }
        let (win, _, _) = im2col_channel(input, h, w, c, ch, k, stride);
        for px in 0..pixels {
            let mut inp = vec![0i32; cmp];
            inp[..taps].copy_from_slice(&win[px * taps..(px + 1) * taps]);
            let ps = mac.mvm_row(row, &inp, &zeros, Mode::Regular, Grouping::Combined);
            out[px * c + ch] = ps[0][0].q;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::{fcc_transform, FilterBank};
    use crate::mapping::im2col::{direct_conv, direct_dwconv};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.int8() as i32).collect()
    }

    /// direct conv with the biased-comp bank = the FCC ground truth
    fn fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let n = fcc.comp.n;
        let l = fcc.comp.l;
        let mut bc = vec![0i32; n * l];
        for p in 0..n / 2 {
            for i in 0..l {
                bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_conv(input, h, w, c, &bc, n, k, stride)
    }

    #[test]
    fn std_fcc_matches_direct_conv() {
        let mut rng = Rng::new(91);
        let (h, w, c, k, n) = (4, 4, 3, 3, 8);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_fcc_pointwise_many_filters_multipass() {
        // enough filters to force multiple groups and a reload pass
        let mut rng = Rng::new(92);
        let (h, w, c, k, n) = (3, 3, 40, 1, 12);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_regular_matches_direct_conv() {
        let mut rng = Rng::new(93);
        let (h, w, c, k, n) = (4, 4, 2, 3, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, n * k * k * c);
        let got = exec_std_regular(&input, h, w, c, &filters, n, k, 1);
        let want = direct_conv(&input, h, w, c, &filters, n, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_stride2() {
        let mut rng = Rng::new(94);
        let (h, w, c, k, n) = (5, 5, 3, 3, 4);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        assert_eq!(
            exec_std_fcc(&input, h, w, c, &fcc, k, 2),
            fcc_oracle(&input, h, w, c, &fcc, k, 2)
        );
    }

    fn dw_fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let taps = k * k;
        let mut bc = vec![0i32; c * taps];
        for p in 0..c / 2 {
            for i in 0..taps {
                bc[(2 * p) * taps + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * taps + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_dwconv(input, h, w, c, &bc, k, stride)
    }

    #[test]
    fn dw_fcc_dbis_matches_direct() {
        let mut rng = Rng::new(95);
        let (h, w, c, k) = (4, 4, 6, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, false);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_matches_direct() {
        let mut rng = Rng::new(96);
        let (h, w, c, k) = (4, 4, 16, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_odd_pair_tail() {
        // pairs not divisible by 4 exercises the tail handling
        let mut rng = Rng::new(97);
        let (h, w, c, k) = (3, 3, 10, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_regular_matches_direct() {
        let mut rng = Rng::new(98);
        let (h, w, c, k) = (4, 4, 5, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, c * k * k);
        let got = exec_dw_regular(&input, h, w, c, &filters, k, 1);
        let want = direct_dwconv(&input, h, w, c, &filters, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_5x5_falls_back_to_dbis() {
        // 5x5 taps don't fit twice -> reconfig path must still be correct
        // via the DBIS fallback inside exec_dw_fcc
        let mut rng = Rng::new(99);
        let (h, w, c, k) = (5, 5, 4, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }
}
