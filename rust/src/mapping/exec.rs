//! Functional layer executor: runs whole conv layers through the
//! bit-true [`PimMacro`] using the paper's mapping strategies, and
//! recovers outputs in the merge unit/ARU.
//!
//! This is the correctness proof of the co-design: for every mapping
//! mode the recovered outputs must equal the direct convolution with the
//! *full* (biased-comp) filter bank, even though only half the filters
//! were ever written into the array.
//!
//! Hot-loop discipline (§Performance architecture in DESIGN.md): each
//! executor owns one [`MvmScratch`] for the whole layer, per-pixel
//! window sums are computed once at im2col time (they are group- and
//! pass-invariant), tile inputs are streamed as im2col slices (the
//! macro zero-extends short tails), and pixels are processed in
//! [`PIXEL_BLOCK`]-sized runs per loaded row so a weight pass streams
//! activations cache-friendly.  No allocation happens inside the
//! per-pixel loops.

use crate::arch::lpu::Mode;
use crate::arch::merge::aru_recover;
use crate::arch::pim_macro::{MvmScratch, PimMacro};
use crate::arch::reconfig::Grouping;
use crate::fcc::FccWeights;

use super::im2col::{im2col, im2col_channel};

/// Pixels streamed per loaded (row, slot) pass: the row's bit-planes
/// stay register/L1-hot while this many activation windows flow past.
const PIXEL_BLOCK: usize = 64;

/// Per-pixel window sums (the ΣI the pre-process unit feeds the ARU),
/// computed once over the im2col matrix `cols` (`[P, l]` row-major).
///
/// The sum depends only on the pixel window — not on the filter group
/// or the weight-reload pass — so the executors compute it here exactly
/// once instead of re-reducing the window inside the (pass, group,
/// pixel) loops as the scalar executor did.
pub fn window_sums(cols: &[i32], l: usize) -> Vec<i64> {
    assert!(l > 0 && cols.len() % l == 0, "im2col shape mismatch");
    cols.chunks_exact(l)
        .map(|w| w.iter().map(|&x| x as i64).sum())
        .collect()
}

/// std/pw-conv in double computing mode with FCC weights (paper Fig. 10).
///
/// Only the even comp filters are loaded; INP and INN carry the same
/// vector-wise input; the ARU recovers both twins of every pair.
/// Returns `[P, N]` i64 outputs equal to conv with the biased-comp bank.
pub fn exec_std_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let l = k * k * c;
    assert_eq!(fcc.comp.l, l, "filter length mismatch");
    let n = fcc.comp.n;
    let pairs = n / 2;
    let (cols, oh, ow) = im2col(input, h, w, c, k, stride);
    let pixels = oh * ow;
    let win_sums = window_sums(&cols, l);

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let slots = mac.core.slots();
    let rows = mac.core.rows();
    let l_tiles = l.div_ceil(cmp);
    let groups = pairs.div_ceil(slots);

    let mut out = vec![0i64; pixels * n];
    let mut scratch = MvmScratch::new();
    // per-(pixel-in-block, slot) psum accumulators, reused across blocks
    let mut blk = Vec::new();
    // iterate groups in row-capacity chunks (weight reload passes)
    let groups_per_pass = (rows / l_tiles).max(1);
    let mut g0 = 0;
    while g0 < groups {
        let g1 = (g0 + groups_per_pass).min(groups);
        // ---- load pass: write even comp filters (normal SRAM mode)
        for g in g0..g1 {
            for ti in 0..l_tiles {
                let row = (g - g0) * l_tiles + ti;
                for cc in 0..cmp {
                    let li = ti * cmp + cc;
                    for s in 0..slots {
                        let p = g * slots + s; // stored pair index
                        let wv = if p < pairs && li < l {
                            fcc.comp.filter(2 * p)[li]
                        } else {
                            0
                        };
                        mac.load_weight(cc, row, s, wv);
                    }
                }
            }
        }
        // ---- compute pass: stream pixel blocks (weight stationary)
        let mut pb0 = 0;
        while pb0 < pixels {
            let pb1 = (pb0 + PIXEL_BLOCK).min(pixels);
            for g in g0..g1 {
                blk.clear();
                blk.resize((pb1 - pb0) * slots, (0i64, 0i64));
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    let lo = ti * cmp;
                    let hi = ((ti + 1) * cmp).min(l);
                    for px in pb0..pb1 {
                        let tile = &cols[px * l + lo..px * l + hi];
                        mac.mvm_row_into(
                            row,
                            tile,
                            tile,
                            Mode::Double,
                            Grouping::Combined,
                            &mut scratch,
                        );
                        let base = (px - pb0) * slots;
                        for s in 0..slots {
                            let ps = scratch.psum(0, s);
                            blk[base + s].0 += ps.q;
                            blk[base + s].1 += ps.qbar;
                        }
                    }
                }
                for px in pb0..pb1 {
                    let base = (px - pb0) * slots;
                    for s in 0..slots {
                        let p = g * slots + s;
                        if p >= pairs {
                            continue;
                        }
                        let m = fcc.means[p] as i64;
                        let (q, qbar) = blk[base + s];
                        let (even, odd) = aru_recover(q, qbar, win_sums[px], win_sums[px], m);
                        out[px * n + 2 * p] = even;
                        out[px * n + 2 * p + 1] = odd;
                    }
                }
            }
            pb0 = pb1;
        }
        g0 = g1;
    }
    out
}

/// std/pw-conv in regular computing mode (PIM baseline): full filter
/// bank loaded, Q path only, ARU bypassed.
pub fn exec_std_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [N, L]
    n: usize,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let l = k * k * c;
    let (cols, oh, ow) = im2col(input, h, w, c, k, stride);
    let pixels = oh * ow;

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let slots = mac.core.slots();
    let rows = mac.core.rows();
    let l_tiles = l.div_ceil(cmp);
    let groups = n.div_ceil(slots);
    let groups_per_pass = (rows / l_tiles).max(1);

    let mut out = vec![0i64; pixels * n];
    let mut scratch = MvmScratch::new();
    let mut blk = Vec::new();
    let mut g0 = 0;
    while g0 < groups {
        let g1 = (g0 + groups_per_pass).min(groups);
        for g in g0..g1 {
            for ti in 0..l_tiles {
                let row = (g - g0) * l_tiles + ti;
                for cc in 0..cmp {
                    let li = ti * cmp + cc;
                    for s in 0..slots {
                        let f = g * slots + s;
                        let wv = if f < n && li < l { filters[f * l + li] } else { 0 };
                        mac.load_weight(cc, row, s, wv);
                    }
                }
            }
        }
        let mut pb0 = 0;
        while pb0 < pixels {
            let pb1 = (pb0 + PIXEL_BLOCK).min(pixels);
            for g in g0..g1 {
                blk.clear();
                blk.resize((pb1 - pb0) * slots, 0i64);
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    let lo = ti * cmp;
                    let hi = ((ti + 1) * cmp).min(l);
                    for px in pb0..pb1 {
                        let tile = &cols[px * l + lo..px * l + hi];
                        mac.mvm_row_into(
                            row,
                            tile,
                            &[],
                            Mode::Regular,
                            Grouping::Combined,
                            &mut scratch,
                        );
                        let base = (px - pb0) * slots;
                        for s in 0..slots {
                            blk[base + s] += scratch.psum(0, s).q;
                        }
                    }
                }
                for px in pb0..pb1 {
                    let base = (px - pb0) * slots;
                    for s in 0..slots {
                        let f = g * slots + s;
                        if f < n {
                            out[px * n + f] = blk[base + s];
                        }
                    }
                }
            }
            pb0 = pb1;
        }
        g0 = g1;
    }
    out
}

/// dw-conv with FCC + DBIS (+ optionally the reconfigurable unit's
/// split-grouping / padded mapping, paper Fig. 11).
///
/// * `reconfig = false` — one channel *pair* per row-step: the stored
///   even comp filter occupies compartments `0..k*k`; INP carries the
///   even channel's window, INN the odd channel's (parallelism 9x1x16).
/// * `reconfig = true` — two pairs per row-step: pair A in compartments
///   `0..k*k`, pair B in `16..16+k*k`, two alternating stages over the
///   two weight slots (parallelism 18x1x16; 8 channels per stored row).
pub fn exec_dw_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
    k: usize,
    stride: usize,
    reconfig: bool,
) -> Vec<i64> {
    let taps = k * k;
    assert_eq!(fcc.comp.l, taps);
    assert_eq!(fcc.comp.n, c);
    let pairs = c / 2;
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pixels = oh * ow;

    // per-channel im2col windows + their pixel sums (ΣI per stream)
    let windows: Vec<Vec<i32>> = (0..c)
        .map(|ch| im2col_channel(input, h, w, c, ch, k, stride).0)
        .collect();
    let win_sums: Vec<Vec<i64>> = windows.iter().map(|wn| window_sums(wn, taps)).collect();

    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let mut scratch = MvmScratch::new();
    let mut out = vec![0i64; pixels * c];

    if reconfig && 2 * taps <= cmp {
        // 4 pairs per stored row: (g0 slot0, g0 slot1, g1 slot0, g1 slot1)
        let half = cmp / 2;
        let row_groups = pairs.div_ceil(4);
        let mut inp = vec![0i32; cmp];
        let mut inn = vec![0i32; cmp];
        for rg in 0..row_groups {
            let row = rg % mac.core.rows();
            // load: group half g in {0,1}, slot s in {0,1}
            for cc in 0..cmp {
                for s in 0..2 {
                    let (ghalf, off) = if cc < half { (0, cc) } else { (1, cc - half) };
                    // layout: stage s selects slot s; half 0 computes
                    // pair (4rg+2s), half 1 pair (4rg+2s+1)
                    let p = rg * 4 + 2 * s + ghalf;
                    let wv = if p < pairs && off < taps {
                        fcc.comp.filter(2 * p)[off]
                    } else {
                        0
                    };
                    mac.load_weight(cc, row, s, wv);
                }
            }
            for px in 0..pixels {
                // two stages, alternating slots
                for s in 0..2 {
                    let pa = rg * 4 + 2 * s; // half 0 pair
                    let pb = rg * 4 + 2 * s + 1; // half 1 pair
                    inp.fill(0);
                    inn.fill(0);
                    for (half_id, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        for t in 0..taps {
                            let ccx = half_id * half + t;
                            inp[ccx] = windows[2 * p][px * taps + t];
                            inn[ccx] = windows[2 * p + 1][px * taps + t];
                        }
                    }
                    mac.mvm_row_into(row, &inp, &inn, Mode::Double, Grouping::Split, &mut scratch);
                    for (ghalf, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        let m = fcc.means[p] as i64;
                        let sp = win_sums[2 * p][px];
                        let sn = win_sums[2 * p + 1][px];
                        let ps = scratch.psum(ghalf, s);
                        let (even, odd) = aru_recover(ps.q, ps.qbar, sp, sn, m);
                        out[px * c + 2 * p] = even;
                        out[px * c + 2 * p + 1] = odd;
                    }
                }
            }
        }
    } else {
        // DBIS-only: one pair per row-step in compartments 0..taps
        for p in 0..pairs {
            let row = p % mac.core.rows();
            for cc in 0..cmp {
                let wv = if cc < taps { fcc.comp.filter(2 * p)[cc] } else { 0 };
                mac.load_weight(cc, row, 0, wv);
                mac.load_weight(cc, row, 1, 0);
            }
            let m = fcc.means[p] as i64;
            for px in 0..pixels {
                let inp = &windows[2 * p][px * taps..(px + 1) * taps];
                let inn = &windows[2 * p + 1][px * taps..(px + 1) * taps];
                mac.mvm_row_into(row, inp, inn, Mode::Double, Grouping::Combined, &mut scratch);
                let ps = scratch.psum(0, 0);
                let sp = win_sums[2 * p][px];
                let sn = win_sums[2 * p + 1][px];
                let (even, odd) = aru_recover(ps.q, ps.qbar, sp, sn, m);
                out[px * c + 2 * p] = even;
                out[px * c + 2 * p + 1] = odd;
            }
        }
    }
    out
}

/// dw-conv baseline: one channel per row-step, regular mode.
pub fn exec_dw_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [C, K*K]
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let taps = k * k;
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let pixels = oh * ow;
    let mut mac = PimMacro::paper();
    let cmp = mac.core.num_compartments();
    let mut scratch = MvmScratch::new();
    let mut out = vec![0i64; pixels * c];
    for ch in 0..c {
        let row = ch % mac.core.rows();
        for cc in 0..cmp {
            let wv = if cc < taps { filters[ch * taps + cc] } else { 0 };
            mac.load_weight(cc, row, 0, wv);
            mac.load_weight(cc, row, 1, 0);
        }
        let (win, _, _) = im2col_channel(input, h, w, c, ch, k, stride);
        for px in 0..pixels {
            let window = &win[px * taps..(px + 1) * taps];
            mac.mvm_row_into(row, window, &[], Mode::Regular, Grouping::Combined, &mut scratch);
            out[px * c + ch] = scratch.psum(0, 0).q;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::{fcc_transform, FilterBank};
    use crate::mapping::im2col::{direct_conv, direct_dwconv};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.int8() as i32).collect()
    }

    /// direct conv with the biased-comp bank = the FCC ground truth
    fn fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let n = fcc.comp.n;
        let l = fcc.comp.l;
        let mut bc = vec![0i32; n * l];
        for p in 0..n / 2 {
            for i in 0..l {
                bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_conv(input, h, w, c, &bc, n, k, stride)
    }

    #[test]
    fn std_fcc_matches_direct_conv() {
        let mut rng = Rng::new(91);
        let (h, w, c, k, n) = (4, 4, 3, 3, 8);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_fcc_pointwise_many_filters_multipass() {
        // enough filters to force multiple groups and a reload pass
        let mut rng = Rng::new(92);
        let (h, w, c, k, n) = (3, 3, 40, 1, 12);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_fcc_more_pixels_than_one_block() {
        // 18x18 output = 324 pixels > PIXEL_BLOCK exercises block seams
        let mut rng = Rng::new(90);
        let (h, w, c, k, n) = (18, 18, 2, 3, 4);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_regular_matches_direct_conv() {
        let mut rng = Rng::new(93);
        let (h, w, c, k, n) = (4, 4, 2, 3, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, n * k * k * c);
        let got = exec_std_regular(&input, h, w, c, &filters, n, k, 1);
        let want = direct_conv(&input, h, w, c, &filters, n, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_stride2() {
        let mut rng = Rng::new(94);
        let (h, w, c, k, n) = (5, 5, 3, 3, 4);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        assert_eq!(
            exec_std_fcc(&input, h, w, c, &fcc, k, 2),
            fcc_oracle(&input, h, w, c, &fcc, k, 2)
        );
    }

    #[test]
    fn window_sum_group_invariant() {
        // the ΣI fed to the ARU depends only on the pixel window: the
        // precomputed sums must equal a per-(pixel, group) recomputation
        // for every group (regression test for the duplicated-reduction
        // bug in the scalar executor)
        let mut rng = Rng::new(89);
        let (h, w, c, k) = (5, 4, 3, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let l = k * k * c;
        let (cols, oh, ow) = im2col(&input, h, w, c, k, 1);
        let sums = window_sums(&cols, l);
        assert_eq!(sums.len(), oh * ow);
        let groups = 6; // any per-group recomputation must agree
        for px in 0..oh * ow {
            for _g in 0..groups {
                let per_group: i64 = cols[px * l..(px + 1) * l].iter().map(|&x| x as i64).sum();
                assert_eq!(per_group, sums[px], "ΣI drifted at pixel {px}");
            }
        }
    }

    fn dw_fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let taps = k * k;
        let mut bc = vec![0i32; c * taps];
        for p in 0..c / 2 {
            for i in 0..taps {
                bc[(2 * p) * taps + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * taps + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_dwconv(input, h, w, c, &bc, k, stride)
    }

    #[test]
    fn dw_fcc_dbis_matches_direct() {
        let mut rng = Rng::new(95);
        let (h, w, c, k) = (4, 4, 6, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, false);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_matches_direct() {
        let mut rng = Rng::new(96);
        let (h, w, c, k) = (4, 4, 16, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_odd_pair_tail() {
        // pairs not divisible by 4 exercises the tail handling
        let mut rng = Rng::new(97);
        let (h, w, c, k) = (3, 3, 10, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_regular_matches_direct() {
        let mut rng = Rng::new(98);
        let (h, w, c, k) = (4, 4, 5, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, c * k * k);
        let got = exec_dw_regular(&input, h, w, c, &filters, k, 1);
        let want = direct_dwconv(&input, h, w, c, &filters, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_5x5_falls_back_to_dbis() {
        // 5x5 taps don't fit twice -> reconfig path must still be correct
        // via the DBIS fallback inside exec_dw_fcc
        let mut rng = Rng::new(99);
        let (h, w, c, k) = (5, 5, 4, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }
}
