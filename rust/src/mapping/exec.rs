//! Functional layer executor: runs whole conv layers through the
//! bit-true [`PimMacro`] using the paper's mapping strategies, and
//! recovers outputs in the merge unit/ARU.
//!
//! This is the correctness proof of the co-design: for every mapping
//! mode the recovered outputs must equal the direct convolution with the
//! *full* (biased-comp) filter bank, even though only half the filters
//! were ever written into the array.
//!
//! # Plan/execute lifecycle
//!
//! The paper's whole point is that weight residency is precious, so the
//! executor is split in two phases (DESIGN.md §Plan/execute lifecycle):
//!
//! * **plan** — [`PlannedConv`] / [`PlannedDwConv`] are built once per
//!   layer.  Building runs every weight-load pass: each pass owns a
//!   [`PimMacro`] with its filter group resident, so SRAM weights are
//!   written exactly once per layer per session (testable via
//!   [`PlannedConv::weight_writes`]).
//! * **execute** — `execute(&self, input, &mut ExecCtx, &mut out)`
//!   streams one input through the resident weights.  It takes `&self`,
//!   so it *cannot* write weights, and per-lane [`ExecCtx`] clones let
//!   [`PlannedConv::execute_par`] / [`PlannedDwConv::execute_par`]
//!   shard the pixel blocks of every resident weight pass across an
//!   [`ExecPool`] without touching the plan.  Every `(pass, block)`
//!   unit writes a disjoint slice of `out` and reads only the shared
//!   staging, so parallel results are byte-identical to the serial
//!   path at every pool width — and `execute_batch_par` folds a whole
//!   batch into the pixel dimension, streaming all images of a batch
//!   through one resident pass (the session-batching path).
//!
//! All reusable buffers (im2col columns, window sums, [`MvmScratch`],
//! pixel-block psums) live in the caller-owned [`ExecCtx`]; after the
//! first execute at a given shape, execute performs no heap allocation.
//!
//! Hot-loop discipline (§Performance architecture in DESIGN.md):
//! per-pixel window sums are computed once at im2col time (they are
//! group- and pass-invariant), tile inputs are streamed as im2col slices
//! (the macro zero-extends short tails), and pixels are processed in
//! [`PIXEL_BLOCK`]-sized runs per resident row so a weight pass streams
//! activations cache-friendly.
//!
//! The original one-shot entry points ([`exec_std_fcc`] & friends) are
//! thin wrappers — plan, execute once, return — so callers migrate
//! without semantic drift.

use crate::arch::fault::{FaultConfig, FaultPlan, FaultTally, ScrubReport, UpsetConfig};
use crate::arch::lpu::Mode;
use crate::arch::merge::aru_recover;
use crate::arch::pim_core::MacroGeometry;
use crate::arch::pim_macro::{MvmScratch, PimMacro};
use crate::arch::reconfig::Grouping;
use crate::fcc::FccWeights;
use crate::util::pool::{SharedMut, WorkPool};

use super::im2col::{im2col_channel_into, im2col_into, out_dims};

/// Pixels streamed per resident (row, slot) pass: the row's bit-planes
/// stay register/L1-hot while this many activation windows flow past.
pub const PIXEL_BLOCK: usize = 64;

/// Caller-owned scratch for the planned executors: every buffer the
/// per-pixel loops touch, reused across `execute` calls (and across
/// plans — buffers are re-sized, never assumed clean).  One `ExecCtx`
/// per executor thread; `execute` borrows it mutably while the plan
/// itself stays shared.
#[derive(Debug, Clone, Default)]
pub struct ExecCtx {
    /// im2col matrix `[P, L]` of the current input.
    cols: Vec<i32>,
    /// Per-pixel window sums (ΣI for the ARU), std path.
    win_sums: Vec<i64>,
    /// Bit-serial row scratch (psums + packed input planes).
    scratch: MvmScratch,
    /// Per-(pixel-in-block, slot) psum accumulators.
    blk: Vec<(i64, i64)>,
    /// Per-channel dw windows, flattened `[C][P, K*K]`.
    dw_windows: Vec<i32>,
    /// Per-channel dw window sums, flattened `[C][P]`.
    dw_sums: Vec<i64>,
    /// Reconfig-mode stage input staging (INP broadcast).
    inp: Vec<i32>,
    /// Reconfig-mode stage input staging (INN broadcast).
    inn: Vec<i32>,
}

impl ExecCtx {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The host-parallel execution handle: a [`WorkPool`] plus the scratch
/// it needs — one shared [`ExecCtx`] for the caller-staged read-only
/// buffers (im2col columns, window sums) and one persistent per-lane
/// [`ExecCtx`] clone for each pool lane's private psum/scratch
/// buffers.  The per-lane contexts are kept warm across calls, so the
/// zero-steady-state-allocation property of the serial path survives
/// parallel dispatch (`tests/alloc_steady_state.rs`).
///
/// Width 1 spawns no threads and routes `execute_par` through exactly
/// the serial block walk; widths > 1 are byte-identical to it because
/// every work unit writes a disjoint output slice.
pub struct ExecPool {
    pool: WorkPool,
    /// Caller-staged buffers shared read-only during dispatch.
    shared: ExecCtx,
    /// One private scratch per pool lane (`per.len() == width()`).
    per: Vec<ExecCtx>,
}

impl ExecPool {
    /// Build a pool of `threads` lanes (clamped to 1..=64 by the
    /// underlying [`WorkPool`]; the caller thread is lane 0).
    pub fn new(threads: usize) -> ExecPool {
        let pool = WorkPool::new(threads);
        let per = (0..pool.width()).map(|_| ExecCtx::new()).collect();
        ExecPool {
            pool,
            shared: ExecCtx::new(),
            per,
        }
    }

    /// Total lanes, caller included.
    pub fn width(&self) -> usize {
        self.pool.width()
    }

    /// Scoped access to the underlying [`WorkPool`] for callers that
    /// shard non-conv work across the same lanes (e.g. the dense MVM
    /// row blocks in `runtime/reference.rs`): `f(lane, unit)` runs
    /// exactly once per `unit in 0..units`, with the same disjoint-
    /// write obligations as the conv executors.
    pub fn run<F: Fn(usize, usize) + Sync>(&mut self, units: usize, f: &F) {
        self.pool.run(units, f)
    }
}

/// Per-pixel window sums (the ΣI the pre-process unit feeds the ARU)
/// over the im2col matrix `cols` (`[P, l]` row-major), into a reusable
/// buffer.
///
/// The sum depends only on the pixel window — not on the filter group
/// or the weight-reload pass — so the executors compute it here exactly
/// once instead of re-reducing the window inside the (pass, group,
/// pixel) loops as the scalar executor did.
pub fn window_sums_into(out: &mut Vec<i64>, cols: &[i32], l: usize) {
    assert!(l > 0 && cols.len() % l == 0, "im2col shape mismatch");
    // resize only (no clear): every element is overwritten below
    out.resize(cols.len() / l, 0);
    for (dst, wdw) in out.iter_mut().zip(cols.chunks_exact(l)) {
        *dst = wdw.iter().map(|&x| x as i64).sum();
    }
}

/// Allocating wrapper over [`window_sums_into`].
pub fn window_sums(cols: &[i32], l: usize) -> Vec<i64> {
    let mut out = Vec::new();
    window_sums_into(&mut out, cols, l);
    out
}

/// Stored INT8 weight bytes of a conv layer with `n` output channels
/// and reduction length `l`: FCC stores only the even comp filters
/// (`n/2 * l` — the paper's capacity doubling), regular mode the full
/// bank.  The streaming planner budgets layer footprints with this
/// before any plan is built.
pub fn stored_weight_bytes(n: usize, l: usize, fcc: bool) -> usize {
    if fcc {
        (n / 2) * l
    } else {
        n * l
    }
}

/// Split a layer stack into weight-reload passes that fit a capacity
/// budget: a greedy left-to-right walk packs consecutive layers while
/// their cumulative footprint stays within `budget_bytes`, and starts a
/// new pass otherwise.  A single layer larger than the whole budget
/// still gets its own pass (a stack cannot split finer than one layer —
/// the executor stages it anyway and reports occupancy > 1).
///
/// Returns the pass boundaries as index ranges over `footprints`; an
/// empty input yields no passes.  Deterministic, so the pass counts the
/// differential tests pin ({1, 2, 4} in `tests/streaming_semantics.rs`)
/// are stable across hosts.
pub fn plan_reload_passes(
    footprints: &[usize],
    budget_bytes: usize,
) -> Vec<std::ops::Range<usize>> {
    let mut passes = Vec::new();
    let mut start = 0;
    let mut acc = 0usize;
    for (i, &bytes) in footprints.iter().enumerate() {
        if i > start && acc + bytes > budget_bytes {
            passes.push(start..i);
            start = i;
            acc = 0;
        }
        acc += bytes;
    }
    if start < footprints.len() {
        passes.push(start..footprints.len());
    }
    passes
}

/// One weight-reload pass of a std/pw plan: the filter groups
/// `[g0, g1)` resident in their own macro.
#[derive(Debug, Clone)]
struct StdPass {
    mac: PimMacro,
    g0: usize,
    g1: usize,
}

/// Which std/pw mapping the plan executes.
#[derive(Debug, Clone)]
enum StdKind {
    /// FCC double-computing mode (paper Fig. 10): only even comp
    /// filters resident, INP == INN, ARU recovers both twins per pair.
    Fcc { means: Vec<i32> },
    /// Regular computing mode (PIM baseline): full bank resident, Q
    /// path only, ARU bypassed.
    Regular,
}

/// A std/pw-conv layer planned onto the macro: weights resident, pass
/// schedule and tile geometry precomputed.  Build once with
/// [`PlannedConv::std_fcc`] / [`PlannedConv::std_regular`], then call
/// [`PlannedConv::execute`] per input.
#[derive(Debug, Clone)]
pub struct PlannedConv {
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    /// Output channels (for Fcc, both twins of every stored pair).
    n: usize,
    l: usize,
    cmp: usize,
    slots: usize,
    l_tiles: usize,
    passes: Vec<StdPass>,
    kind: StdKind,
}

impl PlannedConv {
    /// Plan a std/pw-conv in double computing mode with FCC weights at
    /// the paper geometry (see [`PlannedConv::std_fcc_with`]).
    pub fn std_fcc(
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> PlannedConv {
        Self::std_fcc_with(MacroGeometry::paper(), h, w, c, fcc, k, stride)
    }

    /// Plan a std/pw-conv in double computing mode with FCC weights on
    /// an explicit macro geometry (any compartment count — >64 lanes
    /// pack as multi-word planes): only the even comp filters are
    /// written (normal SRAM mode), once, here.
    #[allow(clippy::too_many_arguments)]
    pub fn std_fcc_with(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> PlannedConv {
        Self::std_fcc_faulted(geom, h, w, c, fcc, k, stride, None)
    }

    /// [`PlannedConv::std_fcc_with`] with optional bit-cell fault
    /// injection: each pass macro gets a [`FaultPlan`] seeded from
    /// `faults`, salted by the pass index so sibling macros fault
    /// independently but deterministically.  `None` takes the exact
    /// unfaulted build path.
    #[allow(clippy::too_many_arguments)]
    pub fn std_fcc_faulted(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
        faults: Option<&FaultConfig>,
    ) -> PlannedConv {
        let l = k * k * c;
        assert_eq!(fcc.comp.l, l, "filter length mismatch");
        let n = fcc.comp.n;
        let pairs = n / 2;
        let (cmp, slots, rows) = (geom.compartments, geom.slots(), geom.rows);
        let l_tiles = l.div_ceil(cmp);
        let groups = pairs.div_ceil(slots);
        let groups_per_pass = (rows / l_tiles).max(1);
        let mut passes = Vec::new();
        let mut g0 = 0;
        while g0 < groups {
            let g1 = (g0 + groups_per_pass).min(groups);
            // load pass: write even comp filters (normal SRAM mode)
            let mut mac = PimMacro::with_geometry(geom);
            if let Some(cfg) = faults {
                mac.core
                    .install_fault_plan(&FaultPlan::seeded(geom, cfg, passes.len() as u64));
            }
            for g in g0..g1 {
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    for cc in 0..cmp {
                        let li = ti * cmp + cc;
                        for s in 0..slots {
                            let p = g * slots + s; // stored pair index
                            let wv = if p < pairs && li < l {
                                fcc.comp.filter(2 * p)[li]
                            } else {
                                0
                            };
                            mac.load_weight(cc, row, s, wv);
                        }
                    }
                }
            }
            passes.push(StdPass { mac, g0, g1 });
            g0 = g1;
        }
        let (oh, ow) = out_dims(h, w, stride);
        PlannedConv {
            h,
            w,
            c,
            k,
            stride,
            oh,
            ow,
            n,
            l,
            cmp,
            slots,
            l_tiles,
            passes,
            kind: StdKind::Fcc {
                means: fcc.means.clone(),
            },
        }
    }

    /// Plan a std/pw-conv in regular computing mode at the paper
    /// geometry (see [`PlannedConv::std_regular_with`]).
    pub fn std_regular(
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [N, L]
        n: usize,
        k: usize,
        stride: usize,
    ) -> PlannedConv {
        Self::std_regular_with(MacroGeometry::paper(), h, w, c, filters, n, k, stride)
    }

    /// Plan a std/pw-conv in regular computing mode (PIM baseline) on
    /// an explicit macro geometry: the full `[N, L]` filter bank is
    /// written.
    #[allow(clippy::too_many_arguments)]
    pub fn std_regular_with(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [N, L]
        n: usize,
        k: usize,
        stride: usize,
    ) -> PlannedConv {
        Self::std_regular_faulted(geom, h, w, c, filters, n, k, stride, None)
    }

    /// [`PlannedConv::std_regular_with`] with optional fault injection
    /// (see [`PlannedConv::std_fcc_faulted`]).
    #[allow(clippy::too_many_arguments)]
    pub fn std_regular_faulted(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [N, L]
        n: usize,
        k: usize,
        stride: usize,
        faults: Option<&FaultConfig>,
    ) -> PlannedConv {
        let l = k * k * c;
        assert_eq!(filters.len(), n * l, "filter bank shape mismatch");
        let (cmp, slots, rows) = (geom.compartments, geom.slots(), geom.rows);
        let l_tiles = l.div_ceil(cmp);
        let groups = n.div_ceil(slots);
        let groups_per_pass = (rows / l_tiles).max(1);
        let mut passes = Vec::new();
        let mut g0 = 0;
        while g0 < groups {
            let g1 = (g0 + groups_per_pass).min(groups);
            let mut mac = PimMacro::with_geometry(geom);
            if let Some(cfg) = faults {
                mac.core
                    .install_fault_plan(&FaultPlan::seeded(geom, cfg, passes.len() as u64));
            }
            for g in g0..g1 {
                for ti in 0..l_tiles {
                    let row = (g - g0) * l_tiles + ti;
                    for cc in 0..cmp {
                        let li = ti * cmp + cc;
                        for s in 0..slots {
                            let f = g * slots + s;
                            let wv = if f < n && li < l { filters[f * l + li] } else { 0 };
                            mac.load_weight(cc, row, s, wv);
                        }
                    }
                }
            }
            passes.push(StdPass { mac, g0, g1 });
            g0 = g1;
        }
        let (oh, ow) = out_dims(h, w, stride);
        PlannedConv {
            h,
            w,
            c,
            k,
            stride,
            oh,
            ow,
            n,
            l,
            cmp,
            slots,
            l_tiles,
            passes,
            kind: StdKind::Regular,
        }
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.oh, self.ow)
    }

    /// Output channel count.
    pub fn out_channels(&self) -> usize {
        self.n
    }

    /// `execute` output length (`oh * ow * n`).
    pub fn out_len(&self) -> usize {
        self.oh * self.ow * self.n
    }

    /// Weight-reload passes this plan performed at build time.
    pub fn load_passes(&self) -> usize {
        self.passes.len()
    }

    /// Total SRAM weight writes across all passes — constant for the
    /// plan's lifetime, because `execute` takes `&self` and cannot
    /// touch the write path (the residency invariant, asserted by the
    /// session tests).
    pub fn weight_writes(&self) -> u64 {
        self.passes.iter().map(|p| p.mac.weight_writes()).sum()
    }

    /// Integrity-scrub every pass macro (detect / quarantine / re-home
    /// / degrade — see [`crate::arch::fault`]), returning the merged
    /// report.  Empty report when the plan was built without faults.
    pub fn scrub(&mut self) -> ScrubReport {
        let mut report = ScrubReport::default();
        for p in &mut self.passes {
            report.merge(&p.mac.core.scrub());
        }
        report
    }

    /// Merged lifetime fault totals of every pass macro.
    pub fn fault_tally(&self) -> FaultTally {
        let mut tally = FaultTally::default();
        for p in &self.passes {
            tally.merge(&p.mac.core.fault_tally());
        }
        tally
    }

    /// Arm the retention-upset process on every pass macro, with the
    /// seed salted per pass so sibling macros draw decorrelated upset
    /// streams (same constant the seeded fault plans salt with).
    pub fn arm_upsets(&mut self, cfg: UpsetConfig) {
        for (pi, p) in self.passes.iter_mut().enumerate() {
            let seed = cfg.seed ^ (pi as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            p.mac.core.arm_upsets(UpsetConfig::new(seed, cfg.per_batch_ber));
        }
    }

    /// Advance every pass macro's virtual batch clock one tick; returns
    /// the total upset bits landed.
    pub fn tick_upsets(&mut self) -> u64 {
        self.passes.iter_mut().map(|p| p.mac.core.tick_upsets()).sum()
    }

    /// Scrub stripes across all pass macros (the concatenated stripe
    /// space the incremental scheduler budgets over).
    pub fn stripe_count(&self) -> usize {
        self.passes.iter().map(|p| p.mac.core.stripe_count()).sum()
    }

    /// Incrementally scrub the stripe window `[start, start+len)` of
    /// the concatenated per-pass stripe space (see
    /// [`crate::arch::pim_core::PimCore::scrub_window`]).
    pub fn scrub_window(&mut self, start: usize, len: usize) -> ScrubReport {
        let mut report = ScrubReport::default();
        let mut base = 0usize;
        let end = start.saturating_add(len);
        for p in &mut self.passes {
            let n = p.mac.core.stripe_count();
            let lo = start.max(base).min(base + n);
            let hi = end.min(base + n);
            if hi > lo {
                report.merge(&p.mac.core.scrub_window(lo - base, hi - lo));
            }
            base += n;
        }
        report
    }

    /// Bytes of stored INT8 weights this plan keeps resident: the FCC
    /// path stores only the even comp filters (`n/2 * l`), the regular
    /// path the full bank (`n * l`).  This is the footprint the
    /// streaming planner budgets against — see
    /// [`stored_weight_bytes`] for computing it without building the
    /// plan.
    pub fn weight_footprint_bytes(&self) -> usize {
        match self.kind {
            StdKind::Fcc { .. } => stored_weight_bytes(self.n, self.l, true),
            StdKind::Regular => stored_weight_bytes(self.n, self.l, false),
        }
    }

    /// Run one `[H, W, C]` input through the resident weights into a
    /// caller-owned `[P, N]` i64 output.  Allocation-free once `ctx`
    /// has grown to this plan's shape.
    pub fn execute(&self, input: &[i32], ctx: &mut ExecCtx, out: &mut [i64]) {
        assert_eq!(input.len(), self.h * self.w * self.c, "input shape mismatch");
        assert_eq!(out.len(), self.out_len(), "output shape mismatch");
        let pixels = self.oh * self.ow;
        // resize only (no clear): im2col_into overwrites the whole
        // buffer, so a second memset here would be pure waste
        ctx.cols.resize(pixels * self.l, 0);
        im2col_into(&mut ctx.cols, input, self.h, self.w, self.c, self.k, self.stride);
        if matches!(self.kind, StdKind::Fcc { .. }) {
            window_sums_into(&mut ctx.win_sums, &ctx.cols, self.l);
        }
        out.fill(0);
        let out_ptr = SharedMut(out.as_mut_ptr());
        let out_len = out.len();
        for pass in &self.passes {
            // compute pass: stream pixel blocks (weight stationary)
            let mut pb0 = 0;
            while pb0 < pixels {
                let pb1 = (pb0 + PIXEL_BLOCK).min(pixels);
                self.run_std_block(
                    pass,
                    pb0,
                    pb1,
                    &ctx.cols,
                    &ctx.win_sums,
                    &mut ctx.blk,
                    &mut ctx.scratch,
                    out_ptr,
                    out_len,
                );
                pb0 = pb1;
            }
        }
    }

    /// Parallel twin of [`PlannedConv::execute`]: shards the
    /// [`PIXEL_BLOCK`] runs of every resident weight pass across the
    /// pool's lanes.  Byte-identical to `execute` at every pool width
    /// (each `(pass, block)` unit writes a disjoint slice of `out`).
    pub fn execute_par(&self, input: &[i32], pool: &mut ExecPool, out: &mut [i64]) {
        self.execute_batch_par(input, 1, pool, out)
    }

    /// Batched parallel execute: stream *all* images of a `[batch, H,
    /// W, C]` batch through each resident weight pass (the software
    /// analogue of the silicon's ping-pong input buffer), into a
    /// caller-owned `[batch, P, N]` output.  The batch folds into the
    /// pixel dimension — every pixel window is pass-independent — so
    /// `batch × pixel` blocks form the parallel work units and the
    /// result is byte-identical to `batch` serial `execute` calls.
    /// Allocation-free once the pool's contexts have grown to shape.
    pub fn execute_batch_par(
        &self,
        input: &[i32],
        batch: usize,
        pool: &mut ExecPool,
        out: &mut [i64],
    ) {
        let img = self.h * self.w * self.c;
        assert_eq!(input.len(), batch * img, "input shape mismatch");
        assert_eq!(out.len(), batch * self.out_len(), "output shape mismatch");
        if batch == 0 {
            return;
        }
        let pixels = self.oh * self.ow;
        let total = batch * pixels;
        let ExecPool { pool: wp, shared, per } = pool;
        // stage the whole batch's im2col + ΣI once on the caller; the
        // workers treat these buffers as read-only
        shared.cols.resize(total * self.l, 0);
        for bi in 0..batch {
            im2col_into(
                &mut shared.cols[bi * pixels * self.l..(bi + 1) * pixels * self.l],
                &input[bi * img..(bi + 1) * img],
                self.h,
                self.w,
                self.c,
                self.k,
                self.stride,
            );
        }
        if matches!(self.kind, StdKind::Fcc { .. }) {
            window_sums_into(&mut shared.win_sums, &shared.cols, self.l);
        }
        out.fill(0);
        let out_ptr = SharedMut(out.as_mut_ptr());
        let out_len = out.len();
        let nblocks = total.div_ceil(PIXEL_BLOCK);
        // no explicit width-1 branch: WorkPool::run at width 1 executes
        // the units inline on the caller, in unit order = the exact
        // pass-major/block-minor walk `execute` performs, with lane 0's
        // scratch — one code path for every width, by construction.
        //
        // pre-grow every lane's private scratch on the caller thread:
        // workers then never allocate, and the warm-up is independent
        // of which lane wins which block
        for ctx in per.iter_mut() {
            ctx.blk.resize(PIXEL_BLOCK * self.slots, (0, 0));
            // Split-capable, 8 input bits, this plan's lane count
            ctx.scratch.warm(2, self.slots, 8, self.cmp);
        }
        let cols: &[i32] = &shared.cols;
        let sums: &[i64] = &shared.win_sums;
        let ctx_base = SharedMut(per.as_mut_ptr());
        let passes = &self.passes;
        wp.run(passes.len() * nblocks, &|lane, unit| {
            let pass = &passes[unit / nblocks];
            let pb0 = (unit % nblocks) * PIXEL_BLOCK;
            let pb1 = (pb0 + PIXEL_BLOCK).min(total);
            // SAFETY: each lane index is driven by exactly one thread,
            // so the &mut to its private ExecCtx is unique
            let ctx = unsafe { &mut *ctx_base.0.add(lane) };
            self.run_std_block(
                pass,
                pb0,
                pb1,
                cols,
                sums,
                &mut ctx.blk,
                &mut ctx.scratch,
                out_ptr,
                out_len,
            );
        });
    }

    /// One `(pass, pixel-block)` work unit: the resident filter groups
    /// of `pass` streamed over pixels `[pb0, pb1)` of the (possibly
    /// batch-folded) im2col staging.  This is the *single* block body
    /// both the serial and the parallel executors run, so parallel
    /// results are bit-true by construction.
    ///
    /// Writes are raw because units on different lanes address the same
    /// output buffer — at provably disjoint indices: `px` ranges never
    /// overlap across blocks, and each pass's groups own disjoint
    /// output channels (`p = g * slots + s` with disjoint `g` ranges).
    #[allow(clippy::too_many_arguments)]
    fn run_std_block(
        &self,
        pass: &StdPass,
        pb0: usize,
        pb1: usize,
        cols: &[i32],
        win_sums: &[i64],
        blk: &mut Vec<(i64, i64)>,
        scratch: &mut MvmScratch,
        out: SharedMut<i64>,
        out_len: usize,
    ) {
        let is_fcc = matches!(self.kind, StdKind::Fcc { .. });
        let mode = if is_fcc { Mode::Double } else { Mode::Regular };
        for g in pass.g0..pass.g1 {
            blk.clear();
            blk.resize((pb1 - pb0) * self.slots, (0i64, 0i64));
            for ti in 0..self.l_tiles {
                let row = (g - pass.g0) * self.l_tiles + ti;
                let lo = ti * self.cmp;
                let hi = ((ti + 1) * self.cmp).min(self.l);
                for px in pb0..pb1 {
                    let tile = &cols[px * self.l + lo..px * self.l + hi];
                    // FCC double mode drives INP and INN with the same
                    // vector-wise input; regular mode leaves the Q̄
                    // path dark
                    let inn: &[i32] = if is_fcc { tile } else { &[] };
                    pass.mac.mvm_row_into(row, tile, inn, mode, Grouping::Combined, scratch);
                    let base = (px - pb0) * self.slots;
                    for s in 0..self.slots {
                        let ps = scratch.psum(0, s);
                        blk[base + s].0 += ps.q;
                        blk[base + s].1 += ps.qbar;
                    }
                }
            }
            match &self.kind {
                StdKind::Fcc { means } => {
                    let pairs = self.n / 2;
                    for px in pb0..pb1 {
                        let base = (px - pb0) * self.slots;
                        for s in 0..self.slots {
                            let p = g * self.slots + s;
                            if p >= pairs {
                                continue;
                            }
                            let m = means[p] as i64;
                            let (q, qbar) = blk[base + s];
                            let (even, odd) =
                                aru_recover(q, qbar, win_sums[px], win_sums[px], m);
                            debug_assert!(px * self.n + 2 * p + 1 < out_len);
                            // SAFETY: disjoint (px, channel) slot — see
                            // the method docs
                            unsafe {
                                *out.0.add(px * self.n + 2 * p) = even;
                                *out.0.add(px * self.n + 2 * p + 1) = odd;
                            }
                        }
                    }
                }
                StdKind::Regular => {
                    for px in pb0..pb1 {
                        let base = (px - pb0) * self.slots;
                        for s in 0..self.slots {
                            let f = g * self.slots + s;
                            if f < self.n {
                                debug_assert!(px * self.n + f < out_len);
                                // SAFETY: disjoint (px, channel) slot
                                unsafe { *out.0.add(px * self.n + f) = blk[base + s].0 };
                            }
                        }
                    }
                }
            }
        }
    }
}

/// One weight-reload pass of a dw plan: mapping units `[u0, u1)` (pairs
/// in DBIS mode, row-groups in reconfig mode, channels in regular mode)
/// resident in their own macro, unit `u` at row `u - u0`.
#[derive(Debug, Clone)]
struct DwPass {
    mac: PimMacro,
    u0: usize,
    u1: usize,
}

/// Which dw mapping the plan executes.
#[derive(Debug, Clone)]
enum DwKind {
    /// FCC + DBIS (+ optionally the reconfigurable unit's
    /// split-grouping / padded mapping, paper Fig. 11).
    Fcc { means: Vec<i32>, reconfig: bool },
    /// Regular computing baseline: one channel per row-step.
    Regular,
}

/// A dw-conv layer planned onto the macro.  Build once with
/// [`PlannedDwConv::fcc`] / [`PlannedDwConv::regular`], then call
/// [`PlannedDwConv::execute`] per input.
#[derive(Debug, Clone)]
pub struct PlannedDwConv {
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    oh: usize,
    ow: usize,
    taps: usize,
    cmp: usize,
    slots: usize,
    passes: Vec<DwPass>,
    kind: DwKind,
}

impl PlannedDwConv {
    /// Plan a dw-conv with FCC + DBIS.  With `reconfig` and `2*k*k`
    /// taps fitting the compartment count, the reconfigurable unit's
    /// split mapping packs two pairs per row half (paper Fig. 11);
    /// otherwise the DBIS-only one-pair-per-row mapping is planned.
    pub fn fcc(
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
        k: usize,
        stride: usize,
        reconfig: bool,
    ) -> PlannedDwConv {
        Self::fcc_with(MacroGeometry::paper(), h, w, c, fcc, k, stride, reconfig)
    }

    /// [`PlannedDwConv::fcc`] on an explicit macro geometry.
    #[allow(clippy::too_many_arguments)]
    pub fn fcc_with(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
        k: usize,
        stride: usize,
        reconfig: bool,
    ) -> PlannedDwConv {
        let taps = k * k;
        assert_eq!(fcc.comp.l, taps, "filter length mismatch");
        assert_eq!(fcc.comp.n, c, "channel count mismatch");
        let pairs = c / 2;
        let (cmp, rows) = (geom.compartments, geom.rows);
        let reconfig = reconfig && 2 * taps <= cmp;
        let mut passes = Vec::new();
        if reconfig {
            // 4 pairs per stored row: (half 0 slot 0, half 0 slot 1,
            // half 1 slot 0, half 1 slot 1)
            let half = cmp / 2;
            let row_groups = pairs.div_ceil(4);
            let mut u0 = 0;
            while u0 < row_groups {
                let u1 = (u0 + rows).min(row_groups);
                let mut mac = PimMacro::with_geometry(geom);
                for rg in u0..u1 {
                    let row = rg - u0;
                    for cc in 0..cmp {
                        for s in 0..2 {
                            let (ghalf, off) = if cc < half { (0, cc) } else { (1, cc - half) };
                            // layout: stage s selects slot s; half 0
                            // computes pair (4rg+2s), half 1 (4rg+2s+1)
                            let p = rg * 4 + 2 * s + ghalf;
                            let wv = if p < pairs && off < taps {
                                fcc.comp.filter(2 * p)[off]
                            } else {
                                0
                            };
                            mac.load_weight(cc, row, s, wv);
                        }
                    }
                }
                passes.push(DwPass { mac, u0, u1 });
                u0 = u1;
            }
        } else {
            // DBIS-only: one pair per row-step in compartments 0..taps
            let mut u0 = 0;
            while u0 < pairs {
                let u1 = (u0 + rows).min(pairs);
                let mut mac = PimMacro::with_geometry(geom);
                for p in u0..u1 {
                    let row = p - u0;
                    for cc in 0..taps.min(cmp) {
                        mac.load_weight(cc, row, 0, fcc.comp.filter(2 * p)[cc]);
                    }
                }
                passes.push(DwPass { mac, u0, u1 });
                u0 = u1;
            }
        }
        let (oh, ow) = out_dims(h, w, stride);
        PlannedDwConv {
            h,
            w,
            c,
            k,
            stride,
            oh,
            ow,
            taps,
            cmp,
            slots: geom.slots(),
            passes,
            kind: DwKind::Fcc {
                means: fcc.means.clone(),
                reconfig,
            },
        }
    }

    /// Plan a dw-conv baseline: one channel per row-step, regular mode.
    pub fn regular(
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [C, K*K]
        k: usize,
        stride: usize,
    ) -> PlannedDwConv {
        Self::regular_with(MacroGeometry::paper(), h, w, c, filters, k, stride)
    }

    /// [`PlannedDwConv::regular`] on an explicit macro geometry.
    #[allow(clippy::too_many_arguments)]
    pub fn regular_with(
        geom: MacroGeometry,
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [C, K*K]
        k: usize,
        stride: usize,
    ) -> PlannedDwConv {
        let taps = k * k;
        assert_eq!(filters.len(), c * taps, "filter bank shape mismatch");
        let (cmp, rows) = (geom.compartments, geom.rows);
        let mut passes = Vec::new();
        let mut u0 = 0;
        while u0 < c {
            let u1 = (u0 + rows).min(c);
            let mut mac = PimMacro::with_geometry(geom);
            for ch in u0..u1 {
                let row = ch - u0;
                for cc in 0..taps.min(cmp) {
                    mac.load_weight(cc, row, 0, filters[ch * taps + cc]);
                }
            }
            passes.push(DwPass { mac, u0, u1 });
            u0 = u1;
        }
        let (oh, ow) = out_dims(h, w, stride);
        PlannedDwConv {
            h,
            w,
            c,
            k,
            stride,
            oh,
            ow,
            taps,
            cmp,
            slots: geom.slots(),
            passes,
            kind: DwKind::Regular,
        }
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.oh, self.ow)
    }

    /// `execute` output length (`oh * ow * c`).
    pub fn out_len(&self) -> usize {
        self.oh * self.ow * self.c
    }

    /// Weight-reload passes this plan performed at build time.
    pub fn load_passes(&self) -> usize {
        self.passes.len()
    }

    /// Total SRAM weight writes across all passes (constant after
    /// build — see [`PlannedConv::weight_writes`]).
    pub fn weight_writes(&self) -> u64 {
        self.passes.iter().map(|p| p.mac.weight_writes()).sum()
    }

    /// Run one `[H, W, C]` input through the resident weights into a
    /// caller-owned `[P, C]` i64 output.  Allocation-free once `ctx`
    /// has grown to this plan's shape.
    pub fn execute(&self, input: &[i32], ctx: &mut ExecCtx, out: &mut [i64]) {
        assert_eq!(input.len(), self.h * self.w * self.c, "input shape mismatch");
        assert_eq!(out.len(), self.out_len(), "output shape mismatch");
        let (pixels, taps, c) = (self.oh * self.ow, self.taps, self.c);
        // per-channel im2col windows + their pixel sums (ΣI per stream);
        // resize only — every chunk/element is overwritten below
        ctx.dw_windows.resize(c * pixels * taps, 0);
        for ch in 0..c {
            im2col_channel_into(
                &mut ctx.dw_windows[ch * pixels * taps..(ch + 1) * pixels * taps],
                input,
                self.h,
                self.w,
                c,
                ch,
                self.k,
                self.stride,
            );
        }
        if matches!(self.kind, DwKind::Fcc { .. }) {
            // one ΣI per (channel, pixel) window — same reduction as the
            // std path, flattened `[C][P]`
            window_sums_into(&mut ctx.dw_sums, &ctx.dw_windows, taps);
        }
        out.fill(0);
        let out_ptr = SharedMut(out.as_mut_ptr());
        let out_len = out.len();
        let ExecCtx { scratch, dw_windows, dw_sums, inp, inn, .. } = ctx;
        for pass in &self.passes {
            self.run_dw_block(
                pass, 0, pixels, dw_windows, dw_sums, scratch, inp, inn, out_ptr, out_len,
            );
        }
    }

    /// Parallel twin of [`PlannedDwConv::execute`]: shards the
    /// [`PIXEL_BLOCK`] runs of every resident weight pass across the
    /// pool's lanes.  Byte-identical to `execute` at every pool width
    /// (each `(pass, block)` unit writes a disjoint slice of `out`:
    /// its own pixel range × its pass's resident channels).
    pub fn execute_par(&self, input: &[i32], pool: &mut ExecPool, out: &mut [i64]) {
        assert_eq!(input.len(), self.h * self.w * self.c, "input shape mismatch");
        assert_eq!(out.len(), self.out_len(), "output shape mismatch");
        let (pixels, taps, c) = (self.oh * self.ow, self.taps, self.c);
        let ExecPool { pool: wp, shared, per } = pool;
        // stage windows + ΣI on the caller; read-only for the workers
        shared.dw_windows.resize(c * pixels * taps, 0);
        for ch in 0..c {
            im2col_channel_into(
                &mut shared.dw_windows[ch * pixels * taps..(ch + 1) * pixels * taps],
                input,
                self.h,
                self.w,
                c,
                ch,
                self.k,
                self.stride,
            );
        }
        if matches!(self.kind, DwKind::Fcc { .. }) {
            window_sums_into(&mut shared.dw_sums, &shared.dw_windows, taps);
        }
        out.fill(0);
        let out_ptr = SharedMut(out.as_mut_ptr());
        let out_len = out.len();
        let nblocks = pixels.div_ceil(PIXEL_BLOCK);
        // no explicit width-1 branch — see execute_batch_par: the pool
        // runs the units inline in the same order on the caller.
        // pre-grow every lane's private scratch on the caller thread
        for ctx in per.iter_mut() {
            // Split-capable, 8 input bits, this plan's lane count
            ctx.scratch.warm(2, self.slots, 8, self.cmp);
            ctx.inp.resize(self.cmp, 0);
            ctx.inn.resize(self.cmp, 0);
        }
        let windows: &[i32] = &shared.dw_windows;
        let sums: &[i64] = &shared.dw_sums;
        let ctx_base = SharedMut(per.as_mut_ptr());
        let passes = &self.passes;
        wp.run(passes.len() * nblocks, &|lane, unit| {
            let pass = &passes[unit / nblocks];
            let px0 = (unit % nblocks) * PIXEL_BLOCK;
            let px1 = (px0 + PIXEL_BLOCK).min(pixels);
            // SAFETY: each lane index is driven by exactly one thread,
            // so the &mut to its private ExecCtx is unique
            let ctx = unsafe { &mut *ctx_base.0.add(lane) };
            self.run_dw_block(
                pass,
                px0,
                px1,
                windows,
                sums,
                &mut ctx.scratch,
                &mut ctx.inp,
                &mut ctx.inn,
                out_ptr,
                out_len,
            );
        });
    }

    /// One `(pass, pixel-block)` work unit, dispatched by mapping kind —
    /// the single block body both the serial and the parallel dw
    /// executors run (see [`PlannedConv::run_std_block`] for the raw
    /// write rationale; disjointness here is pixel range × the pass's
    /// resident channels).
    #[allow(clippy::too_many_arguments)]
    fn run_dw_block(
        &self,
        pass: &DwPass,
        px0: usize,
        px1: usize,
        windows: &[i32],
        sums: &[i64],
        scratch: &mut MvmScratch,
        inp: &mut Vec<i32>,
        inn: &mut Vec<i32>,
        out: SharedMut<i64>,
        out_len: usize,
    ) {
        match &self.kind {
            DwKind::Fcc { means, reconfig } if *reconfig => self.run_dw_reconfig_block(
                pass, px0, px1, means, windows, sums, scratch, inp, inn, out, out_len,
            ),
            DwKind::Fcc { means, .. } => {
                self.run_dw_dbis_block(pass, px0, px1, means, windows, sums, scratch, out, out_len)
            }
            DwKind::Regular => {
                self.run_dw_regular_block(pass, px0, px1, windows, scratch, out, out_len)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_dw_dbis_block(
        &self,
        pass: &DwPass,
        px0: usize,
        px1: usize,
        means: &[i32],
        windows: &[i32],
        sums: &[i64],
        scratch: &mut MvmScratch,
        out: SharedMut<i64>,
        out_len: usize,
    ) {
        let (pixels, taps, c) = (self.oh * self.ow, self.taps, self.c);
        for p in pass.u0..pass.u1 {
            let row = p - pass.u0;
            let m = means[p] as i64;
            for px in px0..px1 {
                let we = &windows[(2 * p) * pixels * taps + px * taps..][..taps];
                let wo = &windows[(2 * p + 1) * pixels * taps + px * taps..][..taps];
                pass.mac.mvm_row_into(row, we, wo, Mode::Double, Grouping::Combined, scratch);
                let ps = scratch.psum(0, 0);
                let sp = sums[(2 * p) * pixels + px];
                let sn = sums[(2 * p + 1) * pixels + px];
                let (even, odd) = aru_recover(ps.q, ps.qbar, sp, sn, m);
                debug_assert!(px * c + 2 * p + 1 < out_len);
                // SAFETY: disjoint (px, channel) slot — see run_dw_block
                unsafe {
                    *out.0.add(px * c + 2 * p) = even;
                    *out.0.add(px * c + 2 * p + 1) = odd;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn run_dw_reconfig_block(
        &self,
        pass: &DwPass,
        px0: usize,
        px1: usize,
        means: &[i32],
        windows: &[i32],
        sums: &[i64],
        scratch: &mut MvmScratch,
        inp: &mut Vec<i32>,
        inn: &mut Vec<i32>,
        out: SharedMut<i64>,
        out_len: usize,
    ) {
        let (pixels, taps, c) = (self.oh * self.ow, self.taps, self.c);
        let pairs = c / 2;
        let half = self.cmp / 2;
        for rg in pass.u0..pass.u1 {
            let row = rg - pass.u0;
            for px in px0..px1 {
                // two stages, alternating slots
                for s in 0..2 {
                    let pa = rg * 4 + 2 * s; // half 0 pair
                    let pb = rg * 4 + 2 * s + 1; // half 1 pair
                    inp.clear();
                    inp.resize(self.cmp, 0);
                    inn.clear();
                    inn.resize(self.cmp, 0);
                    for (half_id, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        for t in 0..taps {
                            let ccx = half_id * half + t;
                            inp[ccx] = windows[(2 * p) * pixels * taps + px * taps + t];
                            inn[ccx] = windows[(2 * p + 1) * pixels * taps + px * taps + t];
                        }
                    }
                    pass.mac.mvm_row_into(row, inp, inn, Mode::Double, Grouping::Split, scratch);
                    for (ghalf, p) in [(0usize, pa), (1usize, pb)] {
                        if p >= pairs {
                            continue;
                        }
                        let m = means[p] as i64;
                        let sp = sums[(2 * p) * pixels + px];
                        let sn = sums[(2 * p + 1) * pixels + px];
                        let ps = scratch.psum(ghalf, s);
                        let (even, odd) = aru_recover(ps.q, ps.qbar, sp, sn, m);
                        debug_assert!(px * c + 2 * p + 1 < out_len);
                        // SAFETY: disjoint (px, channel) slot
                        unsafe {
                            *out.0.add(px * c + 2 * p) = even;
                            *out.0.add(px * c + 2 * p + 1) = odd;
                        }
                    }
                }
            }
        }
    }

    fn run_dw_regular_block(
        &self,
        pass: &DwPass,
        px0: usize,
        px1: usize,
        windows: &[i32],
        scratch: &mut MvmScratch,
        out: SharedMut<i64>,
        out_len: usize,
    ) {
        let (pixels, taps, c) = (self.oh * self.ow, self.taps, self.c);
        for ch in pass.u0..pass.u1 {
            let row = ch - pass.u0;
            for px in px0..px1 {
                let window = &windows[ch * pixels * taps + px * taps..][..taps];
                pass.mac
                    .mvm_row_into(row, window, &[], Mode::Regular, Grouping::Combined, scratch);
                debug_assert!(px * c + ch < out_len);
                // SAFETY: disjoint (px, channel) slot
                unsafe { *out.0.add(px * c + ch) = scratch.psum(0, 0).q };
            }
        }
    }
}

/// std/pw-conv in double computing mode with FCC weights (paper
/// Fig. 10) — one-shot wrapper: plan, execute once, return `[P, N]`.
pub fn exec_std_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let plan = PlannedConv::std_fcc(h, w, c, fcc, k, stride);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(input, &mut ctx, &mut out);
    out
}

/// std/pw-conv in regular computing mode (PIM baseline) — one-shot
/// wrapper over [`PlannedConv::std_regular`].
pub fn exec_std_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [N, L]
    n: usize,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let plan = PlannedConv::std_regular(h, w, c, filters, n, k, stride);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(input, &mut ctx, &mut out);
    out
}

/// dw-conv with FCC + DBIS (+ optionally the reconfigurable unit's
/// split-grouping / padded mapping, paper Fig. 11) — one-shot wrapper
/// over [`PlannedDwConv::fcc`].
pub fn exec_dw_fcc(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
    k: usize,
    stride: usize,
    reconfig: bool,
) -> Vec<i64> {
    let plan = PlannedDwConv::fcc(h, w, c, fcc, k, stride, reconfig);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(input, &mut ctx, &mut out);
    out
}

/// dw-conv baseline: one channel per row-step, regular mode — one-shot
/// wrapper over [`PlannedDwConv::regular`].
pub fn exec_dw_regular(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [C, K*K]
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let plan = PlannedDwConv::regular(h, w, c, filters, k, stride);
    let mut ctx = ExecCtx::new();
    let mut out = vec![0i64; plan.out_len()];
    plan.execute(input, &mut ctx, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fcc::{fcc_transform, FilterBank};
    use crate::mapping::im2col::{direct_conv, direct_dwconv, im2col};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<i32> {
        (0..n).map(|_| rng.int8() as i32).collect()
    }

    /// direct conv with the biased-comp bank = the FCC ground truth
    fn fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let n = fcc.comp.n;
        let l = fcc.comp.l;
        let mut bc = vec![0i32; n * l];
        for p in 0..n / 2 {
            for i in 0..l {
                bc[(2 * p) * l + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * l + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_conv(input, h, w, c, &bc, n, k, stride)
    }

    #[test]
    fn std_fcc_matches_direct_conv() {
        let mut rng = Rng::new(91);
        let (h, w, c, k, n) = (4, 4, 3, 3, 8);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_fcc_pointwise_many_filters_multipass() {
        // enough filters to force multiple groups and a reload pass
        let mut rng = Rng::new(92);
        let (h, w, c, k, n) = (3, 3, 40, 1, 12);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_fcc_more_pixels_than_one_block() {
        // 18x18 output = 324 pixels > PIXEL_BLOCK exercises block seams
        let mut rng = Rng::new(90);
        let (h, w, c, k, n) = (18, 18, 2, 3, 4);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let got = exec_std_fcc(&input, h, w, c, &fcc, k, 1);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_regular_matches_direct_conv() {
        let mut rng = Rng::new(93);
        let (h, w, c, k, n) = (4, 4, 2, 3, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, n * k * k * c);
        let got = exec_std_regular(&input, h, w, c, &filters, n, k, 1);
        let want = direct_conv(&input, h, w, c, &filters, n, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn std_stride2() {
        let mut rng = Rng::new(94);
        let (h, w, c, k, n) = (5, 5, 3, 3, 4);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        assert_eq!(
            exec_std_fcc(&input, h, w, c, &fcc, k, 2),
            fcc_oracle(&input, h, w, c, &fcc, k, 2)
        );
    }

    #[test]
    fn window_sum_group_invariant() {
        // the ΣI fed to the ARU depends only on the pixel window: the
        // precomputed sums must equal a per-(pixel, group) recomputation
        // for every group (regression test for the duplicated-reduction
        // bug in the scalar executor)
        let mut rng = Rng::new(89);
        let (h, w, c, k) = (5, 4, 3, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let l = k * k * c;
        let (cols, oh, ow) = im2col(&input, h, w, c, k, 1);
        let sums = window_sums(&cols, l);
        assert_eq!(sums.len(), oh * ow);
        let groups = 6; // any per-group recomputation must agree
        for px in 0..oh * ow {
            for _g in 0..groups {
                let per_group: i64 = cols[px * l..(px + 1) * l].iter().map(|&x| x as i64).sum();
                assert_eq!(per_group, sums[px], "ΣI drifted at pixel {px}");
            }
        }
    }

    fn dw_fcc_oracle(
        input: &[i32],
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
    ) -> Vec<i64> {
        let taps = k * k;
        let mut bc = vec![0i32; c * taps];
        for p in 0..c / 2 {
            for i in 0..taps {
                bc[(2 * p) * taps + i] = fcc.comp.filter(2 * p)[i] + fcc.means[p];
                bc[(2 * p + 1) * taps + i] = fcc.comp.filter(2 * p + 1)[i] + fcc.means[p];
            }
        }
        direct_dwconv(input, h, w, c, &bc, k, stride)
    }

    #[test]
    fn dw_fcc_dbis_matches_direct() {
        let mut rng = Rng::new(95);
        let (h, w, c, k) = (4, 4, 6, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, false);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_matches_direct() {
        let mut rng = Rng::new(96);
        let (h, w, c, k) = (4, 4, 16, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_fcc_reconfig_odd_pair_tail() {
        // pairs not divisible by 4 exercises the tail handling
        let mut rng = Rng::new(97);
        let (h, w, c, k) = (3, 3, 10, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_regular_matches_direct() {
        let mut rng = Rng::new(98);
        let (h, w, c, k) = (4, 4, 5, 3);
        let input = rand_vec(&mut rng, h * w * c);
        let filters = rand_vec(&mut rng, c * k * k);
        let got = exec_dw_regular(&input, h, w, c, &filters, k, 1);
        let want = direct_dwconv(&input, h, w, c, &filters, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_5x5_falls_back_to_dbis() {
        // 5x5 taps don't fit twice -> reconfig path must still be correct
        // via the DBIS fallback inside PlannedDwConv::fcc
        let mut rng = Rng::new(99);
        let (h, w, c, k) = (5, 5, 4, 5);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let got = exec_dw_fcc(&input, h, w, c, &fcc, k, 1, true);
        let want = dw_fcc_oracle(&input, h, w, c, &fcc, k, 1);
        assert_eq!(got, want);
    }

    #[test]
    fn paper_geometry_matches_the_built_macro() {
        // the const-based planner geometry must never drift from the
        // macro the passes actually build
        let geom = MacroGeometry::paper();
        let mac = PimMacro::paper();
        assert_eq!(
            (geom.compartments, geom.slots(), geom.rows),
            (mac.core.num_compartments(), mac.core.slots(), mac.core.rows())
        );
    }

    #[test]
    fn wide_geometry_plans_match_direct_conv() {
        // >64-compartment geometries (previously hard-rejected by the
        // single-word WeightPlanes): fewer l-tiles per group, multi-word
        // planes in every row-step, same exact outputs
        let mut rng = Rng::new(115);
        let (h, w, c, k, n) = (4, 4, 20, 3, 8); // l = 180 > 128 lanes
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let want = fcc_oracle(&input, h, w, c, &fcc, k, 1);
        for lanes in [65usize, 96, 128] {
            let geom = MacroGeometry::with_compartments(lanes);
            let plan = PlannedConv::std_fcc_with(geom, h, w, c, &fcc, k, 1);
            let mut ctx = ExecCtx::new();
            let mut out = vec![0i64; plan.out_len()];
            plan.execute(&input, &mut ctx, &mut out);
            assert_eq!(out, want, "std_fcc drifted at {lanes} compartments");
            // and through the pool, which warms multi-word scratches
            let mut pool = ExecPool::new(2);
            let mut got = vec![1i64; plan.out_len()];
            plan.execute_par(&input, &mut pool, &mut got);
            assert_eq!(got, want, "execute_par drifted at {lanes} compartments");
        }
    }

    #[test]
    fn planned_execute_is_repeatable_with_shared_ctx() {
        // one ExecCtx serves many plans and many executes: results must
        // not depend on what the buffers held before
        let mut rng = Rng::new(100);
        let (h, w, c, k, n) = (4, 4, 3, 3, 8);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let std_plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        let dw_filters = rand_vec(&mut rng, (c + 1) * k * k);
        let dw_plan = PlannedDwConv::regular(h, w, c + 1, &dw_filters, k, 1);
        let dw_input = rand_vec(&mut rng, h * w * (c + 1));

        let mut ctx = ExecCtx::new();
        let mut std_out = vec![0i64; std_plan.out_len()];
        let mut dw_out = vec![0i64; dw_plan.out_len()];
        std_plan.execute(&input, &mut ctx, &mut std_out);
        let first = std_out.clone();
        dw_plan.execute(&dw_input, &mut ctx, &mut dw_out); // dirty the ctx
        std_plan.execute(&input, &mut ctx, &mut std_out);
        assert_eq!(std_out, first, "ctx reuse leaked state between plans");
        assert_eq!(first, fcc_oracle(&input, h, w, c, &fcc, k, 1));
    }

    #[test]
    fn planned_weights_written_once() {
        // the residency invariant: building the plan performs every
        // SRAM weight write; execute (&self) performs none
        let mut rng = Rng::new(101);
        let (h, w, c, k, n) = (3, 3, 40, 1, 12);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        assert!(plan.load_passes() >= 1);
        let written = plan.weight_writes();
        assert!(written > 0, "plan build must write weights");
        let mut ctx = ExecCtx::new();
        let mut out = vec![0i64; plan.out_len()];
        for _ in 0..3 {
            plan.execute(&input, &mut ctx, &mut out);
        }
        assert_eq!(plan.weight_writes(), written, "execute must not write weights");
    }

    #[test]
    fn planned_multipass_splits_groups() {
        // l_tiles = 2 (l = 40 > 32 compartments), 33 groups vs 64 rows
        // -> 32 groups/pass -> 2 passes; outputs must still be exact
        let mut rng = Rng::new(102);
        let (h, w, c, k, n) = (2, 2, 40, 1, 132);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        assert!(plan.load_passes() >= 2, "shape was meant to force a reload pass");
        let mut ctx = ExecCtx::new();
        let mut out = vec![0i64; plan.out_len()];
        plan.execute(&input, &mut ctx, &mut out);
        assert_eq!(out, fcc_oracle(&input, h, w, c, &fcc, k, 1));
    }

    #[test]
    fn execute_par_matches_serial_across_widths() {
        // multi-pass, multi-block shape: 256 pixels > PIXEL_BLOCK and
        // enough filters for 2 reload passes, so both unit axes shard
        let mut rng = Rng::new(110);
        let (h, w, c, k, n) = (18, 18, 40, 1, 132);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * c), n, c);
        let fcc = fcc_transform(&bank);
        let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        assert!(plan.load_passes() >= 2);
        let mut ctx = ExecCtx::new();
        let mut want = vec![0i64; plan.out_len()];
        plan.execute(&input, &mut ctx, &mut want);
        for width in [1usize, 2, 8] {
            let mut pool = ExecPool::new(width);
            let mut got = vec![1i64; plan.out_len()]; // dirty sentinel
            plan.execute_par(&input, &mut pool, &mut got);
            assert_eq!(got, want, "execute_par diverged at width {width}");
        }
    }

    #[test]
    fn execute_batch_par_equals_per_image_execute() {
        let mut rng = Rng::new(111);
        let (h, w, c, k, n, batch) = (10, 10, 3, 3, 8, 3);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        let inputs = rand_vec(&mut rng, batch * h * w * c);
        let mut ctx = ExecCtx::new();
        let mut want = vec![0i64; batch * plan.out_len()];
        for bi in 0..batch {
            plan.execute(
                &inputs[bi * h * w * c..(bi + 1) * h * w * c],
                &mut ctx,
                &mut want[bi * plan.out_len()..(bi + 1) * plan.out_len()],
            );
        }
        for width in [1usize, 2, 8] {
            let mut pool = ExecPool::new(width);
            let mut got = vec![1i64; batch * plan.out_len()];
            plan.execute_batch_par(&inputs, batch, &mut pool, &mut got);
            assert_eq!(got, want, "batched execute diverged at width {width}");
        }
    }

    #[test]
    fn dw_execute_par_matches_serial_all_mappings() {
        let mut rng = Rng::new(112);
        let (h, w, c, k) = (12, 12, 16, 3); // 100 pixels, 2 blocks at 64
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, c * k * k), c, k * k);
        let fcc = fcc_transform(&bank);
        let filters = rand_vec(&mut rng, c * k * k);
        let plans = [
            PlannedDwConv::fcc(h, w, c, &fcc, k, 1, false), // DBIS
            PlannedDwConv::fcc(h, w, c, &fcc, k, 1, true),  // reconfig/Split
            PlannedDwConv::regular(h, w, c, &filters, k, 1),
        ];
        for (pi, plan) in plans.iter().enumerate() {
            let mut ctx = ExecCtx::new();
            let mut want = vec![0i64; plan.out_len()];
            plan.execute(&input, &mut ctx, &mut want);
            for width in [1usize, 2, 8] {
                let mut pool = ExecPool::new(width);
                let mut got = vec![1i64; plan.out_len()];
                plan.execute_par(&input, &mut pool, &mut got);
                assert_eq!(got, want, "dw plan {pi} diverged at width {width}");
            }
        }
    }

    #[test]
    fn execute_par_keeps_weights_resident() {
        // the residency invariant must survive parallel dispatch
        let mut rng = Rng::new(113);
        let (h, w, c, k, n) = (6, 6, 3, 3, 8);
        let input = rand_vec(&mut rng, h * w * c);
        let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
        let fcc = fcc_transform(&bank);
        let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        let written = plan.weight_writes();
        let mut pool = ExecPool::new(4);
        let mut out = vec![0i64; plan.out_len()];
        for _ in 0..3 {
            plan.execute_par(&input, &mut pool, &mut out);
        }
        assert_eq!(plan.weight_writes(), written, "execute_par wrote weights");
    }

    #[test]
    fn one_pool_serves_many_plans() {
        // pool reuse across plans/shapes must not leak state (the
        // session uses one pool for every fabric layer)
        let mut rng = Rng::new(114);
        let mut pool = ExecPool::new(2);
        for &(h, w, c, k, n) in &[(4usize, 4usize, 3usize, 3usize, 8usize), (9, 9, 2, 3, 4)] {
            let input = rand_vec(&mut rng, h * w * c);
            let bank = FilterBank::new(rand_vec(&mut rng, n * k * k * c), n, k * k * c);
            let fcc = fcc_transform(&bank);
            let plan = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
            let mut got = vec![0i64; plan.out_len()];
            plan.execute_par(&input, &mut pool, &mut got);
            assert_eq!(got, fcc_oracle(&input, h, w, c, &fcc, k, 1));
        }
    }

    #[test]
    fn weight_footprint_is_half_for_fcc() {
        let mut rng = Rng::new(115);
        let (h, w, c, k, n) = (4usize, 4usize, 3usize, 3usize, 8usize);
        let l = k * k * c;
        let bank = FilterBank::new(rand_vec(&mut rng, n * l), n, l);
        let fcc_plan = PlannedConv::std_fcc(h, w, c, &fcc_transform(&bank), k, 1);
        assert_eq!(fcc_plan.weight_footprint_bytes(), (n / 2) * l);
        let reg_plan = PlannedConv::std_regular(h, w, c, &bank.data, n, k, 1);
        assert_eq!(reg_plan.weight_footprint_bytes(), n * l);
        assert_eq!(stored_weight_bytes(n, l, true), (n / 2) * l);
        assert_eq!(stored_weight_bytes(n, l, false), n * l);
    }

    #[test]
    fn reload_pass_planning_is_greedy_and_total() {
        // everything fits: one pass
        assert_eq!(plan_reload_passes(&[10, 20, 30], 100), vec![0..3]);
        // greedy split: 10+20 fits 30, adding 30 would exceed
        assert_eq!(plan_reload_passes(&[10, 20, 30], 30), vec![0..2, 2..3]);
        // a single over-budget layer still gets its own pass
        assert_eq!(plan_reload_passes(&[10, 200, 10], 50), vec![0..1, 1..2, 2..3]);
        // over-budget first layer does not produce an empty pass
        assert_eq!(plan_reload_passes(&[200, 10], 50), vec![0..1, 1..2]);
        // degenerate inputs
        assert_eq!(plan_reload_passes(&[], 50), Vec::<std::ops::Range<usize>>::new());
        assert_eq!(plan_reload_passes(&[5], 0), vec![0..1]);
        // every index appears exactly once, in order
        let fp = [30usize, 30, 30, 30, 30];
        let passes = plan_reload_passes(&fp, 60);
        assert_eq!(passes, vec![0..2, 2..4, 4..5]);
        let covered: Vec<usize> = passes.iter().flat_map(|r| r.clone()).collect();
        assert_eq!(covered, vec![0, 1, 2, 3, 4]);
    }
}
