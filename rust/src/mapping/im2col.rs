//! im2col lowering (paper §III-D: "convert them into 1-dimensional
//! vectors using the im2col function").
//!
//! Feature maps are `[H, W, C]` row-major (HWC); filters are `[N, L]`
//! with `L = K*K*C` in `(ky, kx, c)` order — matching the python side.

/// SAME-padding im2col output shape for an `[H, W]` input.
pub fn out_dims(h: usize, w: usize, stride: usize) -> (usize, usize) {
    (h.div_ceil(stride), w.div_ceil(stride))
}

/// SAME-padding im2col into a caller-owned `[P, L]` buffer
/// (`P = out_h * out_w`, `L = k*k*c`; `out.len()` must match exactly).
/// Out-of-bounds taps read 0.  The zero-allocation twin of [`im2col`],
/// used by the planned executors' hot path.
pub fn im2col_into(
    out: &mut [i32],
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
) -> (usize, usize) {
    assert_eq!(input.len(), h * w * c, "input shape mismatch");
    let (oh, ow) = out_dims(h, w, stride);
    let pad = (k - 1) / 2;
    let l = k * k * c;
    assert_eq!(out.len(), oh * ow * l, "im2col output shape mismatch");
    out.fill(0);
    for oy in 0..oh {
        for ox in 0..ow {
            let base = (oy * ow + ox) * l;
            for ky in 0..k {
                for kx in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                        continue; // zero padding
                    }
                    let src = ((iy as usize) * w + ix as usize) * c;
                    let dst = base + (ky * k + kx) * c;
                    out[dst..dst + c].copy_from_slice(&input[src..src + c]);
                }
            }
        }
    }
    (oh, ow)
}

/// SAME-padding im2col: returns `[P, L]` where `P = out_h * out_w`,
/// `L = k*k*c`.  Out-of-bounds taps read 0.  Allocating convenience
/// wrapper over [`im2col_into`].
pub fn im2col(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
) -> (Vec<i32>, usize, usize) {
    let (oh, ow) = out_dims(h, w, stride);
    let mut out = vec![0i32; oh * ow * k * k * c];
    im2col_into(&mut out, input, h, w, c, k, stride);
    (out, oh, ow)
}

/// Per-channel im2col for depthwise conv, into a caller-owned
/// `[P, K*K]` buffer holding the windows of channel `ch` only.
pub fn im2col_channel_into(
    out: &mut [i32],
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    ch: usize,
    k: usize,
    stride: usize,
) -> (usize, usize) {
    assert_eq!(input.len(), h * w * c, "input shape mismatch");
    let (oh, ow) = out_dims(h, w, stride);
    let pad = (k - 1) / 2;
    let l = k * k;
    assert_eq!(out.len(), oh * ow * l, "im2col output shape mismatch");
    out.fill(0);
    for oy in 0..oh {
        for ox in 0..ow {
            let base = (oy * ow + ox) * l;
            for ky in 0..k {
                for kx in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                        continue;
                    }
                    out[base + ky * k + kx] = input[((iy as usize) * w + ix as usize) * c + ch];
                }
            }
        }
    }
    (oh, ow)
}

/// Per-channel im2col for depthwise conv: returns `[P, K*K]` windows of
/// channel `ch` only.  Allocating wrapper over [`im2col_channel_into`].
pub fn im2col_channel(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    ch: usize,
    k: usize,
    stride: usize,
) -> (Vec<i32>, usize, usize) {
    let (oh, ow) = out_dims(h, w, stride);
    let mut out = vec![0i32; oh * ow * k * k];
    im2col_channel_into(&mut out, input, h, w, c, ch, k, stride);
    (out, oh, ow)
}

/// Direct convolution oracle (std-conv, SAME padding): `[N]` filters of
/// `[L]` against an HWC input — `[P, N]` i64 outputs.
pub fn direct_conv(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32],
    n: usize,
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let (cols, oh, ow) = im2col(input, h, w, c, k, stride);
    let l = k * k * c;
    let mut out = vec![0i64; oh * ow * n];
    for p in 0..oh * ow {
        for f in 0..n {
            let mut acc = 0i64;
            for i in 0..l {
                acc += cols[p * l + i] as i64 * filters[f * l + i] as i64;
            }
            out[p * n + f] = acc;
        }
    }
    out
}

/// Direct depthwise convolution oracle: `[P, C]` outputs.
pub fn direct_dwconv(
    input: &[i32],
    h: usize,
    w: usize,
    c: usize,
    filters: &[i32], // [C, K*K]
    k: usize,
    stride: usize,
) -> Vec<i64> {
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let mut out = vec![0i64; oh * ow * c];
    for ch in 0..c {
        let (cols, _, _) = im2col_channel(input, h, w, c, ch, k, stride);
        for p in 0..oh * ow {
            let mut acc = 0i64;
            for i in 0..k * k {
                acc += cols[p * k * k + i] as i64 * filters[ch * k * k + i] as i64;
            }
            out[p * c + ch] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_1x1() {
        // 1x1 im2col is just a reshape
        let input: Vec<i32> = (0..2 * 2 * 3).collect();
        let (cols, oh, ow) = im2col(&input, 2, 2, 3, 1, 1);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(cols, input);
    }

    #[test]
    fn same_padding_3x3() {
        // all-ones 3x3 input, single channel, 3x3 kernel of ones:
        // corner output = 4 taps in bounds
        let input = vec![1i32; 9];
        let filt = vec![1i32; 9];
        let out = direct_conv(&input, 3, 3, 1, &filt, 1, 3, 1);
        assert_eq!(out[0], 4); // top-left corner
        assert_eq!(out[4], 9); // center
    }

    #[test]
    fn stride_2_shape() {
        let input = vec![0i32; 5 * 5];
        let (_, oh, ow) = im2col(&input, 5, 5, 1, 3, 2);
        assert_eq!((oh, ow), (3, 3));
    }

    #[test]
    fn dw_matches_std_with_diagonal_filters() {
        // dw-conv == std-conv with block-diagonal filters
        let mut rng = Rng::new(81);
        let (h, w, c, k) = (4, 4, 3, 3);
        let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
        let dwf: Vec<i32> = (0..c * k * k).map(|_| rng.int8() as i32).collect();
        // expand to std filters [C, K*K*C] with zeros off-channel
        let l = k * k * c;
        let mut stdf = vec![0i32; c * l];
        for ch in 0..c {
            for t in 0..k * k {
                stdf[ch * l + t * c + ch] = dwf[ch * k * k + t];
            }
        }
        let dw = direct_dwconv(&input, h, w, c, &dwf, k, 1);
        let st = direct_conv(&input, h, w, c, &stdf, c, k, 1);
        assert_eq!(dw, st);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // the zero-alloc twins must fully overwrite a reused buffer,
        // including the zero-padding taps a previous call left behind
        let mut rng = Rng::new(83);
        let (h, w, c, k) = (4, 3, 2, 3);
        let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
        let (want, oh, ow) = im2col(&input, h, w, c, k, 1);
        let mut buf = vec![i32::MAX; oh * ow * k * k * c];
        assert_eq!(im2col_into(&mut buf, &input, h, w, c, k, 1), (oh, ow));
        assert_eq!(buf, want);
        let (want_ch, _, _) = im2col_channel(&input, h, w, c, 1, k, 1);
        let mut chbuf = vec![i32::MIN; oh * ow * k * k];
        im2col_channel_into(&mut chbuf, &input, h, w, c, 1, k, 1);
        assert_eq!(chbuf, want_ch);
    }

    #[test]
    fn channel_extraction_consistent() {
        let mut rng = Rng::new(82);
        let (h, w, c) = (3, 3, 2);
        let input: Vec<i32> = (0..h * w * c).map(|_| rng.int8() as i32).collect();
        let (cols, _, _) = im2col(&input, h, w, c, 3, 1);
        let (ch1, _, _) = im2col_channel(&input, h, w, c, 1, 3, 1);
        // channel 1 of the full im2col equals the per-channel extraction
        for p in 0..9 {
            for t in 0..9 {
                assert_eq!(cols[p * 18 + t * 2 + 1], ch1[p * 9 + t]);
            }
        }
    }
}
