//! Data mapping (paper §III-D): offline decomposition + mapping
//! strategies for std/pw-conv, dw-conv and FC layers.
//!
//! * [`im2col`] — input/window lowering used by both the functional
//!   executor and the AOT model.
//! * [`plan`] — the per-layer cycle/resource plan the timing engine and
//!   the ISA generator consume.
//! * [`exec`] — functional executor: plans a conv layer onto the
//!   bit-true [`crate::arch::pim_macro::PimMacro`] (weights written
//!   once) and executes inputs through the resident weights, recovering
//!   outputs via the ARU; verified against the direct-conv oracle.  It
//!   also owns the capacity-budget primitives of weight streaming:
//!   [`exec::stored_weight_bytes`] sizes a layer's resident footprint
//!   and [`exec::plan_reload_passes`] splits a layer stack into reload
//!   passes that fit a budget (consumed by the streaming session in
//!   `runtime/reference.rs`).

pub mod exec;
pub mod im2col;
pub mod plan;

pub use exec::{
    plan_reload_passes, stored_weight_bytes, ExecCtx, ExecPool, PlannedConv, PlannedDwConv,
};
pub use plan::{plan_layer, plan_network, LayerPlan, PlanKind};
