//! Data mapping (paper §III-D): offline decomposition + mapping
//! strategies for std/pw-conv, dw-conv and FC layers.
//!
//! * [`im2col`] — input/window lowering used by both the functional
//!   executor and the AOT model.
//! * [`plan`] — the per-layer cycle/resource plan the timing engine and
//!   the ISA generator consume.
//! * [`exec`] — functional executor: plans a conv layer onto the
//!   bit-true [`crate::arch::pim_macro::PimMacro`] (weights written
//!   once) and executes inputs through the resident weights, recovering
//!   outputs via the ARU; verified against the direct-conv oracle.  It
//!   also owns the capacity-budget primitives of weight streaming:
//!   [`exec::stored_weight_bytes`] sizes a layer's resident footprint
//!   and [`exec::plan_reload_passes`] splits a layer stack into reload
//!   passes that fit a budget (consumed by the streaming session in
//!   `runtime/reference.rs`).
//! * [`shard`] — grid shard planner: splits one conv layer across a
//!   [`crate::arch::grid::MacroGrid`]'s tiles as independent
//!   single-macro plans with provably disjoint output slices
//!   (std/pw convs by output-channel range, dw convs by output
//!   pixel-row band), byte-identical to the single-macro plan at every
//!   grid shape and pool width.

pub mod exec;
pub mod im2col;
pub mod plan;
pub mod shard;

pub use exec::{
    plan_reload_passes, stored_weight_bytes, ExecCtx, ExecPool, PlannedConv, PlannedDwConv,
};
pub use plan::{plan_layer, plan_network, LayerPlan, PlanKind};
pub use shard::{ShardedConv, ShardedDwConv};
