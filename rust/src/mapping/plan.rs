//! Per-layer mapping plans: how a layer tiles onto the PIM fabric and
//! what it costs (cycles, loads, DRAM traffic) — the quantitative heart
//! of the Fig. 13/14 reproduction.
//!
//! Cycle model (derived from §III-C/D):
//!
//! * one *row-step* = activating one stored row across the compartments
//!   for a full bit-serial input pass = `input_bits` cycles;
//! * **std/pw**: a row-step covers 32 reduction positions and
//!   `weights_per_row` stored filters; double-computing mode (DBIS +
//!   FCC) doubles the output channels per stored filter → 4 channels
//!   per row-step vs 2 for the baseline;
//! * **dw**: a filter occupies `k*k` of the 32 compartments; the
//!   baseline computes 1 channel per row-step (parallelism `9x1x8`),
//!   FCC+DBIS pairs channels on INP/INN (2 per row-step, `9x1x16`), and
//!   the reconfigurable unit's split grouping + padding doubles spatial
//!   utilization again (4 per row-step in two alternating stages,
//!   `18x1x16`) when `2*k*k` compartments fit;
//! * weight loads: one 16-bit row write per cycle per macro; FCC halves
//!   the stored weights (only even comp filters are written);
//! * FC layers: regular mode, no FCC (paper §III-B).

use crate::config::{ArchConfig, SimConfig};
use crate::model::{ConvKind, Layer, Network};

/// How a layer maps onto the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// std/pw conv, regular computing mode (baseline or non-FCC layer).
    StdRegular,
    /// std/pw conv, double computing mode (FCC; INP == INN).
    StdDouble,
    /// dw conv, regular mode, one channel per row-step.
    DwRegular,
    /// dw conv, FCC + DBIS: channel pair per row-step.
    DwDbis,
    /// dw conv, FCC + DBIS + reconfigurable unit: 4 channels/row-step.
    DwReconfig,
    /// FC / attention — regular mode on the FC path.
    Fc,
    /// No PIM work (pool / gap handled by post-process).
    PostProcess,
}

/// The plan for one layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub name: String,
    pub kind: PlanKind,
    /// Weight-stationary compute cycles.
    pub compute_cycles: u64,
    /// SRAM row-write cycles for weight loading (all passes).
    pub load_cycles: u64,
    /// Merge/ARU pipeline flush overhead.
    pub merge_cycles: u64,
    /// Weight bytes fetched from DRAM (FCC: halved + means).
    pub dram_weight_bytes: u64,
    /// Activation bytes moved on-chip (ping-pong traffic).
    pub sram_act_bytes: u64,
    /// Number of weight-reload passes (core capacity overflow).
    pub passes: u64,
    /// MAC count (for GOPS/energy accounting).
    pub macs: u64,
    /// Spatial utilization of the compartment dimension (0..1).
    pub utilization: f64,
    /// Whether FCC is applied to this layer.
    pub fcc: bool,
}

impl LayerPlan {
    /// Cycles the layer occupies the PIM fabric (loads stall compute;
    /// merge is pipelined and only its flush is exposed).
    pub fn pim_cycles(&self) -> u64 {
        self.compute_cycles + self.load_cycles + self.merge_cycles
    }

    fn empty(name: String, kind: PlanKind) -> Self {
        LayerPlan {
            name,
            kind,
            compute_cycles: 0,
            load_cycles: 0,
            merge_cycles: 0,
            dram_weight_bytes: 0,
            sram_act_bytes: 0,
            passes: 0,
            macs: 0,
            utilization: 1.0,
            fcc: false,
        }
    }
}

/// Merge-pipeline flush cost per weight-reload pass (adder tree depth +
/// shift-&-add + ARU stages).
const MERGE_FLUSH_CYCLES: u64 = 8;

fn std_pw_plan(
    name: &str,
    l: usize,
    n: usize,
    pixels: usize,
    macs: u64,
    fcc: bool,
    arch: &ArchConfig,
) -> LayerPlan {
    let cmp = arch.compartments;
    let wpr = arch.weights_per_row(); // stored filters per row
    let ib = arch.input_bits as u64;

    // channels produced per row-step per macro
    let ch_per_step = wpr * if fcc { 2 } else { 1 };
    // filters assigned per macro (output-channel tiling across macros)
    let n_per_macro = n.div_ceil(arch.macros);
    let l_tiles = l.div_ceil(cmp);
    let steps_per_pixel = l_tiles * n_per_macro.div_ceil(ch_per_step);
    let compute_cycles = pixels as u64 * steps_per_pixel as u64 * ib;

    // stored 8-bit weights per macro (FCC stores only even comp filters)
    let stored_per_macro = l * n_per_macro / if fcc { 2 } else { 1 };
    let rows_needed = steps_per_pixel; // one row per (l-tile, filter-group)
    let passes = (rows_needed as u64).div_ceil(arch.rows as u64).max(1);
    let load_cycles = (stored_per_macro as u64).div_ceil(wpr as u64);

    // DRAM: all macros' weights stream in once (+ 1 byte M per pair)
    let total_weights = l * n;
    let dram_weight_bytes = if fcc {
        (total_weights / 2 + n / 2) as u64
    } else {
        total_weights as u64
    };

    let utilization = l as f64 / (l_tiles * cmp) as f64;
    LayerPlan {
        name: name.to_string(),
        kind: if fcc { PlanKind::StdDouble } else { PlanKind::StdRegular },
        compute_cycles,
        load_cycles,
        merge_cycles: passes * MERGE_FLUSH_CYCLES,
        dram_weight_bytes,
        sram_act_bytes: (pixels * l) as u64,
        passes,
        macs,
        utilization,
        fcc,
    }
}

fn dw_plan(
    name: &str,
    k: usize,
    c: usize,
    pixels: usize,
    macs: u64,
    fcc_dbis: bool,
    arch: &ArchConfig,
) -> LayerPlan {
    let taps = k * k;
    let ib = arch.input_bits as u64;
    // reconfig doubling requires two filter groups to fit spatially
    let reconfig_ok = arch.reconfig && 2 * taps <= arch.compartments;
    let (kind, ch_per_step) = if fcc_dbis && reconfig_ok {
        (PlanKind::DwReconfig, 4)
    } else if fcc_dbis {
        (PlanKind::DwDbis, 2)
    } else {
        (PlanKind::DwRegular, 1)
    };
    // dw-conv cannot parallelize across macros: the pre-process unit
    // broadcasts ONE input stream to all four macros, but each dw channel
    // needs its own window — hence the paper's Y = 1 in the 9x1x8 /
    // 18x1x16 parallelism figures.  All channels run through one macro.
    let steps_per_pixel = c.div_ceil(ch_per_step);
    let compute_cycles = pixels as u64 * steps_per_pixel as u64 * ib;

    // stored weights: FCC halves the channel filters
    let stored_per_macro = taps * c / if fcc_dbis { 2 } else { 1 };
    let load_cycles = (stored_per_macro as u64).div_ceil(arch.weights_per_row() as u64);
    let rows_needed = steps_per_pixel;
    let passes = (rows_needed as u64).div_ceil(arch.rows as u64).max(1);

    let total_weights = taps * c;
    let dram_weight_bytes = if fcc_dbis {
        (total_weights / 2 + c / 2) as u64
    } else {
        total_weights as u64
    };

    let spatial = match kind {
        PlanKind::DwReconfig => 2 * taps,
        _ => taps,
    };
    LayerPlan {
        name: name.to_string(),
        kind,
        compute_cycles,
        load_cycles,
        merge_cycles: passes * MERGE_FLUSH_CYCLES,
        dram_weight_bytes,
        sram_act_bytes: (pixels * taps * c) as u64 / c.max(1) as u64 * c as u64,
        passes,
        macs,
        utilization: spatial as f64 / arch.compartments as f64,
        fcc: fcc_dbis,
    }
}

/// Build the plan for one layer under `(arch, sim)`.
pub fn plan_layer(layer: &Layer, arch: &ArchConfig, sim: &SimConfig) -> LayerPlan {
    match layer {
        Layer::Conv {
            name,
            kind,
            k,
            cin,
            cout,
            ..
        } => {
            let (oh, ow) = layer.out_hw();
            let pixels = oh * ow;
            let macs = layer.macs() as u64;
            match kind {
                ConvKind::Depthwise => {
                    let fcc = sim.fcc_dw
                        && layer.fcc_eligible()
                        && *cout > sim.scope_threshold
                        && arch.dbis
                        && arch.recover;
                    dw_plan(name, *k, *cin, pixels, macs, fcc, arch)
                }
                _ => {
                    let fcc = sim.fcc_std_pw
                        && layer.fcc_eligible()
                        && *cout > sim.scope_threshold
                        && arch.dbis
                        && arch.recover;
                    std_pw_plan(name, k * k * cin, *cout, pixels, macs, fcc, arch)
                }
            }
        }
        Layer::Fc { name, cin, cout } => {
            let mut p = std_pw_plan(name, *cin, *cout, 1, layer.macs() as u64, false, arch);
            p.kind = PlanKind::Fc;
            p
        }
        Layer::Attention { name, dim, tokens } => {
            // 4 projections + 2 attention matmuls, all on the FC path
            let mut p = std_pw_plan(name, *dim, 4 * dim, *tokens, layer.macs() as u64, false, arch);
            p.kind = PlanKind::Fc;
            p
        }
        Layer::Pool { .. } | Layer::Gap { .. } => {
            LayerPlan::empty(layer.name(), PlanKind::PostProcess)
        }
    }
}

/// Plan a whole network.
pub fn plan_network(net: &Network, arch: &ArchConfig, sim: &SimConfig) -> Vec<LayerPlan> {
    net.layers
        .iter()
        .map(|l| plan_layer(l, arch, sim))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn conv(kind: ConvKind, k: usize, cin: usize, cout: usize, hw: usize) -> Layer {
        Layer::Conv {
            name: "t".into(),
            kind,
            k,
            cin,
            cout,
            stride: 1,
            in_h: hw,
            in_w: hw,
        }
    }

    #[test]
    fn fcc_halves_std_compute() {
        let arch = ArchConfig::ddc_pim();
        let layer = conv(ConvKind::Pointwise, 1, 64, 128, 16);
        let base = plan_layer(&layer, &arch, &SimConfig::baseline());
        let ddc = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert_eq!(base.kind, PlanKind::StdRegular);
        assert_eq!(ddc.kind, PlanKind::StdDouble);
        assert_eq!(base.compute_cycles, 2 * ddc.compute_cycles);
        // loads and DRAM traffic roughly halved too
        assert!(ddc.load_cycles <= base.load_cycles / 2 + 1);
        assert!(ddc.dram_weight_bytes < base.dram_weight_bytes / 2 + 128);
    }

    #[test]
    fn dw_speedup_ladder_is_1_2_4() {
        let arch = ArchConfig::ddc_pim();
        let layer = conv(ConvKind::Depthwise, 3, 128, 128, 16);
        let base = plan_layer(&layer, &arch, &SimConfig::baseline());
        let full = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert_eq!(base.kind, PlanKind::DwRegular);
        assert_eq!(full.kind, PlanKind::DwReconfig);
        assert_eq!(base.compute_cycles, 4 * full.compute_cycles);

        // DBIS-only arch (no reconfig): 2x
        let mut arch2 = ArchConfig::ddc_pim();
        arch2.reconfig = false;
        let dbis = plan_layer(&layer, &arch2, &SimConfig::ddc_full());
        assert_eq!(dbis.kind, PlanKind::DwDbis);
        assert_eq!(base.compute_cycles, 2 * dbis.compute_cycles);
    }

    #[test]
    fn dw_5x5_no_reconfig_doubling() {
        // 2*25 > 32 compartments: reconfig cannot double 5x5 dw
        let arch = ArchConfig::ddc_pim();
        let layer = conv(ConvKind::Depthwise, 5, 64, 64, 8);
        let p = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert_eq!(p.kind, PlanKind::DwDbis);
    }

    #[test]
    fn dw_parallelism_matches_paper() {
        // paper §III-D2: 3x3 dw utilization 9/32 baseline, 18/32 with
        // padding + reconfig
        let arch = ArchConfig::ddc_pim();
        let layer = conv(ConvKind::Depthwise, 3, 32, 32, 8);
        let base = plan_layer(&layer, &arch, &SimConfig::baseline());
        let full = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert!((base.utilization - 9.0 / 32.0).abs() < 1e-9);
        assert!((full.utilization - 18.0 / 32.0).abs() < 1e-9);
    }

    #[test]
    fn fc_never_fcc() {
        let arch = ArchConfig::ddc_pim();
        let layer = Layer::Fc {
            name: "fc".into(),
            cin: 1280,
            cout: 10,
        };
        let p = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert_eq!(p.kind, PlanKind::Fc);
        assert!(!p.fcc);
    }

    #[test]
    fn scope_threshold_gates_fcc() {
        let arch = ArchConfig::ddc_pim();
        let layer = conv(ConvKind::Pointwise, 1, 32, 64, 8);
        let mut sim = SimConfig::ddc_full();
        sim.scope_threshold = 64; // cout not > 64
        let p = plan_layer(&layer, &arch, &sim);
        assert!(!p.fcc);
        sim.scope_threshold = 63;
        assert!(plan_layer(&layer, &arch, &sim).fcc);
    }

    #[test]
    fn baseline_arch_ignores_fcc_request() {
        // without DBIS/ARU hardware the FCC mapping is impossible
        let arch = ArchConfig::baseline();
        let layer = conv(ConvKind::Pointwise, 1, 64, 64, 8);
        let p = plan_layer(&layer, &arch, &SimConfig::ddc_full());
        assert!(!p.fcc);
        assert_eq!(p.kind, PlanKind::StdRegular);
    }

    #[test]
    fn mobilenet_dw_dominates_baseline_latency() {
        // the paper's premise: dw-conv dominates compact-NN latency on
        // the baseline despite having far fewer MACs
        let arch = ArchConfig::baseline();
        let net = zoo::mobilenet_v2();
        let plans = plan_network(&net, &arch, &SimConfig::baseline());
        let dw_cycles: u64 = plans
            .iter()
            .filter(|p| matches!(p.kind, PlanKind::DwRegular))
            .map(|p| p.pim_cycles())
            .sum();
        let total: u64 = plans.iter().map(|p| p.pim_cycles()).sum();
        let frac = dw_cycles as f64 / total as f64;
        assert!(frac > 0.5, "dw fraction {frac}");
        let dw_macs: u64 = plans
            .iter()
            .filter(|p| matches!(p.kind, PlanKind::DwRegular))
            .map(|p| p.macs)
            .sum();
        let total_macs: u64 = plans.iter().map(|p| p.macs).sum();
        assert!((dw_macs as f64 / total_macs as f64) < 0.15);
    }

    #[test]
    fn pool_layers_free() {
        let arch = ArchConfig::ddc_pim();
        let p = plan_layer(
            &Layer::Pool { in_h: 8, in_w: 8, c: 64 },
            &arch,
            &SimConfig::ddc_full(),
        );
        assert_eq!(p.pim_cycles(), 0);
    }
}
