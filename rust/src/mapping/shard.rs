//! Grid shard planner: split one conv layer across a
//! [`MacroGrid`]'s tiles as *independent single-macro plans* with
//! provably disjoint output slices.
//!
//! Two sharding axes, one per layer family:
//!
//! * **std/pw convs** ([`ShardedConv`]) split by *output channel
//!   range*.  FCC double-computing interleaves each stored pair `p`'s
//!   twins at output channels `2p` / `2p+1`, so the FCC planner
//!   partitions *stored pairs* — a pair range `[p0, p1)` owns the
//!   contiguous channel range `[2p0, 2p1)` and slices contiguous rows
//!   of the comp bank (`[2p0, 2p1)`) plus `means[p0..p1]`.  Regular
//!   mode partitions plain channels.  Every pixel of every shard sees
//!   the identical im2col window and the identical stored weight
//!   vector as the single-macro plan, and psum accumulation walks the
//!   same `l`-tile order (tile count depends only on `L` and the
//!   compartment width), so each output element is byte-identical by
//!   construction.
//! * **dw convs** ([`ShardedDwConv`]) split *spatially* by output
//!   pixel-row bands.  SAME padding makes naive slabs wrong at interior
//!   seams (a tile's own zero padding would land where the full conv
//!   reads real halo rows), so each shard takes a stride-aligned input
//!   slab that *includes* the halo, lets the single-macro plan compute
//!   a few lead/tail rows redundantly, and keeps only the band whose
//!   windows are provably identical to the full plan's: rows whose
//!   windows either lie entirely inside the slab, or pad exactly where
//!   the full input pads (slab start == row 0 / slab end == row `H`).
//!
//! Both execute across the caller's existing
//! [`ExecPool`], so grid × thread-width composes: byte-identity holds
//! at every `(grid shape, pool width)` pair (`tests/grid_semantics.rs`
//! sweeps the matrix).

use std::ops::Range;

use crate::arch::fault::{FaultConfig, FaultTally, ScrubReport, UpsetConfig};
use crate::arch::grid::MacroGrid;
use crate::fcc::{FccWeights, FilterBank};

use super::exec::{ExecPool, PlannedConv, PlannedDwConv};
use super::im2col::out_dims;

/// Derive a shard-private fault stream so sibling tiles (physically
/// distinct macros) fault independently but deterministically.
fn shard_fault(fault: Option<&FaultConfig>, shard: usize) -> Option<FaultConfig> {
    fault.map(|cfg| FaultConfig {
        seed: cfg.seed ^ (shard as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        ..*cfg
    })
}

/// One std/pw shard: a single-macro plan owning output channels
/// `[ch0, ch0 + plan.out_channels())`.
struct StdShard {
    plan: PlannedConv,
    ch0: usize,
}

/// A std/pw-conv split across a macro grid by output-channel range.
/// Build once with [`ShardedConv::std_fcc`] / [`ShardedConv::std_regular`],
/// then call [`ShardedConv::execute_batch_par`] per batch — same
/// plan/execute lifecycle as [`PlannedConv`] (weights written exactly
/// once, at build).
pub struct ShardedConv {
    shards: Vec<StdShard>,
    oh: usize,
    ow: usize,
    n: usize,
}

impl ShardedConv {
    /// Shard an FCC double-computing std/pw conv across `grid`:
    /// stored-pair ranges, each shard an independent
    /// [`PlannedConv::std_fcc_with`] over its slice of the comp bank.
    #[allow(clippy::too_many_arguments)]
    pub fn std_fcc(
        grid: &MacroGrid,
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights,
        k: usize,
        stride: usize,
        faults: Option<&FaultConfig>,
    ) -> ShardedConv {
        let l = k * k * c;
        assert_eq!(fcc.comp.l, l, "filter length mismatch");
        let n = fcc.comp.n;
        let pairs = n / 2;
        let geom = grid.geometry();
        let shards = grid
            .partition(pairs)
            .into_iter()
            .enumerate()
            .map(|(si, pr)| {
                let sub = FccWeights {
                    comp: FilterBank::new(
                        fcc.comp.data[2 * pr.start * l..2 * pr.end * l].to_vec(),
                        2 * pr.len(),
                        l,
                    ),
                    means: fcc.means[pr.clone()].to_vec(),
                };
                StdShard {
                    plan: PlannedConv::std_fcc_faulted(
                        geom,
                        h,
                        w,
                        c,
                        &sub,
                        k,
                        stride,
                        shard_fault(faults, si).as_ref(),
                    ),
                    ch0: 2 * pr.start,
                }
            })
            .collect();
        let (oh, ow) = out_dims(h, w, stride);
        ShardedConv { shards, oh, ow, n }
    }

    /// Shard a regular-mode std/pw conv across `grid` by plain output
    /// channel ranges.
    #[allow(clippy::too_many_arguments)]
    pub fn std_regular(
        grid: &MacroGrid,
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [N, L]
        n: usize,
        k: usize,
        stride: usize,
        faults: Option<&FaultConfig>,
    ) -> ShardedConv {
        let l = k * k * c;
        assert_eq!(filters.len(), n * l, "filter bank shape mismatch");
        let geom = grid.geometry();
        let shards = grid
            .partition(n)
            .into_iter()
            .enumerate()
            .map(|(si, cr)| StdShard {
                plan: PlannedConv::std_regular_faulted(
                    geom,
                    h,
                    w,
                    c,
                    &filters[cr.start * l..cr.end * l],
                    cr.len(),
                    k,
                    stride,
                    shard_fault(faults, si).as_ref(),
                ),
                ch0: cr.start,
            })
            .collect();
        let (oh, ow) = out_dims(h, w, stride);
        ShardedConv { shards, oh, ow, n }
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.oh, self.ow)
    }

    /// Output channel count (all shards together).
    pub fn out_channels(&self) -> usize {
        self.n
    }

    /// `execute` output length (`oh * ow * n`).
    pub fn out_len(&self) -> usize {
        self.oh * self.ow * self.n
    }

    /// Number of grid tiles holding a non-empty shard.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard output channel ranges, in tile order — the disjoint /
    /// covering slices the grid tests pin.
    pub fn channel_ranges(&self) -> Vec<Range<usize>> {
        self.shards
            .iter()
            .map(|s| s.ch0..s.ch0 + s.plan.out_channels())
            .collect()
    }

    /// Total SRAM weight writes across all shards (constant after
    /// build — the residency invariant, per shard).
    pub fn weight_writes(&self) -> u64 {
        self.shards.iter().map(|s| s.plan.weight_writes()).sum()
    }

    /// Weight-reload passes across all shards at build time.
    pub fn load_passes(&self) -> usize {
        self.shards.iter().map(|s| s.plan.load_passes()).sum()
    }

    /// Bytes of stored INT8 weights resident across the whole grid.
    pub fn weight_footprint_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.plan.weight_footprint_bytes()).sum()
    }

    /// Integrity-scrub every shard's macros, returning the merged
    /// report (see [`PlannedConv::scrub`]).
    pub fn scrub(&mut self) -> ScrubReport {
        let mut report = ScrubReport::default();
        for s in &mut self.shards {
            report.merge(&s.plan.scrub());
        }
        report
    }

    /// Merged lifetime fault totals across every shard's macros.
    pub fn fault_tally(&self) -> FaultTally {
        let mut tally = FaultTally::default();
        for s in &self.shards {
            tally.merge(&s.plan.fault_tally());
        }
        tally
    }

    /// Arm the retention-upset process on every shard, with the seed
    /// salted per shard (same constant [`shard_fault`] decorrelates
    /// seeded fault plans with).
    pub fn arm_upsets(&mut self, cfg: UpsetConfig) {
        for (si, s) in self.shards.iter_mut().enumerate() {
            let seed = cfg.seed ^ ((si as u64) + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            s.plan.arm_upsets(UpsetConfig::new(seed, cfg.per_batch_ber));
        }
    }

    /// Advance every shard's virtual batch clock one tick; returns the
    /// total upset bits landed across the grid.
    pub fn tick_upsets(&mut self) -> u64 {
        self.shards.iter_mut().map(|s| s.plan.tick_upsets()).sum()
    }

    /// Scrub stripes across all shards (concatenated stripe space).
    pub fn stripe_count(&self) -> usize {
        self.shards.iter().map(|s| s.plan.stripe_count()).sum()
    }

    /// Incrementally scrub the stripe window `[start, start+len)` of
    /// the concatenated per-shard stripe space.
    pub fn scrub_window(&mut self, start: usize, len: usize) -> ScrubReport {
        let mut report = ScrubReport::default();
        let mut base = 0usize;
        let end = start.saturating_add(len);
        for s in &mut self.shards {
            let n = s.plan.stripe_count();
            let lo = start.max(base).min(base + n);
            let hi = end.min(base + n);
            if hi > lo {
                report.merge(&s.plan.scrub_window(lo - base, hi - lo));
            }
            base += n;
        }
        report
    }

    /// Batched parallel execute across the grid: every shard runs
    /// [`PlannedConv::execute_batch_par`] on the shared pool into
    /// `scratch` (a `[batch * P, shard_n]` staging buffer, grown once),
    /// then scatters its contiguous channel slice into the caller's
    /// `[batch * P, N]` output.  Shards run in tile order; because each
    /// owns a disjoint channel range, the result is independent of that
    /// order and byte-identical to the single-macro plan at every grid
    /// shape and pool width.
    pub fn execute_batch_par(
        &self,
        input: &[i32],
        batch: usize,
        pool: &mut ExecPool,
        scratch: &mut Vec<i64>,
        out: &mut [i64],
    ) {
        assert_eq!(out.len(), batch * self.out_len(), "output shape mismatch");
        let rows = batch * self.oh * self.ow;
        for shard in &self.shards {
            let sn = shard.plan.out_channels();
            scratch.resize(rows * sn, 0);
            shard.plan.execute_batch_par(input, batch, pool, scratch);
            for r in 0..rows {
                out[r * self.n + shard.ch0..r * self.n + shard.ch0 + sn]
                    .copy_from_slice(&scratch[r * sn..(r + 1) * sn]);
            }
        }
    }

    /// Single-input convenience twin of
    /// [`ShardedConv::execute_batch_par`].
    pub fn execute_par(
        &self,
        input: &[i32],
        pool: &mut ExecPool,
        scratch: &mut Vec<i64>,
        out: &mut [i64],
    ) {
        self.execute_batch_par(input, 1, pool, scratch, out)
    }
}

/// One dw shard: a single-macro plan over an input row slab, keeping
/// output rows `[y0, y1)` (plan-local rows `[t_skip, t_skip + y1 - y0)`).
struct DwShard {
    plan: PlannedDwConv,
    /// Output row band this shard owns in the full `[oh, ow, C]` output.
    y0: usize,
    y1: usize,
    /// First input row of the slab (stride-aligned).
    a: usize,
    /// Input rows in the slab.
    h_s: usize,
    /// Leading plan-local output rows computed redundantly (halo
    /// discard).
    t_skip: usize,
}

/// A dw-conv split across a macro grid by output pixel-row bands.
pub struct ShardedDwConv {
    shards: Vec<DwShard>,
    w: usize,
    c: usize,
    oh: usize,
    ow: usize,
}

/// Slab math shared by both dw shard builders: for output rows
/// `[y0, y1)` of a SAME-padded conv, the stride-aligned input slab and
/// the lead rows to discard so every *kept* row's window is identical
/// to the full plan's (interior seams read real halo rows from the
/// slab; top/bottom padding only ever fires where the full plan also
/// pads).
fn dw_slab(h: usize, k: usize, stride: usize, y0: usize, y1: usize) -> (usize, usize, usize) {
    let pad = (k - 1) / 2;
    let lead = pad.div_ceil(stride);
    let y0p = y0.saturating_sub(lead);
    let t_skip = y0 - y0p;
    let a = y0p * stride;
    let end_s = (y1 - 1 - y0p) * stride + k - pad;
    let h_s = end_s.min(h - a);
    (a, h_s, t_skip)
}

impl ShardedDwConv {
    /// Shard an FCC (+DBIS / reconfig) dw conv spatially across `grid`.
    #[allow(clippy::too_many_arguments)]
    pub fn fcc(
        grid: &MacroGrid,
        h: usize,
        w: usize,
        c: usize,
        fcc: &FccWeights, // [C, K*K] comp filters, channel pairs
        k: usize,
        stride: usize,
        reconfig: bool,
    ) -> ShardedDwConv {
        Self::build(grid, h, w, c, k, stride, |h_s| {
            PlannedDwConv::fcc_with(grid.geometry(), h_s, w, c, fcc, k, stride, reconfig)
        })
    }

    /// Shard a regular-mode dw conv spatially across `grid`.
    pub fn regular(
        grid: &MacroGrid,
        h: usize,
        w: usize,
        c: usize,
        filters: &[i32], // [C, K*K]
        k: usize,
        stride: usize,
    ) -> ShardedDwConv {
        Self::build(grid, h, w, c, k, stride, |h_s| {
            PlannedDwConv::regular_with(grid.geometry(), h_s, w, c, filters, k, stride)
        })
    }

    fn build(
        grid: &MacroGrid,
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        stride: usize,
        plan_slab: impl Fn(usize) -> PlannedDwConv,
    ) -> ShardedDwConv {
        let (oh, ow) = out_dims(h, w, stride);
        let shards = grid
            .partition(oh)
            .into_iter()
            .map(|band| {
                let (a, h_s, t_skip) = dw_slab(h, k, stride, band.start, band.end);
                DwShard {
                    plan: plan_slab(h_s),
                    y0: band.start,
                    y1: band.end,
                    a,
                    h_s,
                    t_skip,
                }
            })
            .collect();
        ShardedDwConv { shards, w, c, oh, ow }
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.oh, self.ow)
    }

    /// `execute` output length (`oh * ow * c`).
    pub fn out_len(&self) -> usize {
        self.oh * self.ow * self.c
    }

    /// Number of grid tiles holding a non-empty shard.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard output pixel-row bands, in tile order — disjoint and
    /// covering `0..oh`.
    pub fn row_ranges(&self) -> Vec<Range<usize>> {
        self.shards.iter().map(|s| s.y0..s.y1).collect()
    }

    /// Total SRAM weight writes across all shards (constant after
    /// build).
    pub fn weight_writes(&self) -> u64 {
        self.shards.iter().map(|s| s.plan.weight_writes()).sum()
    }

    /// Parallel execute across the grid: each shard runs its plan over
    /// its (contiguous) input row slab on the shared pool, into
    /// `scratch`, then copies its kept row band — a contiguous slice of
    /// the row-major `[oh, ow, C]` output — into place.  Halo rows are
    /// computed redundantly and discarded; kept rows are byte-identical
    /// to the single-macro plan (see the module docs).
    pub fn execute_par(
        &self,
        input: &[i32],
        pool: &mut ExecPool,
        scratch: &mut Vec<i64>,
        out: &mut [i64],
    ) {
        assert_eq!(out.len(), self.out_len(), "output shape mismatch");
        let row = self.ow * self.c; // one output pixel row, flattened
        let irow = self.w * self.c; // one input row, flattened
        for shard in &self.shards {
            scratch.resize(shard.plan.out_len(), 0);
            shard.plan.execute_par(
                &input[shard.a * irow..(shard.a + shard.h_s) * irow],
                pool,
                scratch,
            );
            let keep = shard.y1 - shard.y0;
            out[shard.y0 * row..shard.y1 * row]
                .copy_from_slice(&scratch[shard.t_skip * row..(shard.t_skip + keep) * row]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::grid::{GridShape, MacroGrid};
    use crate::arch::pim_core::MacroGeometry;
    use crate::fcc::fcc_transform;
    use crate::util::rng::Rng;

    fn bank(rng: &mut Rng, n: usize, l: usize) -> FilterBank {
        FilterBank::new((0..n * l).map(|_| rng.range_i64(-128, 128) as i32).collect(), n, l)
    }

    #[test]
    fn dw_slab_math_stays_in_bounds() {
        for h in 1..20 {
            for k in [1usize, 3, 5] {
                for stride in [1usize, 2] {
                    let (oh, _) = out_dims(h, h, stride);
                    for y0 in 0..oh {
                        for y1 in y0 + 1..=oh {
                            let (a, h_s, t_skip) = dw_slab(h, k, stride, y0, y1);
                            assert!(a + h_s <= h, "slab [{a}, {}) exceeds h={h}", a + h_s);
                            assert!(h_s >= 1);
                            let (oh_s, _) = out_dims(h_s, 1, stride);
                            assert!(
                                oh_s >= t_skip + (y1 - y0),
                                "slab rows {h_s} yield {oh_s} < skip {t_skip} + keep {}",
                                y1 - y0
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fcc_shards_agree_with_single_macro() {
        // direct planner-level parity (the full grid × fabric × mode
        // matrix lives in tests/grid_semantics.rs)
        let mut rng = Rng::new(0x51AD);
        let (h, w, c, n, k) = (6usize, 5, 3, 8, 3);
        let fcc = fcc_transform(&bank(&mut rng, n, k * k * c));
        let input: Vec<i32> = (0..h * w * c).map(|_| rng.range_i64(-128, 128) as i32).collect();
        let single = PlannedConv::std_fcc(h, w, c, &fcc, k, 1);
        let mut pool = ExecPool::new(1);
        let mut want = vec![0i64; single.out_len()];
        single.execute_par(&input, &mut pool, &mut want);
        let grid = MacroGrid::new(GridShape::new(2, 2), MacroGeometry::paper());
        let sharded = ShardedConv::std_fcc(&grid, h, w, c, &fcc, k, 1, None);
        assert_eq!(sharded.shard_count(), 4);
        let mut scratch = Vec::new();
        let mut got = vec![0i64; sharded.out_len()];
        sharded.execute_par(&input, &mut pool, &mut scratch, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn dw_shards_agree_with_single_macro() {
        let mut rng = Rng::new(0xD3);
        let (h, w, c, k) = (9usize, 7, 4, 3);
        let fcc = fcc_transform(&bank(&mut rng, c, k * k));
        let input: Vec<i32> = (0..h * w * c).map(|_| rng.range_i64(-128, 128) as i32).collect();
        let single = PlannedDwConv::fcc(h, w, c, &fcc, k, 1, true);
        let mut pool = ExecPool::new(1);
        let mut want = vec![0i64; single.out_len()];
        single.execute_par(&input, &mut pool, &mut want);
        let grid = MacroGrid::new(GridShape::new(1, 3), MacroGeometry::paper());
        let sharded = ShardedDwConv::fcc(&grid, h, w, c, &fcc, k, 1, true);
        let mut scratch = Vec::new();
        let mut got = vec![0i64; sharded.out_len()];
        sharded.execute_par(&input, &mut pool, &mut scratch, &mut got);
        assert_eq!(got, want);
    }
}
