//! Service-level metrics: streaming latency histogram with percentile
//! queries (used by the coordinator and the serving benches).

use std::time::Duration;

/// Log-bucketed latency histogram (1 µs .. ~68 s range).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; 27],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate percentile (upper edge of the containing bucket).
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Throughput accumulator (ops over wall time).
#[derive(Debug, Clone, Default)]
pub struct Throughput {
    pub items: u64,
    pub elapsed: Duration,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.items as f64 / self.elapsed.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 4, 5, 10, 20, 50, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 9);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        assert!(p99 >= Duration::from_millis(64)); // bucket upper edge
    }

    #[test]
    fn mean_and_max() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert_eq!(h.mean(), Duration::from_millis(20));
        assert_eq!(h.max(), Duration::from_millis(30));
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(Duration::from_millis(5));
        b.record(Duration::from_millis(50));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_millis(50));
    }

    #[test]
    fn empty_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.percentile(99.0), Duration::ZERO);
    }

    #[test]
    fn throughput() {
        let t = Throughput {
            items: 100,
            elapsed: Duration::from_secs(2),
        };
        assert!((t.per_sec() - 50.0).abs() < 1e-9);
    }
}
