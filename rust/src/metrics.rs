//! Service-level metrics: streaming latency histogram with percentile
//! queries (used by the coordinator and the serving benches), plus the
//! [`CapacityPressure`] accumulator the weight-streaming session
//! reports through (`Session::capacity_pressure`) so `serve` and the
//! bench cases can surface reload counts, occupancy and the
//! prefetch-overlap ratio alongside latency.

use std::time::Duration;

/// Log-bucketed latency histogram (1 µs .. ~68 s range).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: vec![0; 27],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate percentile (upper edge of the containing bucket).
    pub fn percentile(&self, p: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        self.max()
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Capacity-pressure counters for a weight-streaming session: how often
/// weights had to be re-staged, how much of the staging cost hid behind
/// compute, and how full the weight memory ran.
///
/// Produced by `Session::capacity_pressure` (absolute counters since
/// session start) and mergeable across sessions/workers like
/// [`LatencyHistogram`].  All-zero (the [`Default`]) means "no streaming
/// configured": the session held every weight resident.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CapacityPressure {
    /// Weight-reload pass switches performed (0 when everything fit in
    /// one resident pass).
    pub reloads: u64,
    /// Regions evicted from the weight memory to make room.
    pub evictions: u64,
    /// Times a single pass exceeded the whole capacity budget
    /// (occupancy > 1.0 — the stack cannot be split finer than one
    /// layer).
    pub overflows: u64,
    /// Bytes staged into the weight memory in total.
    pub staged_bytes: u64,
    /// Peak bytes resident at once.
    pub peak_resident_bytes: u64,
    /// Capacity budget the session ran under (0 = unbudgeted).
    pub capacity_bytes: u64,
    /// Wall time spent building/staging weight passes in total.
    pub stage_busy: Duration,
    /// Stage time that overlapped compute (prefetch hid it).
    pub stage_hidden: Duration,
    /// Stage time the execute path had to wait out (exposed stall).
    pub stall: Duration,
}

impl CapacityPressure {
    /// Peak occupancy of the capacity budget (0..; > 1.0 after an
    /// overflow, 0.0 when unbudgeted).
    pub fn peak_occupancy(&self) -> f64 {
        if self.capacity_bytes == 0 {
            return 0.0;
        }
        self.peak_resident_bytes as f64 / self.capacity_bytes as f64
    }

    /// Fraction of total staging time hidden behind compute (0..=1);
    /// 1.0 when nothing was staged (no stall was ever exposed).
    pub fn overlap_ratio(&self) -> f64 {
        if self.stage_busy.is_zero() {
            return 1.0;
        }
        (self.stage_hidden.as_secs_f64() / self.stage_busy.as_secs_f64()).clamp(0.0, 1.0)
    }

    /// Merge another session's counters into this one (peaks take the
    /// max, the budget is assumed shared).
    pub fn merge(&mut self, other: &CapacityPressure) {
        self.reloads += other.reloads;
        self.evictions += other.evictions;
        self.overflows += other.overflows;
        self.staged_bytes += other.staged_bytes;
        self.peak_resident_bytes = self.peak_resident_bytes.max(other.peak_resident_bytes);
        self.capacity_bytes = self.capacity_bytes.max(other.capacity_bytes);
        self.stage_busy += other.stage_busy;
        self.stage_hidden += other.stage_hidden;
        self.stall += other.stall;
    }
}

/// Reliability counters for a fault-injected / fail-soft deployment:
/// what the fault model corrupted, what the integrity scrub caught and
/// fixed, and how often the serving layer had to degrade instead of
/// dying.
///
/// Produced by `Session::reliability` (fabric-side counters) and the
/// coordinator (serving-side counters), and mergeable across
/// sessions/workers like [`CapacityPressure`].  All-zero (the
/// [`Default`]) means "quiet": no fault plan installed, no thread ever
/// died, no request ever timed out.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReliabilityStats {
    /// Bit-cell faults that actually corrupted a stored weight bit at
    /// write time (benign faults — stuck-ats agreeing with the
    /// intended bit — are not counted).
    pub faults_injected: u64,
    /// Corrupted plane words the integrity scrub detected (Q-plane
    /// checksum mismatches against the write-intent ledger).
    pub faults_detected: u64,
    /// Quarantined rows successfully re-homed onto spare rows and
    /// verified clean.
    pub faults_repaired: u64,
    /// Rows quarantined by the scrub in total (repaired + zeroed).
    pub quarantined_rows: u64,
    /// Quarantined rows zeroed because no clean spare row was left —
    /// the documented graceful degradation; each zeroed stored weight
    /// takes its complementary twin filter with it.
    pub zeroed_rows: u64,
    /// Times a streaming session lost its stager thread and completed
    /// a pass synchronously instead of panicking.
    pub stager_fallbacks: u64,
    /// Times a service worker rebuilt its session after a panic in the
    /// batch execution path.
    pub worker_rebuilds: u64,
    /// Client `infer` calls that hit their timeout instead of an
    /// answer.
    pub timed_out_requests: u64,
    /// Retention-upset bit flips landed on resident weights by the
    /// virtual-batch-clock process (runtime corruption, disjoint from
    /// write-time `faults_injected`).
    pub upset_bits: u64,
    /// Corrupt stored bits the scrub found on quarantined rows
    /// (pre-repair).  With a full-coverage scrub budget this reconciles
    /// exactly against `upset_bits` on an upsets-only configuration.
    pub corrupt_bits_found: u64,
    /// Checksum stripes verified by the incremental serving-time scrub
    /// scheduler (0 when the scheduler is off).
    pub scrub_stripes_checked: u64,
    /// Size of the stripe space the scheduler walks (resident plans;
    /// summed across workers on a merged view).
    pub scrub_stripe_total: u64,
}

impl ReliabilityStats {
    /// Whether anything at all went wrong (or was injected).
    pub fn is_quiet(&self) -> bool {
        *self == ReliabilityStats::default()
    }

    /// Fraction of detected faulty rows that were fully repaired
    /// (1.0 when nothing was ever quarantined).
    pub fn repair_ratio(&self) -> f64 {
        if self.quarantined_rows == 0 {
            return 1.0;
        }
        self.faults_repaired as f64 / self.quarantined_rows as f64
    }

    /// Merge another component's counters into this one (plain sums:
    /// every field is a monotone event count).
    pub fn merge(&mut self, other: &ReliabilityStats) {
        self.faults_injected += other.faults_injected;
        self.faults_detected += other.faults_detected;
        self.faults_repaired += other.faults_repaired;
        self.quarantined_rows += other.quarantined_rows;
        self.zeroed_rows += other.zeroed_rows;
        self.stager_fallbacks += other.stager_fallbacks;
        self.worker_rebuilds += other.worker_rebuilds;
        self.timed_out_requests += other.timed_out_requests;
        self.upset_bits += other.upset_bits;
        self.corrupt_bits_found += other.corrupt_bits_found;
        self.scrub_stripes_checked += other.scrub_stripes_checked;
        self.scrub_stripe_total += other.scrub_stripe_total;
    }
}

/// Health of one serving worker, as assessed at batch boundaries from
/// its reliability deltas.  The machine degrades monotonically within a
/// batch window and recovers only through the documented rejoin path
/// (one clean full scrub cycle while parked).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum WorkerHealth {
    /// Serving normally.
    #[default]
    Healthy,
    /// Repair churn above threshold: still serving (every batch is
    /// scrub-verified), but flagged for the operator.
    Degraded,
    /// Spares exhausted (a row was zeroed) or repeated session
    /// rebuilds: parked, steered around, running a full scrub; rejoins
    /// after one clean cycle.
    Quarantined,
}

/// Aggregated worker-health counters for a serving cluster: the current
/// state census plus lifetime quarantine/rejoin event counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Workers currently healthy.
    pub healthy: u64,
    /// Workers currently degraded (serving, above repair-churn
    /// threshold).
    pub degraded: u64,
    /// Workers currently quarantined (parked, scrubbing).
    pub quarantined: u64,
    /// Healthy/Degraded → Quarantined transitions over the service
    /// lifetime.
    pub quarantine_events: u64,
    /// Quarantined → Healthy rejoins (one clean full scrub cycle).
    pub rejoin_events: u64,
}

impl HealthStats {
    /// Fold one worker's current state into the census.
    pub fn count(&mut self, health: WorkerHealth) {
        match health {
            WorkerHealth::Healthy => self.healthy += 1,
            WorkerHealth::Degraded => self.degraded += 1,
            WorkerHealth::Quarantined => self.quarantined += 1,
        }
    }

    /// Merge another cluster's counters into this one (plain sums: the
    /// census counts disjoint workers, the events are monotone).
    pub fn merge(&mut self, other: &HealthStats) {
        self.healthy += other.healthy;
        self.degraded += other.degraded;
        self.quarantined += other.quarantined;
        self.quarantine_events += other.quarantine_events;
        self.rejoin_events += other.rejoin_events;
    }
}

/// Admission-control counters for the serving tier: how much load the
/// bounded ingress queue admitted, shed, and peaked at.
///
/// Produced by the coordinator's dispatcher (shared across all worker
/// sessions — the queue is one, however many workers drain it).
/// All-zero (the [`Default`]) means "no request ever arrived".  The
/// state machine is simple by design: a request is **admitted** when
/// the in-flight depth (queued + executing) is below the bound, and
/// **rejected** with the typed `ServiceError::Overloaded` otherwise —
/// load is shed at the door, never by unbounded queue growth or a
/// worker-side panic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests admitted past the queue-depth bound.
    pub admitted: u64,
    /// Requests rejected at the door (`ServiceError::Overloaded`).
    pub rejected: u64,
    /// Queue-depth bound in force (0 = unbounded; never shed).
    pub max_queue_depth: u64,
    /// Peak in-flight depth observed (queued + executing).
    pub peak_queue_depth: u64,
    /// Worker sessions draining the queue.
    pub workers: u64,
    /// Admitted requests dropped at batch-cut time because their client
    /// deadline had already expired (deadline propagation: the worker
    /// never wastes a slot computing an answer nobody is waiting for).
    pub shed_expired: u64,
}

impl AdmissionStats {
    /// Fraction of arriving requests shed at the door (0 when nothing
    /// ever arrived).
    pub fn shed_ratio(&self) -> f64 {
        let arrived = self.admitted + self.rejected;
        if arrived == 0 {
            return 0.0;
        }
        self.rejected as f64 / arrived as f64
    }

    /// Merge another dispatcher's counters into this one (sums for
    /// event counts, max for peaks/bounds, sum for workers).
    pub fn merge(&mut self, other: &AdmissionStats) {
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.peak_queue_depth = self.peak_queue_depth.max(other.peak_queue_depth);
        self.workers += other.workers;
        self.shed_expired += other.shed_expired;
    }
}

/// Throughput accumulator (ops over wall time).
#[derive(Debug, Clone, Default)]
pub struct Throughput {
    pub items: u64,
    pub elapsed: Duration,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.items as f64 / self.elapsed.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for ms in [1u64, 2, 3, 4, 5, 10, 20, 50, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 9);
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p99);
        assert!(p99 >= Duration::from_millis(64)); // bucket upper edge
    }

    #[test]
    fn mean_and_max() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert_eq!(h.mean(), Duration::from_millis(20));
        assert_eq!(h.max(), Duration::from_millis(30));
    }

    #[test]
    fn merge_accumulates() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(Duration::from_millis(5));
        b.record(Duration::from_millis(50));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), Duration::from_millis(50));
    }

    #[test]
    fn empty_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.percentile(99.0), Duration::ZERO);
    }

    #[test]
    fn capacity_pressure_ratios() {
        let mut p = CapacityPressure {
            reloads: 4,
            staged_bytes: 4096,
            peak_resident_bytes: 300,
            capacity_bytes: 200,
            stage_busy: Duration::from_millis(10),
            stage_hidden: Duration::from_millis(8),
            stall: Duration::from_millis(2),
            ..Default::default()
        };
        assert!((p.peak_occupancy() - 1.5).abs() < 1e-12);
        assert!((p.overlap_ratio() - 0.8).abs() < 1e-12);
        let q = p;
        p.merge(&q);
        assert_eq!(p.reloads, 8);
        assert_eq!(p.peak_resident_bytes, 300); // max, not sum
        assert_eq!(p.stall, Duration::from_millis(4));
    }

    #[test]
    fn capacity_pressure_default_is_quiet() {
        let p = CapacityPressure::default();
        assert_eq!(p.peak_occupancy(), 0.0);
        assert_eq!(p.overlap_ratio(), 1.0);
    }

    #[test]
    fn admission_stats_shed_ratio_and_merge() {
        let empty = AdmissionStats::default();
        assert_eq!(empty.shed_ratio(), 0.0);
        let mut a = AdmissionStats {
            admitted: 6,
            rejected: 2,
            max_queue_depth: 8,
            peak_queue_depth: 5,
            workers: 2,
            shed_expired: 1,
        };
        assert!((a.shed_ratio() - 0.25).abs() < 1e-12);
        let b = AdmissionStats {
            admitted: 4,
            rejected: 0,
            max_queue_depth: 4,
            peak_queue_depth: 7,
            workers: 1,
            shed_expired: 2,
        };
        a.merge(&b);
        assert_eq!(a.admitted, 10);
        assert_eq!(a.rejected, 2);
        assert_eq!(a.max_queue_depth, 8); // max, not sum
        assert_eq!(a.peak_queue_depth, 7);
        assert_eq!(a.workers, 3);
        assert_eq!(a.shed_expired, 3);
    }

    #[test]
    fn health_stats_census_and_merge() {
        let mut h = HealthStats::default();
        h.count(WorkerHealth::Healthy);
        h.count(WorkerHealth::Healthy);
        h.count(WorkerHealth::Degraded);
        h.count(WorkerHealth::Quarantined);
        assert_eq!((h.healthy, h.degraded, h.quarantined), (2, 1, 1));
        let other = HealthStats {
            healthy: 1,
            degraded: 0,
            quarantined: 2,
            quarantine_events: 3,
            rejoin_events: 1,
        };
        h.quarantine_events = 1;
        h.merge(&other);
        assert_eq!((h.healthy, h.degraded, h.quarantined), (3, 1, 3));
        assert_eq!(h.quarantine_events, 4);
        assert_eq!(h.rejoin_events, 1);
        assert_eq!(WorkerHealth::default(), WorkerHealth::Healthy);
    }

    #[test]
    fn reliability_scrub_fields_merge_and_quietness() {
        let mut a = ReliabilityStats::default();
        assert!(a.is_quiet());
        let b = ReliabilityStats {
            upset_bits: 5,
            corrupt_bits_found: 5,
            scrub_stripes_checked: 40,
            scrub_stripe_total: 16,
            ..ReliabilityStats::default()
        };
        assert!(!b.is_quiet()); // runtime upsets are reliability activity
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.upset_bits, 10);
        assert_eq!(a.corrupt_bits_found, 10);
        assert_eq!(a.scrub_stripes_checked, 80);
        assert_eq!(a.scrub_stripe_total, 32);
    }

    #[test]
    fn throughput() {
        let t = Throughput {
            items: 100,
            elapsed: Duration::from_secs(2),
        };
        assert!((t.per_sec() - 50.0).abs() < 1e-9);
    }
}
