//! Network IR: the layer/graph representation the mapper and simulator
//! consume.  Shape books for the paper's benchmark models live in
//! [`zoo`]; layers are kept in execution order with propagated spatial
//! dimensions.

pub mod zoo;

/// Convolution flavor — determines the mapping strategy and the PIM-core
/// computing mode (paper §III-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvKind {
    /// Standard KxKxCxN convolution.
    Standard,
    /// Pointwise 1x1 convolution (mapped like std-conv).
    Pointwise,
    /// Depthwise convolution (per-channel filters; the low-parallelism
    /// case the DBIS + reconfigurable unit accelerate).
    Depthwise,
}

/// One layer of the network, with resolved input spatial dims.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv {
        name: String,
        kind: ConvKind,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        in_h: usize,
        in_w: usize,
    },
    Fc {
        name: String,
        cin: usize,
        cout: usize,
    },
    /// 2x2/2 pooling — timing handled by the post-process unit.
    Pool { in_h: usize, in_w: usize, c: usize },
    /// Global average pool.
    Gap { in_h: usize, in_w: usize, c: usize },
    /// Self-attention over the flattened feature map (MobileViT); runs on
    /// the FC path (regular mode, no FCC).
    Attention { name: String, dim: usize, tokens: usize },
}

impl Layer {
    /// Output spatial dims (SAME padding for conv).
    pub fn out_hw(&self) -> (usize, usize) {
        match self {
            Layer::Conv {
                stride, in_h, in_w, ..
            } => (in_h.div_ceil(*stride), in_w.div_ceil(*stride)),
            Layer::Pool { in_h, in_w, .. } => (in_h / 2, in_w / 2),
            Layer::Gap { .. } => (1, 1),
            Layer::Fc { .. } | Layer::Attention { .. } => (1, 1),
        }
    }

    /// Number of weights (no bias).
    pub fn params(&self) -> usize {
        match self {
            Layer::Conv {
                kind: ConvKind::Depthwise,
                k,
                cin,
                ..
            } => k * k * cin,
            Layer::Conv { k, cin, cout, .. } => k * k * cin * cout,
            Layer::Fc { cin, cout, .. } => cin * cout,
            Layer::Attention { dim, .. } => 4 * dim * dim,
            _ => 0,
        }
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> usize {
        match self {
            Layer::Conv {
                kind: ConvKind::Depthwise,
                k,
                cin,
                ..
            } => {
                let (oh, ow) = self.out_hw();
                oh * ow * k * k * cin
            }
            Layer::Conv { k, cin, cout, .. } => {
                let (oh, ow) = self.out_hw();
                oh * ow * k * k * cin * cout
            }
            Layer::Fc { cin, cout, .. } => cin * cout,
            Layer::Attention { dim, tokens, .. } => {
                4 * tokens * dim * dim + 2 * tokens * tokens * dim
            }
            _ => 0,
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, Layer::Conv { .. })
    }

    /// FCC-eligible: conv layer with an even number of output channels
    /// (filters pair up).  The paper excludes FC layers by default.
    pub fn fcc_eligible(&self) -> bool {
        match self {
            Layer::Conv { cout, .. } => cout % 2 == 0,
            _ => false,
        }
    }

    pub fn cout(&self) -> usize {
        match self {
            Layer::Conv { cout, .. } | Layer::Fc { cout, .. } => *cout,
            Layer::Attention { dim, .. } => *dim,
            Layer::Pool { c, .. } | Layer::Gap { c, .. } => *c,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Layer::Conv { name, .. } | Layer::Fc { name, .. } | Layer::Attention { name, .. } => {
                name.clone()
            }
            Layer::Pool { .. } => "pool".into(),
            Layer::Gap { .. } => "gap".into(),
        }
    }
}

/// A network = named, ordered layer list with consistent shapes.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(Layer::params).sum()
    }

    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn conv_params(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.is_conv())
            .map(Layer::params)
            .sum()
    }

    pub fn fc_params(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, Layer::Fc { .. }))
            .map(Layer::params)
            .sum()
    }

    /// Paper Table III rightmost column: FC share of total parameters.
    pub fn fc_param_ratio(&self) -> f64 {
        100.0 * self.fc_params() as f64 / self.total_params() as f64
    }

    /// Conv layers within effective scope S(i): "more than i filters"
    /// (paper §IV-E).  Returns layer indices.
    pub fn scope(&self, i: usize) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.fcc_eligible() && l.cout() > i)
            .map(|(idx, _)| idx)
            .collect()
    }

    /// Share of parameters covered by S(i) (bar heights in Fig. 14).
    pub fn scope_param_ratio(&self, i: usize) -> f64 {
        let scoped: usize = self.scope(i).iter().map(|&ix| self.layers[ix].params()).sum();
        100.0 * scoped as f64 / self.total_params() as f64
    }
}

/// Sequential network builder that tracks spatial dims.
pub struct NetBuilder {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<Layer>,
    counter: usize,
}

impl NetBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> Self {
        NetBuilder {
            name: name.to_string(),
            h,
            w,
            c,
            layers: Vec::new(),
            counter: 0,
        }
    }

    fn next_name(&mut self, prefix: &str) -> String {
        let n = format!("{prefix}{}", self.counter);
        self.counter += 1;
        n
    }

    pub fn conv(mut self, cout: usize, k: usize, stride: usize) -> Self {
        let kind = if k == 1 {
            ConvKind::Pointwise
        } else {
            ConvKind::Standard
        };
        let name = self.next_name("conv");
        let layer = Layer::Conv {
            name,
            kind,
            k,
            cin: self.c,
            cout,
            stride,
            in_h: self.h,
            in_w: self.w,
        };
        let (oh, ow) = layer.out_hw();
        self.h = oh;
        self.w = ow;
        self.c = cout;
        self.layers.push(layer);
        self
    }

    pub fn dwconv(mut self, k: usize, stride: usize) -> Self {
        let name = self.next_name("dw");
        let layer = Layer::Conv {
            name,
            kind: ConvKind::Depthwise,
            k,
            cin: self.c,
            cout: self.c,
            stride,
            in_h: self.h,
            in_w: self.w,
        };
        let (oh, ow) = layer.out_hw();
        self.h = oh;
        self.w = ow;
        self.layers.push(layer);
        self
    }

    pub fn pw(self, cout: usize) -> Self {
        self.conv(cout, 1, 1)
    }

    /// MobileNetV2 inverted residual (expand t, project to cout).
    pub fn inv_residual(self, cout: usize, t: usize, stride: usize, k: usize) -> Self {
        let mid = self.c * t;
        let mut b = self;
        if t != 1 {
            b = b.pw(mid);
        }
        b.dwconv(k, stride).pw(cout)
    }

    pub fn basic_block(self, cout: usize, stride: usize) -> Self {
        self.conv(cout, 3, stride).conv(cout, 3, 1)
    }

    pub fn pool(mut self) -> Self {
        let layer = Layer::Pool {
            in_h: self.h,
            in_w: self.w,
            c: self.c,
        };
        self.h /= 2;
        self.w /= 2;
        self.layers.push(layer);
        self
    }

    pub fn gap(mut self) -> Self {
        self.layers.push(Layer::Gap {
            in_h: self.h,
            in_w: self.w,
            c: self.c,
        });
        self.h = 1;
        self.w = 1;
        self
    }

    pub fn fc(mut self, cout: usize) -> Self {
        let cin = self.h * self.w * self.c;
        let name = self.next_name("fc");
        self.layers.push(Layer::Fc { name, cin, cout });
        self.h = 1;
        self.w = 1;
        self.c = cout;
        self
    }

    pub fn attention(mut self, dim: usize) -> Self {
        let tokens = self.h * self.w;
        let name = self.next_name("attn");
        self.layers.push(Layer::Attention { name, dim, tokens });
        self
    }

    pub fn build(self) -> Network {
        Network {
            name: self.name,
            layers: self.layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_tracks_shapes() {
        let net = NetBuilder::new("t", 32, 32, 3)
            .conv(16, 3, 1)
            .conv(32, 3, 2)
            .pool()
            .gap()
            .fc(10)
            .build();
        match &net.layers[1] {
            Layer::Conv { in_h, in_w, cin, .. } => {
                assert_eq!((*in_h, *in_w, *cin), (32, 32, 16));
            }
            _ => panic!(),
        }
        match &net.layers[2] {
            Layer::Pool { in_h, .. } => assert_eq!(*in_h, 16),
            _ => panic!(),
        }
    }

    #[test]
    fn macs_and_params() {
        let l = Layer::Conv {
            name: "c".into(),
            kind: ConvKind::Standard,
            k: 3,
            cin: 16,
            cout: 32,
            stride: 1,
            in_h: 8,
            in_w: 8,
        };
        assert_eq!(l.params(), 3 * 3 * 16 * 32);
        assert_eq!(l.macs(), 8 * 8 * 3 * 3 * 16 * 32);
    }

    #[test]
    fn dw_params_per_channel() {
        let l = Layer::Conv {
            name: "d".into(),
            kind: ConvKind::Depthwise,
            k: 3,
            cin: 64,
            cout: 64,
            stride: 1,
            in_h: 8,
            in_w: 8,
        };
        assert_eq!(l.params(), 9 * 64);
        assert_eq!(l.macs(), 8 * 8 * 9 * 64);
    }

    #[test]
    fn scope_filters_by_cout() {
        let net = NetBuilder::new("t", 32, 32, 3)
            .conv(16, 3, 1)
            .conv(64, 3, 1)
            .fc(10)
            .build();
        assert_eq!(net.scope(0).len(), 2);
        assert_eq!(net.scope(32), vec![1]);
        assert!(net.scope(64).is_empty());
    }

    #[test]
    fn stride_rounding_same_padding() {
        let l = Layer::Conv {
            name: "c".into(),
            kind: ConvKind::Standard,
            k: 3,
            cin: 3,
            cout: 8,
            stride: 2,
            in_h: 15,
            in_w: 15,
        };
        assert_eq!(l.out_hw(), (8, 8));
    }
}
