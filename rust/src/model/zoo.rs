//! Full-fidelity CIFAR-10 shape books for the paper's benchmark models.
//!
//! Timing/speedup (Fig. 13/14) depends only on these layer shapes, so
//! they are kept at full size even though the accuracy experiments run on
//! scaled models (DESIGN.md §2).  All books use 32x32x3 inputs with the
//! standard CIFAR adaptations (stride-1 stems, reduced downsampling).

use super::{NetBuilder, Network};

/// MobileNetV2 (CIFAR-10 adaptation: stem stride 1, first two stages
/// undownsampled; t,c,n,s table from the paper's Table 2 of [9]).
pub fn mobilenet_v2() -> Network {
    let mut b = NetBuilder::new("mobilenet_v2", 32, 32, 3).conv(32, 3, 1);
    // (expansion t, channels c, repeats n, first-stride s)
    let stages: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 1), // CIFAR: no downsample
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for &(t, c, n, s) in stages {
        for i in 0..n {
            b = b.inv_residual(c, t, if i == 0 { s } else { 1 }, 3);
        }
    }
    b.pw(1280).gap().fc(10).build()
}

/// EfficientNet-B0 (CIFAR adaptation; MBConv table from [10], SE and
/// swish omitted from the shape book — they do not run on the PIM array).
pub fn efficientnet_b0() -> Network {
    let mut b = NetBuilder::new("efficientnet_b0", 32, 32, 3).conv(32, 3, 1);
    // (t, c, n, s, k)
    let stages: &[(usize, usize, usize, usize, usize)] = &[
        (1, 16, 1, 1, 3),
        (6, 24, 2, 1, 3), // CIFAR: no downsample
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    for &(t, c, n, s, k) in stages {
        for i in 0..n {
            b = b.inv_residual(c, t, if i == 0 { s } else { 1 }, k);
        }
    }
    b.pw(1280).gap().fc(10).build()
}

/// AlexNet (CIFAR adaptation with the original's FC-heavy head — Table
/// III reports 79.12% of its parameters in FC layers).
pub fn alexnet() -> Network {
    NetBuilder::new("alexnet", 32, 32, 3)
        .conv(64, 3, 1)
        .pool()
        .conv(192, 3, 1)
        .pool()
        .conv(384, 3, 1)
        .conv(256, 3, 1)
        .conv(256, 3, 1)
        .pool()
        .fc(1536)
        .fc(1536)
        .fc(10)
        .build()
}

/// VGG19 (CIFAR adaptation, 16 conv layers + classic 4096-wide FC head).
pub fn vgg19() -> Network {
    let mut b = NetBuilder::new("vgg19", 32, 32, 3);
    for &(c, n) in &[(64usize, 2usize), (128, 2), (256, 4), (512, 4), (512, 4)] {
        for _ in 0..n {
            b = b.conv(c, 3, 1);
        }
        b = b.pool();
    }
    b.fc(4096).fc(4096).fc(10).build()
}

/// ResNet18 (CIFAR adaptation: 3x3 stem, no initial pool).
pub fn resnet18() -> Network {
    let mut b = NetBuilder::new("resnet18", 32, 32, 3).conv(64, 3, 1);
    let blocks: &[(usize, usize)] =
        &[(64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2), (512, 1)];
    for &(c, s) in blocks {
        b = b.basic_block(c, s);
    }
    b.gap().fc(10).build()
}

/// MobileViT-XS (CIFAR adaptation of the XS variant: MV2 blocks +
/// three MobileViT blocks with transformer dims 96/120/144).
pub fn mobilevit_xs() -> Network {
    NetBuilder::new("mobilevit_xs", 32, 32, 3)
        .conv(16, 3, 1)
        .inv_residual(32, 4, 1, 3)
        .inv_residual(48, 4, 2, 3)
        .inv_residual(48, 4, 1, 3)
        // MobileViT block 1
        .conv(48, 3, 1)
        .pw(96)
        .attention(96)
        .pw(48)
        .inv_residual(64, 4, 2, 3)
        // MobileViT block 2
        .conv(64, 3, 1)
        .pw(120)
        .attention(120)
        .pw(64)
        .inv_residual(80, 4, 2, 3)
        // MobileViT block 3
        .conv(80, 3, 1)
        .pw(144)
        .attention(144)
        .pw(80)
        .pw(384)
        .gap()
        .fc(10)
        .build()
}

/// All benchmark networks by name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "mobilenet_v2" => Some(mobilenet_v2()),
        "efficientnet_b0" => Some(efficientnet_b0()),
        "alexnet" => Some(alexnet()),
        "vgg19" => Some(vgg19()),
        "resnet18" => Some(resnet18()),
        "mobilevit_xs" => Some(mobilevit_xs()),
        _ => None,
    }
}

pub const ALL_MODELS: &[&str] = &[
    "mobilenet_v2",
    "efficientnet_b0",
    "alexnet",
    "vgg19",
    "resnet18",
    "mobilevit_xs",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ConvKind, Layer};

    #[test]
    fn mobilenet_v2_param_count() {
        // CIFAR-10 MobileNetV2 is ~2.2-2.4M weights
        let net = mobilenet_v2();
        let p = net.total_params();
        assert!((2_000_000..2_600_000).contains(&p), "params={p}");
    }

    #[test]
    fn mobilenet_v2_has_depthwise() {
        let net = mobilenet_v2();
        let dw = net
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::Conv { kind: ConvKind::Depthwise, .. }))
            .count();
        assert_eq!(dw, 17); // one per inverted residual block
    }

    #[test]
    fn fc_ratios_match_paper_ordering() {
        // Table III: AlexNet 79.12%, VGG19 55.71%, ResNet18 0.04%,
        // MobileNetV2 0.57%, EfficientNet-B0 0.11%
        let a = alexnet().fc_param_ratio();
        let v = vgg19().fc_param_ratio();
        let r = resnet18().fc_param_ratio();
        let m = mobilenet_v2().fc_param_ratio();
        let e = efficientnet_b0().fc_param_ratio();
        assert!(a > 70.0, "alexnet fc ratio {a}");
        assert!(v > 40.0 && v < 70.0, "vgg19 fc ratio {v}");
        assert!(r < 1.0, "resnet18 fc ratio {r}");
        assert!(m < 2.0, "mobilenet fc ratio {m}");
        assert!(e < 2.0, "efficientnet fc ratio {e}");
        assert!(a > v && v > m && m > e && e > r);
    }

    #[test]
    fn all_models_build() {
        for name in ALL_MODELS {
            let net = by_name(name).unwrap();
            assert!(!net.layers.is_empty());
            assert!(net.total_macs() > 0);
        }
    }

    #[test]
    fn efficientnet_larger_than_mobilenet() {
        assert!(efficientnet_b0().total_macs() > mobilenet_v2().total_macs() / 2);
    }

    #[test]
    fn scope_ratio_monotone() {
        let net = mobilenet_v2();
        let mut prev = f64::MAX;
        for i in [0usize, 16, 32, 64, 112, 160, 320] {
            let r = net.scope_param_ratio(i);
            assert!(r <= prev + 1e-9, "S({i}) ratio {r} > prev {prev}");
            prev = r;
        }
        assert!(net.scope_param_ratio(0) > 90.0);
    }

    #[test]
    fn resnet18_shapes() {
        let net = resnet18();
        // final conv stage is 512 channels at 4x4
        let last_conv = net
            .layers
            .iter()
            .filter(|l| l.is_conv())
            .last()
            .unwrap();
        assert_eq!(last_conv.cout(), 512);
    }
}
