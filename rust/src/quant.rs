//! Symmetric per-tensor INT8 quantization and 2:4 structured pruning —
//! the rust-side mirror of `python/compile/fcc/quant.py` (deployment
//! consumes integer weights; these helpers regenerate/verify them and
//! feed the mapper and the functional simulator).

pub const INT8_MIN: i32 = -128;
pub const INT8_MAX: i32 = 127;

/// Symmetric per-tensor scale: `max|w| / 127` (never zero).
pub fn quant_scale(w: &[f32]) -> f32 {
    let amax = w.iter().fold(0f32, |m, &x| m.max(x.abs()));
    amax.max(1e-8) / INT8_MAX as f32
}

/// Quantize to INT8 codes (stored as i32 for headroom in accumulation).
pub fn quantize_int8(w: &[f32], scale: f32) -> Vec<i32> {
    w.iter()
        .map(|&x| ((x / scale).round() as i32).clamp(INT8_MIN, INT8_MAX))
        .collect()
}

/// De-quantize INT8 codes back to float.
pub fn dequantize_int8(codes: &[i32], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// NVIDIA-style 2:4 fine-grained structured pruning: in every group of 4
/// consecutive weights, zero the 2 smallest-magnitude ones.  Tail
/// elements (len % 4) are kept.
pub fn prune_2_4(w: &mut [f32]) {
    let n4 = (w.len() / 4) * 4;
    for g in w[..n4].chunks_mut(4) {
        let mut idx = [0usize, 1, 2, 3];
        idx.sort_by(|&a, &b| g[a].abs().partial_cmp(&g[b].abs()).unwrap());
        g[idx[0]] = 0.0;
        g[idx[1]] = 0.0;
    }
}

/// Fraction of exact zeros.
pub fn sparsity(w: &[f32]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    w.iter().filter(|&&x| x == 0.0).count() as f64 / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let s = quant_scale(&w);
        let back = dequantize_int8(&quantize_int8(&w, s), s);
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= s / 2.0 + 1e-6);
        }
    }

    #[test]
    fn codes_in_range_property() {
        forall(
            2,
            200,
            |r| {
                let n = 1 + r.below(64) as usize;
                (0..n).map(|_| (r.normal() * 3.0) as f32).collect::<Vec<f32>>()
            },
            |w| {
                let s = quant_scale(w);
                quantize_int8(w, s)
                    .iter()
                    .all(|&c| (INT8_MIN..=INT8_MAX).contains(&c))
            },
        );
    }

    #[test]
    fn prune_is_half_sparse() {
        let mut rng = Rng::new(3);
        let mut w: Vec<f32> = (0..128).map(|_| rng.normal() as f32 + 0.1).collect();
        prune_2_4(&mut w);
        assert!((sparsity(&w) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn prune_keeps_largest() {
        let mut w = vec![1.0f32, -4.0, 0.5, 3.0];
        prune_2_4(&mut w);
        assert_eq!(w, vec![0.0, -4.0, 0.0, 3.0]);
    }

    #[test]
    fn prune_keeps_tail() {
        let mut w = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        prune_2_4(&mut w);
        assert_eq!(&w[4..], &[5.0, 6.0]);
    }

    #[test]
    fn scale_never_zero() {
        assert!(quant_scale(&[0.0, 0.0]) > 0.0);
    }
}
