//! Fig. 1: qualitative radar comparison — this work vs analog PIM,
//! analog 6T+LCC and digital 6T+LCC, over the paper's five axes.

use crate::report::fig13::ladder;
use crate::util::table::{f2, Table};

use super::ReportCtx;

/// Scores on a 0..5 scale per the paper's radar plot semantics.
struct Radar {
    name: &'static str,
    accuracy: f64,
    area_eff: f64,
    weight_density: f64,
    speedup: f64,
    integration: f64,
}

pub fn render(_ctx: &ReportCtx) -> String {
    let (_, _, _, total) = ladder("mobilenet_v2").factors();
    let rows = [
        Radar {
            name: "Analog Others",
            accuracy: 2.0,
            area_eff: 2.0,
            weight_density: 2.0,
            speedup: 2.0,
            integration: 3.0,
        },
        Radar {
            name: "Analog 6T+LCC",
            accuracy: 3.0,
            area_eff: 2.5,
            weight_density: 2.5,
            speedup: 2.5,
            integration: 3.5,
        },
        Radar {
            name: "Digital 6T+LCC",
            accuracy: 5.0,
            area_eff: 3.5,
            weight_density: 3.0,
            speedup: 3.0,
            integration: 5.0,
        },
        Radar {
            name: "This Work (DDC-PIM)",
            accuracy: 4.7, // negligible FCC accuracy loss
            area_eff: 5.0,
            weight_density: 5.0,
            speedup: (total).min(5.0),
            integration: 4.5, // slight dip: extra DFFs/adders
        },
    ];
    let mut t = Table::new("Fig. 1 — radar comparison (qualitative, 0-5)").header(&[
        "Design",
        "Accuracy",
        "Area eff.",
        "Weight density",
        "Speedup",
        "Integration",
    ]);
    for r in rows {
        t.row(vec![
            r.name.into(),
            f2(r.accuracy),
            f2(r.area_eff),
            f2(r.weight_density),
            f2(r.speedup),
            f2(r.integration),
        ]);
    }
    format!(
        "{}\n(speedup axis for This Work uses the measured Fig. 13 overall factor)",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_four_designs() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("This Work"));
        assert!(s.contains("Digital 6T+LCC"));
    }
}
