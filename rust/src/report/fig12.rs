//! Fig. 12: implementation summary table + macro area breakdown.

use crate::arch::cost::{CostModel, SYSTEM_POWER_MW};
use crate::config::{ArchConfig, SimConfig};
use crate::model::zoo;
use crate::sim::simulate_network;
use crate::util::table::{f2, fp, Table};

use super::ReportCtx;

pub fn render(_ctx: &ReportCtx) -> String {
    let cfg = ArchConfig::ddc_pim();
    let cost = CostModel::new(cfg.clone());
    let net = zoo::mobilenet_v2();
    let run = simulate_network(&net, &cfg, &SimConfig::ddc_full());

    let mut summary = Table::new("Fig. 12(a) — summary").header(&["item", "value", "paper"]);
    summary.row(vec![
        "Technology Node".into(),
        format!("{} nm", cfg.node_nm),
        "14 nm".into(),
    ]);
    summary.row(vec![
        "Area Estimation".into(),
        format!("{} mm2", fp(cost.system_area_mm2(), 3)),
        "0.918 mm2".into(),
    ]);
    summary.row(vec![
        "Power Consumption".into(),
        format!("{} mW", f2(SYSTEM_POWER_MW)),
        "11.15 mW".into(),
    ]);
    summary.row(vec![
        "Working Frequency".into(),
        format!("{} MHz", cfg.freq_mhz),
        "333 MHz".into(),
    ]);
    summary.row(vec![
        "Peak Performance (8bx8b)".into(),
        format!("{} GOPS", f2(cfg.peak_gops())),
        "42.67 GOPS".into(),
    ]);
    summary.row(vec![
        "Macro Energy Efficiency".into(),
        format!("{} TOPS/W", f2(cost.energy_efficiency_tops_w())),
        "72.41 TOPS/W".into(),
    ]);
    summary.row(vec![
        "End-to-end Latency (MobileNetV2, CIFAR-scale)".into(),
        format!("{} ms", fp(run.latency_ms(), 3)),
        "20.97 ms (ImageNet-scale)".into(),
    ]);
    summary.row(vec![
        "MVM Latency share".into(),
        format!(
            "{} ms ({}%)",
            fp(run.mvm_cycles() as f64 / (cfg.freq_mhz * 1e3), 3),
            f2(100.0 * run.mvm_cycles() as f64 / run.total_cycles as f64)
        ),
        "18.02 of 20.97 ms".into(),
    ]);

    let mut breakdown =
        Table::new("Fig. 12(b) — PIM macro area breakdown").header(&["block", "share"]);
    for (name, frac) in cost.macro_breakdown() {
        breakdown.row(vec![name.into(), format!("{}%", f2(100.0 * frac))]);
    }
    format!("{}\n\n{}", summary.render(), breakdown.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_contains_paper_constants() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("42.67 GOPS"));
        assert!(s.contains("72.41 TOPS/W"));
        assert!(s.contains("86.52%"));
        assert!(s.contains("5.24%"));
    }
}
