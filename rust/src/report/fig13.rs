//! Fig. 13: speedup ablation ladder for MobileNetV2 and EfficientNet-B0.
//!
//! The paper reports three multiplicative contribution factors whose
//! product is the overall speedup (1.196 x 1.583 x 1.501 = 2.841 for
//! MobileNetV2):
//!
//! * FCC on std/pw-conv (double computing mode),
//! * FCC on dw-conv with DBIS (channel pairing),
//! * the DDC-PIM architecture extras (reconfigurable unit / padded dw
//!   mapping).
//!
//! We regenerate the ladder by simulating the four rungs and reporting
//! the same incremental factors.

use crate::config::{ArchConfig, SimConfig};
use crate::model::zoo;
use crate::sim::simulate_network;
use crate::util::table::{speedup, Table};

use super::ReportCtx;

/// Cycle counts of the four ablation rungs for one model.
#[derive(Debug, Clone, Copy)]
pub struct Ladder {
    pub baseline: u64,
    pub fcc_std_pw: u64,
    pub plus_fcc_dw_dbis: u64,
    pub plus_reconfig: u64,
}

impl Ladder {
    /// The three incremental (multiplicative) factors + total.
    pub fn factors(&self) -> (f64, f64, f64, f64) {
        let a = self.baseline as f64 / self.fcc_std_pw as f64;
        let b = self.fcc_std_pw as f64 / self.plus_fcc_dw_dbis as f64;
        let c = self.plus_fcc_dw_dbis as f64 / self.plus_reconfig as f64;
        let total = self.baseline as f64 / self.plus_reconfig as f64;
        (a, b, c, total)
    }
}

/// Simulate the ablation ladder for `model`.
pub fn ladder(model: &str) -> Ladder {
    let net = zoo::by_name(model).expect("unknown model");
    let base_arch = ArchConfig::baseline();
    let ddc = ArchConfig::ddc_pim();
    let mut no_reconfig = ArchConfig::ddc_pim();
    no_reconfig.reconfig = false;

    let baseline =
        simulate_network(&net, &base_arch, &SimConfig::baseline()).total_cycles;
    // rung 1: FCC on std/pw only (DBIS hardware present, dw unchanged)
    let mut sim_std = SimConfig::ddc_full();
    sim_std.fcc_dw = false;
    let fcc_std_pw = simulate_network(&net, &no_reconfig, &sim_std).total_cycles;
    // rung 2: + FCC dw with DBIS (no reconfig doubling yet)
    let plus_dw = simulate_network(&net, &no_reconfig, &SimConfig::ddc_full()).total_cycles;
    // rung 3: full DDC-PIM (reconfigurable unit)
    let full = simulate_network(&net, &ddc, &SimConfig::ddc_full()).total_cycles;
    Ladder {
        baseline,
        fcc_std_pw,
        plus_fcc_dw_dbis: plus_dw,
        plus_reconfig: full,
    }
}

pub fn render(_ctx: &ReportCtx) -> String {
    let mut t = Table::new(
        "Fig. 13 — speedup over PIM baseline (incremental multiplicative factors)",
    )
    .header(&[
        "Model",
        "FCC std/pw",
        "FCC dw + DBIS",
        "arch (reconfig)",
        "overall",
        "paper overall",
    ]);
    for (model, paper) in [("mobilenet_v2", 2.841), ("efficientnet_b0", 2.694)] {
        let l = ladder(model);
        let (a, b, c, total) = l.factors();
        t.row(vec![
            model.into(),
            speedup(a),
            speedup(b),
            speedup(c),
            speedup(total),
            speedup(paper),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factors_multiply_to_total() {
        let l = ladder("mobilenet_v2");
        let (a, b, c, total) = l.factors();
        assert!((a * b * c - total).abs() < 1e-9);
    }

    #[test]
    fn ladder_is_monotone() {
        let l = ladder("mobilenet_v2");
        assert!(l.baseline > l.fcc_std_pw);
        assert!(l.fcc_std_pw > l.plus_fcc_dw_dbis);
        assert!(l.plus_fcc_dw_dbis > l.plus_reconfig);
    }

    #[test]
    fn mobilenet_overall_in_paper_band() {
        let (_, _, _, total) = ladder("mobilenet_v2").factors();
        assert!(total > 2.3 && total < 3.3, "total={total}");
    }

    #[test]
    fn efficientnet_below_mobilenet() {
        let (_, _, _, m) = ladder("mobilenet_v2").factors();
        let (_, _, _, e) = ladder("efficientnet_b0").factors();
        assert!(e < m, "e={e} m={m}");
    }

    #[test]
    fn std_pw_factor_modest() {
        // paper: 1.196x / 1.237x — std/pw rung is the smallest
        let (a, b, _, _) = ladder("mobilenet_v2").factors();
        assert!(a > 1.05 && a < 1.5, "a={a}");
        assert!(b > a, "dw rung should dominate: a={a} b={b}");
    }
}
