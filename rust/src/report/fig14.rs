//! Fig. 14: speedup / accuracy trade-off over the effective scope S(i).
//!
//! The speedup side is simulated on the full-size shape books by sweeping
//! the scope threshold; the accuracy side comes from the python training
//! pass (`accuracy.json`, scaled models — thresholds are scaled
//! correspondingly, see DESIGN.md §2).

use crate::config::{ArchConfig, SimConfig};
use crate::model::zoo;
use crate::sim::simulate_network;
use crate::util::table::{f2, speedup, Table};

use super::ReportCtx;

/// Full-size scope thresholds swept (paper Fig. 14 uses S(i) up to the
/// widest layer; usize::MAX = FCC disabled).
pub const THRESHOLDS: &[usize] = &[usize::MAX, 320, 160, 112, 64, 32, 0];

/// Simulated speedup of DDC-PIM over baseline at scope threshold `i`.
pub fn speedup_at(model: &str, threshold: usize) -> f64 {
    let net = zoo::by_name(model).expect("unknown model");
    let base = simulate_network(&net, &ArchConfig::baseline(), &SimConfig::baseline());
    let mut sim = SimConfig::ddc_full();
    sim.scope_threshold = threshold;
    if threshold == usize::MAX {
        sim.fcc_std_pw = false;
        sim.fcc_dw = false;
    }
    let ddc = simulate_network(&net, &ArchConfig::ddc_pim(), &sim);
    base.total_cycles as f64 / ddc.total_cycles as f64
}

pub fn render(ctx: &ReportCtx) -> String {
    let acc = ctx.accuracy();
    let mut out = String::new();
    for model in ["mobilenet_v2", "efficientnet_b0"] {
        let net = zoo::by_name(model).unwrap();
        let mut t = Table::new(format!(
            "Fig. 14 — {model}: speedup & S(i) parameter share (simulated, full-size shapes)"
        ))
        .header(&["S(i)", "params in scope", "speedup vs baseline"]);
        for &th in THRESHOLDS {
            let label = if th == usize::MAX {
                "none".to_string()
            } else {
                format!("S({th})")
            };
            let share = if th == usize::MAX {
                0.0
            } else {
                net.scope_param_ratio(th)
            };
            t.row(vec![
                label,
                format!("{}%", f2(share)),
                speedup(speedup_at(model, th)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        // accuracy side (scaled models, python pass)
        if let Some(series) = acc
            .as_ref()
            .and_then(|j| j.get("fig14"))
            .and_then(|j| j.get(model))
            .and_then(|j| j.as_arr().map(<[_]>::to_vec))
        {
            let mut ta = Table::new(format!(
                "Fig. 14 — {model}: measured accuracy (scaled model, scaled thresholds)"
            ))
            .header(&["scaled S(i)", "top-1 acc (%)", "FCC param share (%)"]);
            for pt in &series {
                let th = pt.get("threshold").and_then(|v| v.as_i64()).unwrap_or(-1);
                let a = pt.get("acc").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                let r = pt
                    .get("fcc_param_ratio")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0);
                let label = if th < 0 { "none".into() } else { format!("S({th})") };
                ta.row(vec![label, f2(a), f2(r)]);
            }
            out.push_str(&ta.render());
            out.push('\n');
        } else {
            out.push_str("(accuracy series pending: run `make accuracy`)\n");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_monotone_in_scope() {
        // widening the scope (smaller i) can only help
        let s_none = speedup_at("mobilenet_v2", usize::MAX);
        let s_mid = speedup_at("mobilenet_v2", 112);
        let s_all = speedup_at("mobilenet_v2", 0);
        assert!((s_none - 1.0).abs() < 0.05, "s_none={s_none}");
        assert!(s_mid >= s_none - 1e-9);
        assert!(s_all >= s_mid - 1e-9);
        assert!(s_all > 2.0);
    }

    #[test]
    fn s112_speedup_near_paper_2x() {
        // paper: S(112) covers 92.58% of params, 2.01x speedup
        let s = speedup_at("mobilenet_v2", 112);
        assert!(s > 1.4 && s < 2.8, "s={s}");
    }

    #[test]
    fn renders_without_accuracy() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("S(112)"));
        assert!(s.contains("pending"));
    }
}
