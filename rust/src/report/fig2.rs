//! Fig. 2: normalized weight-density improvement + area-efficiency bars
//! vs prior SRAM-based PIM solutions (derived from the Table II data).

use crate::arch::cost::CostModel;
use crate::config::ArchConfig;
use crate::util::table::{f2, Table};

use super::table2::prior_works;
use super::ReportCtx;

fn bar(x: f64, scale: f64) -> String {
    let n = ((x * scale).round() as usize).clamp(1, 60);
    "#".repeat(n)
}

pub fn render(_ctx: &ReportCtx) -> String {
    let cost = CostModel::new(ArchConfig::ddc_pim());
    let ours_wd = cost.weight_density(true);
    let ours_ae = cost.area_efficiency(true);

    let mut t = Table::new(
        "Fig. 2 — normalized (28 nm) weight density & area efficiency vs prior SRAM PIM",
    )
    .header(&["Macro", "WtDens (Kb/mm2)", "norm. improvement", "AreaEff (GOPS/mm2)"]);
    for p in prior_works().iter().filter(|p| p.device == "SRAM") {
        t.row(vec![
            p.name.into(),
            f2(p.weight_density_28()),
            format!("{} {}x", bar(ours_wd / p.weight_density_28(), 4.0),
                    f2(ours_wd / p.weight_density_28())),
            f2(p.area_eff_gops_mm2_28),
        ]);
    }
    t.row(vec![
        "This Work".into(),
        f2(ours_wd),
        "1.00x (reference)".into(),
        f2(ours_ae),
    ]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_range_matches_abstract() {
        // abstract: "up to 8.41x improvement in weight density"
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("8.4"), "{s}");
        assert!(s.contains("This Work"));
    }
}
