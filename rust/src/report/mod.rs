//! Report generators: regenerate every table and figure of the paper's
//! evaluation section from the simulator, the cost model and the
//! python-side accuracy results (`artifacts/accuracy.json`).
//!
//! | generator | paper artifact |
//! |---|---|
//! | [`fig1`]   | radar comparison (qualitative)            |
//! | [`fig2`]   | normalized weight density / area eff bars |
//! | [`fig12`]  | implementation summary + area breakdown   |
//! | [`fig13`]  | speedup ablation ladder                   |
//! | [`fig14`]  | speedup/accuracy vs effective scope S(i)  |
//! | [`table2`] | comparison with prior PIM macros          |
//! | [`table3`] | FCC accuracy across models/layers         |
//! | [`table4`] | FCC + 2:4 pruning                         |
//! | [`table5`] | MobileViT-XS                              |

pub mod fig1;
pub mod fig2;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;

use crate::util::json::Json;
use std::path::Path;

/// Shared context: where artifacts (accuracy.json) live.
pub struct ReportCtx {
    pub artifact_dir: String,
}

impl ReportCtx {
    pub fn new(artifact_dir: impl Into<String>) -> Self {
        ReportCtx {
            artifact_dir: artifact_dir.into(),
        }
    }

    /// Load accuracy.json if the python training pass has produced it.
    pub fn accuracy(&self) -> Option<Json> {
        let path = Path::new(&self.artifact_dir).join("accuracy.json");
        let text = std::fs::read_to_string(path).ok()?;
        Json::parse(&text).ok()
    }
}

/// Render every report in experiment-index order.
pub fn render_all(ctx: &ReportCtx) -> String {
    let mut out = String::new();
    for (name, body) in [
        ("fig1", fig1::render(ctx)),
        ("fig2", fig2::render(ctx)),
        ("fig12", fig12::render(ctx)),
        ("table2", table2::render(ctx)),
        ("fig13", fig13::render(ctx)),
        ("fig14", fig14::render(ctx)),
        ("table3", table3::render(ctx)),
        ("table4", table4::render(ctx)),
        ("table5", table5::render(ctx)),
    ] {
        out.push_str(&format!("\n===== {name} =====\n{body}\n"));
    }
    out
}

/// Dispatch by name (CLI `report <name>`).
pub fn render_named(ctx: &ReportCtx, name: &str) -> Option<String> {
    Some(match name {
        "fig1" => fig1::render(ctx),
        "fig2" => fig2::render(ctx),
        "fig12" => fig12::render(ctx),
        "fig13" => fig13::render(ctx),
        "fig14" => fig14::render(ctx),
        "table2" => table2::render(ctx),
        "table3" => table3::render(ctx),
        "table4" => table4::render(ctx),
        "table5" => table5::render(ctx),
        "all" => render_all(ctx),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_render_without_accuracy_file() {
        let ctx = ReportCtx::new("/nonexistent");
        let s = render_all(&ctx);
        assert!(s.contains("fig13"));
        assert!(s.len() > 1000);
    }

    #[test]
    fn named_dispatch() {
        let ctx = ReportCtx::new("/nonexistent");
        assert!(render_named(&ctx, "table2").is_some());
        assert!(render_named(&ctx, "nope").is_none());
    }
}
