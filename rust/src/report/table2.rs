//! Table II: comparison with prior PIM macros.
//!
//! Prior-work rows are the paper's published constants; the "This Work"
//! row is *recomputed* from our cost model and architecture config, so
//! any change to the modelled macro propagates here.

use crate::arch::cost::CostModel;
use crate::config::ArchConfig;
use crate::util::table::{f2, Table};

use super::ReportCtx;

/// One prior-work column of Table II.
pub struct PriorMacro {
    pub name: &'static str,
    pub device: &'static str,
    pub node_nm: f64,
    pub array_kb: f64,
    pub weight_capacity_kb: f64,
    pub cell: &'static str,
    pub area_mm2: f64,
    pub area_eff_gops_mm2_28: f64,
    pub energy_eff_tops_w: f64,
    pub precision: &'static str,
}

/// The seven prior works of Table II (paper constants).
pub fn prior_works() -> Vec<PriorMacro> {
    vec![
        PriorMacro {
            name: "Nat.Elec.'22 [33]",
            device: "PCM",
            node_nm: 14.0,
            array_kb: 64.0,
            weight_capacity_kb: 64.0,
            cell: "8T4R",
            area_mm2: 1.392,
            area_eff_gops_mm2_28: 177.38,
            energy_eff_tops_w: 9.76,
            precision: "8b/8b",
        },
        PriorMacro {
            name: "JETCAS'22 [34]",
            device: "PCM",
            node_nm: 22.0,
            array_kb: 64.0,
            weight_capacity_kb: 64.0,
            cell: "/",
            area_mm2: 0.83,
            area_eff_gops_mm2_28: 712.15,
            energy_eff_tops_w: 6.39,
            precision: "8b/4b",
        },
        PriorMacro {
            name: "Nat.Elec.'21 [35]",
            device: "RRAM",
            node_nm: 22.0,
            array_kb: 4096.0,
            weight_capacity_kb: 4096.0,
            cell: "1T1R",
            area_mm2: 6.0,
            area_eff_gops_mm2_28: 3.47,
            energy_eff_tops_w: 15.60,
            precision: "8b/8b",
        },
        PriorMacro {
            name: "VLSI'21 [11]",
            device: "SRAM",
            node_nm: 28.0,
            array_kb: 3456.0,
            weight_capacity_kb: 3456.0,
            cell: "10T1C",
            area_mm2: 20.9,
            area_eff_gops_mm2_28: 234.0,
            energy_eff_tops_w: 588.0,
            precision: "1b/1b",
        },
        PriorMacro {
            name: "ISSCC'20 [24]",
            device: "SRAM",
            node_nm: 28.0,
            array_kb: 64.0,
            weight_capacity_kb: 64.0,
            cell: "6T+LCC",
            area_mm2: 0.362,
            area_eff_gops_mm2_28: 84.2,
            energy_eff_tops_w: 14.1,
            precision: "8b/8b",
        },
        PriorMacro {
            name: "ISSCC'21 [26]",
            device: "SRAM",
            node_nm: 22.0,
            array_kb: 64.0,
            weight_capacity_kb: 64.0,
            cell: "6T",
            area_mm2: 0.202,
            area_eff_gops_mm2_28: 2802.5,
            energy_eff_tops_w: 24.7,
            precision: "8b/8b",
        },
        PriorMacro {
            name: "ISSCC'22 [14]",
            device: "SRAM",
            node_nm: 28.0,
            array_kb: 32.0,
            weight_capacity_kb: 32.0,
            cell: "6T+LCC",
            area_mm2: 0.040,
            area_eff_gops_mm2_28: 133.3,
            energy_eff_tops_w: 27.38,
            precision: "8b/8b",
        },
    ]
}

impl PriorMacro {
    pub fn integration_density(&self) -> f64 {
        self.array_kb / self.area_mm2
    }

    pub fn integration_density_28(&self) -> f64 {
        self.integration_density() / (28.0 / self.node_nm).powi(2)
    }

    pub fn weight_density(&self) -> f64 {
        self.weight_capacity_kb / self.area_mm2
    }

    pub fn weight_density_28(&self) -> f64 {
        self.weight_density() / (28.0 / self.node_nm).powi(2)
    }
}

pub fn render(_ctx: &ReportCtx) -> String {
    let cfg = ArchConfig::ddc_pim();
    let cost = CostModel::new(cfg.clone());
    let mut t = Table::new(
        "Table II — comparison with prior works for PIM macros (This Work recomputed from the cost model)",
    )
    .header(&[
        "Macro",
        "Device",
        "Node",
        "Array(Kb)",
        "WeightCap(Kb)",
        "Area(mm2)",
        "IntDens(Kb/mm2@28)",
        "WtDens(Kb/mm2@28)",
        "AreaEff(GOPS/mm2@28)",
        "EnergyEff(TOPS/W)",
    ]);
    for p in prior_works() {
        t.row(vec![
            p.name.into(),
            p.device.into(),
            format!("{}nm", p.node_nm),
            f2(p.array_kb),
            f2(p.weight_capacity_kb),
            format!("{:.3}", p.area_mm2),
            f2(p.integration_density_28()),
            f2(p.weight_density_28()),
            f2(p.area_eff_gops_mm2_28),
            f2(p.energy_eff_tops_w),
        ]);
    }
    t.row(vec![
        "This Work (DDC-PIM)".into(),
        "SRAM".into(),
        format!("{}nm", cfg.node_nm),
        f2(cfg.macro_array_kb()),
        f2(cfg.macro_weight_capacity_kb()),
        format!("{:.4}", cost.macro_area_mm2()),
        f2(cost.integration_density(true)),
        f2(cost.weight_density(true)),
        f2(cost.area_efficiency(true)),
        f2(cost.energy_efficiency_tops_w()),
    ]);
    // the paper's "up to 8.41x" compares against SRAM-based priors
    let sram: Vec<f64> = prior_works()
        .iter()
        .filter(|p| p.device == "SRAM")
        .map(|p| p.weight_density_28())
        .collect();
    let weakest_sram = sram.iter().copied().fold(f64::MAX, f64::min);
    let strongest_sram = sram.iter().copied().fold(f64::MIN, f64::max);
    format!(
        "{}\nweight-density improvement vs SRAM priors: up to {:.2}x (weakest) / {:.2}x (strongest)\narea-efficiency vs ISSCC'22 [14]: {:.2}x",
        t.render(),
        cost.weight_density(true) / weakest_sram,
        cost.weight_density(true) / strongest_sram,
        cost.area_efficiency(true) / 133.3,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_densities_match_paper() {
        let works = prior_works();
        // VLSI'21: 165.4 Kb/mm² at 28 nm (already 28 nm)
        let pimca = &works[3];
        assert!((pimca.integration_density_28() - 165.4).abs() < 0.5);
        // ISSCC'22 [14]: 800 Kb/mm²
        let isscc22 = &works[6];
        assert!((isscc22.integration_density_28() - 800.0).abs() < 1.0);
        // Nat.Elec.'22: 45.98 @ 14nm -> 11.52 @ 28nm
        let ne22 = &works[0];
        assert!((ne22.integration_density() - 45.98).abs() < 0.05);
        assert!((ne22.integration_density_28() - 11.49).abs() < 0.1);
    }

    #[test]
    fn this_work_wins_weight_density() {
        let ctx = ReportCtx::new("/nonexistent");
        let s = render(&ctx);
        assert!(s.contains("This Work"));
        // headline: 8.41x vs weakest prior (PIMCA 165.4)
        assert!(s.contains("8.41x") || s.contains("8.40x"), "{s}");
    }

    #[test]
    fn area_eff_ratio_in_report() {
        let ctx = ReportCtx::new("/nonexistent");
        let s = render(&ctx);
        assert!(s.contains("1.74x") || s.contains("1.73x"), "{s}");
    }
}
