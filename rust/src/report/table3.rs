//! Table III: FCC accuracy across models and layer scopes.
//!
//! FC parameter ratios come from the full-size rust shape books (they
//! match the paper's column); accuracies come from the python training
//! pass on the scaled models (DESIGN.md §2 substitution).

use crate::model::zoo;
use crate::util::table::{f2, Table};

use super::ReportCtx;

pub const MODELS: &[(&str, &str)] = &[
    ("mobilenet_v2", "Compact"),
    ("efficientnet_b0", "Compact"),
    ("alexnet", "Regular"),
    ("vgg19", "Regular"),
    ("resnet18", "Regular"),
];

pub fn render(ctx: &ReportCtx) -> String {
    let acc = ctx.accuracy();
    let rows = acc
        .as_ref()
        .and_then(|j| j.get("table3"))
        .and_then(|j| j.as_arr().map(<[_]>::to_vec));

    let mut t = Table::new(
        "Table III — FCC accuracy by model (scaled models; FC ratio from full-size shape books)",
    )
    .header(&[
        "Class",
        "Model",
        "Baseline acc",
        "Conv-FCC acc",
        "Conv drop",
        "Conv+FC acc",
        "Conv+FC drop",
        "FC param ratio (full-size)",
    ]);
    for (model, class) in MODELS {
        let net = zoo::by_name(model).unwrap();
        let fc_ratio = format!("{}%", f2(net.fc_param_ratio()));
        let found = rows.as_ref().and_then(|rs| {
            rs.iter()
                .find(|r| r.get("model").and_then(|v| v.as_str()) == Some(model))
        });
        match found {
            Some(r) => {
                let g = |k: &str| r.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                t.row(vec![
                    (*class).into(),
                    (*model).into(),
                    f2(g("baseline_acc")),
                    f2(g("conv_acc")),
                    f2(g("conv_drop")),
                    f2(g("conv_fc_acc")),
                    f2(g("conv_fc_drop")),
                    fc_ratio,
                ]);
            }
            None => {
                t.row(vec![
                    (*class).into(),
                    (*model).into(),
                    "pending".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    fc_ratio,
                ]);
            }
        }
    }
    format!(
        "{}\npaper (full-scale): conv drops 0.42-1.12%, conv+FC drops 1.02-1.90%; FC-heavy\nregular NNs (AlexNet/VGG19) degrade most when FC layers are included.",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fc_ratios_render() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("alexnet"));
        assert!(s.contains("pending"));
        // AlexNet FC ratio from the shape book is ~79%
        assert!(s.contains("79.") || s.contains("78."), "{s}");
    }
}
