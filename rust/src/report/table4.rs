//! Table IV: FCC + 2:4 structured pruning on MobileNetV2 (CIFAR-100
//! scale substitution).
//!
//! Compression accounting: 2:4 pruning alone stores half the weights
//! (50%); adding FCC halves the *remaining* conv weights (the odd comp
//! filters are free), compounding to ~75% for conv-dominated models.

use crate::model::zoo;
use crate::util::table::{f2, Table};

use super::ReportCtx;

/// Model-level compression ratio of FCC + 2:4 (fraction of weights that
/// no longer need storing), from the full-size shape book.
pub fn fcc_prune_compression(model: &str) -> f64 {
    let net = zoo::by_name(model).unwrap();
    let total: f64 = net.total_params() as f64;
    let conv_fcc: f64 = net
        .layers
        .iter()
        .filter(|l| l.fcc_eligible())
        .map(|l| l.params() as f64)
        .sum();
    // 2:4 keeps 1/2 of everything; FCC keeps 1/2 of the kept conv part
    let kept = 0.5 * (total - conv_fcc) + 0.25 * conv_fcc;
    1.0 - kept / total
}

pub fn render(ctx: &ReportCtx) -> String {
    let acc = ctx.accuracy().and_then(|j| j.get("table4").cloned());
    let mut t = Table::new(
        "Table IV — accuracy & compression of MobileNetV2 with pruning + FCC (CIFAR-100-scale substitution)",
    )
    .header(&["Method", "Top-1 acc (%)", "Acc drop (%)", "Compression"]);
    let g = |k: &str| {
        acc.as_ref()
            .and_then(|j| j.get(k))
            .and_then(|v| v.as_f64())
    };
    match (g("original_acc"), g("pruned_acc"), g("fcc_pruned_acc")) {
        (Some(orig), Some(pruned), Some(both)) => {
            t.row(vec!["Original".into(), f2(orig), f2(0.0), "0%".into()]);
            t.row(vec![
                "2:4 Pruning".into(),
                f2(pruned),
                f2(orig - pruned),
                "50%".into(),
            ]);
            t.row(vec![
                "FCC + 2:4 Pruning".into(),
                f2(both),
                f2(orig - both),
                format!("~{}%", f2(100.0 * fcc_prune_compression("mobilenet_v2"))),
            ]);
        }
        _ => {
            t.row(vec![
                "pending (run `make accuracy`)".into(),
                "-".into(),
                "-".into(),
                format!("~{}%", f2(100.0 * fcc_prune_compression("mobilenet_v2"))),
            ]);
        }
    }
    format!(
        "{}\npaper: 80.48 / 79.94 (50%) / 78.81 (~75%) — FCC is orthogonal to 2:4 pruning.",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compression_near_75_percent() {
        // MobileNetV2 is conv-dominated, so FCC+2:4 approaches 75%
        let c = fcc_prune_compression("mobilenet_v2");
        assert!(c > 0.70 && c <= 0.75, "c={c}");
    }

    #[test]
    fn fc_heavy_model_compresses_less() {
        assert!(fcc_prune_compression("alexnet") < fcc_prune_compression("mobilenet_v2"));
    }

    #[test]
    fn renders_pending_without_data() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("pending"));
    }
}
