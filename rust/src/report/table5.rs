//! Table V: FCC on MobileViT-XS conv layers (transformer-variant
//! applicability check).

use crate::model::zoo;
use crate::util::table::{f2, Table};

use super::ReportCtx;

pub fn render(ctx: &ReportCtx) -> String {
    let acc = ctx.accuracy().and_then(|j| j.get("table5").cloned());
    let net = zoo::mobilevit_xs();
    let conv_share = 100.0 * net.conv_params() as f64 / net.total_params() as f64;

    let mut t = Table::new("Table V — MobileViT-XS (scaled) accuracy").header(&[
        "Method",
        "Top-1 acc (%)",
    ]);
    let g = |k: &str| {
        acc.as_ref()
            .and_then(|j| j.get(k))
            .and_then(|v| v.as_f64())
    };
    match (g("original_acc"), g("fcc_acc")) {
        (Some(orig), Some(fcc)) => {
            t.row(vec!["Original".into(), f2(orig)]);
            t.row(vec!["FCC (conv layers)".into(), f2(fcc)]);
        }
        _ => {
            t.row(vec!["pending (run `make accuracy`)".into(), "-".into()]);
        }
    }
    format!(
        "{}\nconv layers hold {}% of MobileViT-XS parameters (full-size book);\npaper: 90.88 -> 89.04 with FCC on conv layers only.",
        t.render(),
        f2(conv_share)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders() {
        let s = render(&ReportCtx::new("/nonexistent"));
        assert!(s.contains("MobileViT-XS"));
        assert!(s.contains("conv layers hold"));
    }
}
