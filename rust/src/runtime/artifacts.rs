//! Artifact registry + goldens loader.
//!
//! `goldens.json` (written by `python/compile/aot.py`) carries
//! deterministic inputs/outputs for every artifact; the integration
//! tests replay them through PJRT to prove the AOT bridge is numerically
//! faithful.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// The standard artifact set `make artifacts` produces.
pub const MODEL_B1: &str = "model_b1";
pub const MODEL_B8: &str = "model_b8";
pub const FCC_MVM: &str = "fcc_mvm";
pub const PIM_MAC: &str = "pim_mac";

pub const ALL: &[&str] = &[MODEL_B1, MODEL_B8, FCC_MVM, PIM_MAC];

/// One golden test vector.
#[derive(Debug, Clone)]
pub struct Golden {
    pub x: Vec<f64>,
    pub x_shape: Vec<i64>,
    pub w: Option<Vec<f64>>,
    pub w_shape: Vec<i64>,
    pub m: Option<Vec<f64>>,
    pub m_shape: Vec<i64>,
    pub out: Vec<f64>,
    pub out_shape: Vec<i64>,
}

impl Golden {
    fn from_json(j: &Json) -> Result<Golden> {
        let vecf = |k: &str| -> Option<Vec<f64>> { j.get(k).and_then(Json::as_f64_vec) };
        let shape = |k: &str| -> Vec<i64> {
            j.get(k).and_then(Json::as_i64_vec).unwrap_or_default()
        };
        Ok(Golden {
            x: vecf("x").ok_or_else(|| anyhow!("golden missing x"))?,
            x_shape: shape("x_shape"),
            w: vecf("w"),
            w_shape: shape("w_shape"),
            m: vecf("m"),
            m_shape: shape("m_shape"),
            out: vecf("out").ok_or_else(|| anyhow!("golden missing out"))?,
            out_shape: shape("out_shape"),
        })
    }

    pub fn x_i32(&self) -> Vec<i32> {
        self.x.iter().map(|&v| v as i32).collect()
    }

    pub fn w_i32(&self) -> Vec<i32> {
        self.w.as_deref().unwrap_or(&[]).iter().map(|&v| v as i32).collect()
    }

    pub fn m_i32(&self) -> Vec<i32> {
        self.m.as_deref().unwrap_or(&[]).iter().map(|&v| v as i32).collect()
    }

    pub fn x_f32(&self) -> Vec<f32> {
        self.x.iter().map(|&v| v as f32).collect()
    }

    pub fn out_f32(&self) -> Vec<f32> {
        self.out.iter().map(|&v| v as f32).collect()
    }

    pub fn out_i32(&self) -> Vec<i32> {
        self.out.iter().map(|&v| v as i32).collect()
    }
}

/// The model's weight tensors (the AOT model takes weights as
/// parameters — see `python/compile/aot.py`): flattened f32 data +
/// shape per tensor, in call order.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub tensors: Vec<(Vec<f32>, Vec<i64>)>,
}

/// Load `<dir>/model_weights.{json,bin}`.
pub fn load_model_weights(dir: impl AsRef<Path>) -> Result<ModelWeights> {
    let dir = dir.as_ref();
    let manifest = std::fs::read_to_string(dir.join("model_weights.json"))
        .with_context(|| format!("reading {}/model_weights.json", dir.display()))?;
    let j = Json::parse(&manifest).context("parsing model_weights.json")?;
    let shapes: Vec<Vec<i64>> = j
        .get("shapes")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing shapes"))?
        .iter()
        .filter_map(Json::as_i64_vec)
        .collect();
    let bin = std::fs::read(dir.join("model_weights.bin"))
        .with_context(|| format!("reading {}/model_weights.bin", dir.display()))?;
    let mut tensors = Vec::with_capacity(shapes.len());
    let mut off = 0usize;
    for shape in shapes {
        let n: i64 = shape.iter().product();
        let bytes = n as usize * 4;
        anyhow::ensure!(off + bytes <= bin.len(), "weights bin truncated");
        let data: Vec<f32> = bin[off..off + bytes]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        tensors.push((data, shape));
        off += bytes;
    }
    anyhow::ensure!(off == bin.len(), "weights bin has trailing bytes");
    Ok(ModelWeights { tensors })
}

/// Load all goldens from `<dir>/goldens.json`.
pub fn load_goldens(dir: impl AsRef<Path>) -> Result<Vec<(String, Golden)>> {
    let path = dir.as_ref().join("goldens.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).context("parsing goldens.json")?;
    let obj = j.as_obj().ok_or_else(|| anyhow!("goldens.json not an object"))?;
    let mut out = Vec::new();
    for (k, v) in obj {
        out.push((k.clone(), Golden::from_json(v)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn golden_parses() {
        let j = Json::parse(
            r#"{"x":[1,2],"x_shape":[1,2],"out":[3],"out_shape":[1,1]}"#,
        )
        .unwrap();
        let g = Golden::from_json(&j).unwrap();
        assert_eq!(g.x_i32(), vec![1, 2]);
        assert_eq!(g.out_f32(), vec![3.0]);
        assert!(g.w.is_none());
    }

    #[test]
    fn golden_requires_x_and_out() {
        let j = Json::parse(r#"{"x":[1]}"#).unwrap();
        assert!(Golden::from_json(&j).is_err());
    }
}
