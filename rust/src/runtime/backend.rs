//! The [`Backend`] trait: one interface for every way this system can
//! execute a network, plus the factory that selects an implementation.
//!
//! The contract is deliberately small — a batched float classifier for
//! the serving path and the two integer L1 kernels for golden replay —
//! so a backend can be a pure-Rust interpreter, a PJRT executable, or
//! anything future PRs add (sharded, remote, ...), without the
//! coordinator knowing the difference.

use anyhow::Result;

/// Flattened CIFAR image size the serving path accepts ([32, 32, 3]).
pub const IMG_ELEMS: usize = 32 * 32 * 3;

/// Number of classifier outputs.
pub const NUM_CLASSES: usize = 10;

/// An inference executor.
///
/// Shape conventions match the python side (`compile/kernels/ref.py`):
/// row-major `x: [B, L]`, `w: [L, N]`, `w_even: [L, N/2]` with FCC
/// outputs interleaved `(even, odd, even, ...)` along the channel dim.
pub trait Backend {
    /// Stable implementation name ("reference", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Whether the integer kernels accept arbitrary `(b, l, n)` shapes.
    /// Interpreters return `true`; AOT-compiled backends (PJRT) are
    /// lowered at fixed shapes and return `false` — their kernels are
    /// verified by artifact-golden replay instead of
    /// [`verify_kernel_oracles`].
    fn supports_arbitrary_kernel_shapes(&self) -> bool {
        false
    }

    /// Classify a batch of CIFAR images: `x.len() == batch * IMG_ELEMS`,
    /// returns `batch * NUM_CLASSES` logits.
    fn infer_batch(&mut self, x: &[f32], batch: usize) -> Result<Vec<f32>>;

    /// FCC matrix-vector kernel with ARU recovery (paper Eq. 7, the
    /// `fcc_mvm_ref` oracle): `x [b, l]`, `w_even [l, half]`, `m [half]`
    /// → `[b, 2 * half]` interleaved.
    fn fcc_mvm(
        &mut self,
        x: &[i32],
        w_even: &[i32],
        m: &[i32],
        b: usize,
        l: usize,
        half: usize,
    ) -> Result<Vec<i32>>;

    /// Dense signed-INT8 MVM (the `mvm_int8_ref` / bit-serial PIM-MAC
    /// oracle): `x [b, l]`, `w [l, n]` → `[b, n]` int32.
    fn pim_mac(
        &mut self,
        x: &[i32],
        w: &[i32],
        b: usize,
        l: usize,
        n: usize,
    ) -> Result<Vec<i32>>;
}

/// Which backend to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts are present, else reference.
    #[default]
    Auto,
    /// The pure-Rust reference backend (always available).
    Reference,
    /// The PJRT/HLO artifact path (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "auto" => Some(BackendKind::Auto),
            "reference" | "ref" => Some(BackendKind::Reference),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// Construct a backend.  `artifact_dir` is only consulted by the PJRT
/// path; the reference backend is hermetic.
pub fn create_backend(kind: BackendKind, artifact_dir: &str) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Reference => Ok(Box::new(super::reference::ReferenceBackend::seeded(
            super::reference::DEFAULT_SEED,
        ))),
        BackendKind::Pjrt => create_pjrt(artifact_dir),
        BackendKind::Auto => {
            #[cfg(feature = "pjrt")]
            {
                let has_artifacts = std::path::Path::new(artifact_dir)
                    .join("model_b1.hlo.txt")
                    .exists();
                if has_artifacts {
                    match create_pjrt(artifact_dir) {
                        Ok(b) => return Ok(b),
                        // artifacts exist but PJRT won't come up: fall
                        // back, but say why — a silent fallback would
                        // serve seeded random weights in place of the
                        // trained model with no explanation.
                        Err(e) => eprintln!(
                            "warning: artifacts present in {artifact_dir} but PJRT backend \
                             failed ({e:#}); falling back to the reference backend"
                        ),
                    }
                }
            }
            create_backend(BackendKind::Reference, artifact_dir)
        }
    }
}

/// Verify a backend's integer kernels against the L1 oracle semantics
/// (`kernels/ref.py`) on small random shapes: dense INT8 MVM and the
/// Eq. 7 ARU recovery vs a dense MVM with the recomposed biased-comp
/// bank.
///
/// Only valid for backends that accept arbitrary kernel shapes (the
/// reference interpreter).  AOT/PJRT executables are lowered at *fixed*
/// shapes and must instead be verified by replaying the artifact
/// goldens, which carry their own shapes.
pub fn verify_kernel_oracles(backend: &mut dyn Backend) -> Result<()> {
    use crate::fcc::{fcc_transform, FilterBank};
    use crate::util::rng::Rng;

    // dense INT8 MVM vs the mvm_int8_ref oracle
    let mut rng = Rng::new(101);
    let (b, l, n) = (4usize, 16usize, 8usize);
    let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
    let w: Vec<i32> = (0..l * n).map(|_| rng.int8() as i32).collect();
    let got = backend.pim_mac(&x, &w, b, l, n)?;
    anyhow::ensure!(
        got == super::reference::mvm_i32(&x, &w, b, l, n),
        "pim_mac output mismatch vs dense oracle"
    );

    // FCC MVM vs the Eq. 7 identity
    let half = n / 2;
    let bank = FilterBank::new((0..n * l).map(|_| rng.int8() as i32).collect(), n, l);
    let fcc = fcc_transform(&bank);
    let got = backend.fcc_mvm(&x, &fcc.stored_even_cols(), &fcc.means, b, l, half)?;
    anyhow::ensure!(
        got == super::reference::mvm_i32(&x, &fcc.biased_comp_cols(), b, l, n),
        "fcc_mvm ARU recovery mismatch vs Eq. 7 identity"
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn create_pjrt(artifact_dir: &str) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::new(artifact_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn create_pjrt(_artifact_dir: &str) -> Result<Box<dyn Backend>> {
    Err(anyhow::anyhow!(
        "this binary was built without the `pjrt` feature; \
         rebuild with `--features pjrt` (and a real xla crate) or use --backend reference"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("reference"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("ref"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("tpu"), None);
    }

    #[test]
    fn auto_falls_back_to_reference_without_artifacts() {
        let b = create_backend(BackendKind::Auto, "/nonexistent").expect("backend");
        assert_eq!(b.name(), "reference");
    }

    #[test]
    fn reference_always_constructs() {
        let mut b = create_backend(BackendKind::Reference, "/nonexistent").expect("backend");
        let img = vec![0.0f32; IMG_ELEMS];
        let out = b.infer_batch(&img, 1).expect("infer");
        assert_eq!(out.len(), NUM_CLASSES);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_without_feature() {
        assert!(create_backend(BackendKind::Pjrt, "/nonexistent").is_err());
    }
}
