//! The [`Backend`]/[`Session`] pair: one interface for every way this
//! system can execute a network, plus the factory that selects an
//! implementation.
//!
//! The execution surface is a **prepare → execute** lifecycle: a
//! [`Backend`] holds the model definition (weights, artifacts) and
//! [`Backend::prepare`] builds a [`Session`] in which those weights are
//! *resident* — the reference backend plans its layer stack onto
//! preallocated buffers (and, behind [`FabricChoice::BitSliced`], onto
//! the bit-sliced PIM fabric with SRAM weights written exactly once);
//! the PJRT backend loads/compiles its executables.  Steady-state
//! serving calls [`Session::infer_batch_into`] with caller-owned
//! output and performs no per-batch heap allocation.
//!
//! The one-shot [`Backend::infer_batch`] remains as a thin wrapper
//! (prepare + single execute), so existing callers keep working.  The
//! two integer L1 kernels stay on [`Backend`] for golden replay.

use std::str::FromStr;

use anyhow::Result;

/// Flattened CIFAR image size the serving path accepts ([32, 32, 3]).
pub const IMG_ELEMS: usize = 32 * 32 * 3;

/// Number of classifier outputs.
pub const NUM_CLASSES: usize = 10;

/// A prepared execution session: weights resident, buffers owned.
///
/// Sessions are stateful scratch holders, not model owners — dropping a
/// session never invalidates the backend, and a backend can prepare any
/// number of sessions (e.g. one per worker thread).  Repeated
/// [`Session::infer_batch_into`] calls are deterministic and
/// byte-identical to the one-shot [`Backend::infer_batch`] path.
pub trait Session {
    /// Stable implementation name ("reference", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Classify a batch of CIFAR images into a caller-owned buffer:
    /// `x.len() == batch * IMG_ELEMS`,
    /// `out.len() == batch * NUM_CLASSES`.
    ///
    /// Implementations reuse internal buffers across calls; the
    /// reference session guarantees zero heap allocation after the
    /// first call at a given batch size (asserted by
    /// `tests/alloc_steady_state.rs`).  The PJRT session reuses its
    /// staging buffer but its runtime allocates result literals.
    fn infer_batch_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()>;

    /// Capacity-pressure counters accumulated since the session was
    /// prepared — `Some` only for sessions running under a
    /// weight-streaming budget
    /// (`crate::runtime::reference::StreamConfig`); `None` (the
    /// default) when every weight is resident for the session's
    /// lifetime.
    fn capacity_pressure(&self) -> Option<crate::metrics::CapacityPressure> {
        None
    }

    /// Reliability counters accumulated since the session was prepared
    /// (faults injected/detected/repaired, quarantined rows, stager
    /// fallbacks) — `None` (the default) for sessions with no fault or
    /// degradation model.  The reference session always reports `Some`,
    /// all-zero when nothing has gone wrong.
    fn reliability(&self) -> Option<crate::metrics::ReliabilityStats> {
        None
    }

    /// Run an integrity scrub over this session's resident weight
    /// state: detect corruption (via the stored-Q checksums that cover
    /// both complementary polarities), quarantine and re-home damaged
    /// rows onto spares, zeroize what cannot be repaired.  Returns the
    /// post-scrub reliability counters, or `None` (the default) when
    /// the session has no scrubbable fabric.
    fn scrub(&mut self) -> Option<crate::metrics::ReliabilityStats> {
        None
    }
}

/// An inference executor.
///
/// Shape conventions match the python side (`compile/kernels/ref.py`):
/// row-major `x: [B, L]`, `w: [L, N]`, `w_even: [L, N/2]` with FCC
/// outputs interleaved `(even, odd, even, ...)` along the channel dim.
pub trait Backend {
    /// Stable implementation name ("reference", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Whether the integer kernels accept arbitrary `(b, l, n)` shapes.
    /// Interpreters return `true`; AOT-compiled backends (PJRT) are
    /// lowered at fixed shapes and return `false` — their kernels are
    /// verified by artifact-golden replay instead of
    /// [`verify_kernel_oracles`].
    fn supports_arbitrary_kernel_shapes(&self) -> bool {
        false
    }

    /// Build a [`Session`] with this backend's weights resident: the
    /// load-once half of the load-once/execute-many split.
    fn prepare(&self) -> Result<Box<dyn Session>>;

    /// Classify a batch of CIFAR images: `x.len() == batch * IMG_ELEMS`,
    /// returns `batch * NUM_CLASSES` logits.
    ///
    /// One-shot convenience: prepares a fresh session and executes it
    /// once.  Serving paths should hold a [`Session`] instead.
    fn infer_batch(&mut self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut session = self.prepare()?;
        let mut out = vec![0f32; batch * NUM_CLASSES];
        session.infer_batch_into(x, batch, &mut out)?;
        Ok(out)
    }

    /// FCC matrix-vector kernel with ARU recovery (paper Eq. 7, the
    /// `fcc_mvm_ref` oracle): `x [b, l]`, `w_even [l, half]`, `m [half]`
    /// → `[b, 2 * half]` interleaved.
    fn fcc_mvm(
        &mut self,
        x: &[i32],
        w_even: &[i32],
        m: &[i32],
        b: usize,
        l: usize,
        half: usize,
    ) -> Result<Vec<i32>>;

    /// Dense signed-INT8 MVM (the `mvm_int8_ref` / bit-serial PIM-MAC
    /// oracle): `x [b, l]`, `w [l, n]` → `[b, n]` int32.
    fn pim_mac(
        &mut self,
        x: &[i32],
        w: &[i32],
        b: usize,
        l: usize,
        n: usize,
    ) -> Result<Vec<i32>>;
}

/// Which backend to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts are present, else reference.
    #[default]
    Auto,
    /// The pure-Rust reference backend (always available).
    Reference,
    /// The PJRT/HLO artifact path (requires the `pjrt` cargo feature).
    Pjrt,
}

impl FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<BackendKind, String> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "reference" | "ref" => Ok(BackendKind::Reference),
            "pjrt" => Ok(BackendKind::Pjrt),
            _ => Err(format!("unknown backend {s:?}; have: auto, reference, pjrt")),
        }
    }
}

impl BackendKind {
    /// Parse a CLI flag value (shim over the [`FromStr`] impl).
    pub fn parse(s: &str) -> Option<BackendKind> {
        s.parse().ok()
    }
}

/// Which conv-layer execution fabric the reference backend plans onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricChoice {
    /// The dense `fcc_mvm` reference kernel (default: bit-true against
    /// the python oracles and the checked-in goldens).
    #[default]
    DenseReference,
    /// The bit-sliced functional PIM fabric
    /// ([`crate::mapping::PlannedConv`]): the serving path runs through
    /// the word-parallel bit-plane macro model with SRAM weights
    /// written once per session.
    BitSliced,
}

impl FromStr for FabricChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<FabricChoice, String> {
        match s {
            "dense" | "reference" => Ok(FabricChoice::DenseReference),
            "bitsliced" | "fabric" => Ok(FabricChoice::BitSliced),
            _ => Err(format!("unknown fabric {s:?}; have: dense, bitsliced")),
        }
    }
}

/// Full backend selection: kind plus the knobs individual backends
/// consult (`fabric` and `threads` apply to the reference backend
/// only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BackendSpec {
    pub kind: BackendKind,
    pub fabric: FabricChoice,
    /// Execution-pool width for reference sessions on either fabric
    /// (bit-sliced convs shard pixel blocks, dense convs shard MVM row
    /// blocks): `0` (default) resolves through the `DDC_THREADS`
    /// environment variable and falls back to 1 — the serial path,
    /// which every width is byte-identical to
    /// (`crate::util::pool::resolve_threads`).
    pub threads: usize,
    /// Weight-streaming capacity budget in KiB for reference sessions
    /// (`0` = no budget: every conv layer stays resident).  Non-zero
    /// values stream conv weights through
    /// `crate::runtime::reference::StreamConfig::budget(stream_kb * 1024)`
    /// with background prefetch on; logits are byte-identical at every
    /// budget, and pressure counters surface through
    /// [`Session::capacity_pressure`].
    pub stream_kb: usize,
    /// Seeded bit-cell fault injection for reference sessions on the
    /// bit-sliced fabric, as a bit-error rate in **parts per million**
    /// (`0` = the pristine zero-fault fabric, byte for byte).  Integer
    /// because this struct derives `Eq`; the backend converts through
    /// `crate::arch::fault::FaultConfig::from_ppm`.  Detection/repair
    /// counters surface through [`Session::reliability`] and the scrub
    /// runs on demand via [`Session::scrub`].
    pub fault_ber_ppm: u32,
    /// Seed for the injected fault pattern (only read when
    /// `fault_ber_ppm > 0`); same seed + same BER = same faults.
    /// Also seeds the runtime-upset process and its zero-BER golden
    /// intent ledger when `upset_ppm > 0` with no write-time faults.
    pub fault_seed: u64,
    /// Seeded **runtime** retention-upset process for reference
    /// sessions on the bit-sliced fabric, as a per-batch bit-error rate
    /// in parts per million (`0` = no upsets).  Unlike
    /// `fault_ber_ppm` (write-time corruption), upsets flip stored Q
    /// bits *between batches* against a virtual batch clock, so the
    /// same spec replays the same damage schedule.  Converted through
    /// `crate::arch::fault::UpsetConfig::from_ppm`.
    pub upset_ppm: u32,
    /// Incremental serving-time scrub budget: checksum stripes verified
    /// per batch boundary (`0` = scheduler off).  Any positive budget
    /// walks the full resident stripe space round-robin, reaching full
    /// coverage every `ceil(total / budget)` batches; progress surfaces
    /// through [`Session::reliability`].
    pub scrub_stripes: u32,
    /// Macro-grid shape for reference sessions on the bit-sliced
    /// fabric: non-trivial shapes shard each conv layer across a
    /// `rows × cols` grid of macros via the shard planner
    /// (`crate::mapping::shard`), byte-identical to single-macro
    /// execution at every shape.  [`GridShape::AUTO`] (the default)
    /// resolves through the `DDC_GRID` environment variable and falls
    /// back to `1x1`.  Ignored by the dense fabric and the PJRT path.
    pub grid: crate::arch::grid::GridShape,
}

impl BackendSpec {
    pub fn new(kind: BackendKind) -> BackendSpec {
        BackendSpec {
            kind,
            ..Default::default()
        }
    }

    /// Construct the backend this spec describes.  `artifact_dir` is
    /// only consulted by the PJRT path; the reference backend is
    /// hermetic.
    pub fn create(&self, artifact_dir: &str) -> Result<Box<dyn Backend>> {
        match self.kind {
            BackendKind::Reference => {
                let mut be = super::reference::ReferenceBackend::seeded_with(
                    super::reference::DEFAULT_SEED,
                    self.fabric,
                )
                .with_threads(self.threads)
                .with_grid(self.grid);
                if self.stream_kb > 0 {
                    be = be.with_streaming(super::reference::StreamConfig::budget(
                        self.stream_kb * 1024,
                    ));
                }
                if self.fault_ber_ppm > 0 {
                    be = be.with_faults(crate::arch::fault::FaultConfig::from_ppm(
                        self.fault_seed,
                        self.fault_ber_ppm,
                    ));
                }
                if self.upset_ppm > 0 {
                    be = be.with_upsets(crate::arch::fault::UpsetConfig::from_ppm(
                        self.fault_seed,
                        self.upset_ppm,
                    ));
                }
                if self.scrub_stripes > 0 {
                    be = be.with_scrub_stripes(self.scrub_stripes as usize);
                }
                Ok(Box::new(be))
            }
            BackendKind::Pjrt => create_pjrt(artifact_dir),
            BackendKind::Auto => {
                #[cfg(feature = "pjrt")]
                {
                    let has_artifacts = std::path::Path::new(artifact_dir)
                        .join("model_b1.hlo.txt")
                        .exists();
                    if has_artifacts {
                        match create_pjrt(artifact_dir) {
                            Ok(b) => return Ok(b),
                            // artifacts exist but PJRT won't come up: fall
                            // back, but say why — a silent fallback would
                            // serve seeded random weights in place of the
                            // trained model with no explanation.
                            Err(e) => eprintln!(
                                "warning: artifacts present in {artifact_dir} but PJRT backend \
                                 failed ({e:#}); falling back to the reference backend"
                            ),
                        }
                    }
                }
                BackendSpec {
                    kind: BackendKind::Reference,
                    ..*self
                }
                .create(artifact_dir)
            }
        }
    }
}

/// Construct a backend with default knobs (see [`BackendSpec`]).
pub fn create_backend(kind: BackendKind, artifact_dir: &str) -> Result<Box<dyn Backend>> {
    BackendSpec::new(kind).create(artifact_dir)
}

/// Verify a backend's integer kernels against the L1 oracle semantics
/// (`kernels/ref.py`) on small random shapes: dense INT8 MVM and the
/// Eq. 7 ARU recovery vs a dense MVM with the recomposed biased-comp
/// bank.
///
/// Only valid for backends that accept arbitrary kernel shapes (the
/// reference interpreter).  AOT/PJRT executables are lowered at *fixed*
/// shapes and must instead be verified by replaying the artifact
/// goldens, which carry their own shapes.
pub fn verify_kernel_oracles(backend: &mut dyn Backend) -> Result<()> {
    use crate::fcc::{fcc_transform, FilterBank};
    use crate::util::rng::Rng;

    // dense INT8 MVM vs the mvm_int8_ref oracle
    let mut rng = Rng::new(101);
    let (b, l, n) = (4usize, 16usize, 8usize);
    let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
    let w: Vec<i32> = (0..l * n).map(|_| rng.int8() as i32).collect();
    let got = backend.pim_mac(&x, &w, b, l, n)?;
    anyhow::ensure!(
        got == super::reference::mvm_i32(&x, &w, b, l, n),
        "pim_mac output mismatch vs dense oracle"
    );

    // FCC MVM vs the Eq. 7 identity
    let half = n / 2;
    let bank = FilterBank::new((0..n * l).map(|_| rng.int8() as i32).collect(), n, l);
    let fcc = fcc_transform(&bank);
    let got = backend.fcc_mvm(&x, &fcc.stored_even_cols(), &fcc.means, b, l, half)?;
    anyhow::ensure!(
        got == super::reference::mvm_i32(&x, &fcc.biased_comp_cols(), b, l, n),
        "fcc_mvm ARU recovery mismatch vs Eq. 7 identity"
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn create_pjrt(artifact_dir: &str) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::pjrt::PjrtBackend::new(artifact_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn create_pjrt(_artifact_dir: &str) -> Result<Box<dyn Backend>> {
    Err(anyhow::anyhow!(
        "this binary was built without the `pjrt` feature; \
         rebuild with `--features pjrt` (and a real xla crate) or use --backend reference"
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("reference"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("ref"), Some(BackendKind::Reference));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("tpu"), None);
        // the FromStr impl is the source of truth; the shim delegates
        assert_eq!("pjrt".parse(), Ok(BackendKind::Pjrt));
        assert!("tpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn parse_fabrics() {
        assert_eq!("dense".parse(), Ok(FabricChoice::DenseReference));
        assert_eq!("bitsliced".parse(), Ok(FabricChoice::BitSliced));
        assert_eq!("fabric".parse(), Ok(FabricChoice::BitSliced));
        assert!("analog".parse::<FabricChoice>().is_err());
    }

    #[test]
    fn auto_falls_back_to_reference_without_artifacts() {
        let b = create_backend(BackendKind::Auto, "/nonexistent").expect("backend");
        assert_eq!(b.name(), "reference");
    }

    #[test]
    fn reference_always_constructs() {
        let mut b = create_backend(BackendKind::Reference, "/nonexistent").expect("backend");
        let img = vec![0.0f32; IMG_ELEMS];
        let out = b.infer_batch(&img, 1).expect("infer");
        assert_eq!(out.len(), NUM_CLASSES);
    }

    #[test]
    fn spec_selects_the_bitsliced_fabric() {
        let spec = BackendSpec {
            kind: BackendKind::Reference,
            fabric: FabricChoice::BitSliced,
            threads: 2,
            ..Default::default()
        };
        let mut b = spec.create("/nonexistent").expect("backend");
        let img = vec![0.25f32; IMG_ELEMS];
        let out = b.infer_batch(&img, 1).expect("infer");
        assert_eq!(out.len(), NUM_CLASSES);
    }

    #[test]
    fn streamed_spec_reports_capacity_pressure() {
        let spec = BackendSpec {
            kind: BackendKind::Reference,
            fabric: FabricChoice::DenseReference,
            threads: 1,
            stream_kb: 2, // 2048 B < conv2's 2304 B footprint -> 2 passes
            ..Default::default()
        };
        let b = spec.create("/nonexistent").expect("backend");
        let mut s = b.prepare().expect("session");
        let img = vec![0.25f32; IMG_ELEMS];
        let mut out = vec![0f32; NUM_CLASSES];
        s.infer_batch_into(&img, 1, &mut out).expect("infer");
        let p = s.capacity_pressure().expect("streamed session has pressure");
        assert_eq!(p.capacity_bytes, 2048);
        assert!(p.staged_bytes > 0);
        // an unbudgeted spec reports none
        let b = BackendSpec::new(BackendKind::Reference)
            .create("/nonexistent")
            .expect("backend");
        assert!(b.prepare().expect("session").capacity_pressure().is_none());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_without_feature() {
        assert!(create_backend(BackendKind::Pjrt, "/nonexistent").is_err());
    }
}
