//! PJRT runtime: loads the python-AOT HLO-text artifacts and executes
//! them on the request path (python never runs at inference time).
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.  Artifacts are lowered with
//! `return_tuple=True`, so results unwrap with `to_tuple1`.

pub mod artifacts;

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled executable plus its artifact identity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with f32 inputs; returns the flattened f32 output.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let lits = self.literals_f32(inputs)?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run with i32 inputs; returns the flattened i32 output.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            lits.push(xla::Literal::vec1(data).reshape(dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }

    fn literals_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            lits.push(xla::Literal::vec1(data).reshape(dims)?);
        }
        Ok(lits)
    }
}

/// PJRT client wrapper with a compile cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(
                name.to_string(),
                Executable {
                    name: name.to_string(),
                    exe,
                },
            );
        }
        Ok(&self.cache[name])
    }

    /// Names of currently compiled artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(String::as_str).collect()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Check an artifact file exists without compiling it.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Run a model artifact whose signature is `(x, *weights)` (the AOT
    /// models take their weights as parameters — see artifacts module).
    pub fn run_model(
        &mut self,
        name: &str,
        x: &[f32],
        x_shape: &[i64],
        weights: &artifacts::ModelWeights,
    ) -> Result<Vec<f32>> {
        let exe = self.load(name)?;
        let mut inputs: Vec<(&[f32], &[i64])> = vec![(x, x_shape)];
        for (data, shape) in &weights.tensors {
            inputs.push((data.as_slice(), shape.as_slice()));
        }
        exe.run_f32(&inputs)
    }
}

#[cfg(test)]
mod tests {
    // runtime tests that need artifacts live in rust/tests/ (integration)
    // where `make artifacts` outputs are available; here we only check
    // cheap invariants.
    use super::*;

    #[test]
    fn missing_artifact_detected() {
        if let Ok(rt) = Runtime::cpu("/nonexistent") {
            assert!(!rt.has_artifact("model_b1"));
        }
    }
}
