//! Inference runtime: the pluggable execution layer behind the
//! coordinator and the CLI.
//!
//! Everything that *runs a model* goes through the [`Backend`] trait:
//!
//! * [`reference`] — the default, pure-Rust backend.  Executes a
//!   seeded, FCC-quantized CIFAR network with exactly the integer
//!   semantics of the python oracles in `python/compile/kernels/ref.py`
//!   (dense INT8 MVM and the Eq. 7 ARU recovery), so it is bit-true
//!   against the L1 kernel contracts and needs no artifacts, no native
//!   libraries and no network — this is what CI exercises.
//! * `pjrt` (cargo feature `pjrt`) — the PJRT/HLO path: loads the
//!   python-AOT HLO-text artifacts (see `python/compile/aot.py`) and
//!   executes them through the `xla` crate.  The default build vendors a
//!   compile-time stub for `xla`; swap in the real crate to run the
//!   artifacts (DESIGN.md §Backends).
//! * [`artifacts`] — the artifact registry + goldens loader shared by
//!   both backends (golden replay works on either: the kernels carry
//!   their shapes).
//!
//! Execution follows a **prepare → execute** lifecycle
//! (DESIGN.md §Plan/execute lifecycle): [`Backend::prepare`] builds a
//! [`Session`] with the weights resident (the reference backend plans
//! its layer stack once; PJRT loads its executables), and
//! [`Session::infer_batch_into`] executes batches into caller-owned
//! buffers with zero steady-state allocation.  The one-shot
//! [`Backend::infer_batch`] remains as a prepare-plus-single-execute
//! wrapper.
//!
//! [`create_backend`] picks the implementation: `Auto` prefers PJRT when
//! the feature is on and artifacts exist, and falls back to the
//! reference backend otherwise, so every caller (service, CLI,
//! examples, tests) works on a clean checkout.  [`BackendSpec`] carries
//! the extra knobs (e.g. [`FabricChoice`]: whether the reference
//! backend's convs run on the dense kernel or the bit-sliced fabric,
//! and `stream_kb`: an optional weight-streaming capacity budget —
//! see [`StreamConfig`] — under which sessions reload conv weights in
//! capacity-fitting passes and report [`Session::capacity_pressure`]).

pub mod artifacts;
pub mod backend;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{
    create_backend, verify_kernel_oracles, Backend, BackendKind, BackendSpec, FabricChoice,
    Session, IMG_ELEMS, NUM_CLASSES,
};
pub use reference::{ReferenceBackend, ReferenceSession, StreamConfig};

// the grid shape rides on BackendSpec; re-export it so spec builders
// (service config, CLI, tests) don't need to reach into `arch`
pub use crate::arch::grid::{resolve_grid, GridShape};

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, PjrtBackend, PjrtSession, Runtime};
