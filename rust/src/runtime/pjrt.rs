//! PJRT runtime (cargo feature `pjrt`): loads the python-AOT HLO-text
//! artifacts and executes them through the `xla` crate.
//!
//! Interchange format is HLO **text** (see `python/compile/aot.py`):
//! jax >= 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly.  Artifacts are lowered with `return_tuple=True`,
//! so results unwrap with `to_tuple1`.
//!
//! The default build links the vendored `xla` stub (compiles anywhere,
//! reports "unavailable" at runtime); swap in the real crate to execute
//! artifacts — see DESIGN.md §Backends.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::artifacts;
use super::backend::{Backend, Session, IMG_ELEMS, NUM_CLASSES};

/// Batch size of the wide model artifact (`model_b8`).
const WIDE_BATCH: usize = 8;

/// A compiled executable plus its artifact identity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Run with f32 inputs; returns the flattened f32 output.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            lits.push(xla::Literal::vec1(data).reshape(dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run with i32 inputs; returns the flattened i32 output.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            lits.push(xla::Literal::vec1(data).reshape(dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i32>()?)
    }
}

/// PJRT client wrapper with a compile cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.cache.insert(
                name.to_string(),
                Executable {
                    name: name.to_string(),
                    exe,
                },
            );
        }
        Ok(&self.cache[name])
    }

    /// Names of currently compiled artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(String::as_str).collect()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Check an artifact file exists without compiling it.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifact_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Run a model artifact whose signature is `(x, *weights)` (the AOT
    /// models take their weights as parameters — see artifacts module).
    pub fn run_model(
        &mut self,
        name: &str,
        x: &[f32],
        x_shape: &[i64],
        weights: &artifacts::ModelWeights,
    ) -> Result<Vec<f32>> {
        let exe = self.load(name)?;
        let mut inputs: Vec<(&[f32], &[i64])> = vec![(x, x_shape)];
        for (data, shape) in &weights.tensors {
            inputs.push((data.as_slice(), shape.as_slice()));
        }
        exe.run_f32(&inputs)
    }
}

/// One execution step of a batched inference over the fixed (b1, b8)
/// artifact set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ChunkStep {
    /// First request index covered by this step.
    start: usize,
    /// Real requests in this step.
    chunk: usize,
    /// Which model artifact executes it.
    artifact: &'static str,
    /// Batch dimension of that artifact (`chunk` padded with zeros).
    padded: usize,
}

/// Split a batch into executable steps: full/partial groups of up to
/// [`WIDE_BATCH`] ride `model_b8` (zero-padded), lone trailing images
/// ride `model_b1`.
fn chunk_plan(batch: usize) -> Vec<ChunkStep> {
    let mut plan = Vec::new();
    let mut done = 0;
    while done < batch {
        let chunk = (batch - done).min(WIDE_BATCH);
        let (artifact, padded) = if chunk == 1 {
            ("model_b1", 1)
        } else {
            ("model_b8", WIDE_BATCH)
        };
        plan.push(ChunkStep {
            start: done,
            chunk,
            artifact,
            padded,
        });
        done += chunk;
    }
    plan
}

/// [`Backend`] over the PJRT runtime + AOT artifacts.
pub struct PjrtBackend {
    rt: Runtime,
    weights: artifacts::ModelWeights,
}

impl PjrtBackend {
    /// Requires a PJRT client and the `model_weights` sidecar.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir = artifact_dir.as_ref();
        let rt = Runtime::cpu(dir)?;
        let weights = artifacts::load_model_weights(dir)?;
        Ok(PjrtBackend { rt, weights })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }
}

/// Execute a batch over the fixed (b1, b8) executable set: pad each
/// [`chunk_plan`] step into `staging`, run it, copy the real rows into
/// `out` — the single implementation behind both the session and the
/// one-shot backend path.
fn run_chunked(
    rt: &mut Runtime,
    weights: &artifacts::ModelWeights,
    x: &[f32],
    batch: usize,
    staging: &mut Vec<f32>,
    out: &mut [f32],
) -> Result<()> {
    anyhow::ensure!(
        x.len() == batch * IMG_ELEMS,
        "bad input length {} (want {})",
        x.len(),
        batch * IMG_ELEMS
    );
    anyhow::ensure!(
        out.len() == batch * NUM_CLASSES,
        "bad output length {} (want {})",
        out.len(),
        batch * NUM_CLASSES
    );
    // only b1/b8 artifacts exist: single-image chunks ride the narrow
    // executable, everything else is zero-padded to the wide one and
    // truncated on the way out.
    for step in chunk_plan(batch) {
        staging.clear();
        staging.resize(step.padded * IMG_ELEMS, 0.0);
        staging[..step.chunk * IMG_ELEMS].copy_from_slice(
            &x[step.start * IMG_ELEMS..(step.start + step.chunk) * IMG_ELEMS],
        );
        let logits = rt.run_model(
            step.artifact,
            staging,
            &[step.padded as i64, 32, 32, 3],
            weights,
        )?;
        out[step.start * NUM_CLASSES..(step.start + step.chunk) * NUM_CLASSES]
            .copy_from_slice(&logits[..step.chunk * NUM_CLASSES]);
    }
    Ok(())
}

/// A prepared PJRT session: its own runtime with the model executables
/// loaded/compiled up front, plus a reusable padded staging buffer —
/// the executable-loading half of the prepare/execute split.
pub struct PjrtSession {
    rt: Runtime,
    weights: artifacts::ModelWeights,
    staging: Vec<f32>,
}

impl Session for PjrtSession {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn infer_batch_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        run_chunked(
            &mut self.rt,
            &self.weights,
            x,
            batch,
            &mut self.staging,
            out,
        )
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self) -> Result<Box<dyn Session>> {
        // a session owns its own runtime (PJRT handles are not shared
        // across owners); compile the model executables now so the
        // execute path never compiles lazily
        let mut rt = Runtime::cpu(self.rt.artifact_dir())?;
        for name in ["model_b1", "model_b8"] {
            if rt.has_artifact(name) {
                rt.load(name)?;
            }
        }
        Ok(Box::new(PjrtSession {
            rt,
            weights: self.weights.clone(),
            staging: Vec::new(),
        }))
    }

    fn infer_batch(&mut self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        // one-shot override: reuse this backend's compile cache instead
        // of preparing (and recompiling in) a fresh session per call
        let mut out = vec![0f32; batch * NUM_CLASSES];
        let mut staging = Vec::new();
        run_chunked(&mut self.rt, &self.weights, x, batch, &mut staging, &mut out)?;
        Ok(out)
    }

    fn fcc_mvm(
        &mut self,
        x: &[i32],
        w_even: &[i32],
        m: &[i32],
        b: usize,
        l: usize,
        half: usize,
    ) -> Result<Vec<i32>> {
        let exe = self.rt.load(artifacts::FCC_MVM)?;
        exe.run_i32(&[
            (x, &[b as i64, l as i64]),
            (w_even, &[l as i64, half as i64]),
            (m, &[half as i64]),
        ])
    }

    fn pim_mac(
        &mut self,
        x: &[i32],
        w: &[i32],
        b: usize,
        l: usize,
        n: usize,
    ) -> Result<Vec<i32>> {
        let exe = self.rt.load(artifacts::PIM_MAC)?;
        exe.run_i32(&[(x, &[b as i64, l as i64]), (w, &[l as i64, n as i64])])
    }
}

#[cfg(test)]
mod tests {
    // runtime tests that need artifacts live in rust/tests/ (integration)
    // where `make artifacts` outputs are available; here we only check
    // cheap invariants.
    use super::*;

    #[test]
    fn missing_artifact_detected() {
        if let Ok(rt) = Runtime::cpu("/nonexistent") {
            assert!(!rt.has_artifact("model_b1"));
        }
    }

    #[test]
    fn chunk_plan_covers_batch_contiguously() {
        for batch in [1usize, 2, 7, 8, 9, 12, 16, 17, 25] {
            let plan = chunk_plan(batch);
            let mut next = 0;
            for step in &plan {
                assert_eq!(step.start, next, "batch {batch}: gap in coverage");
                assert!(step.chunk >= 1 && step.chunk <= step.padded);
                next += step.chunk;
            }
            assert_eq!(next, batch, "batch {batch}: not fully covered");
        }
    }

    #[test]
    fn chunk_plan_routes_artifacts() {
        // lone image -> narrow executable
        assert_eq!(
            chunk_plan(1),
            vec![ChunkStep { start: 0, chunk: 1, artifact: "model_b1", padded: 1 }]
        );
        // exact wide batch -> one unpadded wide step
        assert_eq!(
            chunk_plan(8),
            vec![ChunkStep { start: 0, chunk: 8, artifact: "model_b8", padded: 8 }]
        );
        // 9 = wide batch + a lone trailing image on the narrow path
        assert_eq!(
            chunk_plan(9),
            vec![
                ChunkStep { start: 0, chunk: 8, artifact: "model_b8", padded: 8 },
                ChunkStep { start: 8, chunk: 1, artifact: "model_b1", padded: 1 },
            ]
        );
        // partial chunks pad up to the wide executable
        assert_eq!(
            chunk_plan(12),
            vec![
                ChunkStep { start: 0, chunk: 8, artifact: "model_b8", padded: 8 },
                ChunkStep { start: 8, chunk: 4, artifact: "model_b8", padded: 8 },
            ]
        );
    }
}
