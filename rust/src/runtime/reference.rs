//! The pure-Rust reference backend.
//!
//! Executes a deterministic, FCC-quantized CIFAR classifier with the
//! exact integer semantics of the python oracles in
//! `python/compile/kernels/ref.py`:
//!
//! * [`mvm_i32`] is `mvm_int8_ref` — dense signed-INT8 matrix-vector
//!   multiply in wrapping int32 (what the bit-serial PIM array reduces
//!   to);
//! * [`fcc_mvm_i32`] is `fcc_mvm_ref` — only the even comp filters are
//!   stored, the odd twins are recovered through the Eq. 7 ARU identity
//!   (`out_even = psum + ΣI·M`, `out_odd = ΣI·(M-1) - psum`), outputs
//!   interleaved.
//!
//! The network itself is seeded: every weight comes from the
//! deterministic xorshift [`Rng`], and every FCC conv layer stores only
//! half its filters (the [`fcc_transform`] deployment pipeline), so a
//! forward pass exercises symmetrize → complementize → decompose →
//! Eq. 7 recovery end to end — hermetically, on any host.  This is the
//! backend CI runs; PJRT is the opt-in artifact path.

use anyhow::{ensure, Result};

use crate::fcc::{fcc_transform, FilterBank};
use crate::mapping::im2col::im2col;
use crate::util::rng::Rng;

use super::backend::{Backend, IMG_ELEMS, NUM_CLASSES};

/// Default weight seed (recorded so runs are replayable).
pub const DEFAULT_SEED: u64 = 0xDDC0;

/// Input quantization scale: f32 activations → INT8 codes.
const INPUT_SCALE: f32 = 32.0;

/// Logit de-quantization scale (arbitrary but fixed).
const LOGIT_SCALE: f32 = 1.0 / 64.0;

/// Dense signed-INT8 MVM: `x [b, l]` × `w [l, n]` → `[b, n]`, wrapping
/// int32 accumulation (bit-exact vs the jax int32 oracle).
///
/// Register-blocked 4-column kernel: each output chunk keeps its four
/// accumulators live across the whole `l` reduction (one store per
/// output instead of one read-modify-write per `(l, n)` step), with
/// zero activations skipped — the dense analogue of the fabric's
/// zero-bit-plane skip.  Wrapping i32 adds commute, so the result is
/// bit-identical to the naive loop for every input.  Used by both the
/// dense (`pim_mac`) and FCC (`fcc_mvm_i32`) backend paths.
pub fn mvm_i32(x: &[i32], w: &[i32], b: usize, l: usize, n: usize) -> Vec<i32> {
    assert_eq!(x.len(), b * l, "x shape mismatch");
    assert_eq!(w.len(), l * n, "w shape mismatch");
    let mut out = vec![0i32; b * n];
    for bi in 0..b {
        let xrow = &x[bi * l..(bi + 1) * l];
        let orow = &mut out[bi * n..(bi + 1) * n];
        let mut chunks = orow.chunks_exact_mut(4);
        let mut j = 0;
        for chunk in &mut chunks {
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for (li, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let wq = &w[li * n + j..li * n + j + 4];
                a0 = a0.wrapping_add(xv.wrapping_mul(wq[0]));
                a1 = a1.wrapping_add(xv.wrapping_mul(wq[1]));
                a2 = a2.wrapping_add(xv.wrapping_mul(wq[2]));
                a3 = a3.wrapping_add(xv.wrapping_mul(wq[3]));
            }
            chunk[0] = a0;
            chunk[1] = a1;
            chunk[2] = a2;
            chunk[3] = a3;
            j += 4;
        }
        for (t, o) in chunks.into_remainder().iter_mut().enumerate() {
            let col = j + t;
            let mut acc = 0i32;
            for (li, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                acc = acc.wrapping_add(xv.wrapping_mul(w[li * n + col]));
            }
            *o = acc;
        }
    }
    out
}

/// FCC MVM with ARU recovery (paper Eq. 7 / `fcc_mvm_ref`):
/// `x [b, l]` × `w_even [l, half]` with means `m [half]` →
/// `[b, 2*half]`, channels interleaved `(even, odd, ...)`.
pub fn fcc_mvm_i32(
    x: &[i32],
    w_even: &[i32],
    m: &[i32],
    b: usize,
    l: usize,
    half: usize,
) -> Vec<i32> {
    assert_eq!(m.len(), half, "m shape mismatch");
    let psum = mvm_i32(x, w_even, b, l, half);
    let mut out = vec![0i32; b * 2 * half];
    for bi in 0..b {
        let si: i32 = x[bi * l..(bi + 1) * l]
            .iter()
            .fold(0i32, |acc, &v| acc.wrapping_add(v));
        for p in 0..half {
            let ps = psum[bi * half + p];
            let even = ps.wrapping_add(si.wrapping_mul(m[p]));
            let odd = si.wrapping_mul(m[p].wrapping_sub(1)).wrapping_sub(ps);
            out[bi * 2 * half + 2 * p] = even;
            out[bi * 2 * half + 2 * p + 1] = odd;
        }
    }
    out
}

/// One layer of the reference network.
enum RefLayer {
    /// FCC conv: only the even comp filters are stored (column-major
    /// `[L, cout/2]`); the forward pass runs [`fcc_mvm_i32`] per pixel
    /// window, so the model path executes the *same* Eq. 7 kernel the
    /// goldens pin down.  ReLU after requantization.
    ConvFcc {
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        w_even_cols: Vec<i32>,
        means: Vec<i32>,
        /// Requantization right-shift back to the INT8 activation grid.
        shift: u32,
    },
    /// 2x2/2 average pooling (post-process unit).
    Pool2,
    /// Global average pooling.
    Gap,
    /// Fully connected head (regular mode, no FCC — paper §III-B).
    Fc { cin: usize, cout: usize, w: Vec<i32> },
}

/// Pure-Rust backend executing the seeded quantized network.
pub struct ReferenceBackend {
    layers: Vec<RefLayer>,
    seed: u64,
}

impl ReferenceBackend {
    /// Build the default CIFAR-tiny network from a weight seed:
    /// conv3x3(3→16, FCC) → pool → conv3x3(16→32, FCC) → pool → gap →
    /// fc(32→10).  Both conv layers have an even filter count, so the
    /// whole conv stack runs in double-computing mode.
    pub fn seeded(seed: u64) -> ReferenceBackend {
        let mut rng = Rng::new(seed);
        let conv = |rng: &mut Rng, k: usize, cin: usize, cout: usize, shift: u32| {
            let l = k * k * cin;
            let bank = FilterBank::new(
                (0..cout * l).map(|_| rng.int8() as i32).collect(),
                cout,
                l,
            );
            let fcc = fcc_transform(&bank);
            RefLayer::ConvFcc {
                k,
                cin,
                cout,
                stride: 1,
                w_even_cols: fcc.stored_even_cols(),
                means: fcc.means,
                shift,
            }
        };
        let c1 = conv(&mut rng, 3, 3, 16, 9);
        let c2 = conv(&mut rng, 3, 16, 32, 10);
        let fc = RefLayer::Fc {
            cin: 32,
            cout: NUM_CLASSES,
            w: (0..NUM_CLASSES * 32).map(|_| rng.int8() as i32).collect(),
        };
        ReferenceBackend {
            layers: vec![c1, RefLayer::Pool2, c2, RefLayer::Pool2, RefLayer::Gap, fc],
            seed,
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Forward one quantized image (`[32, 32, 3]` HWC INT8 codes) to
    /// integer logit accumulators.
    fn forward_image(&self, img: &[i32]) -> Vec<i64> {
        let (mut data, mut h, mut w, mut c) = (img.to_vec(), 32usize, 32usize, 3usize);
        let mut logits = Vec::new();
        for layer in &self.layers {
            match layer {
                RefLayer::ConvFcc {
                    k,
                    cin,
                    cout,
                    stride,
                    w_even_cols,
                    means,
                    shift,
                } => {
                    debug_assert_eq!(c, *cin);
                    let l = k * k * cin;
                    let (cols, oh, ow) = im2col(&data, h, w, c, *k, *stride);
                    // every pixel window is one row of the FCC MVM
                    // kernel — the exact oracle the goldens replay
                    // (interleaved even/odd channel order)
                    let raw = fcc_mvm_i32(&cols, w_even_cols, means, oh * ow, l, cout / 2);
                    data = raw
                        .iter()
                        .map(|&v| requant_relu(v as i64, *shift))
                        .collect();
                    h = oh;
                    w = ow;
                    c = *cout;
                }
                RefLayer::Pool2 => {
                    let (oh, ow) = (h / 2, w / 2);
                    let mut out = vec![0i32; oh * ow * c];
                    for oy in 0..oh {
                        for ox in 0..ow {
                            for ch in 0..c {
                                let mut s = 0i32;
                                for dy in 0..2 {
                                    for dx in 0..2 {
                                        s += data[((2 * oy + dy) * w + 2 * ox + dx) * c + ch];
                                    }
                                }
                                out[(oy * ow + ox) * c + ch] = s.div_euclid(4);
                            }
                        }
                    }
                    data = out;
                    h = oh;
                    w = ow;
                }
                RefLayer::Gap => {
                    let px = (h * w) as i64;
                    let mut out = vec![0i32; c];
                    for ch in 0..c {
                        let mut s = 0i64;
                        for p in 0..h * w {
                            s += data[p * c + ch] as i64;
                        }
                        out[ch] = (s / px) as i32;
                    }
                    data = out;
                    h = 1;
                    w = 1;
                }
                RefLayer::Fc { cin, cout, w: fw } => {
                    debug_assert_eq!(data.len(), *cin);
                    logits = (0..*cout)
                        .map(|o| {
                            (0..*cin)
                                .map(|i| data[i] as i64 * fw[o * cin + i] as i64)
                                .sum()
                        })
                        .collect();
                }
            }
        }
        logits
    }
}

/// Requantize an accumulator back to the INT8 activation grid and ReLU.
fn requant_relu(v: i64, shift: u32) -> i32 {
    ((v >> shift).clamp(-128, 127) as i32).max(0)
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn supports_arbitrary_kernel_shapes(&self) -> bool {
        true
    }

    fn infer_batch(&mut self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        ensure!(
            x.len() == batch * IMG_ELEMS,
            "bad input length {} (want {} = {batch} x {IMG_ELEMS})",
            x.len(),
            batch * IMG_ELEMS
        );
        let mut out = Vec::with_capacity(batch * NUM_CLASSES);
        for bi in 0..batch {
            let img: Vec<i32> = x[bi * IMG_ELEMS..(bi + 1) * IMG_ELEMS]
                .iter()
                .map(|&v| ((v * INPUT_SCALE).round() as i32).clamp(-128, 127))
                .collect();
            let logits = self.forward_image(&img);
            ensure!(logits.len() == NUM_CLASSES, "classifier head missing");
            out.extend(logits.iter().map(|&a| a as f32 * LOGIT_SCALE));
        }
        Ok(out)
    }

    fn fcc_mvm(
        &mut self,
        x: &[i32],
        w_even: &[i32],
        m: &[i32],
        b: usize,
        l: usize,
        half: usize,
    ) -> Result<Vec<i32>> {
        ensure!(x.len() == b * l, "x shape mismatch");
        ensure!(w_even.len() == l * half, "w_even shape mismatch");
        ensure!(m.len() == half, "m shape mismatch");
        Ok(fcc_mvm_i32(x, w_even, m, b, l, half))
    }

    fn pim_mac(
        &mut self,
        x: &[i32],
        w: &[i32],
        b: usize,
        l: usize,
        n: usize,
    ) -> Result<Vec<i32>> {
        ensure!(x.len() == b * l, "x shape mismatch");
        ensure!(w.len() == l * n, "w shape mismatch");
        Ok(mvm_i32(x, w, b, l, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mvm_matches_dense_oracle() {
        let mut rng = Rng::new(7);
        let (b, l, n) = (3, 12, 5);
        let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
        let w: Vec<i32> = (0..l * n).map(|_| rng.int8() as i32).collect();
        let got = mvm_i32(&x, &w, b, l, n);
        for bi in 0..b {
            for j in 0..n {
                let want: i64 = (0..l)
                    .map(|li| x[bi * l + li] as i64 * w[li * n + j] as i64)
                    .sum();
                assert_eq!(got[bi * n + j] as i64, want);
            }
        }
    }

    #[test]
    fn register_blocked_mvm_matches_naive_wrapping_loop() {
        // the 4-column unroll (incl. the <4 remainder columns) must be
        // bit-identical to the straightforward wrapping triple loop for
        // random shapes — including n < 4 and values that overflow i32
        use crate::util::prop::forall_explain;
        forall_explain(
            23,
            100,
            |r| {
                let b = 1 + r.below(4) as usize;
                let l = 1 + r.below(24) as usize;
                let n = 1 + r.below(11) as usize;
                let x: Vec<i32> = (0..b * l)
                    .map(|_| if r.below(4) == 0 { 0 } else { r.int8() as i32 })
                    .collect();
                let w: Vec<i32> = (0..l * n).map(|_| r.int8() as i32).collect();
                (b, l, n, x, w)
            },
            |(b, l, n, x, w)| {
                let got = mvm_i32(x, w, *b, *l, *n);
                let mut want = vec![0i32; b * n];
                for bi in 0..*b {
                    for j in 0..*n {
                        for li in 0..*l {
                            want[bi * n + j] = want[bi * n + j]
                                .wrapping_add(x[bi * l + li].wrapping_mul(w[li * n + j]));
                        }
                    }
                }
                if got == want {
                    Ok(())
                } else {
                    Err(format!("blocked kernel drifted for b={b} l={l} n={n}"))
                }
            },
        );
    }

    #[test]
    fn fcc_mvm_matches_biased_comp_dense() {
        // the Eq. 7 recovery must equal a dense MVM with the recomposed
        // biased-comp bank — the same identity the hardware ARU implements
        let mut rng = Rng::new(11);
        let (b, l, n) = (4, 9, 6);
        let half = n / 2;
        let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
        let bank = FilterBank::new((0..n * l).map(|_| rng.int8() as i32).collect(), n, l);
        let fcc = fcc_transform(&bank);
        // w_even in [l, half] layout (column-major filters, python side)
        let got = fcc_mvm_i32(&x, &fcc.stored_even_cols(), &fcc.means, b, l, half);
        // dense oracle with the full recomposed biased-comp bank
        let want = mvm_i32(&x, &fcc.biased_comp_cols(), b, l, n);
        assert_eq!(got, want, "Eq. 7 recovery drifted from dense conv");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut b = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(3);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let la = a.infer_batch(&img, 1).unwrap();
        let lb = b.infer_batch(&img, 1).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = ReferenceBackend::seeded(1);
        let mut b = ReferenceBackend::seeded(2);
        let img = vec![0.5f32; IMG_ELEMS];
        assert_ne!(a.infer_batch(&img, 1).unwrap(), b.infer_batch(&img, 1).unwrap());
    }

    #[test]
    fn batch_rows_independent() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(9);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let mut two = img.clone();
        two.extend_from_slice(&img);
        let batched = be.infer_batch(&two, 2).unwrap();
        let single = be.infer_batch(&img, 1).unwrap();
        assert_eq!(&batched[..NUM_CLASSES], single.as_slice());
        assert_eq!(&batched[NUM_CLASSES..], single.as_slice());
    }

    #[test]
    fn rejects_bad_batch_length() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        assert!(be.infer_batch(&[0.0; 7], 1).is_err());
    }

    #[test]
    fn logits_depend_on_input() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(13);
        let a: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        assert_ne!(be.infer_batch(&a, 1).unwrap(), be.infer_batch(&b, 1).unwrap());
    }
}
