//! The pure-Rust reference backend.
//!
//! Executes a deterministic, FCC-quantized CIFAR classifier with the
//! exact integer semantics of the python oracles in
//! `python/compile/kernels/ref.py`:
//!
//! * [`mvm_i32`] is `mvm_int8_ref` — dense signed-INT8 matrix-vector
//!   multiply in wrapping int32 (what the bit-serial PIM array reduces
//!   to);
//! * [`fcc_mvm_i32`] is `fcc_mvm_ref` — only the even comp filters are
//!   stored, the odd twins are recovered through the Eq. 7 ARU identity
//!   (`out_even = psum + ΣI·M`, `out_odd = ΣI·(M-1) - psum`), outputs
//!   interleaved.
//!
//! The network itself is seeded: every weight comes from the
//! deterministic xorshift [`Rng`], and every FCC conv layer stores only
//! half its filters (the [`fcc_transform`] deployment pipeline), so a
//! forward pass exercises symmetrize → complementize → decompose →
//! Eq. 7 recovery end to end — hermetically, on any host.  This is the
//! backend CI runs; PJRT is the opt-in artifact path.
//!
//! # Sessions
//!
//! [`ReferenceBackend::prepare`] plans the layer stack once into a
//! [`ReferenceSession`]: per-layer execution forms are chosen up front
//! ([`FabricChoice::DenseReference`] keeps the `fcc_mvm` kernel;
//! [`FabricChoice::BitSliced`] plans each conv onto the functional PIM
//! fabric via [`PlannedConv`], writing SRAM weights exactly once), and
//! every buffer the forward pass touches is owned by the session.
//! [`Session::infer_batch_into`] then executes whole batches with the
//! batch folded into the MVM row dimension — on the bit-sliced fabric
//! too, where `PlannedConv::execute_batch_par` streams all images of
//! the batch through one resident weight pass and shards the
//! batch×pixel blocks across the session's [`ExecPool`] (width from
//! `BackendSpec::threads` / `DDC_THREADS`; 1 = the serial path, and
//! every width is byte-identical) — and, after the first call at a
//! given batch size, zero heap allocation.
//!
//! # Weight streaming
//!
//! With [`ReferenceBackend::with_streaming`] the session additionally
//! models a finite weight memory ([`StreamConfig::capacity_bytes`]):
//! the conv stack is split into weight-reload passes by
//! [`plan_reload_passes`] over the FCC stored footprints
//! ([`stored_weight_bytes`]), a pass's execution forms are (re)built
//! whenever it is acquired, and — with [`StreamConfig::prefetch`] on —
//! a background stager thread builds pass N+1 while pass N computes on
//! the [`ExecPool`]: the double-buffered analogue of the
//! architecture's ping-pong weight DFFs.  Streamed logits are
//! byte-identical to the resident path at every budget because both
//! route through the same per-layer execution helpers
//! (`run_dense_conv` / `run_fabric_conv`).  Residency is
//! book-kept on a [`StagedBuffer`], and the pressure counters
//! (reloads, evictions, overflow, occupancy, prefetch overlap) surface
//! through [`Session::capacity_pressure`].

use std::ops::Range;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::arch::fault::{FaultConfig, FaultTally, UpsetConfig};
use crate::arch::grid::{GridShape, MacroGrid};
use crate::arch::mem::StagedBuffer;
use crate::arch::pim_core::MacroGeometry;
use crate::fcc::{fcc_transform, FccWeights, FilterBank};
use crate::mapping::exec::{plan_reload_passes, stored_weight_bytes, ExecPool, PlannedConv};
use crate::mapping::shard::ShardedConv;
use crate::mapping::im2col::{im2col_into, out_dims};
use crate::metrics::{CapacityPressure, ReliabilityStats};
use crate::util::pool::{resolve_threads, SharedMut};
use crate::util::rng::Rng;

use super::backend::{Backend, FabricChoice, Session, IMG_ELEMS, NUM_CLASSES};

/// Default weight seed (recorded so runs are replayable).
pub const DEFAULT_SEED: u64 = 0xDDC0;

/// Input quantization scale: f32 activations → INT8 codes.
const INPUT_SCALE: f32 = 32.0;

/// Logit de-quantization scale (arbitrary but fixed).
const LOGIT_SCALE: f32 = 1.0 / 64.0;

/// Weight-streaming configuration for a planned session: the capacity
/// budget the conv stack must fit inside (per reload pass), and whether
/// the next pass is prefetched on a background stager thread while the
/// current one computes.
///
/// `prefetch: false` stages every pass synchronously on the execute
/// path (every staging cycle is an exposed stall) — useful for
/// deterministic allocation accounting; logits are identical either
/// way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamConfig {
    /// Weight-memory budget in bytes a single reload pass must fit
    /// (a lone over-budget layer still gets a pass; it is counted as an
    /// overflow, not split).
    pub capacity_bytes: usize,
    /// Overlap the staging of pass N+1 with the compute of pass N.
    pub prefetch: bool,
}

impl StreamConfig {
    /// Budgeted streaming with prefetch on (the production shape).
    pub fn budget(capacity_bytes: usize) -> StreamConfig {
        StreamConfig {
            capacity_bytes,
            prefetch: true,
        }
    }

    /// Budgeted streaming with prefetch off: all staging is exposed.
    pub fn synchronous(capacity_bytes: usize) -> StreamConfig {
        StreamConfig {
            capacity_bytes,
            prefetch: false,
        }
    }
}

/// Dense signed-INT8 MVM into a caller-owned `[b, n]` buffer: the
/// zero-allocation twin of [`mvm_i32`], wrapping int32 accumulation
/// (bit-exact vs the jax int32 oracle).
///
/// Register-blocked 4-column kernel: each output chunk keeps its four
/// accumulators live across the whole `l` reduction (one store per
/// output instead of one read-modify-write per `(l, n)` step), with
/// zero activations skipped — the dense analogue of the fabric's
/// zero-bit-plane skip.  Wrapping i32 adds commute, so the result is
/// bit-identical to the naive loop for every input.
pub fn mvm_i32_into(out: &mut [i32], x: &[i32], w: &[i32], b: usize, l: usize, n: usize) {
    assert_eq!(x.len(), b * l, "x shape mismatch");
    assert_eq!(w.len(), l * n, "w shape mismatch");
    assert_eq!(out.len(), b * n, "out shape mismatch");
    for bi in 0..b {
        let xrow = &x[bi * l..(bi + 1) * l];
        let orow = &mut out[bi * n..(bi + 1) * n];
        let mut chunks = orow.chunks_exact_mut(4);
        let mut j = 0;
        for chunk in &mut chunks {
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for (li, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let wq = &w[li * n + j..li * n + j + 4];
                a0 = a0.wrapping_add(xv.wrapping_mul(wq[0]));
                a1 = a1.wrapping_add(xv.wrapping_mul(wq[1]));
                a2 = a2.wrapping_add(xv.wrapping_mul(wq[2]));
                a3 = a3.wrapping_add(xv.wrapping_mul(wq[3]));
            }
            chunk[0] = a0;
            chunk[1] = a1;
            chunk[2] = a2;
            chunk[3] = a3;
            j += 4;
        }
        for (t, o) in chunks.into_remainder().iter_mut().enumerate() {
            let col = j + t;
            let mut acc = 0i32;
            for (li, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                acc = acc.wrapping_add(xv.wrapping_mul(w[li * n + col]));
            }
            *o = acc;
        }
    }
}

/// Dense signed-INT8 MVM: `x [b, l]` × `w [l, n]` → `[b, n]`.
/// Allocating wrapper over [`mvm_i32_into`].
pub fn mvm_i32(x: &[i32], w: &[i32], b: usize, l: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; b * n];
    mvm_i32_into(&mut out, x, w, b, l, n);
    out
}

/// Rows of a `[b, n]` output sharded per parallel work unit: coarse
/// enough to amortize dispatch over thousands of MACs, fine enough
/// that typical `batch * pixels` row counts split across every lane.
pub const MVM_ROW_BLOCK: usize = 32;

/// Parallel twin of [`mvm_i32_into`]: shards the `b` row dimension
/// across the pool's lanes in [`MVM_ROW_BLOCK`] runs.  Byte-identical
/// to the serial kernel at every pool width — all arithmetic for an
/// output row happens inside exactly one unit (wrapping adds in
/// row-private register accumulators) and units write disjoint row
/// ranges, so scheduling order cannot change any byte.
pub fn mvm_i32_into_par(
    out: &mut [i32],
    x: &[i32],
    w: &[i32],
    b: usize,
    l: usize,
    n: usize,
    pool: &mut ExecPool,
) {
    let nblocks = b.div_ceil(MVM_ROW_BLOCK);
    if nblocks <= 1 || pool.width() == 1 {
        return mvm_i32_into(out, x, w, b, l, n);
    }
    assert_eq!(x.len(), b * l, "x shape mismatch");
    assert_eq!(w.len(), l * n, "w shape mismatch");
    assert_eq!(out.len(), b * n, "out shape mismatch");
    let out_ptr = SharedMut(out.as_mut_ptr());
    pool.run(nblocks, &|_lane, unit| {
        let r0 = unit * MVM_ROW_BLOCK;
        let r1 = (r0 + MVM_ROW_BLOCK).min(b);
        // SAFETY: units own disjoint row ranges of `out`
        let rows =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(r0 * n), (r1 - r0) * n) };
        mvm_i32_into(rows, &x[r0 * l..r1 * l], w, r1 - r0, l, n);
    });
}

/// FCC MVM with ARU recovery into caller-owned buffers: `out` is the
/// `[b, 2*half]` interleaved result, `psum` the `[b, half]` stored-path
/// partial sums (scratch the caller keeps to avoid allocation).
pub fn fcc_mvm_into(
    out: &mut [i32],
    psum: &mut [i32],
    x: &[i32],
    w_even: &[i32],
    m: &[i32],
    b: usize,
    l: usize,
    half: usize,
) {
    assert_eq!(m.len(), half, "m shape mismatch");
    assert_eq!(out.len(), b * 2 * half, "out shape mismatch");
    mvm_i32_into(psum, x, w_even, b, l, half);
    for bi in 0..b {
        let si: i32 = x[bi * l..(bi + 1) * l]
            .iter()
            .fold(0i32, |acc, &v| acc.wrapping_add(v));
        for p in 0..half {
            let ps = psum[bi * half + p];
            let even = ps.wrapping_add(si.wrapping_mul(m[p]));
            let odd = si.wrapping_mul(m[p].wrapping_sub(1)).wrapping_sub(ps);
            out[bi * 2 * half + 2 * p] = even;
            out[bi * 2 * half + 2 * p + 1] = odd;
        }
    }
}

/// Parallel twin of [`fcc_mvm_into`]: each [`MVM_ROW_BLOCK`] row run
/// performs its own stored-path MVM *and* Eq. 7 recovery, so the whole
/// FCC path of a row stays inside one unit.  Byte-identical to the
/// serial kernel at every pool width (disjoint `out`/`psum` row
/// ranges; see [`mvm_i32_into_par`]).
#[allow(clippy::too_many_arguments)]
pub fn fcc_mvm_into_par(
    out: &mut [i32],
    psum: &mut [i32],
    x: &[i32],
    w_even: &[i32],
    m: &[i32],
    b: usize,
    l: usize,
    half: usize,
    pool: &mut ExecPool,
) {
    let nblocks = b.div_ceil(MVM_ROW_BLOCK);
    if nblocks <= 1 || pool.width() == 1 {
        return fcc_mvm_into(out, psum, x, w_even, m, b, l, half);
    }
    assert_eq!(x.len(), b * l, "x shape mismatch");
    assert_eq!(m.len(), half, "m shape mismatch");
    assert_eq!(out.len(), b * 2 * half, "out shape mismatch");
    assert_eq!(psum.len(), b * half, "psum shape mismatch");
    let out_ptr = SharedMut(out.as_mut_ptr());
    let psum_ptr = SharedMut(psum.as_mut_ptr());
    pool.run(nblocks, &|_lane, unit| {
        let r0 = unit * MVM_ROW_BLOCK;
        let r1 = (r0 + MVM_ROW_BLOCK).min(b);
        let rows = r1 - r0;
        // SAFETY: units own disjoint row ranges of both buffers
        let (o, p) = unsafe {
            (
                std::slice::from_raw_parts_mut(out_ptr.0.add(r0 * 2 * half), rows * 2 * half),
                std::slice::from_raw_parts_mut(psum_ptr.0.add(r0 * half), rows * half),
            )
        };
        fcc_mvm_into(o, p, &x[r0 * l..r1 * l], w_even, m, rows, l, half);
    });
}

/// FCC MVM with ARU recovery (paper Eq. 7 / `fcc_mvm_ref`):
/// `x [b, l]` × `w_even [l, half]` with means `m [half]` →
/// `[b, 2*half]`, channels interleaved `(even, odd, ...)`.  Allocating
/// wrapper over [`fcc_mvm_into`].
pub fn fcc_mvm_i32(
    x: &[i32],
    w_even: &[i32],
    m: &[i32],
    b: usize,
    l: usize,
    half: usize,
) -> Vec<i32> {
    let mut out = vec![0i32; b * 2 * half];
    let mut psum = vec![0i32; b * half];
    fcc_mvm_into(&mut out, &mut psum, x, w_even, m, b, l, half);
    out
}

/// One layer of the reference network (model definition — execution
/// forms are planned per session).
enum RefLayer {
    /// FCC conv: deployable [`FccWeights`] (only the even comp filters
    /// are ever resident at execution time).  ReLU after
    /// requantization.
    ConvFcc {
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        fcc: FccWeights,
        /// Requantization right-shift back to the INT8 activation grid.
        shift: u32,
    },
    /// 2x2/2 average pooling (post-process unit).
    Pool2,
    /// Global average pooling.
    Gap,
    /// Fully connected head (regular mode, no FCC — paper §III-B).
    Fc { cin: usize, cout: usize, w: Vec<i32> },
}

/// Pure-Rust backend holding the seeded quantized network definition.
pub struct ReferenceBackend {
    layers: Vec<RefLayer>,
    seed: u64,
    fabric: FabricChoice,
    /// Requested pool width for planned sessions (0 = `DDC_THREADS`
    /// env, then 1 — see [`resolve_threads`]).  Both fabrics use the
    /// pool: bit-sliced convs shard pixel blocks, dense convs shard
    /// MVM row blocks.
    threads: usize,
    /// Macro geometry bit-sliced sessions plan onto (default: paper).
    geometry: MacroGeometry,
    /// Macro-grid shape bit-sliced sessions shard conv layers across
    /// ([`GridShape::AUTO`] = resolve from `DDC_GRID`, then `1x1`).
    grid: GridShape,
    /// Weight-streaming config for planned sessions (`None` = every
    /// conv layer stays resident for the session's lifetime).
    streaming: Option<StreamConfig>,
    /// Bit-cell fault injection for planned bit-sliced sessions
    /// (`None` = the untouched zero-fault fabric, byte for byte).
    fault: Option<FaultConfig>,
    /// Retention-upset process for planned bit-sliced sessions: seeded
    /// bit flips land on *resident* weights between batches, against a
    /// virtual batch clock (`None` = no runtime upsets).
    upsets: Option<UpsetConfig>,
    /// Incremental serving-time scrub budget: checksum stripes verified
    /// per batch boundary (0 = scrub only at prepare/rebuild time).
    scrub_stripes: usize,
}

impl ReferenceBackend {
    /// Build the default CIFAR-tiny network from a weight seed:
    /// conv3x3(3→16, FCC) → pool → conv3x3(16→32, FCC) → pool → gap →
    /// fc(32→10).  Both conv layers have an even filter count, so the
    /// whole conv stack runs in double-computing mode.
    pub fn seeded(seed: u64) -> ReferenceBackend {
        Self::seeded_with(seed, FabricChoice::default())
    }

    /// Like [`ReferenceBackend::seeded`], with an explicit conv fabric.
    pub fn seeded_with(seed: u64, fabric: FabricChoice) -> ReferenceBackend {
        let mut rng = Rng::new(seed);
        let conv = |rng: &mut Rng, k: usize, cin: usize, cout: usize, shift: u32| {
            let l = k * k * cin;
            let bank = FilterBank::new(
                (0..cout * l).map(|_| rng.int8() as i32).collect(),
                cout,
                l,
            );
            RefLayer::ConvFcc {
                k,
                cin,
                cout,
                stride: 1,
                fcc: fcc_transform(&bank),
                shift,
            }
        };
        let c1 = conv(&mut rng, 3, 3, 16, 9);
        let c2 = conv(&mut rng, 3, 16, 32, 10);
        let fc = RefLayer::Fc {
            cin: 32,
            cout: NUM_CLASSES,
            w: (0..NUM_CLASSES * 32).map(|_| rng.int8() as i32).collect(),
        };
        ReferenceBackend {
            layers: vec![c1, RefLayer::Pool2, c2, RefLayer::Pool2, RefLayer::Gap, fc],
            seed,
            fabric,
            threads: 0,
            geometry: MacroGeometry::paper(),
            grid: GridShape::AUTO,
            streaming: None,
            fault: None,
            upsets: None,
            scrub_stripes: 0,
        }
    }

    /// Like [`ReferenceBackend::seeded_with`], with `extra_convs`
    /// additional seeded conv3x3(32→32, FCC) layers inserted before the
    /// global pool.  SAME-padded, so any depth is valid; each extra
    /// layer adds a 4608 B stored-weight footprint — the knob the
    /// streaming tests use to build stacks that exceed a capacity
    /// budget.
    pub fn seeded_deep(seed: u64, fabric: FabricChoice, extra_convs: usize) -> ReferenceBackend {
        let mut be = Self::seeded_with(seed, fabric);
        let mut rng = Rng::new(seed ^ 0x5EED_DEE9);
        let gap_at = be.layers.len() - 2; // insert before Gap → Fc
        for i in 0..extra_convs {
            let l = 3 * 3 * 32;
            let bank = FilterBank::new((0..32 * l).map(|_| rng.int8() as i32).collect(), 32, l);
            be.layers.insert(
                gap_at + i,
                RefLayer::ConvFcc {
                    k: 3,
                    cin: 32,
                    cout: 32,
                    stride: 1,
                    fcc: fcc_transform(&bank),
                    shift: 10,
                },
            );
        }
        be
    }

    /// Set the execution-pool width planned sessions use — on both
    /// fabrics (0 = resolve from `DDC_THREADS`, then 1).
    pub fn with_threads(mut self, threads: usize) -> ReferenceBackend {
        self.threads = threads;
        self
    }

    /// Set the macro geometry bit-sliced sessions plan onto.  Any
    /// compartment count is accepted — >64 lanes pack as multi-word
    /// weight planes — and every geometry produces identical logits
    /// (only the pass schedule changes).
    pub fn with_macro_geometry(mut self, geometry: MacroGeometry) -> ReferenceBackend {
        self.geometry = geometry;
        self
    }

    /// Shard bit-sliced conv layers across a `rows × cols` macro-grid
    /// (the multi-macro scale-out view; see [`crate::arch::grid`]).
    /// Every shape produces byte-identical logits — each tile plans an
    /// independent shard with a provably disjoint output slice — so
    /// this knob changes *where* work runs, never *what* it computes.
    /// [`GridShape::AUTO`] resolves through `DDC_GRID`, then `1x1`.
    /// No-op on the dense fabric; streamed (capacity-budgeted)
    /// sessions keep their layers single-macro — the streaming pass
    /// store is per-macro residency bookkeeping, and mixing the two
    /// axes is future work tracked in the ROADMAP.
    pub fn with_grid(mut self, grid: GridShape) -> ReferenceBackend {
        self.grid = grid;
        self
    }

    /// Stream conv weights through a finite capacity budget instead of
    /// keeping the whole stack resident.  Logits are byte-identical to
    /// the resident path for every budget; only the reload schedule
    /// (and the capacity-pressure counters) change.
    pub fn with_streaming(mut self, cfg: StreamConfig) -> ReferenceBackend {
        self.streaming = Some(cfg);
        self
    }

    /// Inject seeded bit-cell faults into every bit-sliced conv plan
    /// (see [`crate::arch::fault`]): each layer's macros get their own
    /// deterministically derived fault stream, so a streamed pass
    /// rebuild is identically faulted.  The dense reference fabric has
    /// no modeled bit cells, so this is a no-op there.  Detection and
    /// repair run via [`ReferenceSession::scrub_fabric`] (the service
    /// worker scrubs after prepare); counters surface through
    /// [`Session::reliability`].
    pub fn with_faults(mut self, cfg: FaultConfig) -> ReferenceBackend {
        self.fault = Some(cfg);
        self
    }

    /// Arm the deterministic retention-upset process on planned
    /// bit-sliced sessions: seeded `(cmp, row, slot, bit)` flips land on
    /// the *stored* weight planes between batches, scheduled against a
    /// virtual batch clock (replayable; no wall time).  Each conv
    /// layer's macros draw a decorrelated, layer-keyed stream.  The
    /// intent ledger is untouched — it stays the golden reference the
    /// scrub repairs toward.  No-op on the dense fabric.
    pub fn with_upsets(mut self, cfg: UpsetConfig) -> ReferenceBackend {
        self.upsets = Some(cfg);
        self
    }

    /// Budget the incremental serving-time scrub: verify `stripes`
    /// `(row, slot, word)` checksum stripes per batch boundary, walking
    /// the resident stripe space round-robin so every stripe is visited
    /// within `⌈total/stripes⌉` batches.  Streamed sessions scrub the
    /// resident pass only.  0 disables the scheduler (scrub still runs
    /// at prepare/rebuild time).
    pub fn with_scrub_stripes(mut self, stripes: usize) -> ReferenceBackend {
        self.scrub_stripes = stripes;
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn fabric(&self) -> FabricChoice {
        self.fabric
    }

    /// Plan the layer stack into a concrete [`ReferenceSession`]
    /// without boxing (test/bench convenience; [`Backend::prepare`]
    /// wraps this).
    pub fn plan(&self) -> Result<ReferenceSession> {
        ReferenceSession::plan(
            &self.layers,
            self.fabric,
            self.threads,
            self.geometry,
            self.grid,
            self.streaming,
            self.fault,
            self.upsets,
            self.scrub_stripes,
        )
    }
}

/// One planned layer: the execution form chosen at prepare time.
enum SessionLayer {
    /// FCC conv on the dense reference kernel (`fcc_mvm`), batch folded
    /// into the MVM row dimension.
    ConvDense {
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        /// Stored even comp filters, column-major `[L, cout/2]`.
        w_even_cols: Vec<i32>,
        means: Vec<i32>,
        shift: u32,
    },
    /// FCC conv on the bit-sliced functional fabric: weights resident
    /// in the planned macro(s), written once at prepare time.
    ConvFabric { plan: PlannedConv, shift: u32 },
    /// FCC conv sharded across a multi-tile macro-grid: one
    /// independent single-macro plan per tile, each owning a disjoint
    /// output-channel slice (see [`crate::mapping::shard`]).  Chosen
    /// instead of [`SessionLayer::ConvFabric`] when the resolved grid
    /// has more than one tile; byte-identical to it at every shape.
    ConvFabricGrid { plan: ShardedConv, shift: u32 },
    /// FCC conv whose execution form lives in the streaming pass store
    /// (`slot` indexes [`StreamState`]'s spec list); weights are staged
    /// into the capacity budget on demand and may be evicted between
    /// passes.
    ConvStreamed { slot: usize },
    Pool2,
    Gap,
    Fc { cin: usize, cout: usize, w: Vec<i32> },
}

/// Model-level definition of one streamed conv layer: everything needed
/// to (re)build its execution form from DRAM-side weights, on either
/// fabric, deterministically — so a rebuilt pass is bit-identical to
/// the first build.
struct ConvSpec {
    geometry: MacroGeometry,
    h: usize,
    w: usize,
    k: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    fcc: FccWeights,
    shift: u32,
    fabric: FabricChoice,
    /// Per-layer fault stream (already layer-salted), carried so a
    /// streamed rebuild is identically faulted to the first build.
    fault: Option<FaultConfig>,
    /// Per-layer upset stream (already layer-salted), re-armed every
    /// time this layer's pass becomes resident.  A restage resets the
    /// layer's virtual batch clock — upsets only age weights while they
    /// are resident.
    upsets: Option<UpsetConfig>,
}

/// Derive a layer-private fault stream from the session-level config so
/// sibling conv layers (which often share one geometry) fault
/// independently — but deterministically, keyed by layer position.
fn layer_fault(fault: Option<FaultConfig>, layer: usize) -> Option<FaultConfig> {
    fault.map(|cfg| FaultConfig {
        seed: cfg.seed ^ (layer as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407),
        ber: cfg.ber,
    })
}

/// Layer-keyed upset stream derivation (same constant as
/// [`layer_fault`], so sibling conv layers flip independently but
/// deterministically).
fn layer_upsets(upsets: Option<UpsetConfig>, layer: usize) -> Option<UpsetConfig> {
    upsets.map(|cfg| UpsetConfig {
        seed: cfg.seed ^ (layer as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407),
        per_batch_ber: cfg.per_batch_ber,
    })
}

impl ConvSpec {
    /// Stored-weight footprint this layer occupies in the capacity
    /// budget (FCC: only the even comp filters are resident).
    fn footprint_bytes(&self) -> usize {
        stored_weight_bytes(self.cout, self.k * self.k * self.cin, true)
    }

    /// Build the execution form (the DRAM→SRAM staging work).
    fn build(&self) -> BuiltConv {
        match self.fabric {
            FabricChoice::DenseReference => BuiltConv::Dense {
                k: self.k,
                cin: self.cin,
                cout: self.cout,
                stride: self.stride,
                w_even_cols: self.fcc.stored_even_cols(),
                means: self.fcc.means.clone(),
                shift: self.shift,
            },
            FabricChoice::BitSliced => BuiltConv::Fabric {
                plan: PlannedConv::std_fcc_faulted(
                    self.geometry,
                    self.h,
                    self.w,
                    self.cin,
                    &self.fcc,
                    self.k,
                    self.stride,
                    self.fault.as_ref(),
                ),
                shift: self.shift,
            },
        }
    }
}

/// A staged execution form: the same shapes the resident
/// [`SessionLayer`] conv arms hold, built on demand per reload pass.
enum BuiltConv {
    Dense {
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        w_even_cols: Vec<i32>,
        means: Vec<i32>,
        shift: u32,
    },
    Fabric {
        plan: PlannedConv,
        shift: u32,
    },
}

/// A prefetched pass: (pass index, built layers, build wall time).
type StagedPass = (usize, Vec<BuiltConv>, Duration);

/// Background prefetcher: one thread that builds requested passes off
/// the execute path, so the staging of pass N+1 overlaps the compute of
/// pass N (which runs on the session's [`ExecPool`]).  Requests and
/// responses stay in lockstep — at most one pass is in flight.
struct Stager {
    req: Option<mpsc::Sender<usize>>,
    resp: mpsc::Receiver<StagedPass>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Stager {
    /// Spawn the prefetcher.  `None` if the OS refuses the thread — the
    /// session then stages synchronously (fail-soft, not fatal).
    fn spawn(specs: Arc<Vec<ConvSpec>>, passes: Vec<Range<usize>>) -> Option<Stager> {
        let (req_tx, req_rx) = mpsc::channel::<usize>();
        let (resp_tx, resp_rx) = mpsc::channel::<StagedPass>();
        match thread::Builder::new().name("ddc-stager".into()).spawn(move || {
            for pass in req_rx {
                let t0 = Instant::now();
                let built: Vec<BuiltConv> =
                    passes[pass].clone().map(|s| specs[s].build()).collect();
                if resp_tx.send((pass, built, t0.elapsed())).is_err() {
                    break; // session dropped mid-build
                }
            }
        }) {
            Ok(handle) => Some(Stager {
                req: Some(req_tx),
                resp: resp_rx,
                handle: Some(handle),
            }),
            Err(e) => {
                eprintln!(
                    "[ddc-reliability] could not spawn stager thread ({e}); staging synchronously"
                );
                None
            }
        }
    }

    fn request(&self, pass: usize) {
        if let Some(tx) = &self.req {
            let _ = tx.send(pass);
        }
    }

    /// `None` means the stager thread is gone (panicked or killed) —
    /// callers must fall back to synchronous staging.
    fn recv(&self) -> Option<StagedPass> {
        self.resp.recv().ok()
    }

    /// Chaos hook: make this stager behave exactly like a dead thread
    /// (join it, then disconnect the response channel so the next
    /// `recv` reports death).  Test-only, reached via
    /// [`ReferenceSession::debug_kill_stager`].
    fn kill(&mut self) {
        self.req.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let (_dead_tx, dead_rx) = mpsc::channel();
        self.resp = dead_rx; // sender dropped: every recv errors
    }
}

impl Drop for Stager {
    fn drop(&mut self) {
        // closing the request channel lets the thread drain and exit
        self.req.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Streaming pass store: the reload schedule, the currently resident
/// pass, the optional prefetcher, and the [`StagedBuffer`] that
/// book-keeps SRAM residency (evictions, overflow, peak occupancy).
struct StreamState {
    specs: Arc<Vec<ConvSpec>>,
    /// Reload passes as spec-slot ranges (greedy capacity packing).
    passes: Vec<Range<usize>>,
    /// Pass index of each spec slot.
    pass_of: Vec<usize>,
    /// Total stored bytes of each pass.
    pass_bytes: Vec<usize>,
    /// Execution forms of the resident pass (host side of the budget).
    resident: Vec<BuiltConv>,
    resident_pass: Option<usize>,
    /// Passes staged at least once (a re-acquire is a *re*load).
    seen: Vec<bool>,
    /// Pass currently being built by the stager, if any.
    inflight: Option<usize>,
    stager: Option<Stager>,
    sram: StagedBuffer,
    pressure: CapacityPressure,
    /// Times the session completed a pass synchronously because the
    /// stager thread was dead or could not be spawned.
    fallbacks: u64,
    /// Fault totals of evicted pass builds (their macros are dropped on
    /// eviction; the injected/detected history must survive them).
    dropped_tally: FaultTally,
}

impl StreamState {
    fn new(specs: Vec<ConvSpec>, cfg: StreamConfig) -> StreamState {
        let footprints: Vec<usize> = specs.iter().map(|s| s.footprint_bytes()).collect();
        let passes = plan_reload_passes(&footprints, cfg.capacity_bytes);
        let mut pass_of = vec![0usize; specs.len()];
        let mut pass_bytes = vec![0usize; passes.len()];
        for (pi, range) in passes.iter().enumerate() {
            for slot in range.clone() {
                pass_of[slot] = pi;
            }
            pass_bytes[pi] = footprints[range.start..range.end].iter().sum();
        }
        let specs = Arc::new(specs);
        // a single pass never needs prefetch: after the first batch the
        // weights simply stay resident
        let mut fallbacks = 0;
        let stager = if cfg.prefetch && passes.len() > 1 {
            let s = Stager::spawn(specs.clone(), passes.clone());
            if s.is_none() {
                fallbacks += 1; // requested prefetch, running without it
            }
            s
        } else {
            None
        };
        let seen = vec![false; passes.len()];
        StreamState {
            specs,
            passes,
            pass_of,
            pass_bytes,
            resident: Vec::new(),
            resident_pass: None,
            seen,
            inflight: None,
            stager,
            sram: StagedBuffer::new("weight-stream", cfg.capacity_bytes),
            pressure: CapacityPressure {
                capacity_bytes: cfg.capacity_bytes as u64,
                ..Default::default()
            },
            fallbacks,
            dropped_tally: FaultTally::default(),
        }
    }

    /// Make `pass` resident (double-buffer handoff): take the
    /// prefetched build if one is in flight for it (only the wait is an
    /// exposed stall), else build synchronously (fully exposed), then
    /// stage it into the [`StagedBuffer`] — FIFO-evicting the previous
    /// pass, since by the greedy packing rule two consecutive passes
    /// never fit the budget together — and queue the next prefetch.
    fn ensure_resident(&mut self, pass: usize) {
        if self.resident_pass == Some(pass) {
            return;
        }
        // try the prefetcher; a dead stager (panic, kill) is detected
        // here by the disconnected response channel and the session
        // falls back to synchronous staging — degraded, never fatal
        let mut handoff: Option<(Vec<BuiltConv>, Duration, Duration)> = None;
        let mut stager_dead = false;
        if let Some(st) = &self.stager {
            match self.inflight.take() {
                Some(want) if want == pass => {
                    let t0 = Instant::now();
                    match st.recv() {
                        Some((idx, built, busy)) => {
                            debug_assert_eq!(idx, pass);
                            handoff = Some((built, busy, t0.elapsed()));
                        }
                        None => stager_dead = true,
                    }
                }
                Some(_) => {
                    // drain a mismatched prefetch so request/response
                    // stay in lockstep (out-of-order acquire; not the
                    // hot path)
                    if st.recv().is_none() {
                        stager_dead = true;
                    }
                }
                None => {}
            }
        }
        if stager_dead {
            eprintln!(
                "[ddc-reliability] stager thread died; staging pass {pass} synchronously \
                 (prefetch disabled for the rest of this session)"
            );
            self.fallbacks += 1;
            self.stager = None; // Drop joins whatever is left of it
        }
        let (mut built, busy, waited) = match handoff {
            Some(h) => h,
            None => {
                let t0 = Instant::now();
                let built: Vec<BuiltConv> = self.passes[pass]
                    .clone()
                    .map(|s| self.specs[s].build())
                    .collect();
                let busy = t0.elapsed();
                (built, busy, busy)
            }
        };
        self.pressure.stage_busy += busy;
        self.pressure.stall += waited;
        self.pressure.stage_hidden += busy.saturating_sub(waited);
        let outcome = self.sram.stage(pass as u64, self.pass_bytes[pass]);
        self.pressure.evictions += outcome.evicted as u64;
        if outcome.overflowed {
            self.pressure.overflows += 1;
        }
        self.pressure.staged_bytes += self.pass_bytes[pass] as u64;
        self.pressure.peak_resident_bytes = self
            .pressure
            .peak_resident_bytes
            .max(self.sram.peak_used() as u64);
        if self.seen[pass] {
            self.pressure.reloads += 1;
        }
        self.seen[pass] = true;
        // a freshly staged pass starts its upset clock at zero: flips
        // only age weights while they are resident
        for (i, b) in built.iter_mut().enumerate() {
            if let BuiltConv::Fabric { plan, .. } = b {
                if let Some(u) = self.specs[self.passes[pass].start + i].upsets {
                    plan.arm_upsets(u);
                }
            }
        }
        // the evicted pass's macros are dropped with it: preserve their
        // fault history first
        for b in &self.resident {
            if let BuiltConv::Fabric { plan, .. } = b {
                self.dropped_tally.merge(&plan.fault_tally());
            }
        }
        self.resident = built;
        self.resident_pass = Some(pass);
        // queue the successor (wrapping: the last pass prefetches pass
        // 0 for the next batch) so its staging overlaps this compute
        if let Some(st) = &self.stager {
            let next = (pass + 1) % self.passes.len();
            if self.inflight.is_none() && next != pass {
                st.request(next);
                self.inflight = Some(next);
            }
        }
    }

    /// Execution form for `slot`, staging its pass first if needed.
    fn built_for(&mut self, slot: usize) -> &BuiltConv {
        let pass = self.pass_of[slot];
        self.ensure_resident(pass);
        &self.resident[slot - self.passes[pass].start]
    }
}

/// A prepared reference session: planned layer stack + every buffer the
/// forward pass touches.  See the module docs for the allocation
/// contract.
pub struct ReferenceSession {
    layers: Vec<SessionLayer>,
    /// Current activations, `[batch, H, W, C]` flattened.
    act: Vec<i32>,
    /// Next-layer activations (ping-pong partner of `act`).
    act_next: Vec<i32>,
    /// im2col staging, `[batch * P, L]`.
    cols: Vec<i32>,
    /// Dense conv raw accumulators, `[batch * P, cout]`.
    raw: Vec<i32>,
    /// Dense FCC stored-path partial sums, `[batch * P, cout/2]`.
    psum: Vec<i32>,
    /// Fabric conv raw accumulators for the whole batch,
    /// `[batch * P, cout]`.
    out64: Vec<i64>,
    /// Grid-shard staging: one shard's `[batch * P, shard_n]`
    /// accumulators before the scatter into `out64` (grown once; empty
    /// on 1x1 grids and the dense fabric).
    shard64: Vec<i64>,
    /// Execution pool: shared staging + per-lane scratch, kept warm
    /// for the session's lifetime.  Bit-sliced convs shard pixel
    /// blocks across it; dense convs shard MVM row blocks.
    pool: ExecPool,
    /// Streaming pass store (`None` = all conv layers resident).
    stream: Option<StreamState>,
    /// Whether the retention-upset process is armed (ticked once per
    /// batch boundary against the virtual batch clock).
    upsets_armed: bool,
    /// Incremental scrub budget: stripes verified per batch boundary
    /// (0 = no serving-time scrub).
    scrub_budget: usize,
    /// Next stripe in the concatenated resident stripe space.
    scrub_cursor: usize,
    /// Stripes verified by the incremental scheduler since planning.
    scrub_checked: u64,
    /// Size of the stripe space the cursor is walking (refreshed each
    /// boundary; streamed sessions count the resident pass only).
    scrub_total: usize,
    /// Streamed pass the cursor was walking (a pass change restarts
    /// the cursor — the new pass is freshly staged anyway).
    scrub_pass: Option<usize>,
}

impl ReferenceSession {
    #[allow(clippy::too_many_arguments)]
    fn plan(
        layers: &[RefLayer],
        fabric: FabricChoice,
        threads: usize,
        geometry: MacroGeometry,
        grid: GridShape,
        streaming: Option<StreamConfig>,
        fault: Option<FaultConfig>,
        upsets: Option<UpsetConfig>,
        scrub_stripes: usize,
    ) -> Result<ReferenceSession> {
        // resolve AUTO (DDC_GRID env, then 1x1) exactly once so every
        // conv layer plans against the same concrete shape
        let grid = MacroGrid::new(grid, geometry);
        // upsets and the serving-time scrub reconcile against the intent
        // ledger, which only exists with a fault plan installed: an
        // upsets/scrub-only config installs a zero-BER plan (byte
        // identical storage — the empty-plan property the arch tests pin)
        let fault = match fault {
            Some(cfg) => Some(cfg),
            None if upsets.is_some() || scrub_stripes > 0 => {
                Some(FaultConfig::new(upsets.map(|u| u.seed).unwrap_or(0), 0.0))
            }
            None => None,
        };
        let mut planned = Vec::with_capacity(layers.len());
        let mut specs: Vec<ConvSpec> = Vec::new();
        // walk the activation dims so fabric plans know their geometry
        let (mut h, mut w, mut c) = (32usize, 32usize, 3usize);
        let mut head_cout = None;
        let mut conv_idx = 0usize;
        for layer in layers {
            match layer {
                RefLayer::ConvFcc {
                    k,
                    cin,
                    cout,
                    stride,
                    fcc,
                    shift,
                } => {
                    ensure!(c == *cin, "layer stack dim mismatch: {} != {}", c, cin);
                    let lf = layer_fault(fault, conv_idx);
                    let lu = layer_upsets(upsets, conv_idx);
                    conv_idx += 1;
                    if streaming.is_some() {
                        // defer the build: the spec is the DRAM-side
                        // definition, staged per reload pass at execute
                        // time (byte-identical — ConvSpec::build is
                        // exactly the resident construction below)
                        let slot = specs.len();
                        specs.push(ConvSpec {
                            geometry,
                            h,
                            w,
                            k: *k,
                            cin: *cin,
                            cout: *cout,
                            stride: *stride,
                            fcc: fcc.clone(),
                            shift: *shift,
                            fabric,
                            fault: lf,
                            upsets: lu,
                        });
                        planned.push(SessionLayer::ConvStreamed { slot });
                    } else {
                        planned.push(match fabric {
                            FabricChoice::DenseReference => SessionLayer::ConvDense {
                                k: *k,
                                cin: *cin,
                                cout: *cout,
                                stride: *stride,
                                w_even_cols: fcc.stored_even_cols(),
                                means: fcc.means.clone(),
                                shift: *shift,
                            },
                            // a multi-tile grid shards the layer; 1x1
                            // keeps the exact single-macro plan (the
                            // degenerate grid is not a 1-shard wrapper)
                            FabricChoice::BitSliced if grid.tiles() > 1 => {
                                SessionLayer::ConvFabricGrid {
                                    plan: ShardedConv::std_fcc(
                                        &grid,
                                        h,
                                        w,
                                        *cin,
                                        fcc,
                                        *k,
                                        *stride,
                                        lf.as_ref(),
                                    ),
                                    shift: *shift,
                                }
                            }
                            FabricChoice::BitSliced => SessionLayer::ConvFabric {
                                plan: PlannedConv::std_fcc_faulted(
                                    geometry,
                                    h,
                                    w,
                                    *cin,
                                    fcc,
                                    *k,
                                    *stride,
                                    lf.as_ref(),
                                ),
                                shift: *shift,
                            },
                        });
                        if let Some(u) = lu {
                            match planned.last_mut() {
                                Some(SessionLayer::ConvFabric { plan, .. }) => plan.arm_upsets(u),
                                Some(SessionLayer::ConvFabricGrid { plan, .. }) => {
                                    plan.arm_upsets(u)
                                }
                                _ => {}
                            }
                        }
                    }
                    let (oh, ow) = out_dims(h, w, *stride);
                    h = oh;
                    w = ow;
                    c = *cout;
                }
                RefLayer::Pool2 => {
                    planned.push(SessionLayer::Pool2);
                    h /= 2;
                    w /= 2;
                }
                RefLayer::Gap => {
                    planned.push(SessionLayer::Gap);
                    h = 1;
                    w = 1;
                }
                RefLayer::Fc { cin, cout, w: fw } => {
                    ensure!(c == *cin, "fc input dim mismatch: {} != {}", c, cin);
                    ensure!(
                        *cout == NUM_CLASSES,
                        "classifier head must emit {NUM_CLASSES} classes, got {cout}"
                    );
                    head_cout = Some(*cout);
                    planned.push(SessionLayer::Fc {
                        cin: *cin,
                        cout: *cout,
                        w: fw.clone(),
                    });
                }
            }
        }
        ensure!(head_cout.is_some(), "classifier head missing");
        // both fabrics shard through the pool: bit-sliced convs by
        // pixel block, dense convs by MVM row block — one knob, one
        // byte-identical contract at every width
        let width = resolve_threads(threads);
        Ok(ReferenceSession {
            layers: planned,
            act: Vec::new(),
            act_next: Vec::new(),
            cols: Vec::new(),
            raw: Vec::new(),
            psum: Vec::new(),
            out64: Vec::new(),
            shard64: Vec::new(),
            pool: ExecPool::new(width),
            stream: streaming.map(|cfg| StreamState::new(specs, cfg)),
            upsets_armed: upsets.is_some(),
            scrub_budget: scrub_stripes,
            scrub_cursor: 0,
            scrub_checked: 0,
            scrub_total: 0,
            scrub_pass: None,
        })
    }

    /// The execution-pool width this session shards conv work across
    /// (1 = the serial path; every width is byte-identical).
    pub fn pool_width(&self) -> usize {
        self.pool.width()
    }

    /// Sum of SRAM weight writes across all *resident* fabric-planned
    /// layers (0 on the dense path) — constant for the session's
    /// lifetime.  Streamed layers re-write weights every reload pass by
    /// design; their traffic shows up in
    /// [`ReferenceSession::capacity_pressure_stats`] instead.
    pub fn fabric_weight_writes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                SessionLayer::ConvFabric { plan, .. } => plan.weight_writes(),
                SessionLayer::ConvFabricGrid { plan, .. } => plan.weight_writes(),
                _ => 0,
            })
            .sum()
    }

    /// Number of conv layers planned as multi-tile grid shards (0 on
    /// `1x1` grids, the dense fabric, and streamed sessions — those
    /// keep single-macro plans).
    pub fn grid_layers(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l, SessionLayer::ConvFabricGrid { .. }))
            .count()
    }

    /// Total shard count across all grid-planned conv layers.
    pub fn grid_shards(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                SessionLayer::ConvFabricGrid { plan, .. } => plan.shard_count(),
                _ => 0,
            })
            .sum()
    }

    /// Number of weight-reload passes the streaming planner split the
    /// conv stack into (`None` when the session is not streaming; `1`
    /// means everything fit the budget and stays resident after the
    /// first batch).
    pub fn streaming_passes(&self) -> Option<usize> {
        self.stream.as_ref().map(|s| s.passes.len())
    }

    /// Capacity-pressure counters accumulated since the session was
    /// planned (`None` when the session is not streaming).
    pub fn capacity_pressure_stats(&self) -> Option<CapacityPressure> {
        self.stream.as_ref().map(|s| s.pressure)
    }

    /// Merge the fault/scrub history of every fabric plan this session
    /// has ever owned — resident layers, the streamed pass currently in
    /// SRAM, and evicted passes (folded in at eviction) — plus the
    /// stager fallback count, into one [`ReliabilityStats`] block.
    /// All-zero on the dense fabric or with no fault plan installed.
    pub fn reliability_stats(&self) -> ReliabilityStats {
        let mut t = FaultTally::default();
        for l in &self.layers {
            match l {
                SessionLayer::ConvFabric { plan, .. } => t.merge(&plan.fault_tally()),
                SessionLayer::ConvFabricGrid { plan, .. } => t.merge(&plan.fault_tally()),
                _ => {}
            }
        }
        let mut stats = ReliabilityStats::default();
        if let Some(st) = &self.stream {
            t.merge(&st.dropped_tally);
            for b in &st.resident {
                if let BuiltConv::Fabric { plan, .. } = b {
                    t.merge(&plan.fault_tally());
                }
            }
            stats.stager_fallbacks = st.fallbacks;
        }
        stats.faults_injected = t.injected_bits;
        stats.faults_detected = t.detected_words;
        stats.faults_repaired = t.repaired_rows;
        stats.quarantined_rows = t.quarantined_rows;
        stats.zeroed_rows = t.zeroed_rows;
        stats.upset_bits = t.upset_bits;
        stats.corrupt_bits_found = t.corrupt_bits;
        if self.scrub_budget > 0 {
            stats.scrub_stripes_checked = self.scrub_checked;
            stats.scrub_stripe_total = self.scrub_total as u64;
        }
        stats
    }

    /// Run the integrity scrub over every fabric plan currently in SRAM
    /// (resident layers plus the resident streamed pass), repairing
    /// detected corruption onto spare rows — or zeroizing the damaged
    /// column when spares are exhausted — then return the merged
    /// [`ReliabilityStats`].  A clean fabric makes this a no-op.
    pub fn scrub_fabric(&mut self) -> ReliabilityStats {
        for l in &mut self.layers {
            match l {
                SessionLayer::ConvFabric { plan, .. } => {
                    let _ = plan.scrub();
                }
                SessionLayer::ConvFabricGrid { plan, .. } => {
                    let _ = plan.scrub();
                }
                _ => {}
            }
        }
        if let Some(st) = &mut self.stream {
            for b in &mut st.resident {
                if let BuiltConv::Fabric { plan, .. } = b {
                    let _ = plan.scrub();
                }
            }
        }
        self.reliability_stats()
    }

    /// Stripes in the stripe space the incremental scheduler walks:
    /// resident fabric layers, plus the resident streamed pass.
    pub fn scrub_space(&self) -> usize {
        let mut total = 0usize;
        for l in &self.layers {
            match l {
                SessionLayer::ConvFabric { plan, .. } => total += plan.stripe_count(),
                SessionLayer::ConvFabricGrid { plan, .. } => total += plan.stripe_count(),
                _ => {}
            }
        }
        if let Some(st) = &self.stream {
            for b in &st.resident {
                if let BuiltConv::Fabric { plan, .. } = b {
                    total += plan.stripe_count();
                }
            }
        }
        total
    }

    /// Incremental-scrub progress: `(stripes verified since planning,
    /// stripe-space size)`.  `(0, 0)` when the scheduler is off or the
    /// session has not served a batch yet.
    pub fn scrub_progress(&self) -> (u64, usize) {
        (self.scrub_checked, self.scrub_total)
    }

    /// Scrub the window `[start, start+len)` of the concatenated
    /// resident stripe space (layer order, then the resident streamed
    /// pass).  Reports book into each core's lifetime tally, which
    /// [`Self::reliability_stats`] reads back.
    fn scrub_window_resident(&mut self, start: usize, len: usize) {
        let end = start.saturating_add(len);
        let mut base = 0usize;
        for l in &mut self.layers {
            match l {
                SessionLayer::ConvFabric { plan, .. } => {
                    let n = plan.stripe_count();
                    let lo = start.max(base).min(base + n);
                    let hi = end.min(base + n);
                    if hi > lo {
                        let _ = plan.scrub_window(lo - base, hi - lo);
                    }
                    base += n;
                }
                SessionLayer::ConvFabricGrid { plan, .. } => {
                    let n = plan.stripe_count();
                    let lo = start.max(base).min(base + n);
                    let hi = end.min(base + n);
                    if hi > lo {
                        let _ = plan.scrub_window(lo - base, hi - lo);
                    }
                    base += n;
                }
                _ => {}
            }
        }
        if let Some(st) = &mut self.stream {
            for b in &mut st.resident {
                if let BuiltConv::Fabric { plan, .. } = b {
                    let n = plan.stripe_count();
                    let lo = start.max(base).min(base + n);
                    let hi = end.min(base + n);
                    if hi > lo {
                        let _ = plan.scrub_window(lo - base, hi - lo);
                    }
                    base += n;
                }
            }
        }
    }

    /// Batch-boundary maintenance, run before each batch computes:
    /// (1) advance every resident macro's virtual batch clock one tick,
    /// landing this boundary's retention upsets; (2) verify the next
    /// `scrub_budget` checksum stripes round-robin, repairing what they
    /// catch.  Order is tick → scrub → compute, so a full-coverage
    /// budget guarantees no corrupt stored bit survives into the MVMs.
    fn boundary_maintenance(&mut self) {
        if self.upsets_armed {
            for l in &mut self.layers {
                match l {
                    SessionLayer::ConvFabric { plan, .. } => {
                        let _ = plan.tick_upsets();
                    }
                    SessionLayer::ConvFabricGrid { plan, .. } => {
                        let _ = plan.tick_upsets();
                    }
                    _ => {}
                }
            }
            if let Some(st) = &mut self.stream {
                for b in &mut st.resident {
                    if let BuiltConv::Fabric { plan, .. } = b {
                        let _ = plan.tick_upsets();
                    }
                }
            }
        }
        if self.scrub_budget == 0 {
            return;
        }
        // streamed sessions scrub the resident pass only; a pass change
        // restarts the cursor (the incoming pass was freshly staged)
        if let Some(st) = &self.stream {
            if self.scrub_pass != st.resident_pass {
                self.scrub_pass = st.resident_pass;
                self.scrub_cursor = 0;
            }
        }
        let total = self.scrub_space();
        self.scrub_total = total;
        if total == 0 {
            return;
        }
        if self.scrub_cursor >= total {
            self.scrub_cursor = 0;
        }
        // at most one full sweep per boundary; the cursor wraps so
        // every stripe is visited within ⌈total/budget⌉ batches
        let mut remaining = self.scrub_budget.min(total);
        while remaining > 0 {
            let start = self.scrub_cursor;
            let len = remaining.min(total - start);
            self.scrub_window_resident(start, len);
            self.scrub_cursor = (start + len) % total;
            self.scrub_checked += len as u64;
            remaining -= len;
        }
    }

    /// Chaos hook: kill the prefetch stager thread mid-session so tests
    /// can prove the synchronous staging fallback stays byte-identical.
    /// Returns `true` if there was a live stager to kill.
    #[doc(hidden)]
    pub fn debug_kill_stager(&mut self) -> bool {
        match self.stream.as_mut().and_then(|st| st.stager.as_mut()) {
            Some(stager) => {
                stager.kill();
                true
            }
            None => false,
        }
    }
}

/// Requantize an accumulator back to the INT8 activation grid and ReLU.
fn requant_relu(v: i64, shift: u32) -> i32 {
    ((v >> shift).clamp(-128, 127) as i32).max(0)
}

/// Execute one dense-kernel FCC conv over the batch: im2col → parallel
/// `fcc_mvm` → requant/ReLU → activation ping-pong.  The single body
/// both the resident ([`SessionLayer::ConvDense`]) and streamed
/// ([`BuiltConv::Dense`]) paths run, so streamed logits are
/// byte-identical by construction.
#[allow(clippy::too_many_arguments)]
fn run_dense_conv(
    k: usize,
    cin: usize,
    cout: usize,
    stride: usize,
    w_even_cols: &[i32],
    means: &[i32],
    shift: u32,
    batch: usize,
    h: &mut usize,
    w: &mut usize,
    c: &mut usize,
    act: &mut Vec<i32>,
    act_next: &mut Vec<i32>,
    cols: &mut Vec<i32>,
    raw: &mut Vec<i32>,
    psum: &mut Vec<i32>,
    pool: &mut ExecPool,
) {
    debug_assert_eq!(*c, cin);
    let l = k * k * cin;
    let (oh, ow) = out_dims(*h, *w, stride);
    let pixels = oh * ow;
    // every pixel window of every image is one row of the FCC MVM
    // kernel — the exact oracle the goldens replay, with the batch
    // folded into the row dim
    cols.resize(batch * pixels * l, 0);
    let plane = *h * *w * *c;
    for bi in 0..batch {
        im2col_into(
            &mut cols[bi * pixels * l..(bi + 1) * pixels * l],
            &act[bi * plane..(bi + 1) * plane],
            *h,
            *w,
            *c,
            k,
            stride,
        );
    }
    let half = cout / 2;
    let rows = batch * pixels;
    raw.resize(rows * cout, 0);
    psum.resize(rows * half, 0);
    // batch*pixels MVM rows shard across the session pool in row
    // blocks (serial at width 1)
    fcc_mvm_into_par(raw, psum, cols.as_slice(), w_even_cols, means, rows, l, half, pool);
    act_next.resize(rows * cout, 0);
    for (dst, &v) in act_next.iter_mut().zip(raw.iter()) {
        *dst = requant_relu(v as i64, shift);
    }
    std::mem::swap(act, act_next);
    *h = oh;
    *w = ow;
    *c = cout;
}

/// Execute one bit-sliced fabric conv over the batch: one batched pass
/// per resident weight load, sharded across the pool, then
/// requant/ReLU and the activation ping-pong.  Shared by the resident
/// ([`SessionLayer::ConvFabric`]) and streamed ([`BuiltConv::Fabric`])
/// paths.
#[allow(clippy::too_many_arguments)]
fn run_fabric_conv(
    plan: &PlannedConv,
    shift: u32,
    batch: usize,
    h: &mut usize,
    w: &mut usize,
    c: &mut usize,
    act: &mut Vec<i32>,
    act_next: &mut Vec<i32>,
    out64: &mut Vec<i64>,
    pool: &mut ExecPool,
) {
    let (oh, ow) = plan.out_dims();
    let pixels = oh * ow;
    let cout = plan.out_channels();
    act_next.resize(batch * pixels * cout, 0);
    out64.resize(batch * pixels * cout, 0); // execute fills it
    // one batched pass per resident weight load: every image of the
    // batch streams past the weights while they are hot (the
    // ping-pong-buffer analogue), and the batch×pixel blocks shard
    // across the pool
    plan.execute_batch_par(&act[..batch * *h * *w * *c], batch, pool, out64);
    for (dst, &v) in act_next.iter_mut().zip(out64.iter()) {
        *dst = requant_relu(v, shift);
    }
    std::mem::swap(act, act_next);
    *h = oh;
    *w = ow;
    *c = cout;
}

/// Execute one grid-sharded fabric conv over the batch: every tile's
/// shard runs on the shared pool and scatters its disjoint channel
/// slice into `out64` (see [`ShardedConv::execute_batch_par`]), then
/// the same requant/ReLU + ping-pong as [`run_fabric_conv`] — so a
/// grid layer differs from a single-macro layer only in where the raw
/// accumulators come from, never in their values.
#[allow(clippy::too_many_arguments)]
fn run_fabric_conv_grid(
    plan: &ShardedConv,
    shift: u32,
    batch: usize,
    h: &mut usize,
    w: &mut usize,
    c: &mut usize,
    act: &mut Vec<i32>,
    act_next: &mut Vec<i32>,
    out64: &mut Vec<i64>,
    shard64: &mut Vec<i64>,
    pool: &mut ExecPool,
) {
    let (oh, ow) = plan.out_dims();
    let pixels = oh * ow;
    let cout = plan.out_channels();
    act_next.resize(batch * pixels * cout, 0);
    out64.resize(batch * pixels * cout, 0); // every channel is scattered into
    plan.execute_batch_par(&act[..batch * *h * *w * *c], batch, pool, shard64, out64);
    for (dst, &v) in act_next.iter_mut().zip(out64.iter()) {
        *dst = requant_relu(v, shift);
    }
    std::mem::swap(act, act_next);
    *h = oh;
    *w = ow;
    *c = cout;
}

impl Session for ReferenceSession {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn capacity_pressure(&self) -> Option<CapacityPressure> {
        self.capacity_pressure_stats()
    }

    fn reliability(&self) -> Option<ReliabilityStats> {
        Some(self.reliability_stats())
    }

    fn scrub(&mut self) -> Option<ReliabilityStats> {
        Some(self.scrub_fabric())
    }

    fn infer_batch_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        ensure!(
            x.len() == batch * IMG_ELEMS,
            "bad input length {} (want {} = {batch} x {IMG_ELEMS})",
            x.len(),
            batch * IMG_ELEMS
        );
        ensure!(
            out.len() == batch * NUM_CLASSES,
            "bad output length {} (want {} = {batch} x {NUM_CLASSES})",
            out.len(),
            batch * NUM_CLASSES
        );
        if batch == 0 {
            return Ok(());
        }
        // batch-boundary reliability maintenance: land this tick's
        // retention upsets, then verify the budgeted stripe window —
        // before any weight is read, so a full-coverage budget never
        // lets a corrupt bit reach the MVMs
        self.boundary_maintenance();
        // split the borrow so layer refs and buffers coexist
        let Self {
            layers,
            act,
            act_next,
            cols,
            raw,
            psum,
            out64,
            shard64,
            pool,
            stream,
        } = self;
        // quantize the whole batch onto the INT8 activation grid.
        // Throughout this pass, staging buffers are resize()d without
        // clear(): each consumer overwrites every element, so the extra
        // memset a clear+resize pair implies would be pure waste (only
        // buffers that accumulate — none here — need zeroing).
        act.resize(batch * IMG_ELEMS, 0);
        for (dst, &v) in act.iter_mut().zip(x) {
            *dst = ((v * INPUT_SCALE).round() as i32).clamp(-128, 127);
        }
        let (mut h, mut w, mut c) = (32usize, 32usize, 3usize);
        for layer in layers.iter() {
            match layer {
                SessionLayer::ConvDense {
                    k,
                    cin,
                    cout,
                    stride,
                    w_even_cols,
                    means,
                    shift,
                } => run_dense_conv(
                    *k,
                    *cin,
                    *cout,
                    *stride,
                    w_even_cols,
                    means,
                    *shift,
                    batch,
                    &mut h,
                    &mut w,
                    &mut c,
                    act,
                    act_next,
                    cols,
                    raw,
                    psum,
                    pool,
                ),
                SessionLayer::ConvFabric { plan, shift } => run_fabric_conv(
                    plan,
                    *shift,
                    batch,
                    &mut h,
                    &mut w,
                    &mut c,
                    act,
                    act_next,
                    out64,
                    pool,
                ),
                SessionLayer::ConvFabricGrid { plan, shift } => run_fabric_conv_grid(
                    plan,
                    *shift,
                    batch,
                    &mut h,
                    &mut w,
                    &mut c,
                    act,
                    act_next,
                    out64,
                    shard64,
                    pool,
                ),
                SessionLayer::ConvStreamed { slot } => {
                    let Some(st) = stream.as_mut() else {
                        bail!("streamed layer planned without stream state");
                    };
                    // staging the slot's pass may wait on the
                    // prefetcher (the exposed stall the pressure
                    // counters record) or build synchronously
                    match st.built_for(*slot) {
                        BuiltConv::Dense {
                            k,
                            cin,
                            cout,
                            stride,
                            w_even_cols,
                            means,
                            shift,
                        } => run_dense_conv(
                            *k,
                            *cin,
                            *cout,
                            *stride,
                            w_even_cols,
                            means,
                            *shift,
                            batch,
                            &mut h,
                            &mut w,
                            &mut c,
                            act,
                            act_next,
                            cols,
                            raw,
                            psum,
                            pool,
                        ),
                        BuiltConv::Fabric { plan, shift } => run_fabric_conv(
                            plan,
                            *shift,
                            batch,
                            &mut h,
                            &mut w,
                            &mut c,
                            act,
                            act_next,
                            out64,
                            pool,
                        ),
                    }
                }
                SessionLayer::Pool2 => {
                    let (oh, ow) = (h / 2, w / 2);
                    act_next.resize(batch * oh * ow * c, 0);
                    for bi in 0..batch {
                        let src = &act[bi * h * w * c..(bi + 1) * h * w * c];
                        let dst = &mut act_next[bi * oh * ow * c..(bi + 1) * oh * ow * c];
                        for oy in 0..oh {
                            for ox in 0..ow {
                                for ch in 0..c {
                                    let mut s = 0i32;
                                    for dy in 0..2 {
                                        for dx in 0..2 {
                                            s += src[((2 * oy + dy) * w + 2 * ox + dx) * c + ch];
                                        }
                                    }
                                    dst[(oy * ow + ox) * c + ch] = s.div_euclid(4);
                                }
                            }
                        }
                    }
                    std::mem::swap(act, act_next);
                    h = oh;
                    w = ow;
                }
                SessionLayer::Gap => {
                    let px = (h * w) as i64;
                    act_next.resize(batch * c, 0);
                    for bi in 0..batch {
                        let src = &act[bi * h * w * c..(bi + 1) * h * w * c];
                        for ch in 0..c {
                            let mut s = 0i64;
                            for p in 0..h * w {
                                s += src[p * c + ch] as i64;
                            }
                            act_next[bi * c + ch] = (s / px) as i32;
                        }
                    }
                    std::mem::swap(act, act_next);
                    h = 1;
                    w = 1;
                }
                SessionLayer::Fc { cin, cout, w: fw } => {
                    debug_assert_eq!(c, *cin);
                    for bi in 0..batch {
                        let xrow = &act[bi * cin..(bi + 1) * cin];
                        for o in 0..*cout {
                            let logit: i64 = (0..*cin)
                                .map(|i| xrow[i] as i64 * fw[o * cin + i] as i64)
                                .sum();
                            out[bi * NUM_CLASSES + o] = logit as f32 * LOGIT_SCALE;
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn supports_arbitrary_kernel_shapes(&self) -> bool {
        true
    }

    fn prepare(&self) -> Result<Box<dyn Session>> {
        Ok(Box::new(self.plan()?))
    }

    fn fcc_mvm(
        &mut self,
        x: &[i32],
        w_even: &[i32],
        m: &[i32],
        b: usize,
        l: usize,
        half: usize,
    ) -> Result<Vec<i32>> {
        ensure!(x.len() == b * l, "x shape mismatch");
        ensure!(w_even.len() == l * half, "w_even shape mismatch");
        ensure!(m.len() == half, "m shape mismatch");
        Ok(fcc_mvm_i32(x, w_even, m, b, l, half))
    }

    fn pim_mac(
        &mut self,
        x: &[i32],
        w: &[i32],
        b: usize,
        l: usize,
        n: usize,
    ) -> Result<Vec<i32>> {
        ensure!(x.len() == b * l, "x shape mismatch");
        ensure!(w.len() == l * n, "w shape mismatch");
        Ok(mvm_i32(x, w, b, l, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mvm_matches_dense_oracle() {
        let mut rng = Rng::new(7);
        let (b, l, n) = (3, 12, 5);
        let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
        let w: Vec<i32> = (0..l * n).map(|_| rng.int8() as i32).collect();
        let got = mvm_i32(&x, &w, b, l, n);
        for bi in 0..b {
            for j in 0..n {
                let want: i64 = (0..l)
                    .map(|li| x[bi * l + li] as i64 * w[li * n + j] as i64)
                    .sum();
                assert_eq!(got[bi * n + j] as i64, want);
            }
        }
    }

    #[test]
    fn register_blocked_mvm_matches_naive_wrapping_loop() {
        // the 4-column unroll (incl. the <4 remainder columns) must be
        // bit-identical to the straightforward wrapping triple loop for
        // random shapes — including n < 4 and values that overflow i32
        use crate::util::prop::forall_explain;
        forall_explain(
            23,
            100,
            |r| {
                let b = 1 + r.below(4) as usize;
                let l = 1 + r.below(24) as usize;
                let n = 1 + r.below(11) as usize;
                let x: Vec<i32> = (0..b * l)
                    .map(|_| if r.below(4) == 0 { 0 } else { r.int8() as i32 })
                    .collect();
                let w: Vec<i32> = (0..l * n).map(|_| r.int8() as i32).collect();
                (b, l, n, x, w)
            },
            |(b, l, n, x, w)| {
                let got = mvm_i32(x, w, *b, *l, *n);
                let mut want = vec![0i32; b * n];
                for bi in 0..*b {
                    for j in 0..*n {
                        for li in 0..*l {
                            want[bi * n + j] = want[bi * n + j]
                                .wrapping_add(x[bi * l + li].wrapping_mul(w[li * n + j]));
                        }
                    }
                }
                if got == want {
                    Ok(())
                } else {
                    Err(format!("blocked kernel drifted for b={b} l={l} n={n}"))
                }
            },
        );
    }

    #[test]
    fn fcc_mvm_matches_biased_comp_dense() {
        // the Eq. 7 recovery must equal a dense MVM with the recomposed
        // biased-comp bank — the same identity the hardware ARU implements
        let mut rng = Rng::new(11);
        let (b, l, n) = (4, 9, 6);
        let half = n / 2;
        let x: Vec<i32> = (0..b * l).map(|_| rng.int8() as i32).collect();
        let bank = FilterBank::new((0..n * l).map(|_| rng.int8() as i32).collect(), n, l);
        let fcc = fcc_transform(&bank);
        // w_even in [l, half] layout (column-major filters, python side)
        let got = fcc_mvm_i32(&x, &fcc.stored_even_cols(), &fcc.means, b, l, half);
        // dense oracle with the full recomposed biased-comp bank
        let want = mvm_i32(&x, &fcc.biased_comp_cols(), b, l, n);
        assert_eq!(got, want, "Eq. 7 recovery drifted from dense conv");
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut b = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(3);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let la = a.infer_batch(&img, 1).unwrap();
        let lb = b.infer_batch(&img, 1).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = ReferenceBackend::seeded(1);
        let mut b = ReferenceBackend::seeded(2);
        let img = vec![0.5f32; IMG_ELEMS];
        assert_ne!(a.infer_batch(&img, 1).unwrap(), b.infer_batch(&img, 1).unwrap());
    }

    #[test]
    fn batch_rows_independent() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(9);
        let img: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let mut two = img.clone();
        two.extend_from_slice(&img);
        let batched = be.infer_batch(&two, 2).unwrap();
        let single = be.infer_batch(&img, 1).unwrap();
        assert_eq!(&batched[..NUM_CLASSES], single.as_slice());
        assert_eq!(&batched[NUM_CLASSES..], single.as_slice());
    }

    #[test]
    fn rejects_bad_batch_length() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        assert!(be.infer_batch(&[0.0; 7], 1).is_err());
    }

    #[test]
    fn session_rejects_bad_output_length() {
        let be = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut s = be.plan().unwrap();
        let img = vec![0.0f32; IMG_ELEMS];
        let mut short = vec![0f32; NUM_CLASSES - 1];
        assert!(s.infer_batch_into(&img, 1, &mut short).is_err());
    }

    #[test]
    fn logits_depend_on_input() {
        let mut be = ReferenceBackend::seeded(DEFAULT_SEED);
        let mut rng = Rng::new(13);
        let a: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        assert_ne!(be.infer_batch(&a, 1).unwrap(), be.infer_batch(&b, 1).unwrap());
    }

    #[test]
    fn threaded_fabric_sessions_are_bit_identical() {
        // pool widths must never change logits: every (pass, block)
        // unit writes a disjoint output slice
        let mut rng = Rng::new(21);
        let batch = 3;
        let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let mut want = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
            .with_threads(1)
            .infer_batch(&x, batch)
            .unwrap();
        for threads in [2usize, 4] {
            let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
                .with_threads(threads);
            let session = be.plan().unwrap();
            assert_eq!(session.pool_width(), threads);
            let mut s = session;
            let mut out = vec![0f32; batch * NUM_CLASSES];
            s.infer_batch_into(&x, batch, &mut out).unwrap();
            assert_eq!(out, want, "fabric logits drifted at {threads} threads");
            want = out;
        }
    }

    #[test]
    fn grid_fabric_sessions_are_bit_identical() {
        // a multi-tile macro-grid must never change logits: every tile
        // owns a disjoint output-channel slice of each conv layer
        let mut rng = Rng::new(41);
        let batch = 2;
        let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let want = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
            .with_grid(GridShape::SINGLE)
            .infer_batch(&x, batch)
            .unwrap();
        for (rows, cols) in [(1usize, 2usize), (2, 2), (2, 4)] {
            let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
                .with_grid(GridShape::new(rows, cols))
                .with_threads(2);
            let session = be.plan().unwrap();
            assert_eq!(session.grid_layers(), 2, "both convs must shard");
            assert!(session.grid_shards() > 2);
            assert!(session.fabric_weight_writes() > 0);
            let mut s = session;
            let mut out = vec![0f32; batch * NUM_CLASSES];
            s.infer_batch_into(&x, batch, &mut out).unwrap();
            assert_eq!(out, want, "grid logits drifted at {rows}x{cols}");
        }
    }

    #[test]
    fn single_tile_grid_keeps_single_macro_plans() {
        let s = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
            .with_grid(GridShape::SINGLE)
            .plan()
            .unwrap();
        assert_eq!(s.grid_layers(), 0, "1x1 is the degenerate single-macro path");
        // the dense fabric ignores the grid entirely
        let s = ReferenceBackend::seeded(DEFAULT_SEED)
            .with_grid(GridShape::new(2, 2))
            .plan()
            .unwrap();
        assert_eq!(s.grid_layers(), 0);
    }

    #[test]
    fn batched_fabric_session_equals_per_image() {
        // the session-batching path (one resident pass per batch) must
        // equal feeding the same session one image at a time
        let mut rng = Rng::new(22);
        let batch = 4;
        let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced)
            .with_threads(2);
        let mut s = be.plan().unwrap();
        let mut batched = vec![0f32; batch * NUM_CLASSES];
        s.infer_batch_into(&x, batch, &mut batched).unwrap();
        let mut single = vec![0f32; NUM_CLASSES];
        for bi in 0..batch {
            s.infer_batch_into(&x[bi * IMG_ELEMS..(bi + 1) * IMG_ELEMS], 1, &mut single)
                .unwrap();
            assert_eq!(
                &batched[bi * NUM_CLASSES..(bi + 1) * NUM_CLASSES],
                single.as_slice(),
                "image {bi} drifted between batched and per-image sessions"
            );
        }
    }

    #[test]
    fn dense_sessions_use_the_pool_too() {
        // the dense fcc_mvm path shards MVM row blocks through the same
        // ExecPool as the fabric (the ROADMAP mvm_i32 follow-up), so a
        // dense session honors the requested width
        let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::DenseReference)
            .with_threads(8);
        assert_eq!(be.plan().unwrap().pool_width(), 8);
    }

    #[test]
    fn dense_parallel_sessions_are_bit_identical() {
        // dense logits must not depend on the pool width: every MVM
        // output row is computed wholly inside one work unit
        let mut rng = Rng::new(23);
        let batch = 3;
        let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let want = ReferenceBackend::seeded(DEFAULT_SEED)
            .with_threads(1)
            .infer_batch(&x, batch)
            .unwrap();
        for threads in [2usize, 4] {
            let got = ReferenceBackend::seeded(DEFAULT_SEED)
                .with_threads(threads)
                .infer_batch(&x, batch)
                .unwrap();
            assert_eq!(got, want, "dense logits drifted at {threads} threads");
        }
    }

    // NB: one owner, no in-module duplicates — the width-{1,4}
    // byte-identity pin of mvm_i32_into_par / fcc_mvm_into_par lives
    // in tests/parallel_determinism.rs
    // (dense_mvm_kernels_pinned_at_widths_1_and_4), and the
    // 128-compartment end-to-end envelope is pinned by
    // tests/session_semantics.rs
    // (wide_geometry_fabric_session_matches_dense_reference).

    #[test]
    fn streamed_session_plans_expected_pass_counts() {
        // seeded_deep(.., 2) stored footprints: [216, 2304, 4608, 4608]
        for (budget, want_passes) in [(16384usize, 1usize), (9300, 2), (2400, 4)] {
            let be = ReferenceBackend::seeded_deep(DEFAULT_SEED, FabricChoice::DenseReference, 2)
                .with_streaming(StreamConfig::budget(budget));
            let s = be.plan().unwrap();
            assert_eq!(
                s.streaming_passes(),
                Some(want_passes),
                "budget {budget} planned the wrong pass count"
            );
        }
    }

    #[test]
    fn single_pass_streaming_stages_once_and_never_reloads() {
        let be = ReferenceBackend::seeded(DEFAULT_SEED)
            .with_streaming(StreamConfig::synchronous(16384));
        let mut s = be.plan().unwrap();
        assert_eq!(s.streaming_passes(), Some(1));
        let img = vec![0.5f32; IMG_ELEMS];
        let mut out = vec![0f32; NUM_CLASSES];
        for _ in 0..3 {
            s.infer_batch_into(&img, 1, &mut out).unwrap();
        }
        let p = s.capacity_pressure_stats().unwrap();
        assert_eq!(p.reloads, 0, "a fitting stack must stay resident");
        assert_eq!(p.evictions, 0);
        assert_eq!(p.overflows, 0);
        // staged exactly once: conv1 (216 B) + conv2 (2304 B)
        assert_eq!(p.staged_bytes, 2520);
        assert_eq!(p.peak_resident_bytes, 2520);
        assert!(p.peak_occupancy() > 0.0 && p.peak_occupancy() < 1.0);
    }

    #[test]
    fn multi_pass_streaming_counts_reloads_and_evictions() {
        // budget 2304: conv1 (216 B) and conv2 (2304 B) cannot coexist
        // → 2 passes, and every batch after the first re-stages both
        let be = ReferenceBackend::seeded(DEFAULT_SEED)
            .with_streaming(StreamConfig::synchronous(2304));
        let mut s = be.plan().unwrap();
        assert_eq!(s.streaming_passes(), Some(2));
        let img = vec![0.5f32; IMG_ELEMS];
        let mut out = vec![0f32; NUM_CLASSES];
        let batches = 3u64;
        for _ in 0..batches {
            s.infer_batch_into(&img, 1, &mut out).unwrap();
        }
        let p = s.capacity_pressure_stats().unwrap();
        // first batch: 2 cold stagings; each later batch: 2 reloads
        assert_eq!(p.reloads, 2 * (batches - 1));
        assert!(p.evictions > 0, "pass switches must evict the old pass");
        assert_eq!(p.overflows, 0);
        assert_eq!(p.staged_bytes, (216 + 2304) * batches);
        assert_eq!(p.peak_resident_bytes, 2304);
        // synchronous staging exposes every staging cycle
        assert_eq!(p.stage_hidden, Duration::ZERO);
        assert_eq!(p.stall, p.stage_busy);
    }

    #[test]
    fn streamed_logits_match_resident_across_budgets_dense() {
        let mut rng = Rng::new(31);
        let batch = 2;
        let x: Vec<f32> = (0..batch * IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let want = ReferenceBackend::seeded(DEFAULT_SEED)
            .infer_batch(&x, batch)
            .unwrap();
        for budget in [16384usize, 2304, 300] {
            let got = ReferenceBackend::seeded(DEFAULT_SEED)
                .with_streaming(StreamConfig::budget(budget))
                .infer_batch(&x, batch)
                .unwrap();
            assert_eq!(got, want, "streamed logits drifted at budget {budget}");
        }
    }

    #[test]
    fn over_budget_layer_overflows_but_still_matches() {
        // budget 100 < conv1's 216 B: both passes overflow, occupancy
        // exceeds 1.0, and logits must still be byte-identical
        let mut rng = Rng::new(33);
        let x: Vec<f32> = (0..IMG_ELEMS).map(|_| rng.normal() as f32).collect();
        let want = ReferenceBackend::seeded(DEFAULT_SEED).infer_batch(&x, 1).unwrap();
        let be = ReferenceBackend::seeded(DEFAULT_SEED)
            .with_streaming(StreamConfig::synchronous(100));
        let mut s = be.plan().unwrap();
        let mut out = vec![0f32; NUM_CLASSES];
        s.infer_batch_into(&x, 1, &mut out).unwrap();
        assert_eq!(out, want.as_slice());
        let p = s.capacity_pressure_stats().unwrap();
        assert_eq!(p.overflows, 2);
        assert!(p.peak_occupancy() > 1.0, "occupancy {}", p.peak_occupancy());
    }

    #[test]
    fn non_streaming_session_reports_no_pressure() {
        let s = ReferenceBackend::seeded(DEFAULT_SEED).plan().unwrap();
        assert_eq!(s.streaming_passes(), None);
        assert!(s.capacity_pressure_stats().is_none());
        assert!(Session::capacity_pressure(&s).is_none());
    }

    #[test]
    fn fabric_session_resides_weights_once() {
        let be = ReferenceBackend::seeded_with(DEFAULT_SEED, FabricChoice::BitSliced);
        let session = be.plan().unwrap();
        let written = session.fabric_weight_writes();
        assert!(written > 0, "bitsliced plan must write conv weights");
        let mut s = session;
        let img = vec![0.5f32; IMG_ELEMS];
        let mut out = vec![0f32; NUM_CLASSES];
        s.infer_batch_into(&img, 1, &mut out).unwrap();
        s.infer_batch_into(&img, 1, &mut out).unwrap();
        assert_eq!(s.fabric_weight_writes(), written, "execute wrote weights");
    }
}
